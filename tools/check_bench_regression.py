"""CI benchmark regression gate.

Compares the ``BENCH_<module>.json`` files ``benchmarks/run.py`` emits
against a committed baseline (``benchmarks/baseline.json``) and exits
non-zero when a tracked metric regresses by more than the threshold
(default 30%, per-side: throughput metrics may not *drop* past it, latency
metrics may not *rise* past it).

Tracked metrics (chosen to be meaningful at CI smoke budgets):

* every ``pps`` / ``steps_per_s`` value in a row's derived column
  (higher is better) — executor, fabric, scheduler, and trainer rates;
* ``bnn_export``'s ``us_per_call`` (lower is better) — end-to-end export
  latency, the control-plane cost of pushing a model to the switch;
* for rows that also carry a ``streams=`` count (the fleet benchmarks), a
  derived ``pps_per_stream`` (higher is better) — aggregate rate divided by
  fleet size, so a regression that only shows up per-switch is visible even
  when the aggregate still clears the threshold;
* likewise, rows carrying a ``tenants=`` count (the multi-tenant scheduler
  sweep, including the ``dataplane_merged_interleaved`` headline) get a
  derived ``pps_per_tenant`` (higher is better);
* every ``roofline_frac`` value (higher is better), published flat as
  ``<row>_roofline_frac`` (e.g. ``dataplane_packed_roofline_frac``) —
  measured rate as a fraction of the analytic roofline packets/s bound
  (``repro.roofline.dataplane``), so utilization regressions are gated even
  when absolute rates still pass.

The baseline records the budget env (``DATAPLANE_BENCH_PACKETS`` etc.) it
was generated under; CI must run the benchmarks with the same budgets or
the comparison is meaningless — the gate fails loudly on a budget mismatch.

Usage:
    python tools/check_bench_regression.py [--bench-dir DIR]
        [--baseline FILE] [--threshold 0.30] [--update]

``--update`` refreshes the baseline from the current BENCH files instead of
checking (run it on the CI reference machine, commit the result).
``BENCH_REGRESSION_THRESHOLD`` overrides the threshold from the environment.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

HIGHER_IS_BETTER_KEYS = ("pps", "steps_per_s")
LATENCY_ROWS = ("bnn_export",)
BUDGET_ENV = (
    "DATAPLANE_BENCH_PACKETS",
    "TRAIN_DEPLOY_BENCH_STEPS",
    "MULTITENANT_BENCH_TENANTS",
    "MULTITENANT_BENCH_PACKETS",
    "PCAP_BENCH_PACKETS",
    "FLEET_BENCH_STREAMS",
)


def collect_metrics(bench_dir: str) -> dict[str, dict]:
    """Flatten BENCH_*.json rows into ``{metric_name: {value, higher}}``."""
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    if not paths:
        raise FileNotFoundError(
            f"no BENCH_*.json under {bench_dir!r}; run "
            "`python -m benchmarks.run` first"
        )
    metrics: dict[str, dict] = {}
    for path in paths:
        with open(path) as fh:
            payload = json.load(fh)
        for row in payload["rows"]:
            for key in HIGHER_IS_BETTER_KEYS:
                val = row["metrics"].get(key)
                if val is not None and math.isfinite(val) and val > 0:
                    metrics[f"{row['name']}.{key}"] = {
                        "value": val,
                        "higher_is_better": True,
                    }
            frac = row["metrics"].get("roofline_frac")
            if frac is not None and math.isfinite(frac) and frac > 0:
                metrics[f"{row['name']}_roofline_frac"] = {
                    "value": frac,
                    "higher_is_better": True,
                }
            pps = row["metrics"].get("pps")
            streams = row["metrics"].get("streams")
            if (
                pps is not None
                and streams is not None
                and math.isfinite(pps)
                and pps > 0
                and streams > 0
            ):
                metrics[f"{row['name']}.pps_per_stream"] = {
                    "value": pps / streams,
                    "higher_is_better": True,
                }
            tenants = row["metrics"].get("tenants")
            if (
                pps is not None
                and tenants is not None
                and math.isfinite(pps)
                and pps > 0
                and tenants > 0
            ):
                metrics[f"{row['name']}.pps_per_tenant"] = {
                    "value": pps / tenants,
                    "higher_is_better": True,
                }
            if row["name"] in LATENCY_ROWS and math.isfinite(
                row["us_per_call"]
            ):
                metrics[f"{row['name']}.us_per_call"] = {
                    "value": row["us_per_call"],
                    "higher_is_better": False,
                }
    return metrics


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-dir", default=".")
    ap.add_argument(
        "--baseline",
        default=os.path.join("benchmarks", "baseline.json"),
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=float(os.environ.get("BENCH_REGRESSION_THRESHOLD", 0.30)),
        help="max fractional regression (0.30 = 30%%)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline from the current BENCH files",
    )
    args = ap.parse_args()

    current = collect_metrics(args.bench_dir)
    budgets = {k: os.environ.get(k) for k in BUDGET_ENV}

    if args.update:
        payload = {
            "comment": (
                "Benchmark baseline for tools/check_bench_regression.py. "
                "Regenerate with: python tools/check_bench_regression.py "
                "--update (after python -m benchmarks.run under the SAME "
                "budget env)."
            ),
            "budget_env": budgets,
            "metrics": {
                k: current[k] for k in sorted(current)
            },
        }
        with open(args.baseline, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(
            f"baseline updated: {args.baseline} ({len(current)} metrics)"
        )
        return 0

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    base_budgets = baseline.get("budget_env", {})
    mismatched = {
        k: (base_budgets.get(k), budgets.get(k))
        for k in BUDGET_ENV
        if base_budgets.get(k) != budgets.get(k)
    }
    if mismatched:
        print(
            "FAIL: benchmark budgets differ from the baseline's — rates are "
            "not comparable:"
        )
        for k, (want, got) in mismatched.items():
            print(f"  {k}: baseline={want!r} current={got!r}")
        return 1

    failures = 0
    missing = 0
    print(
        f"bench regression gate: threshold {args.threshold:.0%}, "
        f"{len(baseline['metrics'])} baseline metrics"
    )
    for name, ref in sorted(baseline["metrics"].items()):
        cur = current.get(name)
        if cur is None:
            missing += 1
            print(f"  MISSING {name} (baseline {ref['value']:.4g})")
            continue
        base_val, cur_val = ref["value"], cur["value"]
        if ref["higher_is_better"]:
            change = (cur_val - base_val) / base_val
            bad = cur_val < base_val * (1.0 - args.threshold)
        else:
            change = (base_val - cur_val) / base_val
            bad = cur_val > base_val * (1.0 + args.threshold)
        status = "FAIL" if bad else "ok"
        if bad:
            failures += 1
        print(
            f"  {status:>4} {name}: {cur_val:.4g} vs {base_val:.4g} "
            f"({change:+.1%})"
        )
    if missing:
        print(f"FAIL: {missing} baseline metric(s) missing from this run")
    if failures:
        print(f"FAIL: {failures} metric(s) regressed > {args.threshold:.0%}")
    if failures or missing:
        return 1
    print("bench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
