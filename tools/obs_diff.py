"""Regression attribution: diff two observability exports (or bench runs)
and say *where* a packets/s delta came from.

A rate regression on its own is a mystery; the phase decomposition the obs
layer records makes it attributable.  This tool compares two runs and
splits every throughput delta into the phases that moved:

* **two obs export dirs** (``repro.obs.export_all`` artifacts) — diffs the
  trace's per-category wall time (``compile`` / ``execute`` / ``stream`` /
  ``ingest``), every matching counter/gauge (the ``*.pps`` family first),
  and histogram counts/means; the attribution table ranks phases by their
  share of the wall-time delta;
* **two BENCH_<module>.json files** (``benchmarks/run.py`` artifacts) —
  diffs every parsed row metric plus the module's ``warmup_seconds`` vs
  ``steady_seconds`` split, so a pps drop is labeled compile-side (warmup
  grew) or execute-side (steady grew);
* **--baseline benchmarks/baseline.json --bench-dir DIR** — flattens the
  current BENCH files exactly like ``tools/check_bench_regression.py`` and
  diffs against the committed baseline (no gating, just the deltas).

Stdlib-only.  Usage::

    python tools/obs_diff.py A_DIR B_DIR
    python tools/obs_diff.py --bench BENCH_A.json BENCH_B.json
    python tools/obs_diff.py --baseline benchmarks/baseline.json \
        --bench-dir .

Exits 0 always (attribution, not a gate — the gate is
``check_bench_regression.py``) unless inputs are missing/malformed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import check_bench_regression as _cbr  # noqa: E402 - sibling tool import
import obs_report as _report  # noqa: E402 - sibling tool import

PHASES = ("compile", "execute", "stream", "ingest")


def _fmt_delta(a: float | None, b: float | None) -> str:
    """``a -> b (+x%)`` with dashes for missing sides."""
    if a is None and b is None:
        return "-"
    if a is None:
        return f"(new) {b:.4g}"
    if b is None:
        return f"{a:.4g} (gone)"
    if a == 0:
        return f"{a:.4g} -> {b:.4g}"
    return f"{a:.4g} -> {b:.4g} ({(b - a) / abs(a):+.1%})"


def _metric_key(row: dict) -> tuple:
    labels = row.get("labels") or {}
    return (row["name"], tuple(sorted(labels.items())))


def _index(metrics: list[dict], kind: str) -> dict[tuple, dict]:
    return {
        _metric_key(m): m for m in metrics if m.get("type") == kind
    }


def diff_obs_dirs(dir_a: str, dir_b: str) -> list[str]:
    lines: list[str] = []
    out = lines.append

    sides = []
    for d in (dir_a, dir_b):
        mp = _report._find_one(d, "_metrics.jsonl")
        tp = _report._find_one(d, "_trace.json")
        if mp is None and tp is None:
            raise SystemExit(
                f"no *_metrics.jsonl or *_trace.json under {d!r}; "
                "export with repro.obs.export_all(dir) first"
            )
        sides.append(
            (
                _report.load_metrics(mp) if mp else [],
                _report.load_trace(tp) if tp else [],
            )
        )
    (met_a, ev_a), (met_b, ev_b) = sides
    out(f"obs diff: {dir_a!r} (A) vs {dir_b!r} (B)")
    out("")

    tot_a = _report.phase_totals(ev_a)
    tot_b = _report.phase_totals(ev_b)
    cats = sorted(set(tot_a) | set(tot_b))
    if cats:
        out("== phase wall time (s) ==")
        for cat in cats:
            out(f"  {cat:<10} {_fmt_delta(tot_a.get(cat), tot_b.get(cat))}")
        # Attribution: which phase owns the wall-time delta.  Top-level
        # categories overlap (a compile span nests inside a stream span),
        # so shares are of the summed absolute per-phase movement, not of
        # an end-to-end wall clock.
        deltas = {
            c: tot_b.get(c, 0.0) - tot_a.get(c, 0.0)
            for c in cats
            if c in PHASES
        }
        moved = sum(abs(d) for d in deltas.values())
        if moved > 0:
            out("  attribution (share of phase-time movement):")
            for cat in sorted(deltas, key=lambda c: -abs(deltas[c])):
                if deltas[cat] == 0:
                    continue
                out(
                    f"    {cat:<10} {deltas[cat]:+.4f}s "
                    f"({abs(deltas[cat]) / moved:.0%})"
                )
        out("")

    for kind in ("gauge", "counter"):
        ia, ib = _index(met_a, kind), _index(met_b, kind)
        keys = sorted(set(ia) | set(ib))
        if not keys:
            continue
        # pps-family gauges lead: they are the deltas being attributed.
        keys.sort(key=lambda k: (0 if "pps" in k[0] else 1, k))
        out(f"== {kind}s ==")
        for k in keys:
            a, b = ia.get(k), ib.get(k)
            label = k[0] + (
                "{" + ",".join(f"{lk}={lv}" for lk, lv in k[1]) + "}"
                if k[1]
                else ""
            )
            out(
                f"  {label:<44} "
                f"{_fmt_delta(a and a.get('value'), b and b.get('value'))}"
            )
        out("")

    ia, ib = _index(met_a, "histogram"), _index(met_b, "histogram")
    keys = sorted(set(ia) | set(ib))
    if keys:
        out("== histograms (count / mean) ==")
        for k in keys:
            a, b = ia.get(k), ib.get(k)
            label = k[0] + (
                "{" + ",".join(f"{lk}={lv}" for lk, lv in k[1]) + "}"
                if k[1]
                else ""
            )
            out(
                f"  {label:<44} "
                f"n: {_fmt_delta(a and a.get('count'), b and b.get('count'))}"
                f"  mean: "
                f"{_fmt_delta(a and a.get('mean'), b and b.get('mean'))}"
            )
        out("")
    return lines


def _load_bench(path: str) -> dict:
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except OSError as e:
        raise SystemExit(f"cannot read bench file {path!r}: {e}")
    except json.JSONDecodeError as e:
        raise SystemExit(f"{path}: bad bench JSON: {e}")
    if not isinstance(payload, dict) or "rows" not in payload:
        raise SystemExit(f"{path}: not a BENCH_<module>.json payload")
    return payload


def diff_bench_files(path_a: str, path_b: str) -> list[str]:
    lines: list[str] = []
    out = lines.append
    a, b = _load_bench(path_a), _load_bench(path_b)
    out(f"bench diff: {path_a!r} (A) vs {path_b!r} (B)")
    out("")

    out("== module timing ==")
    for key in ("seconds", "warmup_seconds", "steady_seconds"):
        out(f"  {key:<16} {_fmt_delta(a.get(key), b.get(key))}")
    dw = (b.get("warmup_seconds") or 0) - (a.get("warmup_seconds") or 0)
    ds = (b.get("steady_seconds") or 0) - (a.get("steady_seconds") or 0)
    moved = abs(dw) + abs(ds)
    if moved > 0:
        side = "compile-side (warmup)" if abs(dw) > abs(ds) else (
            "execute-side (steady)"
        )
        out(
            f"  attribution: {side} — warmup {dw:+.3f}s "
            f"({abs(dw) / moved:.0%}), steady {ds:+.3f}s "
            f"({abs(ds) / moved:.0%})"
        )
    out("")

    rows_a = {r["name"]: r.get("metrics", {}) for r in a["rows"]}
    rows_b = {r["name"]: r.get("metrics", {}) for r in b["rows"]}
    out("== row metrics ==")
    for name in sorted(set(rows_a) | set(rows_b)):
        ma, mb = rows_a.get(name, {}), rows_b.get(name, {})
        for key in sorted(set(ma) | set(mb)):
            out(
                f"  {name + '.' + key:<52} "
                f"{_fmt_delta(ma.get(key), mb.get(key))}"
            )
    out("")
    return lines


def diff_vs_baseline(baseline_path: str, bench_dir: str) -> list[str]:
    lines: list[str] = []
    out = lines.append
    try:
        with open(baseline_path) as fh:
            baseline = json.load(fh)
    except OSError as e:
        raise SystemExit(f"cannot read baseline {baseline_path!r}: {e}")
    except json.JSONDecodeError as e:
        raise SystemExit(f"{baseline_path}: bad baseline JSON: {e}")
    try:
        current = _cbr.collect_metrics(bench_dir)
    except FileNotFoundError as e:
        raise SystemExit(str(e))
    base = baseline.get("metrics", {})
    out(
        f"baseline diff: {baseline_path!r} (A) vs BENCH files in "
        f"{bench_dir!r} (B)"
    )
    budgets = {
        k: os.environ.get(k)
        for k in _cbr.BUDGET_ENV
        if baseline.get("budget_env", {}).get(k) != os.environ.get(k)
    }
    if budgets:
        out(
            "  WARNING: budget env differs from the baseline's — deltas "
            "are not rate-comparable:"
        )
        for k, got in sorted(budgets.items()):
            want = baseline.get("budget_env", {}).get(k)
            out(f"    {k}: baseline={want!r} current={got!r}")
    out("")
    out("== gated metrics ==")
    for name in sorted(set(base) | set(current)):
        a = base.get(name, {}).get("value")
        b = current.get(name, {}).get("value")
        out(f"  {name:<52} {_fmt_delta(a, b)}")
    out("")
    return lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "paths", nargs="*",
        help="two obs export dirs (default mode) or, with --bench, two "
        "BENCH_<module>.json files",
    )
    ap.add_argument(
        "--bench", action="store_true",
        help="treat the two paths as BENCH_<module>.json files",
    )
    ap.add_argument(
        "--baseline",
        help="diff BENCH files in --bench-dir against this baseline.json",
    )
    ap.add_argument("--bench-dir", default=".")
    args = ap.parse_args(argv)

    if args.baseline:
        if args.paths:
            ap.error("--baseline takes no positional paths")
        lines = diff_vs_baseline(args.baseline, args.bench_dir)
    elif len(args.paths) == 2:
        a, b = args.paths
        if args.bench or (os.path.isfile(a) and os.path.isfile(b)):
            lines = diff_bench_files(a, b)
        else:
            lines = diff_obs_dirs(a, b)
    else:
        ap.error("need two paths, or --baseline FILE")
        return 2  # pragma: no cover - error() raises
    print("\n".join(lines))
    return 0


if __name__ == "__main__":
    sys.exit(main())
