"""Render a human-readable run summary from exported observability files.

Reads the artifacts ``repro.obs.export_all`` writes — a metrics JSONL file
and a Chrome Trace Event JSON — and prints the operator's view of a run:

* **phase decomposition** — wall time per span category (``compile`` vs
  ``execute`` vs ``stream``), the split that turns "the executor is 10,000x
  off the ASIC model" into named phases;
* **top spans** — where the time went, by span name;
* **per-tenant table** — packets / served / dropped / deferred and queue
  delay p50/p99 per tenant (from the ``mt.*`` metric family);
* **hardware utilization** — the ``roofline.*`` gauge family grouped per
  compiled path (``packed``, ``jnp``, ``fleetN:...``): analytic packets/s
  bound, measured fraction of it, and bytes per packet
  (``repro.roofline.dataplane``);
* **counters, gauges, histograms** — everything else in the registry.

Stdlib-only (CI's docs job runs it on a tiny traced run).  Usage::

    python tools/obs_report.py [DIR]                 # find obs_* files in DIR
    python tools/obs_report.py --metrics M.jsonl --trace T.json

Exits non-zero (with a one-line message, never a traceback) if no artifact
is found or a file is missing/malformed — a smoke gate, not just a
pretty-printer.  Partial exports are fine: rows missing optional fields
render as zeros/dashes rather than crashing the report.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_metrics(path: str) -> list[dict]:
    rows = []
    try:
        fh = open(path)
    except OSError as e:
        raise SystemExit(f"cannot read metrics file {path!r}: {e}")
    with fh:
        for i, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{i}: bad JSONL line: {e}")
            if not isinstance(row, dict):
                raise SystemExit(f"{path}:{i}: metric row is not an object")
            if "name" not in row or "type" not in row:
                raise SystemExit(f"{path}:{i}: metric missing name/type")
            rows.append(row)
    return rows


def load_trace(path: str) -> list[dict]:
    try:
        fh = open(path)
    except OSError as e:
        raise SystemExit(f"cannot read trace file {path!r}: {e}")
    with fh:
        try:
            payload = json.load(fh)
        except json.JSONDecodeError as e:
            raise SystemExit(f"{path}: bad trace JSON: {e}")
    if not isinstance(payload, dict):
        raise SystemExit(f"{path}: trace payload is not an object")
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        raise SystemExit(f"{path}: no traceEvents list")
    return [e for e in events if isinstance(e, dict)]


def _fmt_s(seconds: float | None) -> str:
    if seconds is None:
        return "-"
    if seconds >= 1.0:
        return f"{seconds:.2f}s"
    if seconds >= 1e-3:
        return f"{seconds * 1e3:.2f}ms"
    return f"{seconds * 1e6:.1f}us"


def _fmt_g(value: float | None, spec: str) -> str:
    if value is None:
        return "-"
    return format(value, spec)


def _labels(row: dict) -> str:
    labels = row.get("labels") or {}
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def phase_totals(events: list[dict]) -> dict[str, float]:
    """Summed seconds per category, counting only spans not contained in a
    same-category ancestor (mirrors ``Tracer.total_by_category``)."""
    totals: dict[str, float] = {}
    by_tid: dict[int, list[dict]] = {}
    for e in events:
        if e.get("ph") == "X":
            by_tid.setdefault(e.get("tid", 0), []).append(e)
    for evs in by_tid.values():
        for e in evs:
            depth = (e.get("args") or {}).get("depth", 0)
            ts, dur = e.get("ts", 0), e.get("dur", 0)
            contained = any(
                o is not e
                and o.get("cat") == e.get("cat")
                and o.get("ts", 0) <= ts
                and o.get("ts", 0) + o.get("dur", 0) >= ts + dur
                and (o.get("args") or {}).get("depth", 0) < depth
                for o in evs
            )
            if not contained:
                cat = e.get("cat", "span")
                totals[cat] = totals.get(cat, 0.0) + dur / 1e6
    return totals


def span_summary(events: list[dict]) -> list[tuple[str, str, int, float]]:
    """(name, cat, count, total_seconds), sorted by descending total."""
    agg: dict[tuple[str, str], tuple[int, float]] = {}
    for e in events:
        if e.get("ph") != "X":
            continue
        key = (e.get("name", "?"), e.get("cat", "span"))
        n, tot = agg.get(key, (0, 0.0))
        agg[key] = (n + 1, tot + e.get("dur", 0) / 1e6)
    rows = [(k[0], k[1], n, tot) for k, (n, tot) in agg.items()]
    rows.sort(key=lambda r: -r[3])
    return rows


def tenant_table(metrics: list[dict]) -> list[dict]:
    """Per-tenant rollup of the ``mt.*`` metric family."""
    tenants: dict[str, dict] = {}

    def cell(name: str) -> dict:
        return tenants.setdefault(
            name,
            {
                "tenant": name, "packets": 0, "served": 0,
                "dropped": 0, "deferred": 0, "slices": 0,
                "qdelay_p50": None, "qdelay_p99": None, "qdelay_n": 0,
            },
        )

    for row in metrics:
        tenant = (row.get("labels") or {}).get("tenant")
        if tenant is None or not row["name"].startswith("mt."):
            continue
        c = cell(tenant)
        if row["name"] == "mt.packets_total":
            c["packets"] = int(row.get("value", 0))
        elif row["name"] == "mt.served_total":
            c["served"] = int(row.get("value", 0))
        elif row["name"] == "mt.dropped_total":
            c["dropped"] = int(row.get("value", 0))
        elif row["name"] == "mt.deferred_total":
            c["deferred"] = int(row.get("value", 0))
        elif row["name"] == "mt.slices_total":
            c["slices"] = int(row.get("value", 0))
        elif row["name"] == "mt.queue_delay_seconds":
            c["qdelay_p50"] = row.get("p50")
            c["qdelay_p99"] = row.get("p99")
            c["qdelay_n"] = row.get("count", 0)
    return [tenants[k] for k in sorted(tenants)]


def roofline_table(metrics: list[dict]) -> list[dict]:
    """Per-path rollup of the ``roofline.*`` gauge family
    (``repro.roofline.dataplane.record``)."""
    paths: dict[str, dict] = {}
    for row in metrics:
        if row.get("type") != "gauge" or not row["name"].startswith(
            "roofline."
        ):
            continue
        path = (row.get("labels") or {}).get("path", "?")
        c = paths.setdefault(path, {"path": path})
        c[row["name"].removeprefix("roofline.")] = row.get("value")
    return [paths[k] for k in sorted(paths)]


def render(metrics: list[dict], events: list[dict]) -> str:
    lines: list[str] = []
    out = lines.append

    if events:
        out("== phase decomposition (trace) ==")
        totals = phase_totals(events)
        width = max(len(c) for c in totals) if totals else 4
        for cat in sorted(totals, key=lambda c: -totals[c]):
            out(f"  {cat:<{width}}  {_fmt_s(totals[cat]):>10}")
        compile_s = totals.get("compile", 0.0)
        execute_s = totals.get("execute", 0.0)
        if execute_s > 0:
            out(
                f"  compile/execute ratio: {compile_s / execute_s:.2f} "
                f"(compile {_fmt_s(compile_s)}, execute {_fmt_s(execute_s)})"
            )
        out("")
        out("== top spans by total time ==")
        out(f"  {'span':<28} {'cat':<8} {'count':>6} {'total':>10} {'avg':>10}")
        for name, cat, n, tot in span_summary(events)[:12]:
            out(
                f"  {name:<28} {cat:<8} {n:>6} {_fmt_s(tot):>10} "
                f"{_fmt_s(tot / n):>10}"
            )
        out("")

    tenants = tenant_table(metrics)
    if tenants:
        out("== per-tenant (mt.*) ==")
        out(
            f"  {'tenant':<14} {'packets':>8} {'served':>8} {'dropped':>8} "
            f"{'deferred':>9} {'slices':>7} {'qdelay p50':>11} {'p99':>10}"
        )
        for c in tenants:
            out(
                f"  {c['tenant']:<14} {c['packets']:>8} {c['served']:>8} "
                f"{c['dropped']:>8} {c['deferred']:>9} {c['slices']:>7} "
                f"{_fmt_s(c['qdelay_p50']):>11} {_fmt_s(c['qdelay_p99']):>10}"
            )
        out("")

    roofline = roofline_table(metrics)
    if roofline:
        out("== hardware utilization (roofline.*) ==")
        out(
            f"  {'path':<16} {'pps bound':>12} {'fraction':>10} "
            f"{'bytes/pkt':>10} {'hlo bytes':>11} {'hlo flops':>11}"
        )
        for c in roofline:
            frac = c.get("fraction")
            out(
                f"  {c['path']:<16} "
                f"{_fmt_g(c.get('pps_bound'), '.3e'):>12} "
                f"{_fmt_g(frac, '.2%'):>10} "
                f"{_fmt_g(c.get('bytes_per_packet'), '.1f'):>10} "
                f"{_fmt_g(c.get('hlo_bytes'), '.3e'):>11} "
                f"{_fmt_g(c.get('hlo_flops'), '.3e'):>11}"
            )
        out("")

    counters = [m for m in metrics if m["type"] == "counter"]
    gauges = [
        m for m in metrics
        if m["type"] == "gauge" and not m["name"].startswith("roofline.")
    ]
    histos = [m for m in metrics if m["type"] == "histogram"]
    if counters:
        out("== counters ==")
        for m in counters:
            out(f"  {m['name']}{_labels(m)} = {m.get('value', 0):g}")
        out("")
    if gauges:
        out("== gauges ==")
        for m in gauges:
            out(f"  {m['name']}{_labels(m)} = {m.get('value', 0):g}")
        out("")
    if histos:
        out("== histograms ==")
        out(
            f"  {'histogram':<44} {'count':>7} {'mean':>9} {'p50':>9} "
            f"{'p95':>9} {'p99':>9} {'max':>9}"
        )
        for m in histos:
            label = f"{m['name']}{_labels(m)}"
            out(
                f"  {label:<44} {m.get('count', 0):>7} "
                f"{_fmt_s(m.get('mean')):>9} "
                f"{_fmt_s(m.get('p50')):>9} {_fmt_s(m.get('p95')):>9} "
                f"{_fmt_s(m.get('p99')):>9} {_fmt_s(m.get('max')):>9}"
            )
        out("")
    return "\n".join(lines)


def _find_one(directory: str, suffix: str) -> str | None:
    hits = sorted(glob.glob(os.path.join(directory, f"*{suffix}")))
    return hits[0] if hits else None


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "directory", nargs="?", default=".",
        help="directory holding *_metrics.jsonl / *_trace.json "
        "(from repro.obs.export_all)",
    )
    ap.add_argument("--metrics", help="explicit metrics JSONL path")
    ap.add_argument("--trace", help="explicit chrome trace JSON path")
    args = ap.parse_args(argv)

    metrics_path = args.metrics or _find_one(args.directory, "_metrics.jsonl")
    trace_path = args.trace or _find_one(args.directory, "_trace.json")
    if metrics_path is None and trace_path is None:
        print(
            f"no *_metrics.jsonl or *_trace.json under {args.directory!r}; "
            "export with repro.obs.export_all(dir) first",
            file=sys.stderr,
        )
        return 1

    metrics = load_metrics(metrics_path) if metrics_path else []
    events = load_trace(trace_path) if trace_path else []
    print(
        f"obs report: {len(metrics)} metric(s) from "
        f"{metrics_path or '-'}, {len(events)} span(s) from "
        f"{trace_path or '-'}"
    )
    print()
    print(render(metrics, events))
    return 0


if __name__ == "__main__":
    sys.exit(main())
