"""Append one benchmark run to the committed performance trajectory.

Each invocation flattens the ``BENCH_<module>.json`` files a
``benchmarks/run.py`` run produced — the same flattening
``tools/check_bench_regression.py`` gates on — and appends a single JSON
line to ``benchmarks/trajectory.jsonl``:

* UTC timestamp and (when available) the git revision;
* the budget env the run used (``DATAPLANE_BENCH_PACKETS`` etc.) — lines
  are only rate-comparable when budgets match;
* summed ``warmup_seconds`` / ``steady_seconds`` across modules, so
  compile-time drift is tracked separately from execution;
* every gated metric's value (``dataplane_packed_uniform_random.pps``,
  ``dataplane_packed_roofline_frac``, ...).

The file is append-only history: CI appends after the regression gate and
uploads it as an artifact; committing it periodically gives the repo a
performance trajectory that ``tools/obs_diff.py --baseline`` snapshots
cannot (one line per run, not just latest-vs-baseline).

Stdlib-only.  Usage::

    python tools/bench_history.py [--bench-dir DIR]
        [--history benchmarks/trajectory.jsonl] [--note TEXT]
"""
from __future__ import annotations

import argparse
import datetime
import glob
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import check_bench_regression as _cbr  # noqa: E402 - sibling tool import


def _git_rev() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    return out.stdout.strip() or None if out.returncode == 0 else None


def _module_timing(bench_dir: str) -> tuple[float, float]:
    """Summed (warmup_seconds, steady_seconds) across BENCH payloads."""
    warmup = steady = 0.0
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        try:
            with open(path) as fh:
                payload = json.load(fh)
        except (OSError, json.JSONDecodeError):
            continue
        warmup += float(payload.get("warmup_seconds", 0.0) or 0.0)
        steady += float(payload.get("steady_seconds", 0.0) or 0.0)
    return warmup, steady


def record(bench_dir: str, note: str | None = None) -> dict:
    """Build one trajectory line from the BENCH files in ``bench_dir``."""
    try:
        metrics = _cbr.collect_metrics(bench_dir)
    except FileNotFoundError as e:
        raise SystemExit(str(e))
    warmup, steady = _module_timing(bench_dir)
    line = {
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "git": _git_rev(),
        "budget_env": {k: os.environ.get(k) for k in _cbr.BUDGET_ENV},
        "warmup_seconds": round(warmup, 3),
        "steady_seconds": round(steady, 3),
        "metrics": {k: metrics[k]["value"] for k in sorted(metrics)},
    }
    if note:
        line["note"] = note
    return line


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-dir", default=".")
    ap.add_argument(
        "--history",
        default=os.path.join("benchmarks", "trajectory.jsonl"),
    )
    ap.add_argument("--note", help="free-form tag stored with the line")
    args = ap.parse_args(argv)

    line = record(args.bench_dir, note=args.note)
    os.makedirs(os.path.dirname(args.history) or ".", exist_ok=True)
    with open(args.history, "a") as fh:
        fh.write(json.dumps(line, sort_keys=True) + "\n")
    print(
        f"bench history: appended {len(line['metrics'])} metric(s) "
        f"@ {line['git'] or '?'} to {args.history}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
