"""Stdlib import-order / ``__all__`` consistency lint (stdlib-only, CI).

Checks every ``.py`` file under src/, tests/, benchmarks/, tools/, and
examples/:

1. **Import grouping** — in a module's leading import block (top-level
   imports before the first non-import statement), groups must appear in
   ``__future__`` -> stdlib -> third-party -> first-party order; within a
   group, plain ``import x`` statements come before ``from x import y``
   statements and each kind is alphabetically sorted by module name (the
   isort "straight then from" convention the codebase follows).
2. **__all__ consistency** — when a module defines a top-level ``__all__``
   list/tuple of string literals: entries must be unique, sorted, and every
   named symbol must actually be bound at module top level.

Exits non-zero listing each violation as ``path:line: message``.
"""
from __future__ import annotations

import ast
import os
import sys

ROOTS = ("src", "tests", "benchmarks", "tools", "examples")
THIRD_PARTY = {"jax", "jaxlib", "numpy", "pytest", "hypothesis"}
FIRST_PARTY = {
    "repro",
    "benchmarks",
    "tests",
    "tools",
    "examples",
    "conftest",          # tests' local plugin module
    "_hypothesis_stub",  # tests' hypothesis fallback
}
GROUP_NAMES = ("__future__", "stdlib", "third-party", "first-party")


def module_group(name: str) -> int:
    root = name.split(".")[0]
    if root == "__future__":
        return 0
    if root in FIRST_PARTY:
        return 3
    if root in THIRD_PARTY:
        return 2
    if root in sys.stdlib_module_names:
        return 1
    return 2  # unknown: assume an external dependency


def stmt_module(node: ast.stmt) -> str:
    if isinstance(node, ast.Import):
        return node.names[0].name
    assert isinstance(node, ast.ImportFrom)
    return node.module or "." * node.level


def check_import_block(path: str, tree: ast.Module) -> list[str]:
    leading: list[ast.stmt] = []
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            leading.append(node)
        elif isinstance(node, ast.Expr) and isinstance(
            node.value, ast.Constant
        ):
            continue  # docstring
        else:
            break
    errors = []
    prev_group = -1
    prev_kind = 0   # 0 = plain import, 1 = from-import
    prev_name: str | None = None
    for node in leading:
        name = stmt_module(node)
        group = module_group(name)
        kind = 1 if isinstance(node, ast.ImportFrom) else 0
        if group < prev_group:
            errors.append(
                f"{path}:{node.lineno}: {GROUP_NAMES[group]} import "
                f"{name!r} after a {GROUP_NAMES[prev_group]} import"
            )
        elif group == prev_group:
            if kind < prev_kind:
                errors.append(
                    f"{path}:{node.lineno}: plain import {name!r} after a "
                    "from-import of the same group"
                )
            elif kind == prev_kind and prev_name is not None:
                if name.lower() < prev_name.lower():
                    errors.append(
                        f"{path}:{node.lineno}: import {name!r} not "
                        f"alphabetically after {prev_name!r}"
                    )
        prev_group, prev_kind, prev_name = group, kind, name
    return errors


def top_level_bindings(tree: ast.Module) -> set[str]:
    names: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                names.add(alias.asname or alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                names.add(alias.asname or alias.name)
        elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for tgt in targets:
                for leaf in ast.walk(tgt):
                    if isinstance(leaf, ast.Name):
                        names.add(leaf.id)
        elif isinstance(node, (ast.If, ast.Try)):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name) and isinstance(
                    sub.ctx, ast.Store
                ):
                    names.add(sub.id)
                elif isinstance(sub, (ast.Import, ast.ImportFrom)):
                    for alias in sub.names:
                        names.add(alias.asname or alias.name.split(".")[0])
                elif isinstance(
                    sub, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
                ):
                    names.add(sub.name)
    return names


def check_all(path: str, tree: ast.Module) -> list[str]:
    errors = []
    for node in tree.body:
        if not (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == "__all__"
        ):
            continue
        if not isinstance(node.value, (ast.List, ast.Tuple)):
            continue
        entries = []
        for elt in node.value.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                entries.append(elt.value)
            else:
                errors.append(
                    f"{path}:{node.lineno}: __all__ entry is not a string "
                    "literal"
                )
                return errors
        if len(set(entries)) != len(entries):
            dupes = sorted({e for e in entries if entries.count(e) > 1})
            errors.append(
                f"{path}:{node.lineno}: duplicate __all__ entries: {dupes}"
            )
        if entries != sorted(entries):
            errors.append(f"{path}:{node.lineno}: __all__ is not sorted")
        bound = top_level_bindings(tree)
        for name in entries:
            if name not in bound:
                errors.append(
                    f"{path}:{node.lineno}: __all__ names {name!r} which is "
                    "not bound at module top level"
                )
    return errors


def main() -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    errors: list[str] = []
    checked = 0
    for root in ROOTS:
        base = os.path.join(repo, root)
        if not os.path.isdir(base):
            continue
        for dirpath, _, files in os.walk(base):
            for fname in sorted(files):
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, repo)
                with open(path, "r", encoding="utf-8") as fh:
                    try:
                        tree = ast.parse(fh.read(), filename=rel)
                    except SyntaxError as e:
                        errors.append(f"{rel}:{e.lineno}: syntax error: {e.msg}")
                        continue
                checked += 1
                errors.extend(check_import_block(rel, tree))
                errors.extend(check_all(rel, tree))
    for err in errors:
        print(err)
    print(
        f"import/__all__ lint: {checked} files, {len(errors)} violation(s)"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
