"""Markdown link checker (stdlib-only; the CI docs job runs this).

Scans every tracked ``*.md`` file for inline links/images
``[text](target)`` and verifies that relative-path targets exist on disk.
External schemes (http/https/mailto), pure in-page anchors (``#...``), and
bare autolinks are skipped; a ``path#anchor`` target is checked for the
path part only.

Usage: python tools/check_md_links.py [root]      (default: repo root)
Exits non-zero listing every broken link.
"""
from __future__ import annotations

import os
import re
import sys

# Inline [text](target) / ![alt](target); target ends at the first ')' —
# good enough for the plain paths these docs use.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}


def md_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
        out.extend(
            os.path.join(dirpath, f) for f in filenames if f.endswith(".md")
        )
    return sorted(out)


def check_file(path: str, root: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    errors = []
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(_SKIP_SCHEMES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            base = root if rel.startswith("/") else os.path.dirname(path)
            resolved = os.path.normpath(os.path.join(base, rel.lstrip("/")))
            if not os.path.exists(resolved):
                errors.append(
                    f"{os.path.relpath(path, root)}:{lineno}: "
                    f"broken link -> {target}"
                )
    return errors


def main() -> int:
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1 else
                           os.path.join(os.path.dirname(__file__), ".."))
    files = md_files(root)
    errors = [e for p in files for e in check_file(p, root)]
    for e in errors:
        print(e)
    print(f"checked {len(files)} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
