"""Fleet serving benchmark: aggregate packets/s vs. fleet size.

The paper's claim is per-chip line rate; this module measures what one HOST
can simulate when many independent switches are batched through one
compiled executor (``repro.dataplane.fleet``).  Per-stream chunks are kept
SMALL (``FLEET_BENCH_CHUNK``, default 16 packets — the low-latency serving
regime): a lone stream at that chunk size is dispatch-bound, which is
exactly the orchestration starvation the fleet amortizes by folding N
streams into one ``(N, chunk, bits)`` dispatch.

Rows (packed backend throughout — the production path):

* ``dataplane_fleet_single_pps`` — one stream, ``execute_stream``;
* ``dataplane_fleet_<S>``        — fleet of S vmapped streams, geometric S
  up to ``FLEET_BENCH_STREAMS`` (default 10240 — the 10k-switches-on-one-
  host target; CI smoke sets 64);
* ``dataplane_fleet_agg_pps``    — the CI gate row: the 64-stream fleet
  aggregate, with ``speedup=`` vs single-stream (acceptance: >= 8x);
* ``dataplane_fleet_pipeline``   — ``serving.engine.FleetEngine``'s async
  ingest/execute pipeline over the same fleet (``overlap=`` is busy/wall);
* ``dataplane_fabric_scanned`` / ``_unrolled`` — a deep hop chain through
  ``SwitchFabric`` with the hop loop as one ``lax.scan`` vs. per-hop Python
  dispatch (same bits out either way; the scan removes ``hops`` dispatches
  per chunk).

Every ``pps=`` value lands under ``tools/check_bench_regression.py``, which
also derives ``pps_per_stream`` for rows that carry a ``streams=`` count.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import bnn, compile_bnn
from repro.core.pipeline import ChipSpec
from repro.dataplane import execute_stream, lower_program
from repro.dataplane.fabric import SwitchFabric
from repro.dataplane.fleet import execute_fleet
from repro.dataplane.plan import ExecutionPlan
from repro.serving.engine import FleetEngine

GATE_STREAMS = 64      # the acceptance-criterion fleet size
FABRIC_PACKETS = 8192  # hop-chain comparison workload
BLOCKS = 20            # fleet blocks per measurement


def _fleet_sizes(max_streams: int) -> list[int]:
    sizes = []
    s = 4
    while s < max_streams:
        sizes.append(s)
        s *= 4
    sizes.append(max_streams)
    return sizes


def rows() -> list[tuple[str, float, str]]:
    import jax

    max_streams = int(os.environ.get("FLEET_BENCH_STREAMS", 10_240))
    chunk = int(os.environ.get("FLEET_BENCH_CHUNK", 16))

    spec = bnn.BnnSpec((32, 64, 32))
    params = bnn.init_params(spec, jax.random.PRNGKey(0))
    prog = compile_bnn([np.asarray(w) for w in params])
    lp = lower_program(prog)
    n = chunk * BLOCKS
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, (n, 32)).astype(np.int32)

    out = []

    # -- one stream at the fleet's per-stream chunk: dispatch-bound --------
    # 10x the fleet workload per stream: at 16-packet dispatches the row is
    # all fixed overhead, so it needs more samples than the batched rows.
    x1 = np.tile(x, (10, 1))
    sr = execute_stream(lp, iter([x1]), backend="packed", chunk_size=chunk)
    sr = execute_stream(lp, iter([x1]), backend="packed", chunk_size=chunk)
    single_pps = sr.packets_per_second
    out.append(
        (
            "dataplane_fleet_single_pps",
            1e6 * sr.seconds / max(1, sr.chunks),
            f"pps={single_pps:.3e} streams=1 chunk={chunk} "
            f"warmup_us={1e6 * sr.warmup_seconds:.0f}",
        )
    )

    # -- aggregate pps vs fleet size ---------------------------------------
    gate_pps = None
    for s in _fleet_sizes(max_streams):
        streams = [x] * s  # replicas: identical load per simulated switch
        plan = ExecutionPlan(backend="packed", chunk_size=chunk)
        fr = execute_fleet(lp, streams, plan=plan)
        fr = execute_fleet(lp, streams, plan=plan)
        out.append(
            (
                f"dataplane_fleet_{s}",
                1e6 * fr.seconds / max(1, fr.chunks),
                f"pps={fr.packets_per_second:.3e} streams={s} "
                f"chunk={chunk} warmup_us={1e6 * fr.warmup_seconds:.0f}",
            )
        )
        if s == GATE_STREAMS:
            gate_pps = fr.packets_per_second
    if gate_pps is None:  # max_streams < 64: measure the gate size anyway
        fr = execute_fleet(
            lp,
            [x] * GATE_STREAMS,
            plan=ExecutionPlan(backend="packed", chunk_size=chunk),
        )
        gate_pps = fr.packets_per_second
    out.append(
        (
            "dataplane_fleet_agg_pps",
            0.0,
            f"pps={gate_pps:.3e} streams={GATE_STREAMS} chunk={chunk} "
            f"speedup={gate_pps / single_pps:.1f} "
            f"(acceptance: >=8x single-stream)",
        )
    )

    # -- async ingest/execute pipeline over the gate-size fleet ------------
    eng = FleetEngine(
        lp, plan=ExecutionPlan(backend="packed", chunk_size=chunk)
    )
    pr = eng.serve([x] * GATE_STREAMS)
    pr = eng.serve([x] * GATE_STREAMS)
    out.append(
        (
            "dataplane_fleet_pipeline",
            1e6 * pr.wall_seconds / max(1, pr.chunks),
            f"pps={pr.packets_per_second:.3e} streams={GATE_STREAMS} "
            f"overlap={pr.overlap_ratio:.2f} "
            f"ingest_us={1e6 * pr.ingest_seconds:.0f} "
            f"warmup_us={1e6 * pr.warmup_seconds:.0f}",
        )
    )

    # -- scanned vs unrolled hop chain -------------------------------------
    hop_chip = ChipSpec(
        num_elements=max(1, prog.num_elements // 12),
        phv_bits=prog.chip.phv_bits,
        name="bench/hop",
    )
    fab = SwitchFabric.partition(prog, mode="multi_hop", chip=hop_chip)
    fx = rng.integers(0, 2, (FABRIC_PACKETS, 32)).astype(np.int32)
    for label, scan in (("scanned", True), ("unrolled", False)):
        fres = fab.run(fx, backend="jnp", chunk_size=4096, scan_hops=scan)
        fres = fab.run(fx, backend="jnp", chunk_size=4096, scan_hops=scan)
        out.append(
            (
                f"dataplane_fabric_{label}",
                1e6 * fres.seconds,
                f"pps={fres.packets_per_second:.3e} hops={fab.num_hops} "
                f"packets={fres.packets} "
                f"warmup_us={1e6 * fres.warmup_seconds:.0f}",
            )
        )
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
