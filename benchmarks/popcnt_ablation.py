"""Paper §3 ablation: a native 32-bit POPCNT primitive cuts the element
range from 12-25 to 5-10 and doubles parallelism (duplication removed)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import bnn, compile_bnn
from repro.core.pipeline import (
    RMT,
    RMT_NATIVE_POPCNT,
    elements_for_neuron_group,
    max_parallel_neurons,
)

WIDTHS = (16, 32, 64, 128, 256, 512, 1024, 2048)


def rows() -> list[tuple[str, float, str]]:
    out = []
    base_els, native_els = [], []
    for n in WIDTHS:
        pb = max_parallel_neurons(n, RMT)
        pn = max_parallel_neurons(n, RMT_NATIVE_POPCNT)
        eb = elements_for_neuron_group(n, pb, RMT)
        # §3 recomputes Table 1's operating points (Table-1 parallelism).
        en = elements_for_neuron_group(n, pb, RMT_NATIVE_POPCNT)
        base_els.append(eb)
        native_els.append(en)
        out.append(
            (
                f"popcnt_ablation_N{n}",
                0.0,
                f"elements {eb}->{en} parallel {pb}->{pn} (2x={pn == 2 * pb})",
            )
        )
    # range claims + compiled correctness spot check
    params = bnn.init_params(bnn.BnnSpec((64, 32)), jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    prog = compile_bnn([np.asarray(w) for w in params], RMT_NATIVE_POPCNT)
    dt_us = (time.perf_counter() - t0) * 1e6
    out.append(
        (
            "popcnt_ablation_ranges",
            dt_us,
            f"base_range={min(base_els)}-{max(base_els)} (paper 12-25) "
            f"native_range={min(native_els)}-{max(native_els)} (paper 5-10) "
            f"native_compiles={prog.num_elements}el",
        )
    )
    return out
