"""Paper §2 Evaluation throughput claims, plus measured interpreter rates.

Analytic: 960M packets/s pipeline; neurons/s scales with parallelism; the
headline 960M two-layer-BNNs/s (32b activations, layers 64+32, one pass).
Measured (us_per_call): the JAX chip-interpreter on a 4096-packet batch —
the software simulation rate, NOT the ASIC rate (derived column carries the
modeled ASIC numbers)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnn, compile_bnn, throughput
from repro.core.interpreter import run_program_jit


def rows() -> list[tuple[str, float, str]]:
    out = []
    for n in (32, 256, 2048):
        rate = throughput.neuron_rate(n)
        out.append(
            (
                f"neuron_rate_N{n}",
                0.0,
                f"neurons_per_s={rate:.3e} (paper: 960e6 x parallelism)",
            )
        )

    spec = bnn.BnnSpec((32, 64, 32))
    params = bnn.init_params(spec, jax.random.PRNGKey(0))
    prog = compile_bnn([np.asarray(w) for w in params])
    rep = throughput.report_for_program(prog)

    batch = 4096
    x = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (batch, 32)).astype(jnp.int32)
    run_program_jit(prog, x).block_until_ready()  # compile
    t0 = time.perf_counter()
    iters = 5
    for _ in range(iters):
        run_program_jit(prog, x).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    sim_pps = batch / dt
    out.append(
        (
            "headline_2layer_bnn",
            dt / batch * 1e6,
            f"asic_networks_per_s={rep.networks_per_second:.3e} "
            f"passes={rep.passes} elements={rep.elements_used} "
            f"sim_packets_per_s={sim_pps:.3e}",
        )
    )
    return out
