"""Paper Table 1: max parallel neurons and required elements per activation
width — reproduced from BOTH the analytic cost model and actually-compiled
programs.  Also times the compiler itself (us_per_call column)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import bnn, compile_bnn
from repro.core.pipeline import RMT, elements_for_neuron_group, max_parallel_neurons

WIDTHS = (16, 32, 64, 128, 256, 512, 1024, 2048)
PAPER_PARALLEL = (128, 64, 32, 16, 8, 4, 2, 1)
PAPER_ELEMENTS = (12, 14, 16, 18, 20, 22, 24, 25)


def rows() -> list[tuple[str, float, str]]:
    out = []
    for n, p_paper, e_paper in zip(WIDTHS, PAPER_PARALLEL, PAPER_ELEMENTS):
        par = max_parallel_neurons(n)
        el = elements_for_neuron_group(n, par)
        # compile a 1-group layer at the Table-1 operating point
        params = bnn.init_params(bnn.BnnSpec((n, par)), jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        prog = compile_bnn([np.asarray(w) for w in params])
        dt_us = (time.perf_counter() - t0) * 1e6
        match = (par == p_paper) and (el == e_paper) and (prog.num_elements == e_paper)
        out.append(
            (
                f"table1_N{n}",
                dt_us,
                f"parallel={par}/{p_paper} elements={el}/{e_paper} "
                f"compiled={prog.num_elements} peak_phv={prog.peak_phv_bits} "
                f"match={match}",
            )
        )
    return out
