"""Dataplane executor benchmark: bit-packed PHV executor vs fused op-table
executor vs the legacy per-op interpreter vs the analytic ASIC model, per
traffic scenario.

Workload: the paper's headline model (32b activations, layers 64+32) over
``DATAPLANE_BENCH_PACKETS`` packets (default 1M; CI smoke sets it small).
The packed and fused executors stream every scenario end-to-end; the legacy
interpreter — eager, op-by-op Python dispatch — is timed on a single chunk
of the same size the fused path uses (its per-packet cost is batch-linear,
and a full million packets through it would take minutes), and all are
compared as packets/s.  Two acceptance rows gate regressions:
``dataplane_speedup`` (fused >= 10x legacy) and ``dataplane_packed_speedup``
(packed >= 5x fused — 32 activation bits per popcount lane instead of one
per select-chain row).

``us_per_call`` is microseconds per 32768-packet chunk dispatch.
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import bnn, compile_bnn, throughput
from repro.core.interpreter import run_program
from repro.dataplane import execute_stream, lower_program, traffic
from repro.dataplane.executor import DEFAULT_CHUNK
from repro.roofline import dataplane as roofline_dp


def rows() -> list[tuple[str, float, str]]:
    import jax

    n_packets = int(os.environ.get("DATAPLANE_BENCH_PACKETS", 1_000_000))
    chunk = min(DEFAULT_CHUNK, n_packets)

    spec = bnn.BnnSpec((32, 64, 32))
    params = bnn.init_params(spec, jax.random.PRNGKey(0))
    prog = compile_bnn([np.asarray(w) for w in params])
    lp = lower_program(prog)
    asic = throughput.report_for_program(prog)

    out = [
        (
            "dataplane_analytic_asic",
            0.0,
            f"pps={asic.packets_per_second:.3e} passes={asic.passes} "
            f"elements={asic.elements_used}",
        )
    ]

    fused_pps = {}
    for name in sorted(traffic.SCENARIOS):
        sr = execute_stream(
            lp,
            traffic.stream(name, n_packets, 32, chunk_size=chunk),
            chunk_size=chunk,
            backend="jnp",
        )
        fused_pps[name] = sr.packets_per_second
        out.append(
            (
                f"dataplane_fused_{name}",
                1e6 * sr.seconds / max(1, sr.chunks),
                f"pps={sr.packets_per_second:.3e} packets={sr.packets} "
                f"asic_gap={sr.packets_per_second / asic.packets_per_second:.2e} "
                f"warmup_us={1e6 * sr.warmup_seconds:.0f}",
            )
        )

    packed_pps = {}
    for name in sorted(traffic.SCENARIOS):
        sr = execute_stream(
            lp,
            traffic.stream(name, n_packets, 32, chunk_size=chunk),
            chunk_size=chunk,
            backend="packed",
        )
        packed_pps[name] = sr.packets_per_second
        out.append(
            (
                f"dataplane_packed_{name}",
                1e6 * sr.seconds / max(1, sr.chunks),
                f"pps={sr.packets_per_second:.3e} packets={sr.packets} "
                f"asic_gap={sr.packets_per_second / asic.packets_per_second:.2e} "
                f"warmup_us={1e6 * sr.warmup_seconds:.0f}",
            )
        )

    # Roofline-anchored utilization: cost the exact compiled packed dispatch
    # at this chunk size and judge the best measured packed rate against the
    # TPU v5e memory-roofline packets/s ceiling (repro/roofline/dataplane).
    # The CI gate tracks the fraction as ``dataplane_packed_roofline_frac``.
    t0 = time.perf_counter()
    rf = roofline_dp.probe_stream(lp, backend="packed", chunk=chunk)
    probe_us = 1e6 * (time.perf_counter() - t0)
    best_packed = max(packed_pps.values())
    out.append(
        (
            "dataplane_packed",
            probe_us,
            f"roofline_frac={rf.fraction(best_packed):.4e} "
            f"roofline_pps={rf.roofline_pps:.3e} "
            f"bytes_per_packet={rf.bytes_per_packet:.1f} "
            f"measured_pps={best_packed:.3e} bottleneck={rf.bottleneck}",
        )
    )

    # Legacy per-op interpreter: one chunk, same size, eager dispatch.
    x = jnp.asarray(traffic.generate("uniform_random", chunk, 32, seed=0))
    t0 = time.perf_counter()
    run_program(prog, x).block_until_ready()  # warm any lazy init
    legacy_warm_us = 1e6 * (time.perf_counter() - t0)
    t0 = time.perf_counter()
    run_program(prog, x).block_until_ready()
    legacy_s = time.perf_counter() - t0
    legacy_pps = chunk / legacy_s
    out.append(
        (
            "dataplane_legacy_interpreter",
            1e6 * legacy_s,
            f"pps={legacy_pps:.3e} packets={chunk} "
            f"warmup_us={legacy_warm_us:.0f} (per-op eager dispatch)",
        )
    )

    best = max(fused_pps.values())
    worst = min(fused_pps.values())
    out.append(
        (
            "dataplane_speedup",
            0.0,
            f"fused/legacy={worst / legacy_pps:.1f}x..{best / legacy_pps:.1f}x "
            f"(acceptance: >=10x)",
        )
    )
    ratios = [packed_pps[n] / fused_pps[n] for n in sorted(traffic.SCENARIOS)]
    out.append(
        (
            "dataplane_packed_speedup",
            0.0,
            f"packed/fused={min(ratios):.1f}x..{max(ratios):.1f}x "
            f"(acceptance: >=5x)",
        )
    )
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
