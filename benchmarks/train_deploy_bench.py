"""Train->deploy loop benchmark: STE steps/s + end-to-end export latency.

Three rows:

* ``bnn_train_step``      — steady-state jitted STE step time (compile
  excluded) on the example task; derived column reports steps/s and the
  final training-batch accuracy.
* ``bnn_export``          — end-to-end export latency: latent -> bit
  matrices -> ``compile_bnn`` -> lowered op-tables (the deploy-side cost a
  control plane would pay to push a retrained model to the switch).
* ``train_deploy_roundtrip`` — verification latency over the held-out set:
  oracle + fused executor + multi-hop fabric, all compared bit-for-bit.
  The derived column is the acceptance bit: ``bit_exact=True``.

``TRAIN_DEPLOY_BENCH_STEPS`` shrinks the training budget for CI smoke.
"""
from __future__ import annotations

import os
import time


def rows() -> list[tuple[str, float, str]]:
    from repro.core.export import verify_roundtrip
    from repro.train.bnn_trainer import BnnTrainConfig, BnnTrainer

    # >= 2: one step is consumed by the jit warm-up outside the clock.
    steps = max(2, int(os.environ.get("TRAIN_DEPLOY_BENCH_STEPS", 300)))
    cfg = BnnTrainConfig(
        steps=steps,
        train_packets_per_class=max(1024, min(8192, steps * 16)),
        eval_packets_per_class=max(256, min(5000, steps * 16)),
    )
    trainer = BnnTrainer(cfg)

    # One step outside the clock warms the jit cache; train() then times the
    # steady state.  The warm step's wall time is the jit compile cost, so
    # it lands in the warmup/steady split as ``warmup_us=``.
    trainer.cfg.steps = 1
    t0 = time.perf_counter()
    trainer.train()
    warmup_us = 1e6 * (time.perf_counter() - t0)
    trainer.cfg.steps = steps
    summary = trainer.train()
    acc = summary["history"][-1]["accuracy"] if summary["history"] else float("nan")
    out = [
        (
            "bnn_train_step",
            1e6 / summary["steps_per_second"],
            f"steps_per_s={summary['steps_per_second']:.1f} "
            f"batch={cfg.batch} final_acc={acc:.3f} "
            f"warmup_us={warmup_us:.0f}",
        )
    ]

    t0 = time.perf_counter()
    exported = trainer.export()
    export_us = (time.perf_counter() - t0) * 1e6
    out.append(
        (
            "bnn_export",
            export_us,
            f"elements={exported.program.num_elements} "
            f"ops={exported.lowered.num_ops} "
            f"compile_ms={exported.compile_seconds * 1e3:.1f} "
            f"lower_ms={exported.lower_seconds * 1e3:.1f}",
        )
    )

    report = verify_roundtrip(
        exported,
        trainer.eval_x,
        mode="multi_hop",
        reference_bits=trainer.forward_bits(trainer.eval_x),
        check=False,
    )
    out.append(
        (
            "train_deploy_roundtrip",
            report.verify_seconds * 1e6,
            f"bit_exact={report.ok} packets={report.packets} "
            f"hops={report.hops}",
        )
    )
    return out
