"""Multi-tenant scheduler benchmark: aggregate pkts/s vs tenant count.

One shared chip serves up to ``MULTITENANT_BENCH_TENANTS`` independently
compiled BNN classifiers over a mixed tagged stream, across the three
scheduling layouts.  **merged/interleave** packs every tenant's elements
onto shared physical stages, so one fused pass costs the *deepest* tenant
per chunk — the layout that scales to 100+ tenants;
**merged/concat** stacks tenants end to end, so the pass costs the *sum*
(swept only at small counts, where its narrower per-stage rows can still
win); **time-sliced** dispatches each tenant's narrow table separately and
pays per-turn scheduling overhead.  The headline gated metric is
``dataplane_merged_interleaved`` — the worst interleaved aggregate rate
over the 2..8-tenant counts, the regime where interleave must beat
time-slicing (its ``advantage_vs_sliced`` ratio rides in the derived
column).

Tenant counts sweep the subset of {2, 8, 32, 128} allowed by
``MULTITENANT_BENCH_TENANTS`` (default 8; CI pins 8 — the 32/128-tenant
points are for workstation runs).  ``MULTITENANT_BENCH_PACKETS`` sets the
stream length per run (default 200k; CI smoke shrinks it).  All layouts
run on the packed backend (``MULTITENANT_BENCH_BACKEND`` overrides), where
interleave uses the stacked widest-tenant dispatch
(``executor.routed_packed_stacked_fn``).  ``us_per_call`` is microseconds
per scheduled device dispatch (merged: per mixed chunk; sliced: per turn).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import bnn, compile_bnn
from repro.core.pipeline import MAX_FIELDS, ChipSpec
from repro.dataplane import (
    SwitchScheduler,
    TenantTrafficSpec,
    mixed_tenant_stream,
)
from repro.dataplane.lowering import lower_program, peak_stage_rows

# Distinct small nets so merged tables mix shapes, scenarios, and widths.
_SHAPES = [(32, 64, 32), (16, 32, 8), (32, 16), (8, 12, 6), (16, 8, 4), (32, 32, 4)]
_SCENARIOS = ("ddos_burst", "iot_telemetry", "flow_tuple",
              "adversarial_bitflip", "uniform_random")
_WEIGHTS = (3.0, 2.0, 1.0, 1.0, 2.0, 1.0)


def _tenant_pool(count: int):
    import jax

    progs, specs = [], []
    for i in range(count):
        shape = _SHAPES[i % len(_SHAPES)]
        params = bnn.init_params(bnn.BnnSpec(shape), jax.random.PRNGKey(i))
        progs.append(compile_bnn([np.asarray(w) for w in params]))
        specs.append(
            TenantTrafficSpec(
                _SCENARIOS[i % len(_SCENARIOS)], shape[0],
                _WEIGHTS[i % len(_WEIGHTS)],
            )
        )
    return progs, specs


TENANT_COUNTS = (2, 8, 32, 128)
CONCAT_MAX = 8     # concat's sum-scaling makes larger merges pointless
SLICED_MAX = 32    # per-tenant dispatch cost dominates past this


def rows() -> list[tuple[str, float, str]]:
    max_tenants = max(2, int(os.environ.get("MULTITENANT_BENCH_TENANTS", 8)))
    n_packets = int(os.environ.get("MULTITENANT_BENCH_PACKETS", 200_000))
    backend = os.environ.get("MULTITENANT_BENCH_BACKEND", "packed")
    # Moderate chunks are the honest operating point for the comparison:
    # merged pays one fused dispatch per chunk while time-slicing pays one
    # per tenant turn, and giant chunks would amortize sliced's scheduling
    # overhead away entirely (no real switch batches 16k packets before
    # dispatching).
    chunk = min(1 << 10, n_packets)
    counts = [c for c in TENANT_COUNTS if c <= max_tenants]
    progs, specs = _tenant_pool(counts[-1])
    # Budgets sized to admit the largest merge in *either* layout (concat
    # needs the element sum, interleave the widest shared stage): the sweep
    # is about scheduling cost, not admission (tests cover admission).
    chip = ChipSpec(
        num_elements=sum(p.num_elements for p in progs) + 1,
        phv_bits=sum(p.peak_phv_bits for p in progs),
        max_parallel_ops=max(
            MAX_FIELDS,
            peak_stage_rows([lower_program(p, compact=True) for p in progs]),
        ),
        name="shared",
    )

    out = []
    interleave_pps: dict[int, float] = {}
    sliced_pps: dict[int, float] = {}
    for count in counts:
        sched = SwitchScheduler(chip, quantum=chunk)
        for i in range(count):
            sched.admit(progs[i], name=f"t{i}", weight=specs[i].weight)
        runs = [("interleave", "merged", "interleave")]
        if count <= CONCAT_MAX:
            runs.append(("concat", "merged", "concat"))
        if count <= SLICED_MAX:
            runs.append(("sliced", "time_sliced", None))
        repeats = max(1, int(os.environ.get("MULTITENANT_BENCH_REPEATS", 3)))
        for tag, mode, layout in runs:
            # Best-of-N: the clocked region per config is a few ms at CI
            # budgets, so a single run is scheduler-noise-bound.
            res = None
            for _ in range(repeats):
                r = sched.run(
                    mixed_tenant_stream(
                        specs[:count], n_packets, chunk_size=chunk,
                        seed=count,
                    ),
                    mode=mode,
                    merged=layout,
                    backend=backend,
                    chunk_size=chunk,
                    collect=False,
                )
                if res is None or r.packets_per_second > res.packets_per_second:
                    res = r
            dispatches = (
                res.chunks
                if mode == "merged"
                else sum(st.slices for st in res.tenants)
            )
            per_pps = [st.packets_per_second for st in res.tenants]
            if tag == "interleave":
                interleave_pps[count] = res.packets_per_second
            elif tag == "sliced":
                sliced_pps[count] = res.packets_per_second
            out.append(
                (
                    f"multitenant_{tag}_t{count}",
                    1e6 * res.seconds / max(1, dispatches),
                    f"pps={res.packets_per_second:.3e} packets={res.packets} "
                    f"tenants={count} dispatches={dispatches} "
                    f"tenant_pps_min={min(per_pps):.3e} "
                    f"tenant_pps_max={max(per_pps):.3e} "
                    f"warmup_us={1e6 * res.warmup_seconds:.0f}",
                )
            )
    # The gated headline: interleave's worst aggregate rate over the small
    # counts (2..8 tenants), where it must at least match time-slicing.
    small = [c for c in counts if c <= CONCAT_MAX]
    headline = min(interleave_pps[c] for c in small)
    advantage = min(
        interleave_pps[c] / sliced_pps[c] for c in small if c in sliced_pps
    )
    out.append(
        (
            "dataplane_merged_interleaved",
            0.0,
            f"pps={headline:.3e} advantage_vs_sliced={advantage:.3f} "
            f"tenants={max(small)}",
        )
    )
    footprint = sum(p.num_elements for p in progs)
    out.append(
        (
            "multitenant_footprint",
            0.0,
            f"tenants={counts[-1]} concat_elements={footprint} "
            f"interleave_elements={max(p.num_elements for p in progs)} "
            f"chip_elements={chip.num_elements} "
            f"stage_rows={chip.max_parallel_ops} "
            f"phv_bits={sum(p.peak_phv_bits for p in progs)}",
        )
    )
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
