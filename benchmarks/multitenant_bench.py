"""Multi-tenant scheduler benchmark: aggregate pkts/s vs tenant count.

One shared chip serves 2..``MULTITENANT_BENCH_TENANTS`` independently
compiled BNN classifiers over a mixed tagged stream, in both scheduling
modes.  The two modes trade differently in software than on the ASIC:
**merged** runs one fused pass over the *union* of all tenants' elements, so
simulator cost per packet grows with tenant count (on the real chip those
stages execute spatially in parallel — merged is the mode that keeps every
tenant at line rate, which is what the analytic model in
``SwitchScheduler.analytic_pps`` reports); **time-sliced** dispatches each
tenant's narrow table separately and pays per-turn scheduling overhead
instead.  This bench pins the simulator-side costs of both so regressions in
either path are visible.

``MULTITENANT_BENCH_TENANTS`` caps the tenant sweep (default 4; CI smoke
sets 3).  ``MULTITENANT_BENCH_PACKETS`` sets the stream length per run
(default 200k; CI smoke shrinks it).  ``us_per_call`` is microseconds per
scheduled device dispatch (merged: per mixed chunk; sliced: per turn).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import bnn, compile_bnn
from repro.core.pipeline import ChipSpec
from repro.dataplane import (
    SwitchScheduler,
    TenantTrafficSpec,
    mixed_tenant_stream,
)

# Distinct small nets so merged tables mix shapes, scenarios, and widths.
_SHAPES = [(32, 64, 32), (16, 32, 8), (32, 16), (8, 12, 6), (16, 8, 4), (32, 32, 4)]
_SCENARIOS = ("ddos_burst", "iot_telemetry", "flow_tuple",
              "adversarial_bitflip", "uniform_random")
_WEIGHTS = (3.0, 2.0, 1.0, 1.0, 2.0, 1.0)


def _tenant_pool(count: int):
    import jax

    progs, specs = [], []
    for i in range(count):
        shape = _SHAPES[i % len(_SHAPES)]
        params = bnn.init_params(bnn.BnnSpec(shape), jax.random.PRNGKey(i))
        progs.append(compile_bnn([np.asarray(w) for w in params]))
        specs.append(
            TenantTrafficSpec(
                _SCENARIOS[i % len(_SCENARIOS)], shape[0],
                _WEIGHTS[i % len(_WEIGHTS)],
            )
        )
    return progs, specs


def rows() -> list[tuple[str, float, str]]:
    max_tenants = max(2, int(os.environ.get("MULTITENANT_BENCH_TENANTS", 4)))
    n_packets = int(os.environ.get("MULTITENANT_BENCH_PACKETS", 200_000))
    chunk = min(1 << 14, n_packets)
    progs, specs = _tenant_pool(max_tenants)
    # Element/PHV budgets sized to admit the largest merge: the sweep is
    # about scheduling cost, not admission (tests cover admission).
    chip = ChipSpec(
        num_elements=sum(p.num_elements for p in progs) + 1,
        phv_bits=sum(p.peak_phv_bits for p in progs),
        name="shared",
    )

    out = []
    for count in range(2, max_tenants + 1):
        sched = SwitchScheduler(chip, quantum=chunk)
        for i in range(count):
            sched.admit(progs[i], name=f"t{i}", weight=specs[i].weight)
        for mode in ("merged", "time_sliced"):
            res = sched.run(
                mixed_tenant_stream(
                    specs[:count], n_packets, chunk_size=chunk, seed=count
                ),
                mode=mode,
                backend="jnp",
                chunk_size=chunk,
                collect=False,
            )
            dispatches = (
                res.chunks
                if mode == "merged"
                else sum(st.slices for st in res.tenants)
            )
            per_pps = [st.packets_per_second for st in res.tenants]
            tag = "merged" if mode == "merged" else "sliced"
            out.append(
                (
                    f"multitenant_{tag}_t{count}",
                    1e6 * res.seconds / max(1, dispatches),
                    f"pps={res.packets_per_second:.3e} packets={res.packets} "
                    f"tenants={count} dispatches={dispatches} "
                    f"tenant_pps_min={min(per_pps):.3e} "
                    f"tenant_pps_max={max(per_pps):.3e} "
                    f"warmup_us={1e6 * res.warmup_seconds:.0f}",
                )
            )
    footprint = sum(p.num_elements for p in progs)
    out.append(
        (
            "multitenant_footprint",
            0.0,
            f"tenants={max_tenants} merged_elements={footprint} "
            f"chip_elements={chip.num_elements} "
            f"phv_bits={sum(p.peak_phv_bits for p in progs)}",
        )
    )
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
