"""Pcap ingestion benchmark: capture write/read and featurizer throughput.

The featurizer (``dataplane.pcap.parse_headers`` + ``featurize``) is the
hot path between a capture file and the executor's activation bits — it
must keep up with the fused executor's packet rates, so its pkts/s is gated
against the baseline alongside them.  The readers/writers are control-plane
(run once per capture), but their rates are pinned too so a quadratic-copy
regression can't hide.

Workload: ``PCAP_BENCH_PACKETS`` packets (default 200k; CI smoke sets it
small) of the deterministic two-class synthetic trace, serialized and
re-read in both formats, then featurized at the full 136-bit layout folded
to 64 model input bits.  ``us_per_call`` is microseconds per whole-capture
operation.
"""
from __future__ import annotations

import os
import time

from repro.dataplane import pcap


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def rows() -> list[tuple[str, float, str]]:
    n = int(os.environ.get("PCAP_BENCH_PACKETS", 200_000))
    packets, ts, labels = pcap.synthesize_capture(n, seed=0)

    raw, w_s = _timed(lambda: pcap.write_pcap(packets, ts))
    raw_ng, wng_s = _timed(lambda: pcap.write_pcapng(packets, ts))
    cap, r_s = _timed(lambda: pcap.read_pcap(raw))
    cap_ng, rng_s = _timed(lambda: pcap.read_pcap(raw_ng))
    assert cap.num_packets == cap_ng.num_packets == n

    # Warm once (numpy allocator, log tables), then time the hot path.
    _, warm_s = _timed(lambda: pcap.featurize(cap, 64))
    bits, f_s = _timed(lambda: pcap.featurize(cap, 64))
    assert bits.shape == (n, 64)

    return [
        (
            "pcap_write",
            1e6 * w_s,
            f"pps={n / w_s:.3e} bytes={len(raw)} packets={n}",
        ),
        (
            "pcap_write_pcapng",
            1e6 * wng_s,
            f"pps={n / wng_s:.3e} bytes={len(raw_ng)} packets={n}",
        ),
        (
            "pcap_read",
            1e6 * r_s,
            f"pps={n / r_s:.3e} packets={n}",
        ),
        (
            "pcap_read_pcapng",
            1e6 * rng_s,
            f"pps={n / rng_s:.3e} packets={n}",
        ),
        (
            "pcap_featurize",
            1e6 * f_s,
            f"pps={n / f_s:.3e} packets={n} feature_bits="
            f"{pcap.PCAP_FEATURE_BITS} folded_bits=64 "
            f"flood_share={labels.mean():.2f} warmup_us={1e6 * warm_s:.0f}",
        ),
    ]


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
