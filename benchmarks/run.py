"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV:
  * table1_elements   — paper Table 1 (parallel neurons + element counts)
  * throughput_model  — §2 Evaluation rates incl. the 960M-networks headline
  * popcnt_ablation   — §3 native-POPCNT ablation (12-25 -> 5-10 elements)
  * kernel_bench      — binary-GEMM kernel paths
  * roofline_summary  — dry-run roofline table (EXPERIMENTS.md §Roofline)
  * dataplane_bench   — fused op-table executor vs legacy interpreter vs
                        analytic ASIC model, per traffic scenario
                        (DATAPLANE_BENCH_PACKETS tunes the workload)
  * train_deploy_bench— STE training steps/s + export latency + round-trip
                        verification (TRAIN_DEPLOY_BENCH_STEPS tunes)
  * multitenant_bench — aggregate pkts/s vs tenant count, merged vs
                        time-sliced scheduling (MULTITENANT_BENCH_TENANTS /
                        MULTITENANT_BENCH_PACKETS tune)
  * pcap_bench        — capture write/read + header-featurizer throughput
                        (PCAP_BENCH_PACKETS tunes the capture size)
  * fleet_bench       — aggregate pkts/s vs fleet size: N vmapped streams
                        through one compiled dispatch, plus the async
                        serving pipeline and scanned-vs-unrolled hop chains
                        (FLEET_BENCH_STREAMS / FLEET_BENCH_CHUNK tune)

Besides the CSV, each module's rows land in ``BENCH_<module>.json`` (in
``BENCH_OUT_DIR``, default cwd) with every ``key=<float>`` pair from the
derived column parsed into a ``metrics`` map — the artifact
``tools/check_bench_regression.py`` gates CI on — and a per-module timing
summary is printed at the end (``# timing ...`` lines) so slow modules are
visible in the job log.

Timing is split **warmup vs steady-state**: modules report their jit
warm-call time as ``warmup_us=`` metrics in the derived column, and the
JSON payload carries ``warmup_seconds`` (their sum) next to
``steady_seconds`` (module wall time minus warmup) — so the regression
gate's throughput numbers never conflate compile time with execution, and
a compile-time blow-up is visible as its own number.

Set ``$REPRO_OBS`` truthy to run the whole harness under the runtime
observability layer (``repro.obs``): metrics JSONL, Prometheus text, and a
chrome trace land in ``$REPRO_OBS_DIR`` (default ``BENCH_OUT_DIR``) —
render them with ``tools/obs_report.py``.
"""
from __future__ import annotations

import json
import os
import re
import sys
import time

_METRIC_RE = re.compile(r"(\w+)=([-+]?[0-9][0-9_]*\.?[0-9]*(?:[eE][-+]?[0-9]+)?)\b")


def parse_metrics(derived: str) -> dict[str, float]:
    """Every ``key=<number>`` pair in a derived column, as floats."""
    out = {}
    for key, val in _METRIC_RE.findall(derived):
        try:
            out[key] = float(val)
        except ValueError:  # pragma: no cover - regex already filters
            continue
    return out


def write_bench_json(out_dir: str, module: str, seconds: float, rows) -> str:
    path = os.path.join(out_dir, f"BENCH_{module}.json")
    # Warmup vs steady-state split: every row's ``warmup_us=`` metric (the
    # module's jit warm calls, reported by the benchmarks themselves) is
    # summed out of the module wall time, so ``steady_seconds`` is the
    # execution-only budget the throughput metrics were measured in.
    warmup = sum(
        parse_metrics(derived).get("warmup_us", 0.0) for _, _, derived in rows
    ) / 1e6
    payload = {
        "module": module,
        "seconds": round(seconds, 3),
        "warmup_seconds": round(warmup, 3),
        "steady_seconds": round(max(seconds - warmup, 0.0), 3),
        "rows": [
            {
                "name": name,
                "us_per_call": us,
                "derived": derived,
                "metrics": parse_metrics(derived),
            }
            for name, us, derived in rows
        ],
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def main() -> None:
    from benchmarks import (
        dataplane_bench,
        fleet_bench,
        kernel_bench,
        multitenant_bench,
        obs_overhead_bench,
        pcap_bench,
        popcnt_ablation,
        roofline_summary,
        table1_elements,
        throughput_model,
        train_deploy_bench,
    )
    from repro import obs

    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    observing = obs.enable_from_env()
    print("name,us_per_call,derived")
    modules = [
        table1_elements,
        throughput_model,
        popcnt_ablation,
        kernel_bench,
        roofline_summary,
        dataplane_bench,
        train_deploy_bench,
        multitenant_bench,
        pcap_bench,
        fleet_bench,
        obs_overhead_bench,
    ]
    failures = 0
    timings: list[tuple[str, float, bool]] = []
    for mod in modules:
        short = mod.__name__.rsplit(".", 1)[-1]
        t0 = time.perf_counter()
        try:
            rows = mod.rows()
        except Exception as e:  # noqa: BLE001
            failures += 1
            timings.append((short, time.perf_counter() - t0, False))
            print(f"{mod.__name__},nan,ERROR {type(e).__name__}: {e}")
            continue
        seconds = time.perf_counter() - t0
        timings.append((short, seconds, True))
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
        write_bench_json(out_dir, short, seconds, rows)

    total = sum(s for _, s, _ in timings)
    print(f"# timing: {total:.1f}s total across {len(timings)} modules")
    for short, seconds, ok in sorted(timings, key=lambda t: -t[1]):
        status = "" if ok else "  [FAILED]"
        print(f"# timing {short:<22} {seconds:>7.1f}s{status}")
    if observing:
        obs_dir = os.environ.get(obs.OBS_DIR_ENV, out_dir)
        paths = obs.export_all(obs_dir, prefix="bench_obs")
        for key in sorted(paths):
            print(f"# obs {key}: {paths[key]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
