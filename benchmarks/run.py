"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV:
  * table1_elements   — paper Table 1 (parallel neurons + element counts)
  * throughput_model  — §2 Evaluation rates incl. the 960M-networks headline
  * popcnt_ablation   — §3 native-POPCNT ablation (12-25 -> 5-10 elements)
  * kernel_bench      — binary-GEMM kernel paths
  * roofline_summary  — dry-run roofline table (EXPERIMENTS.md §Roofline)
  * dataplane_bench   — fused op-table executor vs legacy interpreter vs
                        analytic ASIC model, per traffic scenario
                        (DATAPLANE_BENCH_PACKETS tunes the workload)
  * train_deploy_bench— STE training steps/s + export latency + round-trip
                        verification (TRAIN_DEPLOY_BENCH_STEPS tunes)
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        dataplane_bench,
        kernel_bench,
        popcnt_ablation,
        roofline_summary,
        table1_elements,
        throughput_model,
        train_deploy_bench,
    )

    print("name,us_per_call,derived")
    modules = [
        table1_elements,
        throughput_model,
        popcnt_ablation,
        kernel_bench,
        roofline_summary,
        dataplane_bench,
        train_deploy_bench,
    ]
    failures = 0
    for mod in modules:
        try:
            for name, us, derived in mod.rows():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},nan,ERROR {type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
