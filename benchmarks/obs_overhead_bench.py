"""Observability overhead benchmark: the disabled path must be (near) free.

The runtime observability layer (``repro.obs``) instruments the dataplane
hot path behind a global switch.  The contract — ISSUE acceptance — is that
with observability **disabled** the instrumented executor is bit-exact and
within 5% of its uninstrumented cost, and this module is the measurement:

* ``obs_disabled_stream`` / ``obs_enabled_stream`` — the same
  ``execute_stream`` workload timed with the switch off and on (best of 3
  steady-state repetitions each, shared jit cache, warm call reported as
  ``warmup_us=``);
* ``obs_overhead`` — the headline ``overhead_pct`` (enabled vs disabled)
  and ``bitexact`` (outputs compared element-wise across the two modes);
* ``obs_null_span`` — nanoseconds per no-op ``obs.span()`` call on the
  disabled path, the per-callsite cost the <5% bound rests on.

``OBS_BENCH_PACKETS`` sets the stream length (default 200k; CI smoke
shrinks it).  The bench saves and restores the global observability state,
so it composes with ``$REPRO_OBS`` harness runs (it never resets the
registry — metrics it emits while enabled simply join the export).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro import obs
from repro.core import bnn, compile_bnn
from repro.dataplane import execute_stream, lower_program, traffic

_REPS = 3


def _time_stream(lp, n_packets: int, chunk: int) -> tuple[float, np.ndarray]:
    """Best-of-``_REPS`` wall seconds for one full stream, plus outputs."""
    best, outputs = float("inf"), None
    for rep in range(_REPS):
        t0 = time.perf_counter()
        sr = execute_stream(
            lp,
            traffic.stream("uniform_random", n_packets, 32, chunk_size=chunk),
            chunk_size=chunk,
            backend="jnp",
            collect=True,
        )
        best = min(best, time.perf_counter() - t0)
        outputs = sr.outputs
    return best, outputs


def rows() -> list[tuple[str, float, str]]:
    import jax

    n_packets = int(os.environ.get("OBS_BENCH_PACKETS", 200_000))
    chunk = min(1 << 14, n_packets)

    params = bnn.init_params(bnn.BnnSpec((32, 64, 32)), jax.random.PRNGKey(0))
    prog = compile_bnn([np.asarray(w) for w in params])
    lp = lower_program(prog)

    was_enabled = obs.enabled()
    try:
        # Warm the jit cache once (outside both timed modes) so neither
        # measurement pays compile time; report it as the module's warmup.
        obs.disable()
        t0 = time.perf_counter()
        execute_stream(
            lp,
            traffic.stream("uniform_random", chunk, 32, chunk_size=chunk),
            chunk_size=chunk,
            backend="jnp",
        )
        warmup_us = 1e6 * (time.perf_counter() - t0)

        disabled_s, out_off = _time_stream(lp, n_packets, chunk)
        obs.enable()
        enabled_s, out_on = _time_stream(lp, n_packets, chunk)

        # Disabled fast path microcost: a no-op context manager per callsite.
        obs.disable()
        n_calls = 100_000
        t0 = time.perf_counter()
        for _ in range(n_calls):
            with obs.span("bench:null"):
                pass
        span_ns = 1e9 * (time.perf_counter() - t0) / n_calls
    finally:
        if was_enabled:
            obs.enable()
        else:
            obs.disable()

    enabled_pct = 100.0 * (enabled_s - disabled_s) / disabled_s
    bitexact = float(np.array_equal(out_off, out_on))
    chunks = max(1, -(-n_packets // chunk))
    # Disabled-path overhead estimate: ~2 span entries + the enabled() check
    # per chunk dispatch, judged against the measured per-chunk cost.  This
    # is the <5% acceptance number — the disabled path is the default.
    disabled_pct = 100.0 * (3 * span_ns * 1e-9 * chunks) / disabled_s
    return [
        (
            "obs_disabled_stream",
            1e6 * disabled_s / chunks,
            f"disabled_pps={n_packets / disabled_s:.3e} packets={n_packets} "
            f"warmup_us={warmup_us:.0f}",
        ),
        (
            "obs_enabled_stream",
            1e6 * enabled_s / chunks,
            f"enabled_pps={n_packets / enabled_s:.3e} packets={n_packets}",
        ),
        (
            "obs_overhead",
            0.0,
            f"disabled_overhead_pct={disabled_pct:.4f} "
            f"enabled_overhead_pct={enabled_pct:.2f} bitexact={bitexact:.0f} "
            f"(acceptance: disabled <5%)",
        ),
        (
            "obs_null_span",
            0.0,
            f"ns_per_span={span_ns:.0f} calls={n_calls} (disabled no-op path)",
        ),
    ]


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived}")
