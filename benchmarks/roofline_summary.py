"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

Emits one row per compiled (arch x shape x mesh) cell with the three
roofline terms, the dominant bottleneck, and the roofline fraction — the
source table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os


def rows(out_dir: str = "experiments/dryrun") -> list[tuple[str, float, str]]:
    out = []
    files = sorted(glob.glob(os.path.join(out_dir, "*.json")))
    if not files:
        return [("roofline_summary", 0.0, "no dry-run artifacts yet — run "
                 "`python -m repro.launch.dryrun`")]
    n_ok = n_skip = n_err = 0
    for f in files:
        try:
            r = json.load(open(f))
        except json.JSONDecodeError:
            continue
        if r["status"] == "skipped":
            n_skip += 1
            continue
        if r["status"] != "ok":
            n_err += 1
            out.append((f"roofline_{r['cell']}", 0.0, f"ERROR {r.get('error','')[:80]}"))
            continue
        n_ok += 1
        rl = r["roofline"]
        out.append(
            (
                f"roofline_{r['cell']}",
                rl["step_time_s"] * 1e6,
                f"bottleneck={rl['bottleneck']} compute={rl['compute_s']:.4g}s "
                f"memory={rl['memory_s']:.4g}s collective={rl['collective_s']:.4g}s "
                f"useful_flops={rl['useful_flops_fraction']:.3f} "
                f"roofline_frac={rl['roofline_fraction']:.4f} "
                f"fits={r['memory']['argument_bytes_per_dev'] < 12 * 2**30}",
            )
        )
    out.append(("roofline_totals", 0.0, f"ok={n_ok} skipped={n_skip} errors={n_err}"))
    return out
