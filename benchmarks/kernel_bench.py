"""Binary-GEMM kernel microbenchmarks (CPU timings of the XLA paths; the
Pallas kernels run in interpret mode — their TPU performance is covered by
the roofline analysis, these timings validate correctness-path overheads
and the packed representation's 32x byte reduction)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=3) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def rows() -> list[tuple[str, float, str]]:
    m, n, k = 256, 256, 4096
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (n, k), jnp.float32)

    out = []
    f_ref = jax.jit(lambda a, b: ref.bnn_matmul_ref(a, b))
    us_ref = _time(f_ref, x, w)
    out.append(("kernel_ref_pm1_matmul", us_ref, f"M=N=256 K=4096 f32"))

    f_packed = jax.jit(
        lambda a, b: ops.binary_matmul(a, b, implementation="packed_ref")
    )
    us_packed = _time(f_packed, x, w)
    wp, _ = ops.pack_weights(w)
    ratio = (w.size * 4) / (wp.size * 4)
    out.append(
        (
            "kernel_packed_ref_matmul",
            us_packed,
            f"weight_bytes_ratio={ratio:.1f}x speed_vs_ref={us_ref/us_packed:.2f}x",
        )
    )

    f_bitpack = jax.jit(lambda a: ops.bitpack(a, interpret=True))
    us_bp = _time(f_bitpack, x)
    out.append(("kernel_bitpack_interpret", us_bp, f"(256,4096)->(256,128)u32"))

    # small-shape pallas interpret sanity timing (correctness covered in tests)
    xs, ws = x[:64, :512], w[:64, :512]
    f_pp = jax.jit(
        lambda a, b: ops.binary_matmul(a, b, implementation="pallas_packed")
    )
    us_pp = _time(f_pp, xs, ws, iters=1)
    out.append(("kernel_pallas_packed_interpret", us_pp, "64x64x512 (interpret mode)"))
    return out
