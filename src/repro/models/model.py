"""Model assembly: one init/forward/decode entry point for every family.

Layer stacks are homogeneous and scanned (``lax.scan`` over stacked params):
HLO size is O(1) in depth, which is what keeps the 512-device dry-run
compiles tractable.  The hybrid (zamba2) family scans an outer
(group = ``hybrid_period`` SSM layers + one *shared* attention block) unit so
the shared weights appear once; the tail layers get their own small scan.

Families:
  dense  — [norm -> attn -> res] [norm -> ffn -> res] x L
  moe    —  same, ffn replaced by expert-parallel MoE
  ssm    — [norm -> mamba2 -> res] x L
  hybrid — ssm backbone + shared attn/ffn block every ``hybrid_period``
  vlm    — dense LM consuming [patch embeds ; token embeds]
  audio  — encoder-only (bidirectional) over stubbed frame embeddings
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.attention import KVCache, attention_apply, attention_init
from repro.layers.embedding import embed, embedding_init, unembed
from repro.layers.ffn import ffn_apply, ffn_init
from repro.layers.mamba2 import SsmCache, mamba2_apply, mamba2_init
from repro.layers.mla import MlaCache, mla_apply, mla_init
from repro.layers.moe import moe_apply, moe_init
from repro.layers.norms import rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HybridCache:
    """SSM states for every layer + KV cache for the shared-attn instances."""

    ssm: SsmCache
    kv: KVCache  # stacked over shared-block applications

    @property
    def index(self):
        return self.kv.index


jax.tree_util.register_dataclass(HybridCache, ["ssm", "kv"], [])


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache for a model family (None for encoder-only)."""
    if cfg.encoder_only:
        return None
    if cfg.family in ("dense", "vlm"):
        if cfg.attention == "mla":
            return MlaCache.init(cfg, batch, max_len, cfg.num_layers)
        return KVCache.init(cfg, batch, max_len, cfg.num_layers)
    if cfg.family == "moe":
        if cfg.attention == "mla":
            return MlaCache.init(cfg, batch, max_len, cfg.num_layers)
        return KVCache.init(cfg, batch, max_len, cfg.num_layers)
    if cfg.family == "ssm":
        return SsmCache.init(cfg, batch, cfg.num_layers)
    if cfg.family == "hybrid":
        n_shared = cfg.num_layers // cfg.hybrid_period
        return HybridCache(
            ssm=SsmCache.init(cfg, batch, cfg.num_layers),
            kv=KVCache.init(cfg, batch, max_len, n_shared),
        )
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    """One transformer/ssm block's params (family-dependent)."""
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm" or (cfg.family == "hybrid"):
        return {
            "norm": rmsnorm_init(cfg.d_model, dtype),
            "mamba": mamba2_init(ks[0], cfg, dtype),
        }
    p = {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype),
        "ffn_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.attention == "mla":
        p["attn"] = mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = attention_init(ks[0], cfg, dtype)
    if cfg.family == "moe":
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, std=cfg.init_std, dtype=dtype, quant=cfg.quant)
    return p


def _shared_block_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention_init(ks[0], cfg, dtype),
        "ffn_norm": rmsnorm_init(cfg.d_model, dtype),
        "ffn": ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, std=cfg.init_std, dtype=dtype, quant=cfg.quant),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Full parameter pytree (usable under ``jax.eval_shape`` for dry-runs)."""
    dtype = cfg.param_dtype()
    k_embed, k_layers, k_shared, k_norm = jax.random.split(key, 4)

    p: dict[str, Any] = {
        "embed": embedding_init(k_embed, cfg.vocab_size, cfg.d_model, std=cfg.init_std, dtype=dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }

    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.num_layers // period
        tail = cfg.num_layers - n_groups * period
        gk = jax.random.split(k_layers, n_groups * period).reshape(n_groups, period, 2)
        p["layers"] = jax.vmap(
            jax.vmap(lambda k: _block_init(k, cfg, dtype))
        )(gk)
        if tail:
            tk = jax.random.split(jax.random.fold_in(k_layers, 1), tail)
            p["tail"] = jax.vmap(lambda k: _block_init(k, cfg, dtype))(tk)
        p["shared"] = _shared_block_init(k_shared, cfg, dtype)
    else:
        lk = jax.random.split(k_layers, cfg.num_layers)
        p["layers"] = jax.vmap(lambda k: _block_init(k, cfg, dtype))(lk)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _attn_block(bp, h, cfg, positions, layer_cache, cache_index, causal, mesh):
    """attention (+ffn/moe) block with residuals.  Returns (h, cache, aux)."""
    apply = mla_apply if cfg.attention == "mla" else attention_apply
    a, new_cache = apply(
        bp["attn"], rmsnorm(bp["attn_norm"], h, cfg.norm_eps), cfg,
        positions=positions, layer_cache=layer_cache, cache_index=cache_index,
        causal=causal,
    )
    h = h + a
    hn = rmsnorm(bp["ffn_norm"], h, cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = moe_apply(bp["moe"], hn, cfg, mesh=mesh)
    else:
        f, aux = ffn_apply(bp["ffn"], hn, cfg), jnp.zeros((), jnp.float32)
    return h + f, new_cache, aux


def _ssm_block(bp, h, cfg, layer_cache, cache_index):
    out, new_cache = mamba2_apply(
        bp["mamba"], rmsnorm(bp["norm"], h, cfg.norm_eps), cfg,
        layer_cache=layer_cache, cache_index=cache_index,
    )
    return h + out, new_cache


def _maybe_remat(fn, cfg: ModelConfig, enable: bool):
    return jax.checkpoint(fn) if (cfg.remat and enable) else fn


def _embed_inputs(params, batch: dict, cfg: ModelConfig) -> jax.Array:
    dtype = cfg.param_dtype()
    if cfg.input_mode == "frames":
        return batch["frames"].astype(dtype)
    if cfg.input_mode == "tokens+patches":
        tok = embed(params["embed"], batch["tokens"], dtype)
        return jnp.concatenate([batch["patches"].astype(dtype), tok], axis=1)
    return embed(params["embed"], batch["tokens"], dtype)


def forward(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train / eval / prefill-style).

    batch: {"tokens": (B, S)} (+ "patches"/"frames" per input_mode).
    Returns (logits (B, S_total, V) f32, aux losses scalar).
    """
    h = _embed_inputs(params, batch, cfg)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    causal = not cfg.encoder_only

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(carry, lp):
            hh, aux = carry
            hh, _, a = _attn_block(lp, hh, cfg, positions, None, None, causal, mesh)
            return (hh, aux + a), None

        (h, aux), _ = jax.lax.scan(
            _maybe_remat(body, cfg, remat), (h, jnp.zeros((), jnp.float32)),
            params["layers"],
        )
    elif cfg.family == "ssm":
        def body(hh, lp):
            hh, _ = _ssm_block(lp, hh, cfg, None, None)
            return hh, None

        h, _ = jax.lax.scan(_maybe_remat(body, cfg, remat), h, params["layers"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        shared = params["shared"]

        def inner(hh, lp):
            hh, _ = _ssm_block(lp, hh, cfg, None, None)
            return hh, None

        def group(hh, glp):
            hh, _ = jax.lax.scan(inner, hh, glp)
            hh, _, _ = _attn_block(shared, hh, cfg, positions, None, None, True, mesh)
            return hh, None

        h, _ = jax.lax.scan(_maybe_remat(group, cfg, remat), h, params["layers"])
        if "tail" in params:
            h, _ = jax.lax.scan(_maybe_remat(inner, cfg, remat), h, params["tail"])
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.family)

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params["embed"], h), aux


# ---------------------------------------------------------------------------
# prefill (inference: seed decode caches, emit last-position logits only)
# ---------------------------------------------------------------------------

def prefill(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
):
    """Prefill: full-sequence pass that seeds the decode cache.

    Returns (last_logits (B, V) f32, cache with index = S).  Cache buffers
    are sized to the prompt length; the serving engine right-pads them to its
    decode budget.  Logits are computed for the *last* position only — a
    (B, S, V) logits tensor at 32k prompt length would not fit HBM.
    """
    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no prefill/decode")
    h = _embed_inputs(params, batch, cfg)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    idx0 = jnp.zeros((), jnp.int32)

    if cfg.family in ("dense", "moe", "vlm"):
        is_mla = cfg.attention == "mla"

        def body(hh, lp):
            hh, nc, _ = _attn_block(lp, hh, cfg, positions, None, idx0, True, mesh)
            return hh, nc

        h, lcs = jax.lax.scan(body, h, params["layers"])
        if is_mla:
            cache = MlaCache(lcs["c_kv"], lcs["k_rope"], jnp.int32(s))
        else:
            cache = KVCache(lcs["k"], lcs["v"], jnp.int32(s))
    elif cfg.family == "ssm":
        def body(hh, lp):
            hh, nc = _ssm_block(lp, hh, cfg, None, idx0)
            return hh, nc

        h, lcs = jax.lax.scan(body, h, params["layers"])
        cache = SsmCache(lcs["h"], lcs["conv_x"], lcs["conv_bc"], jnp.int32(s))
    elif cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.num_layers // period
        shared = params["shared"]

        def inner(hh, lp):
            hh, nc = _ssm_block(lp, hh, cfg, None, idx0)
            return hh, nc

        def group(hh, glp):
            hh, ncs = jax.lax.scan(inner, hh, glp)
            hh, nkv, _ = _attn_block(shared, hh, cfg, positions, None, idx0, True, mesh)
            return hh, (ncs, nkv)

        h, (ssm_groups, kvs) = jax.lax.scan(group, h, params["layers"])
        ssm_flat = jax.tree.map(
            lambda a: a.reshape(n_groups * period, *a.shape[2:]), ssm_groups
        )
        if "tail" in params:
            h, tail_lcs = jax.lax.scan(inner, h, params["tail"])
            ssm_flat = jax.tree.map(
                lambda a, t: jnp.concatenate([a, t], axis=0), ssm_flat, tail_lcs
            )
        cache = HybridCache(
            ssm=SsmCache(
                ssm_flat["h"], ssm_flat["conv_x"], ssm_flat["conv_bc"], jnp.int32(s)
            ),
            kv=KVCache(kvs["k"], kvs["v"], jnp.int32(s)),
        )
    else:
        raise ValueError(cfg.family)

    h = rmsnorm(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    return unembed(params["embed"], h)[:, 0, :], cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(
    params: dict,
    token: jax.Array,
    cache,
    cfg: ModelConfig,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
):
    """One decode step.  token: (B,) int32.  Returns (logits (B, V), cache)."""
    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    h = embed(params["embed"], token[:, None], cfg.param_dtype())  # (B, 1, d)
    b = h.shape[0]
    idx = cache.index
    positions = jnp.broadcast_to(idx, (b, 1)).astype(jnp.int32)

    if cfg.family in ("dense", "moe", "vlm"):
        # The stacked cache rides the scan CARRY; the layer attends against a
        # read-only slice plus the current token as an explicit extra column,
        # then commits the single new position.  (Measured ALTERNATIVE —
        # commit-before-read so the mask covers the new token — was 1.4x
        # WORSE: the post-commit slice read materializes a fresh copy.  See
        # EXPERIMENTS.md §Perf, decode hillclimb, hypothesis log.)
        is_mla = cfg.attention == "mla"
        xs = (params["layers"], jnp.arange(cfg.num_layers))
        if not cfg.decode_cache_carry:
            # ys-rewrite path: per-layer cache slices flow through scan
            # xs -> ys (full rewrite per step).  Needed when the cache is
            # sequence-sharded over 'model' — the carried dynamic write into
            # the sharded dim degrades under the SPMD partitioner.
            if is_mla:
                lxs = (params["layers"],
                       {"c_kv": cache.c_kv, "k_rope": cache.k_rope})
            else:
                lxs = (params["layers"], {"k": cache.k, "v": cache.v})

            def body_ys(hh, x):
                lp, lc = x
                hh, nc, _ = _attn_block(lp, hh, cfg, positions, lc, idx, True, mesh)
                if is_mla:
                    # MLA layers always return new-position entries
                    nc = {
                        "c_kv": jax.lax.dynamic_update_slice(
                            lc["c_kv"], nc["c_kv"].astype(lc["c_kv"].dtype),
                            (0, idx, 0)),
                        "k_rope": jax.lax.dynamic_update_slice(
                            lc["k_rope"], nc["k_rope"].astype(lc["k_rope"].dtype),
                            (0, idx, 0)),
                    }
                # GQA layers with decode_cache_carry=False already committed
                # the position and returned the full updated slice.
                return hh, nc

            h, new_lc = jax.lax.scan(body_ys, h, lxs)
            if is_mla:
                new_cache = MlaCache(new_lc["c_kv"], new_lc["k_rope"], idx + 1)
            else:
                new_cache = KVCache(new_lc["k"], new_lc["v"], idx + 1)
        elif is_mla:
            def body(carry, x):
                hh, ckv, krp = carry
                lp, i = x
                lc = {
                    "c_kv": jax.lax.dynamic_index_in_dim(ckv, i, 0, False),
                    "k_rope": jax.lax.dynamic_index_in_dim(krp, i, 0, False),
                }
                hh, nc, _ = _attn_block(lp, hh, cfg, positions, lc, idx, True, mesh)
                ckv = jax.lax.dynamic_update_slice(
                    ckv, nc["c_kv"][None].astype(ckv.dtype), (i, 0, idx, 0)
                )
                krp = jax.lax.dynamic_update_slice(
                    krp, nc["k_rope"][None].astype(krp.dtype), (i, 0, idx, 0)
                )
                return (hh, ckv, krp), None

            (h, ckv, krp), _ = jax.lax.scan(body, (h, cache.c_kv, cache.k_rope), xs)
            new_cache = MlaCache(ckv, krp, idx + 1)
        else:
            def body(carry, x):
                hh, kc, vc = carry
                lp, i = x
                lc = {
                    "k": jax.lax.dynamic_index_in_dim(kc, i, 0, False),
                    "v": jax.lax.dynamic_index_in_dim(vc, i, 0, False),
                }
                hh, nc, _ = _attn_block(lp, hh, cfg, positions, lc, idx, True, mesh)
                kc = jax.lax.dynamic_update_slice(
                    kc, nc["k"][None].astype(kc.dtype), (i, 0, idx, 0, 0)
                )
                vc = jax.lax.dynamic_update_slice(
                    vc, nc["v"][None].astype(vc.dtype), (i, 0, idx, 0, 0)
                )
                return (hh, kc, vc), None

            (h, kc, vc), _ = jax.lax.scan(body, (h, cache.k, cache.v), xs)
            new_cache = KVCache(kc, vc, idx + 1)
    elif cfg.family == "ssm":
        def body(hh, x):
            lp, lc = x
            hh, nc = _ssm_block(lp, hh, cfg, lc, idx)
            return hh, nc

        h, new_lc = jax.lax.scan(
            body, h,
            (params["layers"],
             {"h": cache.h, "conv_x": cache.conv_x, "conv_bc": cache.conv_bc}),
        )
        new_cache = SsmCache(new_lc["h"], new_lc["conv_x"], new_lc["conv_bc"], idx + 1)
    elif cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.num_layers // period
        shared = params["shared"]
        ssm_lc = {
            "h": cache.ssm.h,
            "conv_x": cache.ssm.conv_x,
            "conv_bc": cache.ssm.conv_bc,
        }
        ssm_parts = jax.tree.map(
            lambda a: a[: n_groups * period].reshape(
                n_groups, period, *a.shape[1:]
            ),
            ssm_lc,
        )

        def inner(carry, x):
            hh = carry
            lp, lc = x
            hh, nc = _ssm_block(lp, hh, cfg, lc, idx)
            return hh, nc

        def group(carry, x):
            hh, kc, vc = carry
            glp, glc, g = x
            hh, ncs = jax.lax.scan(inner, hh, (glp, glc))
            kv_lc = {
                "k": jax.lax.dynamic_index_in_dim(kc, g, 0, False),
                "v": jax.lax.dynamic_index_in_dim(vc, g, 0, False),
            }
            hh, nkv, _ = _attn_block(shared, hh, cfg, positions, kv_lc, idx, True, mesh)
            kc = jax.lax.dynamic_update_slice(
                kc, nkv["k"][None].astype(kc.dtype), (g, 0, idx, 0, 0)
            )
            vc = jax.lax.dynamic_update_slice(
                vc, nkv["v"][None].astype(vc.dtype), (g, 0, idx, 0, 0)
            )
            return (hh, kc, vc), ncs

        (h, kc, vc), new_ssm_groups = jax.lax.scan(
            group, (h, cache.kv.k, cache.kv.v),
            (params["layers"], ssm_parts, jnp.arange(n_groups)),
        )
        new_ssm = jax.tree.map(
            lambda a: a.reshape(n_groups * period, *a.shape[2:]), new_ssm_groups
        )
        if "tail" in params:
            tail_lc = jax.tree.map(lambda a: a[n_groups * period :], ssm_lc)
            h, new_tail = jax.lax.scan(inner, h, (params["tail"], tail_lc))
            new_ssm = jax.tree.map(
                lambda a, t: jnp.concatenate([a, t], axis=0), new_ssm, new_tail
            )
        new_cache = HybridCache(
            ssm=SsmCache(
                new_ssm["h"], new_ssm["conv_x"], new_ssm["conv_bc"], idx + 1
            ),
            kv=KVCache(kc, vc, idx + 1),
        )
    else:
        raise ValueError(cfg.family)

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h)[:, 0, :]
    return logits, new_cache
