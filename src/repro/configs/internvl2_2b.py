"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
— InternViT + InternLM2 [arXiv:2404.16821; hf].

Backbone only per the assignment: the ViT frontend is a STUB —
``input_specs()`` supplies precomputed patch embeddings (B, num_patches,
d_model) that are prepended to the token embeddings.  Full attention ->
``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, register


@register("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        input_mode="tokens+patches",
        num_patches=256,
        decode_cache_carry=False,  # kv=8 cache sequence-shards over model
    )
