"""The paper's own BNN configurations (for the switch-chip pipeline).

These describe the fully-connected binary networks N2Net compiles, not the
LM architectures.  ``HEADLINE`` is the paper's closing example: "960 million
two-layers-BNNs per second, using 32b activations (e.g., the destination IP
address of the packet), and two layers of 64 and 32 neurons."
"""
from repro.core.bnn import BnnSpec

# Paper §2 Evaluation / §3 examples.
HEADLINE = BnnSpec((32, 64, 32))          # dst-IP classifier, 1 pipeline pass
SINGLE_NEURON_2048 = BnnSpec((2048, 1))   # Table 1 right edge: 25 elements
TABLE1_WIDTHS = (16, 32, 64, 128, 256, 512, 1024, 2048)

# A DoS white/blacklist-style classifier over a 104-bit 5-tuple
# (src ip, dst ip, src port, dst port, proto) padded to 128 bits.
FIVE_TUPLE = BnnSpec((128, 64, 32, 2))
