"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2D/partial RoPE, extreme GQA [arXiv:2406.12793; hf].
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, register


@register("chatglm3-6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        rotary_pct=0.5,  # GLM applies rotary to half the head dims (2D RoPE)
        decode_cache_carry=False,  # kv=2 cache sequence-shards over model
    )
