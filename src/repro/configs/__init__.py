from repro.configs.base import (
    MlaConfig,
    ModelConfig,
    MoeConfig,
    QuantConfig,
    SsmConfig,
    get_config,
    list_archs,
    register,
)

__all__ = [
    "MlaConfig",
    "ModelConfig",
    "MoeConfig",
    "QuantConfig",
    "SsmConfig",
    "get_config",
    "list_archs",
    "register",
]
