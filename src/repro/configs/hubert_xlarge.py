"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
— encoder-only, wav2vec2-style stack [arXiv:2106.07447; unverified].

The conv feature extractor is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S, d_model).  The 504-way output
head is the masked-prediction codebook.  Encoder-only -> no decode step:
``decode_32k`` and ``long_500k`` skipped.  GELU MLP (not gated), no RoPE
(HuBERT uses convolutional relative positions — absorbed into the stubbed
frontend embeddings).
"""
from repro.configs.base import ModelConfig, register


@register("hubert-xlarge")
def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        encoder_only=True,
        input_mode="frames",
        act="gelu",
        rotary_pct=0.0,
    )
