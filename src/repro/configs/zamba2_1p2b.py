"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  Shared transformer block (attention + MLP, one set
of weights) applied every ``hybrid_period`` SSM layers, Zamba-style.
Sub-quadratic majority -> runs ``long_500k``.
"""
from repro.configs.base import ModelConfig, SsmConfig, register


@register("zamba2-1.2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        ssm=SsmConfig(state_dim=64, head_dim=64, expand=2),
        hybrid_period=6,
        sub_quadratic=True,
        max_seq_len=524_288,
    )
