"""Imports every architecture config module to populate the registry."""
from repro.configs import (  # noqa: F401
    chatglm3_6b,
    deepseek_v2_236b,
    hubert_xlarge,
    internvl2_2b,
    mamba2_1p3b,
    minicpm3_4b,
    phi3_mini_3p8b,
    qwen3_moe_30b_a3b,
    stablelm_3b,
    zamba2_1p2b,
)

ASSIGNED_ARCHS = (
    "zamba2-1.2b",
    "stablelm-3b",
    "minicpm3-4b",
    "chatglm3-6b",
    "phi3-mini-3.8b",
    "internvl2-2b",
    "deepseek-v2-236b",
    "qwen3-moe-30b-a3b",
    "hubert-xlarge",
    "mamba2-1.3b",
)
