"""deepseek-v2-236b [moe]: 60L d_model=5120 128H vocab=102400 — MLA
kv_lora=512, MoE: 2 shared + 160 routed top-6, expert d_ff=1536
[arXiv:2405.04434; hf].

Notes vs the released model: every layer is MoE here (the release uses a
dense first layer) so the stack stays homogeneous and scannable — recorded
in DESIGN.md §8.  FSDP on: 236B params must shard over the data axis too.
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import MlaConfig, ModelConfig, MoeConfig, register


@register("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=1536,
        vocab_size=102400,
        attention="mla",
        mla=MlaConfig(
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_dim=128,
            qk_rope_dim=64,
            v_head_dim=128,
        ),
        moe=MoeConfig(
            num_experts=160,
            top_k=6,
            expert_ffn_dim=1536,
            num_shared=2,
        ),
        fsdp=True,
        microbatches=16,
        opt_half_moments=True,
        opt_master=False,
    )
