"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, register


@register("phi3-mini-3.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
    )
