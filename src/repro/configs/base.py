"""Model configuration schema + registry.

One frozen dataclass tree describes every assigned architecture; families
(dense / moe / ssm / hybrid / vlm / audio) select block wiring in
``repro.models``.  The paper's technique appears as ``QuantConfig`` — binary
(XNOR-popcount) projection layers, available to every architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """N2Net binary quantization of projection matrices.

    Modes:
      * ``bnn_weight_only`` / ``bnn_xnor`` — latent fp weights, binarized
        forward with STE (training-capable).
      * ``bnn_packed`` — inference-only: weights STORED as packed uint32 sign
        words (32 weights/word) + per-channel alpha; the contraction is the
        XNOR-popcount GEMM.  16x less weight HBM traffic than bf16 — the
        paper's memory-vs-compute trade on the TPU memory hierarchy.
    """

    mode: str = "none"   # none | bnn_weight_only | bnn_xnor | bnn_packed
    targets: tuple[str, ...] = ("ffn", "attn_proj")

    @property
    def enabled(self) -> bool:
        return self.mode != "none"

    @property
    def packed(self) -> bool:
        return self.mode == "bnn_packed"

    @property
    def scale(self) -> str:
        return {
            "bnn_weight_only": "weight_only",
            "bnn_xnor": "xnor",
            "bnn_packed": "xnor",
        }.get(self.mode, "none")


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    num_experts: int               # routed experts
    top_k: int
    expert_ffn_dim: int
    num_shared: int = 0            # always-on shared experts
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MlaConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 = direct q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    """Mamba2 / SSD block parameters."""

    state_dim: int = 128
    conv_width: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def num_ssm_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    max_seq_len: int = 32768
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0        # fraction of head_dim that rotates
    norm_eps: float = 1e-5
    act: str = "swiglu"            # swiglu | gelu
    attention: str = "gqa"         # gqa | mla | none
    mla: Optional[MlaConfig] = None
    moe: Optional[MoeConfig] = None
    ssm: Optional[SsmConfig] = None
    # hybrid (zamba2-style): shared attention block applied every N ssm layers
    hybrid_period: int = 0
    encoder_only: bool = False
    input_mode: str = "tokens"     # tokens | frames (audio stub) | tokens+patches
    num_patches: int = 0           # vlm: patch embeddings per sample
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    quant: QuantConfig = QuantConfig()
    fsdp: bool = False             # shard weights over the data axis too
    remat: bool = True
    attn_q_chunk: int = 1024       # chunked-attention query block
    attn_impl: str = "xla"         # xla | pallas_flash (fused online-softmax
                                   # Pallas kernel; TPU deploy path — runs in
                                   # interpret mode on CPU)
    attn_scores_dtype: str = "f32" # f32 | bf16 (halves score HBM traffic)
    decode_cache_carry: bool = True  # carry-resident decode cache (single-
                                   # position commits); False = ys-rewrite
                                   # path, required when the cache is
                                   # sequence-sharded (extreme GQA: kv heads
                                   # don't divide the model axis and the
                                   # partitioner mishandles dynamic writes
                                   # into the sharded sequence dim)
    ar_bf16: bool = False          # barrier block outputs so TP all-reduces
                                   # run in bf16 (XLA otherwise hoists the
                                   # f32 upcast above the all-reduce)
    sub_quadratic: bool = False    # may run long_500k
    # training
    init_std: float = 0.02
    microbatches: int = 8          # gradient-accumulation slices at train_4k
    opt_half_moments: bool = False # bf16 Adam moments (largest models)
    opt_master: bool = True        # keep f32 master copy of bf16 params

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_dtype(self):
        import jax.numpy as jnp

        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str, **overrides) -> ModelConfig:
    import repro.configs.archs  # noqa: F401  (populates the registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs() -> list[str]:
    import repro.configs.archs  # noqa: F401

    return sorted(_REGISTRY)
