"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, expert d_ff=768 [hf:Qwen/Qwen3-30B-A3B; hf].
head_dim=128 (explicit in the release).  Full attention -> ``long_500k``
skipped.  FSDP on (30B total params).
"""
from repro.configs.base import ModelConfig, MoeConfig, register


@register("qwen3-moe-30b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        moe=MoeConfig(
            num_experts=128,
            top_k=8,
            expert_ffn_dim=768,
            num_shared=0,
        ),
        fsdp=True,
        decode_cache_carry=False,  # kv=4 cache sequence-shards over model
    )
