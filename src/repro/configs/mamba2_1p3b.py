"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].
Attention-free -> runs all four shapes including ``long_500k``.
"""
from repro.configs.base import ModelConfig, SsmConfig, register


@register("mamba2-1.3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=1,        # unused (attention-free)
        num_kv_heads=1,
        head_dim=64,
        d_ff=0,             # no MLP: the Mamba2 block is the mixer
        vocab_size=50280,
        attention="none",
        ssm=SsmConfig(state_dim=128, head_dim=64, expand=2),
        sub_quadratic=True,
        max_seq_len=524_288,
    )
