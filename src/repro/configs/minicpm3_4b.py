"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
[hf:openbmb/MiniCPM3-4B; hf].  Multi-head Latent Attention with low-rank q
and compressed kv cache.  Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import MlaConfig, ModelConfig, register


@register("minicpm3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        attention="mla",
        mla=MlaConfig(
            kv_lora_rank=256,
            q_lora_rank=768,
            qk_nope_dim=64,
            qk_rope_dim=32,
            v_head_dim=64,
        ),
    )
