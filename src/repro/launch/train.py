"""Cluster training launcher.

On a real fleet this binary runs once per host under the cluster scheduler;
``jax.distributed.initialize`` wires the hosts together and the mesh spans
all devices.  In this container it runs single-process (the mesh comes from
``make_local_mesh``), exercising the identical code path.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
      --steps 100 --batch 8 --seq 128 [--reduced] [--quant bnn_weight_only]
"""
from __future__ import annotations

import argparse
import dataclasses
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="none")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    args = ap.parse_args()

    if args.distributed:
        import jax

        jax.distributed.initialize()

    import jax

    from repro.configs import get_config
    from repro.configs.base import QuantConfig
    from repro.launch.mesh import make_local_mesh
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        from examples.train_lm import reduced  # same recipe as the example

        cfg = reduced(cfg)
    if args.quant != "none":
        cfg = dataclasses.replace(cfg, quant=QuantConfig(mode=args.quant))

    mesh = make_local_mesh()
    tcfg = TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=max(10, args.steps // 4),
        checkpoint_dir=args.ckpt_dir,
        microbatches=args.microbatches,
        compression=args.compression,
        global_batch=args.batch,
        seq_len=args.seq,
    )
    trainer = Trainer(cfg, tcfg, mesh=mesh)
    out = trainer.run()
    print(f"done at step {out['final_step']}; recoveries={out['recoveries']}")


if __name__ == "__main__":
    main()
