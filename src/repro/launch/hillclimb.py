"""§Perf hillclimbing driver: run named optimization variants of the three
selected cells on the production pod mesh and log before/after roofline
terms.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  A. deepseek-v2-236b  train_4k   — worst roofline fraction & most
     collective-bound (FSDP gathers x microbatches dominate).
  B. chatglm3-6b       train_4k   — collective-bound dense TP (f32
     all-reduces), plus the attention-score memory term.
  C. phi3-mini-3.8b    decode_32k — most representative of the paper's
     technique: N2Net packed-weight (XNOR-popcount) inference.

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [--only A1,B2,...]
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("N2NET_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

import argparse  # noqa: E402
import json      # noqa: E402

from repro.configs.base import QuantConfig  # noqa: E402
from repro.launch.dryrun import run_cell    # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

EXPERIMENTS = {
    # --- cell A: deepseek train (collective-bound: FSDP gathers + f32 AR) ---
    "A1": ("deepseek-v2-236b", "train_4k", {"ar_bf16": True}, "+arbf16"),
    "A2": ("deepseek-v2-236b", "train_4k",
           {"ar_bf16": True, "microbatches": 8}, "+arbf16+mb8"),
    "A3": ("deepseek-v2-236b", "train_4k",
           {"ar_bf16": True, "microbatches": 8, "attn_scores_dtype": "bf16"},
           "+arbf16+mb8+sbf16"),
    # --- cell B: chatglm train (f32 AR + score traffic) ---
    "B1": ("chatglm3-6b", "train_4k", {"ar_bf16": True}, "+arbf16"),
    "B2": ("chatglm3-6b", "train_4k",
           {"ar_bf16": True, "attn_scores_dtype": "bf16"}, "+arbf16+sbf16"),
    "B3": ("chatglm3-6b", "train_4k",
           {"ar_bf16": True, "attn_scores_dtype": "bf16", "microbatches": 4},
           "+arbf16+sbf16+mb4"),
    # --- cell C: phi3 decode (the paper's technique: packed BNN weights) ---
    "C1": ("phi3-mini-3.8b", "decode_32k",
           {"quant": QuantConfig(mode="bnn_packed",
                                 targets=("ffn", "attn_proj"))}, "+bnnpacked"),
    "C2": ("phi3-mini-3.8b", "prefill_32k",
           {"quant": QuantConfig(mode="bnn_packed",
                                 targets=("ffn", "attn_proj"))}, "+bnnpacked"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()
    names = list(EXPERIMENTS) if args.only == "all" else args.only.split(",")

    mesh = make_production_mesh(multi_pod=False)
    for name in names:
        arch, shape, overrides, tag = EXPERIMENTS[name]
        base = json.load(open(f"experiments/dryrun/{arch}_{shape}_pod.json"))
        rec = run_cell(arch, shape, mesh, "pod", args.out, overrides, tag)
        if rec["status"] != "ok":
            print(f"[{name}] ERROR: {rec.get('error')}", flush=True)
            continue
        b, r = base["roofline"], rec["roofline"]
        print(
            f"[{name}] {arch} {shape} {tag}\n"
            f"  compute    {b['compute_s']:.4g} -> {r['compute_s']:.4g}\n"
            f"  memory     {b['memory_s']:.4g} -> {r['memory_s']:.4g}\n"
            f"  collective {b['collective_s']:.4g} -> {r['collective_s']:.4g}\n"
            f"  step(max)  {b['step_time_s']:.4g} -> {r['step_time_s']:.4g} "
            f"({b['step_time_s']/max(r['step_time_s'],1e-12):.2f}x)\n"
            f"  roofline_frac {b['roofline_fraction']:.4f} -> "
            f"{r['roofline_fraction']:.4f}",
            flush=True,
        )


if __name__ == "__main__":
    main()
