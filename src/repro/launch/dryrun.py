"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the host-device override before any other import touches jax —
jax locks the device count on first backend init.
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("N2NET_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro import sharding  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.archs import ASSIGNED_ARCHS  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import shapes as shp  # noqa: E402
from repro.models import decode_step, init_params, prefill  # noqa: E402
from repro.optim.adamw import AdamW  # noqa: E402
from repro.roofline import analysis, hlo  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402


def count_params(cfg, params_sds) -> tuple[float, float]:
    """(total, active) parameter counts; MoE routed experts scale by k/E."""
    total = active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_sds)[0]:
        ps = "/".join(str(getattr(p, "key", "")) for p in path)
        n = float(np.prod(leaf.shape))
        if re.search(r"(/|_)packed$", ps):
            n *= 32.0  # packed sign words: 32 logical weights per uint32
        if ps.endswith("/alpha"):
            continue   # scales, not weights
        total += n
        if cfg.moe and re.search(r"moe/w_(gate|up|down)", ps):
            active += n * cfg.moe.top_k / cfg.moe.num_experts
        else:
            active += n
    return total, active


def _opt_specs(param_specs_tree, opt_state_sds):
    """Optimizer-state specs mirror param specs; empty placeholders replicate."""
    from jax.sharding import PartitionSpec as P

    def fix(spec, leaf):
        return P() if leaf.ndim <= 1 and leaf.shape in ((), (0,)) else spec

    m = jax.tree.map(fix, param_specs_tree, opt_state_sds.m)
    v = jax.tree.map(fix, param_specs_tree, opt_state_sds.v)
    master = jax.tree.map(fix, param_specs_tree, opt_state_sds.master)
    from repro.optim.adamw import AdamWState

    return AdamWState(P(), m, v, master)


def build_cell(cfg, shape: shp.Shape, mesh):
    """-> (fn, args_sds tuple, in_specs tree, out_specs_or_None, donate)"""
    from jax.sharding import PartitionSpec as P

    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    slog: list = []
    pspecs = sharding.param_specs(cfg, params_sds, mesh, log=slog)

    if shape.kind == "train":
        dp = 1
        for a in sharding.dp_axes(mesh):
            dp *= mesh.shape[a]
        mb = shp.microbatches_for(cfg, shape, dp)
        opt = AdamW(
            moment_dtype=jnp.bfloat16 if cfg.opt_half_moments else jnp.float32,
            use_master=cfg.opt_master,
        )
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = _opt_specs(pspecs, opt_sds)
        batch = shp.train_inputs(cfg, shape)
        bspecs = sharding.batch_specs(cfg, batch, mesh)
        fn = make_train_step(cfg, opt, mesh=mesh, microbatches=mb)
        in_specs = (pspecs, ospecs, bspecs)
        out_specs = (pspecs, ospecs, None)
        return fn, (params_sds, opt_sds, batch), in_specs, out_specs, (0, 1), slog, mb

    if shape.kind == "prefill":
        batch = shp.prefill_inputs(cfg, shape)
        bspecs = sharding.batch_specs(cfg, batch, mesh)

        if cfg.encoder_only:
            from repro.models import forward

            fn = lambda p, b: forward(p, b, cfg, mesh=mesh, remat=False)  # noqa: E731
        else:
            fn = lambda p, b: prefill(p, b, cfg, mesh=mesh)  # noqa: E731
        return fn, (params_sds, batch), (pspecs, bspecs), None, (), slog, 1

    # decode
    token, cache_sds = shp.decode_inputs(cfg, shape)
    cspecs = sharding.cache_specs(cfg, cache_sds, mesh)
    tspec = sharding.batch_specs(cfg, token, mesh)
    fn = lambda p, t, c: decode_step(p, t, c, cfg, mesh=mesh)  # noqa: E731
    in_specs = (pspecs, tspec, cspecs)
    out_specs = (None, cspecs)
    return fn, (params_sds, token, cache_sds), in_specs, out_specs, (2,), slog, 1


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, out_dir: str,
             overrides: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch, **(overrides or {}))
    shape = shp.SHAPES[shape_name]
    ok, reason = shp.runnable(cfg, shape)
    cell_id = f"{arch}{tag}_{shape_name}_{mesh_name}"
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": reason}
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{cell_id}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    fn, args, in_specs, out_specs, donate, slog, mb = build_cell(cfg, shape, mesh)
    named_in = sharding.to_named(in_specs, mesh)
    kwargs = dict(in_shardings=named_in)
    if out_specs is not None:
        kwargs["out_shardings"] = sharding.to_named(out_specs, mesh)
    if donate:
        kwargs["donate_argnums"] = donate

    with mesh:
        lowered = jax.jit(fn, **kwargs).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):  # older jax: one dict per program
        xla_cost = xla_cost[0] if xla_cost else {}
    text = compiled.as_text()
    costs = hlo.analyze(text)

    params_sds = args[0]
    n_total, n_active = count_params(cfg, params_sds)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops = analysis.model_flops_estimate(n_active, tokens, shape.kind)

    per_dev_bytes = float(mem.argument_size_in_bytes + mem.temp_size_in_bytes)
    roof = analysis.build(
        arch=arch + tag,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=int(np.prod(list(mesh.shape.values()))),
        costs=costs,
        model_flops=model_flops,
        per_device_hbm_bytes=per_dev_bytes,
        xla_cost_flops=float(xla_cost.get("flops", 0.0)),
    )

    record = {
        "cell": cell_id,
        "status": "ok",
        "microbatches": mb,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_params": n_total,
        "n_active_params": n_active,
        "memory": {
            "argument_bytes_per_dev": int(mem.argument_size_in_bytes),
            "temp_bytes_per_dev": int(mem.temp_size_in_bytes),
            "output_bytes_per_dev": int(mem.output_size_in_bytes),
            "peak_bytes_per_dev": int(getattr(mem, "peak_memory_in_bytes", 0)),
            "fits_16GiB": per_dev_bytes < 16 * 2**30,
        },
        "roofline": roof.row(),
        "collective_counts": costs.collective_counts,
        "sharding_log": slog[:40],
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{cell_id}.json"), "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main():
    ap = argparse.ArgumentParser(description="N2Net framework multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--quant", default="none", help="bnn quant mode override")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(shp.SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"pod": False, "multipod": True}
    if args.mesh != "both":
        meshes = {args.mesh: meshes[args.mesh]}

    overrides = {}
    tag = ""
    if args.quant != "none":
        from repro.configs.base import QuantConfig

        overrides["quant"] = QuantConfig(mode=args.quant)
        tag = f"+{args.quant}"

    results = []
    for mesh_name, multi in meshes.items():
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape_name in shapes:
                cell = f"{arch}{tag}_{shape_name}_{mesh_name}"
                path = os.path.join(args.out, cell + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {cell}")
                    continue
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name, args.out,
                                   overrides, tag)
                except Exception as e:  # noqa: BLE001 — report, keep sweeping
                    rec = {"cell": cell, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    os.makedirs(args.out, exist_ok=True)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                results.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f"compile={rec['compile_s']}s "
                        f"peak={rec['memory']['peak_bytes_per_dev']/2**30:.2f}GiB "
                        f"bottleneck={r['bottleneck']} "
                        f"roofline_frac={r['roofline_fraction']:.3f}"
                    )
                elif status == "skipped":
                    extra = rec["reason"]
                else:
                    extra = rec["error"]
                print(f"[{status}] {cell} {extra}", flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
