"""Assigned input shapes and per-(arch × shape) input specs.

Shapes (LM-family, per the assignment):
  train_4k    — seq 4096,    global batch 256 (training step)
  prefill_32k — seq 32768,   global batch 32  (inference prefill)
  decode_32k  — seq 32768,   global batch 128 (one token vs 32k cache)
  long_500k   — seq 524288,  global batch 1   (long-context decode)

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token with a KV/SSM
cache of seq_len), not ``train_step``.  Skips (recorded in DESIGN.md
§Arch-applicability): ``long_500k`` for pure full-attention archs;
``decode_32k``/``long_500k`` for encoder-only archs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import init_cache


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def runnable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs, and the reason when skipped."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention (full-attn arch)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_inputs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStruct stand-ins for a training batch."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "frames":
        return {
            "frames": _sds((b, s, cfg.d_model), cfg.param_dtype()),
            "labels": _sds((b, s), jnp.int32),
        }
    if cfg.input_mode == "tokens+patches":
        st = s - cfg.num_patches
        return {
            "tokens": _sds((b, st), jnp.int32),
            "patches": _sds((b, cfg.num_patches, cfg.d_model), cfg.param_dtype()),
            "labels": _sds((b, st), jnp.int32),
        }
    return {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }


def prefill_inputs(cfg: ModelConfig, shape: Shape) -> dict:
    batch = train_inputs(cfg, shape)
    batch.pop("labels", None)
    return batch


def decode_inputs(cfg: ModelConfig, shape: Shape) -> tuple:
    """(token, cache) stand-ins for a decode step."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    token = _sds((b,), jnp.int32)
    return token, cache


def microbatches_for(cfg: ModelConfig, shape: Shape, dp_size: int) -> int:
    """Gradient-accumulation factor: as many microbatches as the batch allows
    without dropping below one sequence per data shard."""
    if shape.kind != "train":
        return 1
    want = getattr(cfg, "microbatches", 1) or 1
    return max(1, min(want, shape.global_batch // max(1, dp_size)))
