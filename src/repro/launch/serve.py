"""Serving launcher: stand up an Engine for an architecture and drain a
synthetic request stream (the cluster-facing sibling of
examples/serve_batched.py).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import Engine, Request

    cfg = get_config(args.arch)
    if args.reduced:
        from examples.serve_batched import small

        cfg = small(cfg)

    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(4, 24))).astype(np.int32),
            max_new_tokens=args.new_tokens,
        ))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s")


if __name__ == "__main__":
    main()
