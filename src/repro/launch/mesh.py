"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod slice).
Multi-pod: (pod=2, data=16, model=16) = 512 chips — the ``pod`` axis carries
pure data parallelism (only gradient all-reduce crosses pod boundaries, the
slowest links).  Defined as functions so importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """Degenerate mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = 1
    for m in (8, 4, 2):
        if n % m == 0 and n >= m:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
