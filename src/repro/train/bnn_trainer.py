"""Batched straight-through-estimator trainer for deployable BNNs.

The train half of the train->deploy loop.  Latent float weights are trained
with a jit-compiled STE loop whose forward pass is, *by construction*,
bit-for-bit the deployed network: hidden activations are hard signs with the
oracle's tie rule (pre-activation 0 -> +1), weights binarize with
``bnn.binarize_ste`` (latent >= 0 -> +1), and pre-activations are exact small
integers in float32 — so :meth:`BnnTrainer.forward_bits` at any training step
equals ``bnn.forward`` on the would-be exported bit matrices, and therefore
equals the compiled pipeline, the fused executor, and the switch fabric
(:func:`repro.core.export.verify_roundtrip` proves the whole chain).

The training task is in-network traffic classification, generated from the
dataplane's scenario library (:func:`make_traffic_task`): each class is one
``dataplane.traffic`` scenario, the packet's header bits are the BNN input,
and the network's single output bit is the classification the switch would
act on (drop/mirror/mark).  Scaling beyond one output bit means one-vs-all
heads; the deployed artifact acts on bits, so the trainer keeps the deploy
semantics honest by training exactly what the switch executes.

Real traces fit the same mold: :func:`make_capture_task` temporal-splits any
labeled activation-bit trace — e.g. a pcap capture featurized by
``dataplane.pcap.featurize`` and labeled through ``dataplane.pcap
.label_packets`` — into the trainer's task tuple, and
:class:`BnnTrainer` accepts that tuple via its ``task`` argument in place
of the synthetic-scenario default (``examples/pcap_replay.py`` closes the
loop capture -> train -> switch).

Checkpointing follows ``train/trainer.py`` conventions: atomic
``train.checkpoint`` bundles of ``{"latent", "opt"}`` plus step extras, with
restore-latest resume.  Batch order is ``(seed, step)``-deterministic, so a
resumed run replays the interrupted one bit-consistently.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bnn
from repro.core.bnn import BnnSpec, binarize_ste
from repro.core.export import ExportedModel, bit_weights_from_latent, export_latent
from repro.core.pipeline import RMT, ChipSpec
from repro.dataplane import traffic
from repro.optim.adamw import AdamW
from repro.train import checkpoint as ckpt


# Activations binarize through the same primitive as weights
# (``bnn.binarize_ste``): hard sign with the oracle's tie rule (0 -> +1)
# and a |u| <= 1 pass-through gate.  Pre-activations are normalized to
# roughly unit scale before the sign so the gate bites.


def init_latent(spec: BnnSpec, key: jax.Array) -> list[jax.Array]:
    """Uniform(-1, 1) latent weights — balanced signs, full STE gradient."""
    latent = []
    for i in range(spec.num_layers):
        key, sub = jax.random.split(key)
        shape = (spec.layer_sizes[i + 1], spec.layer_sizes[i])
        latent.append(jax.random.uniform(sub, shape, jnp.float32, -1.0, 1.0))
    return latent


def forward_logits(latent: Sequence[jax.Array], x_pm1: jax.Array) -> jax.Array:
    """STE forward pass on ±1 activations; returns scaled final pre-acts.

    Each layer's pre-activation is divided by ``sqrt(fan_in)`` (unit variance
    for random ±1 operands) *before* the sign — positive scaling never moves
    a sign, so the binarized trajectory is untouched while the STE gate and
    the loss see well-scaled values.
    """
    h = x_pm1
    for w in latent[:-1]:
        pre = h @ binarize_ste(w).T
        h = binarize_ste(pre / np.sqrt(w.shape[1]))
    w = latent[-1]
    return (h @ binarize_ste(w).T) / np.sqrt(w.shape[1])


def forward_bits(latent: Sequence[jax.Array], x_bits: jax.Array) -> jax.Array:
    """{0,1} outputs of the *deployed* network at the current latent state.

    Bit-exact with ``bnn.forward(bit_weights_from_latent(latent), x_bits)``:
    pre-activations are sums of ±1 terms, exact in float32, and the positive
    per-layer scaling cannot flip a sign or perturb a zero tie.
    """
    x_pm1 = (2 * x_bits.astype(jnp.float32)) - 1.0
    return (forward_logits(latent, x_pm1) >= 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Task generation
# ---------------------------------------------------------------------------

def make_traffic_task(
    scenarios: Sequence[str],
    n_per_class: int,
    input_bits: int,
    seed: int = 0,
    eval_per_class: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """A labeled classification task from dataplane traffic scenarios.

    Class ``i``'s packets are drawn from ``scenarios[i]``.  The split is
    *temporal*, as a real capture-then-deploy pipeline would be: one trace
    per class, the first ``n_per_class`` packets train, the last
    ``eval_per_class`` are held out — unseen packets (sensor walks continue,
    bursts re-jitter) from the same traffic worlds the model deploys into.

    Returns shuffled ``(train_x, train_y, eval_x, eval_y)``; eval arrays are
    empty when ``eval_per_class == 0``.  Packets are (n, input_bits) int32
    {0,1}, labels (n,) int32 class indices.
    """
    tr_x, tr_y, ev_x, ev_y = [], [], [], []
    for i, name in enumerate(scenarios):
        trace = traffic.generate(
            name, n_per_class + eval_per_class, input_bits, seed=seed + i
        )
        tr_x.append(trace[:n_per_class])
        tr_y.append(np.full(n_per_class, i, np.int32))
        ev_x.append(trace[n_per_class:])
        ev_y.append(np.full(eval_per_class, i, np.int32))

    def shuffle(xs, ys, salt):
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        perm = np.random.default_rng((seed, salt)).permutation(x.shape[0])
        return x[perm], y[perm]

    train = shuffle(tr_x, tr_y, 0)
    held = shuffle(ev_x, ev_y, 1)
    return train[0], train[1], held[0], held[1]


def make_capture_task(
    bits: np.ndarray,
    labels: np.ndarray,
    *,
    train_frac: float = 0.8,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """A trainer task from one labeled packet trace (e.g. a featurized pcap).

    The split is *temporal*, matching :func:`make_traffic_task` and real
    capture-then-deploy practice: the first ``train_frac`` of the trace (in
    arrival order) trains — shuffled for SGD — and the rest is held out in
    arrival order, so evaluation replays the unseen tail of the capture.

    ``bits`` is ``(n, input_bits)`` int {0,1} (``dataplane.pcap.featurize``
    output), ``labels`` ``(n,)`` binary ints (``dataplane.pcap
    .label_packets`` output, or ground truth).  Returns ``(train_x,
    train_y, eval_x, eval_y)`` for :class:`BnnTrainer`'s ``task`` argument.
    """
    bits = np.asarray(bits, np.int32)
    labels = np.asarray(labels, np.int32)
    if bits.ndim != 2 or labels.shape != (bits.shape[0],):
        raise ValueError(
            f"need (n, input_bits) bits and (n,) labels, got {bits.shape} "
            f"and {labels.shape}"
        )
    if not np.isin(bits, (0, 1)).all():
        raise ValueError("bits must be {0,1}")
    if not np.isin(labels, (0, 1)).all():
        raise ValueError(
            "labels must be binary {0,1}; the deployed switch acts on the "
            "single output bit"
        )
    if not 0.0 < train_frac < 1.0:
        raise ValueError(f"train_frac must be in (0, 1), got {train_frac}")
    k = int(round(train_frac * bits.shape[0]))
    if k == 0 or k == bits.shape[0]:
        raise ValueError(
            f"trace of {bits.shape[0]} packets leaves an empty split at "
            f"train_frac={train_frac}"
        )
    perm = np.random.default_rng((seed, 2)).permutation(k)
    return bits[:k][perm], labels[:k][perm], bits[k:], labels[k:]


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BnnTrainConfig:
    """Defaults train the example's headline task in a few CPU seconds."""

    layer_sizes: tuple[int, ...] = (32, 128, 64, 1)
    scenarios: tuple[str, ...] = ("iot_telemetry", "ddos_burst")
    steps: int = 600
    batch: int = 512
    train_packets_per_class: int = 8192
    eval_packets_per_class: int = 5000
    lr: float = 0.02
    seed: int = 0
    checkpoint_dir: str | None = None
    checkpoint_every: int = 200
    log_every: int = 50

    def __post_init__(self):
        if len(self.scenarios) != 2:
            raise ValueError(
                "binary classification only: exactly 2 scenarios "
                f"(got {len(self.scenarios)}); the deployed switch acts on "
                "the single output bit"
            )
        if self.layer_sizes[-1] != 1:
            raise ValueError(
                f"final layer must be 1 neuron (the class bit), got "
                f"{self.layer_sizes[-1]}"
            )
        for name in self.scenarios:
            traffic.get_scenario(name)  # fail fast on typos


class BnnTrainer:
    """Train a BNN on traffic, then export it into the dataplane fabric.

    By default the task is synthesized from ``cfg.scenarios`` via
    :func:`make_traffic_task`; pass ``task`` (a ``(train_x, train_y,
    eval_x, eval_y)`` tuple, e.g. from :func:`make_capture_task` over a
    featurized pcap) to train on an external trace instead.
    """

    def __init__(self, cfg: BnnTrainConfig, task=None):
        self.cfg = cfg
        self.spec = BnnSpec(cfg.layer_sizes)
        self.latent = init_latent(self.spec, jax.random.PRNGKey(cfg.seed))
        # Latent weights live in [-1, 1]; decay pulls them toward the 0
        # binarization boundary, so it is off.
        self.optimizer = AdamW(lr=cfg.lr, weight_decay=0.0, use_master=False)
        self.opt_state = self.optimizer.init(self.latent)
        self.step = 0
        self.history: list[dict] = []
        if task is None:
            task = make_traffic_task(
                cfg.scenarios,
                cfg.train_packets_per_class,
                self.spec.input_bits,
                seed=cfg.seed,
                eval_per_class=cfg.eval_packets_per_class,
            )
        else:
            if len(task) != 4:
                raise ValueError(
                    "task must be (train_x, train_y, eval_x, eval_y), got "
                    f"{len(task)} items"
                )
            task = tuple(np.asarray(a) for a in task)
            for xs, ys, part in (
                (task[0], task[1], "train"),
                (task[2], task[3], "eval"),
            ):
                if xs.ndim != 2 or xs.shape[1] != self.spec.input_bits:
                    raise ValueError(
                        f"task {part}_x must be (n, {self.spec.input_bits}) "
                        f"to match layer_sizes {cfg.layer_sizes}, got "
                        f"{xs.shape}"
                    )
                if ys.shape != (xs.shape[0],):
                    raise ValueError(
                        f"task {part}_y shape {ys.shape} does not match "
                        f"{part}_x's {xs.shape[0]} packets"
                    )
        (self._train_x, self._train_y, self.eval_x, self.eval_y) = task
        self._step_fn = jax.jit(self._train_step)
        self._bits_fn = jax.jit(forward_bits)

    # -- internals ----------------------------------------------------------

    def _train_step(self, latent, opt_state, x_pm1, y):
        def loss_fn(lat):
            logits = forward_logits(lat, x_pm1)[:, 0]
            margin = (2.0 * y - 1.0) * logits
            loss = jnp.mean(jax.nn.softplus(-margin))  # BCE with logits
            acc = jnp.mean(((logits >= 0) == (y == 1)).astype(jnp.float32))
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(latent)
        latent, opt_state, om = self.optimizer.update(grads, opt_state, latent)
        # BinaryNet clip: keeps latents where the STE weight gate has signal.
        latent = [jnp.clip(w, -1.0, 1.0) for w in latent]
        return latent, opt_state, {"loss": loss, "accuracy": acc, **om}

    def _batch(self, step: int) -> tuple[jax.Array, jax.Array]:
        idx = np.random.default_rng((self.cfg.seed, step)).integers(
            0, self._train_x.shape[0], self.cfg.batch
        )
        x_pm1 = 2.0 * self._train_x[idx].astype(np.float32) - 1.0
        return jnp.asarray(x_pm1), jnp.asarray(self._train_y[idx].astype(np.float32))

    def _save(self) -> None:
        if self.cfg.checkpoint_dir:
            ckpt.save(
                self.cfg.checkpoint_dir,
                self.step,
                {"latent": self.latent, "opt": self.opt_state},
                {"step": self.step},
            )

    def _restore(self) -> bool:
        if not self.cfg.checkpoint_dir:
            return False
        like = {"latent": self.latent, "opt": self.opt_state}
        got = ckpt.restore_latest(self.cfg.checkpoint_dir, like)
        if got is None:
            return False
        bundle, step, extras = got
        self.latent = [jnp.asarray(w) for w in bundle["latent"]]
        self.opt_state = jax.tree_util.tree_map(jnp.asarray, bundle["opt"])
        self.step = int(extras.get("step", step))
        return True

    # -- public -------------------------------------------------------------

    def train(self) -> dict:
        """Run to ``cfg.steps`` (resuming from a checkpoint if one exists).

        Instrumented through ``repro.obs``: a ``compile:train_step`` span
        around the first (jit-tracing) step, per-step latency into the
        ``train.step_seconds`` histogram, and loss/accuracy gauges at log
        points — all no-ops while observability is off.
        """
        resumed = self._restore()
        start_step = self.step
        observing = obs.enabled()
        first = True
        t0 = time.perf_counter()
        with obs.span(
            "stream:train_run", cat="stream",
            start_step=start_step, steps=self.cfg.steps,
        ):
            while self.step < self.cfg.steps:
                x, y = self._batch(self.step)
                with obs.span(
                    "compile:train_step" if first else "execute:train_step",
                    cat="compile" if first else "execute",
                    step=self.step,
                ):
                    s0 = time.perf_counter()
                    self.latent, self.opt_state, metrics = self._step_fn(
                        self.latent, self.opt_state, x, y
                    )
                    if observing:
                        jax.block_until_ready(self.latent)
                        step_dt = time.perf_counter() - s0
                self.step += 1
                if observing:
                    m = obs.registry()
                    m.counter("train.steps_total").inc()
                    if first:
                        m.histogram("train.compile_seconds").observe(step_dt)
                    else:
                        m.histogram("train.step_seconds").observe(step_dt)
                first = False
                if (
                    self.step % self.cfg.log_every == 0
                    or self.step == 1
                    or self.step == self.cfg.steps
                ):
                    self.history.append(
                        {
                            "step": self.step,
                            **{k: float(v) for k, v in metrics.items()},
                        }
                    )
                    if observing:
                        m = obs.registry()
                        m.gauge("train.loss").set(float(metrics["loss"]))
                        m.gauge("train.accuracy").set(
                            float(metrics["accuracy"])
                        )
                if (
                    self.cfg.checkpoint_every
                    and self.step % self.cfg.checkpoint_every == 0
                ):
                    self._save()
            jax.block_until_ready(self.latent)
        seconds = time.perf_counter() - t0
        self._save()
        ran = self.step - start_step
        return {
            "final_step": self.step,
            "resumed": resumed,
            "seconds": seconds,
            "steps_per_second": ran / seconds if seconds > 0 else float("inf"),
            "history": self.history,
        }

    def forward_bits(self, x_bits) -> np.ndarray:
        """Deployed-network outputs of the current latent state (train-time
        witness for the export round-trip)."""
        return np.asarray(self._bits_fn(self.latent, jnp.asarray(x_bits)))

    def evaluate(self, x_bits, y) -> dict:
        """Accuracy of the deployed (binarized) network on labeled packets."""
        with obs.span(
            "execute:train_eval", cat="execute",
            packets=int(np.asarray(y).shape[0]),
        ):
            t0 = time.perf_counter()
            bits = self.forward_bits(x_bits)[:, 0]
            dt = time.perf_counter() - t0
        acc = float((bits == np.asarray(y)).mean())
        if obs.enabled():
            m = obs.registry()
            m.histogram("train.eval_seconds").observe(dt)
            m.gauge("train.eval_accuracy").set(acc)
        return {"accuracy": acc, "packets": int(np.asarray(y).shape[0])}

    def evaluate_held_out(self) -> dict:
        """Accuracy on the temporal held-out split (unseen packets from the
        training traffic worlds — the deploy-time distribution)."""
        return self.evaluate(self.eval_x, self.eval_y)

    def export(self, chip: ChipSpec = RMT) -> ExportedModel:
        """Round latents to bits and compile into the dataplane (deploy)."""
        return export_latent(self.latent, chip)

    def oracle_bits(self, x_bits) -> np.ndarray:
        """Oracle predictions on the exported bit matrices (sanity hook)."""
        weights = [jnp.asarray(w) for w in bit_weights_from_latent(self.latent)]
        return np.asarray(bnn.forward(weights, jnp.asarray(x_bits)))
