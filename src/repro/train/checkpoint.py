"""Sharded, atomic, restartable checkpoints (no orbax dependency).

Layout::

    <dir>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, crc32s, extras
        arr_00000.npy ...      # one file per leaf (host's shard in multihost)
    <dir>/LATEST               # committed pointer, written atomically last

Commit protocol: write into ``step_N.tmp``, fsync files, rename to
``step_N``, then atomically replace ``LATEST``.  A crash at any point leaves
either the previous checkpoint (pointer not swapped) or a complete new one —
never a half state.  ``restore_latest`` validates the manifest (presence +
crc32) and falls back to older steps if the newest is corrupt, which is the
node-failure recovery path exercised by the fault-tolerance test.

In a true multi-host deployment each host writes only the leaves it owns
(addressable shards) under ``host_<k>/``; this container is single-process,
so host 0 owns everything — the protocol is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, extras: dict | None = None) -> str:
    """Atomically persist a pytree (params/opt/data-state bundle)."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "extras": extras or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16, ...) -> uint view
            arr = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize])
        fname = f"arr_{i:05d}.npy"
        path = os.path.join(tmp, fname)
        np.save(path, arr)
        with open(path, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": logical_dtype,
             "crc32": crc}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def _validate(path: str) -> dict | None:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return None
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for entry in manifest["leaves"]:
            fpath = os.path.join(path, entry["file"])
            with open(fpath, "rb") as f:
                if zlib.crc32(f.read()) != entry["crc32"]:
                    return None
        return manifest
    except Exception:  # noqa: BLE001 — any corruption means invalid
        return None


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def restore_latest(directory: str, like: Any):
    """-> (tree, step, extras) from the newest VALID checkpoint, or None.

    ``like`` provides the tree structure (e.g. freshly-initialized state);
    leaf dtypes/shapes are validated against the manifest.
    """
    candidates = available_steps(directory)
    # prefer the committed pointer, fall back through history on corruption
    latest_path = os.path.join(directory, "LATEST")
    order: list[int] = []
    if os.path.exists(latest_path):
        with open(latest_path) as f:
            name = f.read().strip()
        try:
            order.append(int(name.split("_")[1]))
        except (IndexError, ValueError):
            pass
    order += [s for s in reversed(candidates) if s not in order]

    leaves_like, treedef = _flatten(like)
    for step in order:
        path = os.path.join(directory, f"step_{step:08d}")
        manifest = _validate(path)
        if manifest is None:
            continue
        if len(manifest["leaves"]) != len(leaves_like):
            continue
        leaves = []
        for e in manifest["leaves"]:
            arr = np.load(os.path.join(path, e["file"]))
            want = np.dtype(e["dtype"])  # ml_dtypes names resolve via jax import
            if arr.dtype != want:
                arr = arr.view(want)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["step"], manifest.get("extras", {})
    return None
