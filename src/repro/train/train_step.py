"""Training step: loss, gradient accumulation (microbatching), optimizer.

Microbatching splits the global batch into ``k`` sequential slices inside a
``lax.scan`` and accumulates gradients in float32 — the activation working
set shrinks k-fold (this is what fits the 236B-parameter cell into HBM), and
the deferred all-reduce of the accumulated gradient overlaps with the next
step's compute under XLA's async collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.optim.adamw import AdamW


def loss_fn(params, batch: dict, cfg: ModelConfig, mesh=None):
    """Next-token (or masked-frame) cross entropy.  labels < 0 are masked."""
    logits, aux = forward(params, batch, cfg, mesh=mesh)
    labels = batch["labels"]
    if cfg.input_mode == "tokens+patches":
        logits = logits[:, cfg.num_patches :, :]  # text positions only
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    token_loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return token_loss + aux, {"loss": token_loss, "aux": aux}


def _accumulate_grads(params, batch, cfg: ModelConfig, mesh, microbatches: int):
    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, b, cfg, mesh=mesh), has_aux=True
    )
    if microbatches <= 1:
        (_, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def slice_mb(leaf):
        b = leaf.shape[0]
        out = leaf.reshape(microbatches, b // microbatches, *leaf.shape[1:])
        if mesh is not None:
            # Pin the *batch* dim (1) to the data axes: without this, XLA is
            # free to shard the microbatch dim (0) over data instead, which
            # makes every device compute the FULL microbatch (observed 4x
            # flops).  The microbatch axis is sequential by construction.
            from jax.sharding import NamedSharding, PartitionSpec as P

            dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            dp_size = 1
            for a in dp:
                dp_size *= mesh.shape[a]
            if (b // microbatches) % max(1, dp_size) == 0:
                spec = P(None, dp, *([None] * (leaf.ndim - 1)))
                out = jax.lax.with_sharding_constraint(
                    out, NamedSharding(mesh, spec)
                )
        return out

    mb_batch = jax.tree.map(slice_mb, batch)

    def body(carry, mb):
        acc, metrics_acc = carry
        (_, metrics), grads = grad_fn(params, mb)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        metrics_acc = jax.tree.map(lambda a, m: a + m, metrics_acc, metrics)
        return (acc, metrics_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    m0 = {"loss": jnp.zeros(()), "aux": jnp.zeros(())}
    (acc, msum), _ = jax.lax.scan(body, (zeros, m0), mb_batch)
    inv = 1.0 / microbatches
    return (
        jax.tree.map(lambda g: g * inv, acc),
        jax.tree.map(lambda m: m * inv, msum),
    )


def make_train_step(
    cfg: ModelConfig,
    optimizer: AdamW,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    microbatches: int = 1,
):
    """Build the jittable (params, opt_state, batch) -> (params', state', metrics)."""

    def train_step(params, opt_state, batch):
        grads, metrics = _accumulate_grads(params, batch, cfg, mesh, microbatches)
        params, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, metrics

    return train_step
