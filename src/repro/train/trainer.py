"""Training loop with production fault tolerance.

Features (all exercised by tests/test_trainer.py):
  * periodic atomic checkpoints (params + optimizer + data-pipeline state);
  * crash recovery — any step exception triggers restore-from-latest-valid
    and replay (the data pipeline is (seed, step)-deterministic so the
    restored run is bit-consistent with an uninterrupted one);
  * straggler mitigation — per-step wall time is tracked with an EMA; steps
    slower than ``straggler_factor`` x EMA are logged and counted, and the
    hook ``on_straggler`` lets deployments trigger re-scheduling (here it
    feeds the metrics log);
  * optional error-feedback gradient compression for the cross-pod exchange
    (see optim/compression.py) — applied between accumulation and the
    optimizer;
  * fault injection for tests: ``fail_at_steps`` raises inside the step to
    prove the recovery path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import PipelineState, SyntheticTokens
from repro.models import init_params
from repro.optim.adamw import AdamW
from repro.optim.compression import Compressor
from repro.train import checkpoint as ckpt
from repro.train.train_step import loss_fn, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    microbatches: int = 1
    straggler_factor: float = 3.0
    compression: str = "none"
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 64
    fail_at_steps: tuple[int, ...] = ()   # fault injection (tests)


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        *,
        mesh: Optional[jax.sharding.Mesh] = None,
        optimizer: Optional[AdamW] = None,
        on_straggler: Optional[Callable[[int, float, float], None]] = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.optimizer = optimizer or AdamW(lr=1e-3)
        self.on_straggler = on_straggler
        self.compressor = Compressor(kind=tcfg.compression)
        self.history: list[dict] = []
        self.straggler_events: list[dict] = []
        self.recoveries = 0

        self.data = SyntheticTokens(
            cfg, global_batch=tcfg.global_batch, seq_len=tcfg.seq_len,
            seed=tcfg.seed,
        )
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = init_params(cfg, key)
        self.opt_state = self.optimizer.init(self.params)
        self.error_fb = (
            self.compressor.init_error(self.params)
            if tcfg.compression != "none" else None
        )
        self._step_fn = self._build_step()
        self.step = 0

    # -- internals -----------------------------------------------------------

    def _build_step(self):
        base = make_train_step(
            self.cfg, self.optimizer, mesh=self.mesh,
            microbatches=self.tcfg.microbatches,
        )
        if self.tcfg.compression == "none":
            return jax.jit(base)

        from repro.train.train_step import _accumulate_grads

        def step_with_compression(params, opt_state, error, batch):
            grads, metrics = _accumulate_grads(
                params, batch, self.cfg, self.mesh, self.tcfg.microbatches
            )
            grads, error, _ = self.compressor.compress_decompress(grads, error)
            params, opt_state, om = self.optimizer.update(grads, opt_state, params)
            return params, opt_state, error, dict(metrics, **om)

        return jax.jit(step_with_compression)

    def _save(self):
        bundle = {"params": self.params, "opt": self.opt_state}
        extras = {"data": self.data.state.to_dict(), "step": self.step}
        ckpt.save(self.tcfg.checkpoint_dir, self.step, bundle, extras)

    def _restore(self) -> bool:
        like = {"params": self.params, "opt": self.opt_state}
        got = ckpt.restore_latest(self.tcfg.checkpoint_dir, like)
        if got is None:
            return False
        bundle, step, extras = got
        self.params = bundle["params"]
        self.opt_state = bundle["opt"]
        self.step = int(extras.get("step", step))
        self.data.state = PipelineState.from_dict(
            extras.get("data", {"seed": self.tcfg.seed, "step": self.step})
        )
        return True

    # -- loop -----------------------------------------------------------------

    def run(self) -> dict:
        ema = None
        injected = set(self.tcfg.fail_at_steps)
        self._save()  # step-0 baseline checkpoint
        while self.step < self.tcfg.total_steps:
            batch_np = self.data.next_batch()
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            t0 = time.time()
            try:
                if self.step in injected:
                    injected.discard(self.step)
                    raise RuntimeError(f"injected node failure at step {self.step}")
                if self.error_fb is None:
                    self.params, self.opt_state, metrics = self._step_fn(
                        self.params, self.opt_state, batch
                    )
                else:
                    (self.params, self.opt_state, self.error_fb, metrics) = (
                        self._step_fn(self.params, self.opt_state, self.error_fb, batch)
                    )
                metrics = {k: float(v) for k, v in metrics.items()}
            except Exception as e:  # noqa: BLE001 — node failure path
                self.recoveries += 1
                restored = self._restore()
                self.history.append(
                    {"step": self.step, "event": "failure",
                     "error": str(e)[:200], "restored": restored}
                )
                if not restored:
                    raise
                continue

            dt = time.time() - t0
            if ema is not None and dt > self.tcfg.straggler_factor * ema:
                ev = {"step": self.step, "dt": dt, "ema": ema}
                self.straggler_events.append(ev)
                if self.on_straggler:
                    self.on_straggler(self.step, dt, ema)
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt

            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == 1:
                self.history.append({"step": self.step, **metrics, "dt": dt})
            if self.step % self.tcfg.checkpoint_every == 0:
                self._save()
        self._save()
        return {
            "final_step": self.step,
            "history": self.history,
            "stragglers": self.straggler_events,
            "recoveries": self.recoveries,
        }


def eval_loss(cfg: ModelConfig, params, batch, mesh=None) -> float:
    loss, _ = loss_fn(params, batch, cfg, mesh=mesh)
    return float(loss)
