"""Elastic scaling: resume a run on a different device extent.

The checkpoint stores full (unsharded-on-disk) leaves, so elasticity is a
resharding problem: build the new mesh, recompute PartitionSpecs against it
(the rule table drops axes that no longer divide), and device_put the
restored tree.  The data pipeline is (seed, step)-deterministic and
global-batch-defined, so changing the number of data shards changes only
which host materializes which rows — the training trajectory is preserved.

``rescale`` is exercised by tests at toy scale (1 device -> 1 device with a
different logical mesh); on real fleets the same path handles pod loss
(shrink ``data``) and pod join (grow ``data``).
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro import sharding
from repro.configs.base import ModelConfig
from repro.train import checkpoint as ckpt


def rescale(
    cfg: ModelConfig,
    directory: str,
    like: Any,
    new_mesh: jax.sharding.Mesh,
) -> Optional[tuple]:
    """Restore the latest checkpoint and reshard it onto ``new_mesh``.

    -> (bundle_on_new_mesh, step, extras) or None if no valid checkpoint.
    """
    got = ckpt.restore_latest(directory, like)
    if got is None:
        return None
    bundle, step, extras = got

    pspecs = sharding.param_specs(cfg, bundle["params"], new_mesh)
    named = sharding.to_named(pspecs, new_mesh)
    params = jax.device_put(bundle["params"], named)

    # optimizer state mirrors param specs (placeholder leaves replicate)
    from jax.sharding import NamedSharding, PartitionSpec as P

    def opt_leaf(spec, leaf):
        if getattr(leaf, "ndim", 0) <= 1 and getattr(leaf, "shape", ()) in ((), (0,)):
            return jax.device_put(leaf, NamedSharding(new_mesh, P()))
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))

    opt = bundle["opt"]
    new_opt = type(opt)(
        step=jax.device_put(opt.step, NamedSharding(new_mesh, P())),
        m=jax.tree.map(opt_leaf, pspecs, opt.m),
        v=jax.tree.map(opt_leaf, pspecs, opt.v),
        master=jax.tree.map(opt_leaf, pspecs, opt.master),
    )
    return {"params": params, "opt": new_opt}, step, extras
