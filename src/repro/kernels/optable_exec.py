"""Pallas kernel: fused op-table executor for lowered pipeline programs.

The accelerator backend of ``dataplane.executor``.  The register file is laid
out transposed — ``(num_regs, batch)`` uint32, registers on the sublane axis,
packets on the lane axis — so each ALU row is a dynamic *row* gather
(``out_ref[pl.ds(slot, 1), :]``), the supported dynamic-index pattern, and
every scalar op applies across a full lane vector of packets at once (exactly
how a switch ALU spans the pipeline).

Grid: ``(batch_blocks, num_elements)`` with the element axis innermost; the
output block's index map ignores the element index, so the register block
stays resident in VMEM across the whole program for each batch block (the
same accumulator-residency pattern as ``bnn_matmul``).  Per element the
kernel makes two passes over the rows — compute into a scratch buffer, then
write back — preserving RMT's read-before-write semantics.  Scalar tables
(one row per grid step) live in SMEM; uint32 immediates travel bitcast as
int32 and are bitcast back per scalar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.dataplane import lowering

_ALL_OPS = (
    lowering.XOR_IMM,
    lowering.SHR_AND_IMM,
    lowering.ADD,
    lowering.GE_IMM,
    lowering.SHL_IMM,
    lowering.POPCNT,
)


def _kernel(
    opc_ref, dst_ref, s0_ref, s1_ref, i0_ref, i1_ref, m_ref, fw_ref,
    regs_ref, out_ref, scratch_ref, *, rows: int, used: tuple,
):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = regs_ref[...]

    def compute_row(r, carry):
        # Shared opcode->expression table with the jnp backend; local import
        # keeps the kernels package from depending on dataplane at load time.
        from repro.dataplane.executor import alu_variants

        opc = opc_ref[0, r]
        s0 = s0_ref[0, r]
        s1 = s1_ref[0, r]
        i0 = jax.lax.bitcast_convert_type(i0_ref[0, r], jnp.uint32)
        i1 = jax.lax.bitcast_convert_type(i1_ref[0, r], jnp.uint32)
        m = jax.lax.bitcast_convert_type(m_ref[0, r], jnp.uint32)

        r0 = out_ref[pl.ds(s0, 1), :]
        r1 = out_ref[pl.ds(s1, 1), :]

        variants = alu_variants(r0, r1, i0, i1, used)
        _, val = variants[0]
        for code, v in variants[1:]:
            val = jnp.where(opc == code, v, val)
        scratch_ref[pl.ds(r, 1), :] = val & m
        return carry

    jax.lax.fori_loop(0, rows, compute_row, 0)

    def write_row(r, carry):
        dst = dst_ref[0, r]
        first = fw_ref[0, r]
        val = scratch_ref[pl.ds(r, 1), :]
        cur = out_ref[pl.ds(dst, 1), :]
        # First writer of a slot overwrites; FOLD continuation rows deposit
        # additional (disjoint) bits additively.
        out_ref[pl.ds(dst, 1), :] = jnp.where(first == 1, val, cur + val)
        return carry

    jax.lax.fori_loop(0, rows, write_row, 0)


def optable_run(
    regs: jax.Array,
    opcode: jax.Array,
    dst: jax.Array,
    src0: jax.Array,
    src1: jax.Array,
    imm0: jax.Array,
    imm1: jax.Array,
    mask: jax.Array,
    first_write: jax.Array,
    *,
    used: tuple | None = None,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Run the op-table over a transposed register file.

    ``regs``: (num_regs, batch) uint32 — parsed packets, one column each.
    Tables: (num_elements, max_rows) as produced by ``lowering``.  Returns
    the final (num_regs, batch) register file.
    """
    num_regs, batch = regs.shape
    num_el, rows = opcode.shape
    if used is None:
        used = _ALL_OPS

    bb = min(block_b, batch)
    pad = (-batch) % bb
    if pad:
        regs = jnp.pad(regs, ((0, 0), (0, pad)))
    padded = batch + pad

    as_i32 = functools.partial(jax.lax.bitcast_convert_type, new_dtype=jnp.int32)
    table_spec = pl.BlockSpec(
        (1, rows), lambda b, e: (e, 0), memory_space=pltpu.SMEM
    )
    regs_spec = pl.BlockSpec((num_regs, bb), lambda b, e: (0, b))

    out = pl.pallas_call(
        functools.partial(_kernel, rows=rows, used=tuple(used)),
        grid=(padded // bb, num_el),
        in_specs=[table_spec] * 8 + [regs_spec],
        out_specs=regs_spec,
        out_shape=jax.ShapeDtypeStruct((num_regs, padded), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((rows, bb), jnp.uint32)],
        interpret=interpret,
    )(
        opcode, dst, src0, src1,
        as_i32(imm0), as_i32(imm1), as_i32(mask), first_write,
        regs,
    )
    return out[:, :batch] if pad else out


def optable_run_segmented(
    regs: jax.Array,
    opcode: jax.Array,
    dst: jax.Array,
    src0: jax.Array,
    src1: jax.Array,
    imm0: jax.Array,
    imm1: jax.Array,
    mask: jax.Array,
    first_write: jax.Array,
    *,
    runs: tuple[tuple[int, int, tuple[int, ...]], ...],
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Run opcode-homogeneous element segments back to back.

    ``runs`` is ``LoweredProgram.opcode_runs()``: static ``(start, stop,
    used)`` element ranges.  Bit-identical to one :func:`optable_run` over
    the whole table with the union used-set — but each segment's kernel
    specializes ``alu_variants`` to that segment's opcodes, collapsing the
    per-row where-select chain to (usually) a single expression.
    """
    for start, stop, used in runs:
        regs = optable_run(
            regs,
            opcode[start:stop], dst[start:stop],
            src0[start:stop], src1[start:stop],
            imm0[start:stop], imm1[start:stop],
            mask[start:stop], first_write[start:stop],
            used=used, block_b=block_b, interpret=interpret,
        )
    return regs
