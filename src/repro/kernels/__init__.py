# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Kernels here:
#   bnn_matmul / bnn_matmul_mxu / bitpack — binary-GEMM paths (see ops.py)
#   optable_exec — fused op-table executor for the dataplane simulator
#                  (dispatched via repro.dataplane.executor, backend="pallas")
