"""Pallas TPU kernel: ±1 binary GEMM on the MXU.

The compute-bound sibling of ``bnn_matmul.py``: operands are the same sign
matrices but represented as ±1 bf16 so the systolic array does the
contraction (the TPU analogue of the paper's "adding circuitry to perform
computation is much cheaper" — here the idle MXU *is* that circuitry).

The kernel fuses the binarize step (sign of the input tile) so the bf16
operands never round-trip through HBM: inputs may arrive as real-valued
activations; weights are expected pre-binarized to ±1 bf16 (they are static
at inference, like N2Net's pre-configured SRAM weights).

Tiling: classic (M/bm, N/bn, K/bk) matmul grid with an f32 VMEM accumulator
in the output block; MXU-aligned 128-multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, k_steps: int, binarize_x: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    if binarize_x:
        x = jnp.where(x >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    else:
        x = x.astype(jnp.bfloat16)
    w = w_ref[...].astype(jnp.bfloat16)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("binarize_x", "block_m", "block_n", "block_k", "interpret"),
)
def bnn_matmul_mxu(
    x: jax.Array,
    w: jax.Array,
    *,
    binarize_x: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """``binarize(x) @ w`` with f32 accumulation on the MXU.

    x: (M, K) bf16/f32 (binarized in-kernel when ``binarize_x``);
    w: (K, N) ±1 bf16 (pre-binarized weights).  Returns (M, N) f32.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"K mismatch: {k} vs {k2}")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"shape ({m},{n},{k}) not divisible by blocks "
            f"({block_m},{block_n},{block_k})"
        )
    k_steps = k // block_k

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, binarize_x=binarize_x),
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)
