"""Pallas TPU kernel: fused sign + bitpack (bf16/f32 -> uint32 words).

Converts real-valued activations into the packed sign representation consumed
by ``bnn_matmul.py``, writing 32x fewer bytes than the input.  This is the
"SIGN + folding" pair of N2Net's five steps, fused: on the switch the fold
deposits sign bits into the Y vector; on TPU we deposit 32 lane-neighbour
signs into one uint32 via a weighted reduction over the lane axis.

Tiling: grid (M/bm, K/(32*bkw)); each step reads an (bm, 32*bkw) activation
tile and writes an (bm, bkw) uint32 tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD = 32


def _kernel(x_ref, o_ref):
    x = x_ref[...]
    bm, kb = x.shape
    bits = (x >= 0).astype(jnp.uint32)
    grouped = bits.reshape(bm, kb // WORD, WORD)
    weights = jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)
    o_ref[...] = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_kw", "interpret")
)
def bitpack(
    x: jax.Array,
    *,
    block_m: int = 256,
    block_kw: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """Pack sign bits of ``x`` (M, K) into (M, K/32) uint32 (K % 32 == 0)."""
    m, k = x.shape
    if k % WORD:
        raise ValueError(f"K={k} must be a multiple of {WORD}")
    kw = k // WORD
    block_m = min(block_m, m)
    block_kw = min(block_kw, kw)
    if m % block_m or kw % block_kw:
        raise ValueError(
            f"shape ({m},{kw}) not divisible by blocks ({block_m},{block_kw})"
        )
    return pl.pallas_call(
        _kernel,
        grid=(m // block_m, kw // block_kw),
        in_specs=[
            pl.BlockSpec((block_m, block_kw * WORD), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_kw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, kw), jnp.uint32),
        interpret=interpret,
    )(x)


def pack_bits_words(
    bits: jax.Array,
    *,
    block_m: int = 256,
    block_kw: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """Pack ``(M, N)`` {0,1} bits into ``(M, ceil(N/32))`` uint32 words.

    Any-width front end over :func:`bitpack` for the dataplane's packed-PHV
    parse step: maps bits to ±1 signs (bit 1 -> +1 packs as 1), pads rows and
    trailing bits (with -1, which packs as 0 — the packed-layout zero-padding
    rule) up to the kernel's block divisibility, then slices the result back.
    Word layout matches ``lowering.pack_bit_rows``: bit ``k`` -> word
    ``k // 32``, shift ``k % 32``.
    """
    m, n = bits.shape
    kw = max(1, -(-n // WORD))
    if m == 0:
        return jnp.zeros((0, kw), jnp.uint32)
    bkw = min(block_kw, kw)
    kw_padded = kw + (-kw) % bkw
    bm = min(block_m, m)
    m_padded = m + (-m) % bm
    x = bits.astype(jnp.int32) * 2 - 1
    x = jnp.pad(
        x,
        ((0, m_padded - m), (0, kw_padded * WORD - n)),
        constant_values=-1,
    )
    out = bitpack(x, block_m=bm, block_kw=bkw, interpret=interpret)
    return out[:m, :kw]
