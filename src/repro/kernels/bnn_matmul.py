"""Pallas TPU kernel: bit-packed XNOR-popcount GEMM.

The TPU-native adaptation of N2Net's compute scheme.  Operands are sign bits
packed 32/uint32 (32x less HBM traffic than bf16 — the switch-chip insight
"memory is the scarce resource" mapped onto the TPU memory hierarchy).  The
inner product is ``popcount(XNOR(x̂, ŵ))`` on the VPU, with the affine
correction folded into the epilogue.

Tiling: grid (M/bm, N/bn, Kw/bkw); each step loads an (bm, bkw) x-tile and an
(bn, bkw) w-tile into VMEM, broadcasts to (bm, bn, bkw), popcounts and
reduces over the word axis into an (bm, bn) int32 accumulator that lives in
the output VMEM block across the K grid dimension (k-innermost accumulation
pattern).  Block defaults keep the broadcast tile ≤ 2 MiB of VMEM:
128 * 128 * 8 words * 4 B = 512 KiB.

There is no MXU use here by design — see ``bnn_matmul_mxu.py`` for the
compute-bound variant; the roofline analysis in EXPERIMENTS.md quantifies
when each wins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD = 32


def _kernel(x_ref, w_ref, o_ref, *, k_steps: int, affine: int):
    """One (m, n, k) grid step.

    x_ref: (bm, bkw) uint32;  w_ref: (bn, bkw) uint32;  o_ref: (bm, bn) int32.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...]
    agree = jax.lax.population_count(~(x[:, None, :] ^ w[None, :, :]))
    o_ref[...] += jnp.sum(agree.astype(jnp.int32), axis=-1)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        # dot = 2*acc - 2*K_padded + k_bits  (pad bits agree as 0/0).
        o_ref[...] = 2 * o_ref[...] + affine


@functools.partial(
    jax.jit, static_argnames=("k_bits", "block_m", "block_n", "block_kw", "interpret")
)
def bnn_matmul_packed(
    x_packed: jax.Array,
    w_packed: jax.Array,
    *,
    k_bits: int,
    block_m: int = 128,
    block_n: int = 128,
    block_kw: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """``sign(x) @ sign(w).T`` on packed operands.

    x_packed: (M, Kw) uint32; w_packed: (N, Kw) uint32; returns (M, N) int32.
    M, N must divide by the block sizes; Kw by block_kw (callers pad — see
    ``ops.binary_matmul`` which handles padding and layout).
    """
    m, kw = x_packed.shape
    n, kw2 = w_packed.shape
    if kw != kw2:
        raise ValueError(f"K mismatch: {kw} vs {kw2}")
    if m % block_m or n % block_n or kw % block_kw:
        raise ValueError(
            f"shape ({m},{n},{kw}) not divisible by blocks "
            f"({block_m},{block_n},{block_kw})"
        )
    k_steps = kw // block_kw
    affine = -2 * kw * WORD + k_bits

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, affine=affine),
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_kw), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_kw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x_packed, w_packed)
