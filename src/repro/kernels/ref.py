"""Pure-jnp oracles for the binary-GEMM kernels.

These are the ground truth every Pallas kernel in this package is tested
against (``assert_allclose`` over shape/dtype sweeps, interpret=True).
They are also the *production CPU path*: real XNOR-popcount arithmetic
expressed in XLA ops, used whenever the Pallas TPU kernels are unavailable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32


def bitpack_ref(x: jax.Array, axis: int = -1) -> jax.Array:
    """sign-bits of ``x`` packed into uint32 along ``axis`` (bit=1 iff x>=0).

    The axis length must be a multiple of 32.
    """
    axis = axis % x.ndim
    n = x.shape[axis]
    if n % WORD:
        raise ValueError(f"pack axis {n} not a multiple of {WORD}")
    bits = (jnp.moveaxis(x, axis, -1) >= 0).astype(jnp.uint32)
    grouped = bits.reshape(bits.shape[:-1] + (n // WORD, WORD))
    weights = jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)
    packed = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


def bnn_matmul_packed_ref(
    x_packed: jax.Array, w_packed: jax.Array, k_bits: int
) -> jax.Array:
    """±1 GEMM on packed sign bits: ``out[m,n] = sum_k x[m,k] * w[n,k]``.

    ``x_packed``: (M, Kw) uint32; ``w_packed``: (N, Kw) uint32; both packed
    from ``k_bits`` genuine sign bits, zero-padded to ``Kw*32``.  Pad bits are
    0 in both operands, so each contributes one agreement; the affine
    correction removes them:  ``dot = 2*acc - 2*Kw*32 + k_bits``.
    Returns (M, N) int32.
    """
    agree = jax.lax.population_count(~(x_packed[:, None, :] ^ w_packed[None, :, :]))
    acc = jnp.sum(agree.astype(jnp.int32), axis=-1)
    kw = x_packed.shape[-1]
    return 2 * acc - 2 * kw * WORD + k_bits


def bnn_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """±1 GEMM oracle in plain arithmetic: sign(x) @ sign(w).T as float32.

    ``x``: (M, K) real; ``w``: (N, K) real.  Both are binarized with the
    sign convention ``>= 0 -> +1``.  Returns (M, N) float32 — identical to
    :func:`bnn_matmul_packed_ref` on the packed representations.
    """
    xs = jnp.where(x >= 0, 1.0, -1.0).astype(jnp.float32)
    ws = jnp.where(w >= 0, 1.0, -1.0).astype(jnp.float32)
    return xs @ ws.T


def bnn_matmul_mxu_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for the MXU kernel: ±1 bf16 operands, f32 accumulation."""
    xs = jnp.where(x >= 0, 1, -1).astype(jnp.bfloat16)
    ws = jnp.where(w >= 0, 1, -1).astype(jnp.bfloat16)
    return jnp.dot(xs, ws.T, preferred_element_type=jnp.float32)


def xnor_dense_ref(
    x: jax.Array, w: jax.Array, alpha: jax.Array | None = None,
    beta: jax.Array | None = None,
) -> jax.Array:
    """XNOR-Net style binary dense: scaled ±1 GEMM.

    ``alpha``: per-output-channel |w| mean (N,); ``beta``: per-row |x| mean
    (M, 1).  Either may be None (unscaled).
    """
    out = bnn_matmul_ref(x, w)
    if alpha is not None:
        out = out * alpha[None, :]
    if beta is not None:
        out = out * beta
    return out
