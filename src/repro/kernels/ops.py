"""Public binary-GEMM ops: padding, implementation dispatch, STE autodiff.

Implementation tiers (``implementation=`` argument / ``default_impl``):

  * ``"pallas_packed"`` — bit-packed XNOR-popcount Pallas kernel (TPU VPU;
    ``interpret=True`` on CPU).  Inference-oriented: weights packed offline.
  * ``"pallas_mxu"``    — ±1 bf16 Pallas kernel on the MXU.
  * ``"packed_ref"``    — same packed arithmetic in plain XLA ops
    (``lax.population_count``); the production CPU path and the dry-run path
    (cost analysis then reflects packed byte movement).
  * ``"ref"``           — ±1 matmul oracle.

Training uses the straight-through estimator: :func:`ste_sign` is the only
``custom_vjp`` primitive; binary layers compose it with ordinary matmuls so
autodiff produces the BinaryNet/XNOR-Net gradients (clipped pass-through).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.bitpack import bitpack
from repro.kernels.bnn_matmul import bnn_matmul_packed
from repro.kernels.bnn_matmul_mxu import bnn_matmul_mxu

WORD = 32

_VALID_IMPLS = ("pallas_packed", "pallas_mxu", "packed_ref", "ref", "auto")


def resolve_impl(implementation: str = "auto") -> str:
    if implementation not in _VALID_IMPLS:
        raise ValueError(f"implementation must be one of {_VALID_IMPLS}")
    if implementation != "auto":
        return implementation
    return "pallas_packed" if jax.default_backend() == "tpu" else "packed_ref"


def _pad_axis(a: jax.Array, axis: int, mult: int) -> jax.Array:
    rem = (-a.shape[axis]) % mult
    if not rem:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad)


def pack_weights(w: jax.Array) -> tuple[jax.Array, int]:
    """Pack a (N, K) ±1/real weight matrix into (N, ceil(K/32)) uint32.

    Returns (packed, k_bits).  Done once offline for inference — the TPU
    analogue of N2Net pre-configuring weights into element SRAM.
    """
    n, k = w.shape
    bits = (w >= 0).astype(jnp.uint32)
    bits = _pad_axis(bits, 1, WORD)
    grouped = bits.reshape(n, -1, WORD)
    weights = jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32), k


def binary_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    implementation: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """``sign(x) @ sign(w).T`` — the N2Net contraction for real inputs.

    x: (..., M, K); w: (N, K).  Returns (..., M, N) float32.
    """
    impl = resolve_impl(implementation)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    lead = x.shape[:-2]
    m, k = x.shape[-2:]
    n = w.shape[0]
    x2 = x.reshape(-1, k)

    if impl == "ref":
        out = _ref.bnn_matmul_ref(x2, w)
    elif impl == "packed_ref":
        xp = _pack_rows(x2)
        wp, _ = pack_weights(w)
        out = _ref.bnn_matmul_packed_ref(xp, wp, k).astype(jnp.float32)
    elif impl == "pallas_packed":
        xp = _pack_rows(x2)
        wp, _ = pack_weights(w)
        bm, bn, bkw = _packed_blocks(x2.shape[0], n, xp.shape[-1])
        xp = _pad_axis(_pad_axis(xp, 0, bm), 1, bkw)
        wp = _pad_axis(_pad_axis(wp, 0, bn), 1, bkw)
        out = bnn_matmul_packed(
            xp, wp, k_bits=k, block_m=bm, block_n=bn, block_kw=bkw,
            interpret=interpret,
        )[: x2.shape[0], :n].astype(jnp.float32)
    elif impl == "pallas_mxu":
        ws = jnp.where(w >= 0, 1, -1).astype(jnp.bfloat16).T  # (K, N)
        bm, bn, bk = _mxu_blocks(x2.shape[0], n, k)
        xpad = _pad_axis(_pad_axis(x2, 0, bm), 1, bk)
        wpad = _pad_axis(_pad_axis(ws, 0, bk), 1, bn)
        out = bnn_matmul_mxu(
            xpad, wpad, binarize_x=True, block_m=bm, block_n=bn, block_k=bk,
            interpret=interpret,
        )[: x2.shape[0], :n]
        # padded K region contributes sign(0)=+1 times w-pad 0 -> no correction
        # needed for K padding on this path (w pad rows are zeros).
    else:  # pragma: no cover
        raise AssertionError(impl)
    return out.reshape(*lead, m, n)


def _pack_rows(x: jax.Array) -> jax.Array:
    """Pack sign bits of (M, K) rows into (M, ceil(K/32)) uint32."""
    bits = (x >= 0).astype(jnp.uint32)
    bits = _pad_axis(bits, 1, WORD)
    grouped = bits.reshape(bits.shape[0], -1, WORD)
    weights = jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)


def _packed_blocks(m: int, n: int, kw: int) -> tuple[int, int, int]:
    bm = min(128, _round_pow2(m))
    bn = min(128, _round_pow2(n))
    bkw = min(8, _round_pow2(kw))
    return bm, bn, bkw


def _mxu_blocks(m: int, n: int, k: int) -> tuple[int, int, int]:
    bm = min(128, _round_pow2(m))
    bn = min(128, _round_pow2(n))
    bk = min(512, _round_pow2(k))
    return bm, bn, bk


def _round_pow2(v: int) -> int:
    """Largest power of two <= v (at least 1)."""
    return 1 << max(0, v.bit_length() - 1) if v > 0 else 1


# ---------------------------------------------------------------------------
# Straight-through estimator — the only custom-gradient primitive.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_sign(v: jax.Array) -> jax.Array:
    """sign(v) in ±1 with straight-through gradient (clipped at |v|<=1)."""
    return jnp.where(v >= 0, 1.0, -1.0).astype(v.dtype)


def _ste_fwd(v):
    return ste_sign(v), v


def _ste_bwd(v, g):
    return (g * (jnp.abs(v) <= 1.0).astype(g.dtype),)


ste_sign.defvjp(_ste_fwd, _ste_bwd)


def binary_dense_train(
    x: jax.Array,
    w_latent: jax.Array,
    *,
    scale: str = "weight_only",
) -> jax.Array:
    """Differentiable binary dense for training (composes ste_sign + matmul).

    ``scale``:
      * "weight_only" — y = x @ (sign(w) * alpha).T, alpha = per-channel |w|
        mean.  Activations stay real (least lossy; LM default).
      * "xnor"        — y = sign(x) @ (sign(w) * alpha).T * beta,
        beta = per-row |x| mean (full XNOR-Net).
      * "none"        — unscaled fully-binary.
    """
    alpha = jnp.mean(jnp.abs(w_latent), axis=-1)  # (N,)
    wb = ste_sign(w_latent)
    if scale == "weight_only":
        return x @ (wb * alpha[:, None]).T
    if scale == "xnor":
        beta = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
        return (ste_sign(x) @ (wb * alpha[:, None]).T) * beta
    if scale == "none":
        return ste_sign(x) @ wb.T
    raise ValueError(f"unknown scale mode {scale!r}")


def binary_dense_infer(
    x: jax.Array,
    w_latent: jax.Array,
    *,
    scale: str = "weight_only",
    implementation: str = "auto",
) -> jax.Array:
    """Inference-path binary dense using the packed/MXU kernels."""
    alpha = jnp.mean(jnp.abs(w_latent), axis=-1)
    if scale == "weight_only":
        # x @ sign(w).T == binary_matmul with x kept real requires the MXU
        # path (packed path binarizes x too); emulate via per-column scaling.
        wb = jnp.where(w_latent >= 0, 1.0, -1.0).astype(x.dtype)
        return x @ (wb * alpha[:, None]).T
    out = binary_matmul(x, w_latent, implementation=implementation)
    if scale == "xnor":
        beta = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
        out = out * alpha[None, :] * beta
    elif scale != "none":
        raise ValueError(f"unknown scale mode {scale!r}")
    return out


__all__ = [
    "binary_dense_infer",
    "binary_dense_train",
    "binary_matmul",
    "bitpack",
    "bnn_matmul_mxu",
    "bnn_matmul_packed",
    "pack_weights",
    "resolve_impl",
    "ste_sign",
]
