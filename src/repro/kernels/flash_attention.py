"""Pallas TPU kernel: fused causal attention (flash-style online softmax).

The §Roofline analysis shows every training cell's memory term is dominated
by materialized (S_q, S_k) score/prob buffers (EXPERIMENTS.md) — XLA cannot
keep them in VMEM across the dot->mask->softmax->dot chain.  This kernel is
the structural fix on real TPUs: scores live only in VMEM scratch; HBM
traffic is Q + K + V + O (linear in S), independent of the score matrix.

Layout: q/k/v are (BH, S, D) — batch and heads pre-flattened (GQA callers
repeat or reshape k/v; see ``ops.flash_attention``).  Grid = (BH, S/bq);
each step streams K/V in ``bk`` chunks with the online-softmax recurrence:

    m' = max(m, rowmax(s));  l' = l*e^{m-m'} + rowsum(e^{s-m'})
    acc' = acc*e^{m-m'} + e^{s-m'} @ V_chunk

Causal masking skips nothing structurally (chunks are masked), matching the
jnp reference exactly; the fully-masked upper chunks are a known ~2x
compute overhead documented in EXPERIMENTS.md (the VMEM win dominates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[...]                      # (bq, D)
    bq, d = q.shape
    s_total = k_ref.shape[0]
    nk = s_total // bk

    m0 = jnp.full((bq, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(ki, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(ki * bk, bk), slice(None)))
        v = pl.load(v_ref, (pl.dslice(ki * bk, bk), slice(None)))
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                        # (bq, bk)
        if causal:
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> use 0
        alpha = jnp.where(jnp.isfinite(m_new), jnp.exp(m - m_new), 0.0)
        p = jnp.where(jnp.isfinite(m_new), jnp.exp(s - m_new), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Fused attention.  q/k/v: (BH, S, D); returns (BH, S, D) in q.dtype.

    S must divide block_q and block_k (callers pad — see ops wrapper);
    D should be a multiple of 128 for MXU alignment on real hardware.
    """
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must divide block sizes ({block_q},{block_k})")
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, bk=block_k, causal=causal, scale=scale),
        grid=(bh, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, s, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
