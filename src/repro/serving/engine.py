"""Serving engines: batched LM decode slots and the dataplane fleet pipeline.

Two engines share this module's deployment shape — a stream of units
classified/extended at a fixed batched rate:

* :class:`Engine` — LM continuous batching.  ``max_batch`` decode slots;
  requests are prefilled (cache seeded at prompt length, right-padded to
  the decode budget) and inserted into free slots; every step decodes ALL
  active slots in one batched ``decode_step`` call; finished sequences free
  their slot for the next queued request.  Single-cache-per-slot variant:
  the batched cache is a pytree whose batch dim is the slot axis.

* :class:`FleetEngine` — the dataplane's async chunk pipeline.  Packet
  featurization (pcap decode + header featurization runs at ~230k pps on
  the host, an order of magnitude under the packed executor) is the serving
  bottleneck if run inline, so a producer thread assembles ``(streams,
  chunk, bits)`` fleet blocks from the per-stream iterators into a bounded
  queue while the main thread dispatches the compiled
  ``repro.dataplane.fleet`` executable — ingest and execution overlap
  instead of alternating.  Bit-exactness is untouched (the pipeline only
  reorders *when* blocks are built, never their contents); the result
  reports ingest/execute/wall seconds so the overlap is measurable.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.dataplane import fleet as _fleet
from repro.dataplane.lowering import LoweredProgram, lower_program
from repro.dataplane.plan import ExecutionPlan
from repro.models import decode_step, init_cache, prefill
from repro.obs.slo import BreachEvent, SloSpec, SloStatus, SloTracker
from repro.obs.windows import WindowedHistogram, WindowedRate


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        mesh=None,
        sampler: Optional[Callable] = None,
    ):
        if cfg.encoder_only:
            raise ValueError("encoder-only architectures cannot be served")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.mesh = mesh
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, axis=-1))

        self.cache = init_cache(cfg, max_batch, max_len)
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)       # next write index
        self.slot_budget = np.zeros(max_batch, np.int32)
        self.last_token = np.zeros(max_batch, np.int32)
        self.queue: list[Request] = []
        self.completed: list[Request] = []

        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, t, c, cfg, mesh=mesh)
        )
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, mesh=mesh)
        )

    # -- API -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Process until queue + slots drain (or step budget)."""
        for _ in range(max_steps):
            self._admit()
            if not any(s is not None for s in self.slots):
                if not self.queue:
                    break
                continue
            self._step()
        return self.completed

    # -- internals ---------------------------------------------------------

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            s = len(req.prompt)
            logits, pcache = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None, :])}
            )
            tok = int(np.asarray(self.sampler(logits))[0])
            req.output.append(tok)
            # EOS / budget may already hit on the prefill-sampled token
            if (req.eos_id is not None and tok == req.eos_id) or req.max_new_tokens <= 1:
                req.done = True
                self.completed.append(req)
                continue
            self._install(slot, pcache, s)
            self.slots[slot] = req
            self.slot_pos[slot] = s
            self.slot_budget[slot] = req.max_new_tokens - 1
            self.last_token[slot] = tok

    def _install(self, slot: int, pcache, prompt_len: int) -> None:
        """Copy a prefilled (batch=1, len=S) cache into the slot axis of the
        batched cache, right-padding the sequence axis to max_len."""

        def put(dst, src):
            if src.ndim == 0:
                return dst
            # src: (L, 1, S, ...) or (L, 1, ...); dst: (L, B, max_len, ...)
            pad = [(0, 0)] * src.ndim
            if src.ndim >= 3 and dst.shape[2] != src.shape[2]:
                pad[2] = (0, dst.shape[2] - src.shape[2])
                src = jnp.pad(src, pad)
            idx = (slice(None), slice(slot, slot + 1))
            return dst.at[idx].set(src.astype(dst.dtype))

        self.cache = jax.tree.map(put, self.cache, pcache)
        # index field lives per-cache (scalar): decode uses per-slot positions
        # via the max — single-sequence engines keep them aligned; mixed-length
        # slots decode against the padded region masked by position index.
        self.cache = _set_index(self.cache, int(max(self.slot_pos.max(), prompt_len)))

    def _step(self) -> None:
        tokens = jnp.asarray(self.last_token)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        next_tok = np.asarray(self.sampler(logits))
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(next_tok[slot])
            req.output.append(tok)
            self.slot_pos[slot] += 1
            self.slot_budget[slot] -= 1
            if (req.eos_id is not None and tok == req.eos_id) or (
                self.slot_budget[slot] <= 0
                or self.slot_pos[slot] >= self.max_len - 1
            ):
                req.done = True
                self.completed.append(req)
                self.slots[slot] = None


# ---------------------------------------------------------------------------
# Dataplane fleet serving: async ingest/execute pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetServeResult:
    """Outcome of a pipelined fleet serve.

    ``wall_seconds`` is end-to-end steady-state time (first-block warmup
    excluded, queue stalls included) — the honest serving number.
    ``ingest_seconds``/``execute_seconds`` are the per-side busy times; with
    perfect overlap ``wall ~= max(ingest, execute)``, serialized it would be
    their sum."""

    streams: int
    packets: int
    chunks: int
    wall_seconds: float
    ingest_seconds: float
    execute_seconds: float
    warmup_seconds: float
    per_stream_packets: np.ndarray
    outputs: list | None = None

    @property
    def packets_per_second(self) -> float:
        return (
            self.packets / self.wall_seconds
            if self.wall_seconds > 0
            else float("inf")
        )

    @property
    def overlap_ratio(self) -> float:
        """(ingest + execute) / wall — 1.0 is fully serialized, 2.0 is
        perfect two-stage overlap."""
        busy = self.ingest_seconds + self.execute_seconds
        return busy / self.wall_seconds if self.wall_seconds > 0 else 1.0


@dataclasses.dataclass(frozen=True)
class FleetHealth:
    """Live ``FleetEngine`` snapshot as of an explicit ``now``.

    The windowed fields (aggregate and per-stream pps, chunk-latency p99,
    SLO posture) come from the engine's explicit-timestamp windows — with
    an injected deterministic ``clock`` they are a pure function of the
    served blocks, which is what the determinism tests pin.
    ``queue_depth`` is the one genuinely live field: the number of
    assembled blocks waiting in the ingest queue at call time.
    """

    now: float
    streams: int
    queue_depth: int                   # blocks waiting in the ingest queue
    queue_capacity: int
    chunks: int                        # blocks dispatched since construction
    packets: int                       # packets served since construction
    windowed_pps: float                # aggregate rate over the trailing window
    per_stream_pps: tuple[float, ...]  # same, per fleet stream
    chunk_p99_s: float | None          # windowed p99 dispatch latency
    overlap_ratio: float | None        # last completed serve() (None before)
    slo: SloStatus | None              # None when no SLO was configured
    breach_events: tuple[BreachEvent, ...]
    roofline_pps_bound: float | None   # hardware ceiling of the compiled fn
    roofline_fraction: float | None    # windowed_pps / bound

    def render(self) -> str:
        lines = [
            f"fleet health @ {self.now:.3f}: {self.streams} stream(s), "
            f"queue {self.queue_depth}/{self.queue_capacity}, "
            f"{self.chunks} chunk(s) / {self.packets} packet(s) served",
            f"  windowed: {self.windowed_pps:.4g} pps aggregate, "
            f"chunk p99 "
            + (f"{self.chunk_p99_s * 1e3:.3f}ms"
               if self.chunk_p99_s is not None else "-"),
        ]
        if self.roofline_pps_bound is not None:
            frac = (
                f" ({self.roofline_fraction:.2e} of bound)"
                if self.roofline_fraction is not None else ""
            )
            lines.append(
                f"  roofline: {self.roofline_pps_bound:.4g} pps bound{frac}"
            )
        if self.slo is not None:
            s = self.slo
            burns = []
            if s.delay_burn_rate is not None:
                burns.append(f"delay burn {s.delay_burn_rate:.2f}x")
            if s.pps_burn_rate is not None:
                burns.append(f"pps burn {s.pps_burn_rate:.2f}x")
            state = "BREACHED" if s.breached else "ok"
            lines.append(
                f"  slo[{s.tenant}]: {state} "
                + (", ".join(burns) if burns else "no data")
                + f", {len(self.breach_events)} breach event(s)"
            )
        return "\n".join(lines)


class FleetEngine:
    """Async fleet pipeline: featurize/assemble blocks on a producer thread
    while the main thread runs the compiled fleet executable.

    ``plan`` carries backend/chunk/fleet/devices exactly as in
    ``repro.dataplane.run``; ``queue_depth`` bounds how many assembled
    blocks may wait (bounded memory even when ingest outruns execution).

    ``health()`` is the live snapshot API: sliding-window pps (aggregate
    and per stream), queue depth, chunk-latency p99, and — when an
    :class:`~repro.obs.slo.SloSpec` is passed — SLO burn rates and breach
    events.  All window/SLO timestamps come from ``clock`` (default
    ``time.perf_counter``), which is called only on the main dispatch
    thread, once per served block: inject a deterministic clock and every
    windowed health field becomes reproducible bit-for-bit.
    """

    def __init__(
        self,
        program,
        *,
        plan: ExecutionPlan | None = None,
        queue_depth: int = 4,
        slo: SloSpec | None = None,
        clock: Callable[[], float] | None = None,
        window_s: float = 10.0,
        window_buckets: int = 10,
    ):
        self.lowered = (
            program
            if isinstance(program, LoweredProgram)
            else lower_program(program)
        )
        self.plan = plan or ExecutionPlan()
        self.backend = _fleet._executor.resolve_backend(self.plan.backend_str)
        self.chunk = self.plan.chunk_size or _fleet.DEFAULT_STREAM_CHUNK
        self.queue_depth = queue_depth
        self.fn = _fleet.fleet_fn(
            self.lowered,
            backend=self.backend,
            interpret=self.plan.interpret,
            scan_hops=bool(self.plan.scan_hops),
            devices=self.plan.devices,
        )
        # -- health-snapshot state (explicit-timestamp windows + SLO) -------
        self._clock = clock or time.perf_counter
        self.window_s = float(window_s)
        self._window_buckets = int(window_buckets)
        self._agg_rate = WindowedRate(self.window_s, buckets=window_buckets)
        self._chunk_delay = WindowedHistogram(
            self.window_s, buckets=window_buckets
        )
        self._stream_rates: list[WindowedRate] = []
        self._slo = SloTracker(slo, buckets=window_buckets) if slo else None
        self._queue: _queue.Queue | None = None
        self._chunks_total = 0
        self._packets_total = 0
        self._last_result: FleetServeResult | None = None
        self._roofline = None
        self._last_now = 0.0

    def health(self, now: float | None = None) -> FleetHealth:
        """The live engine snapshot (see class docstring).  ``now`` defaults
        to the engine clock; pass the timestamp explicitly to re-read a
        window at a known instant (the deterministic-testing path)."""
        if now is None:
            now = self._clock()
        q = self._queue
        windowed = self._agg_rate.rate(now)
        bound = (
            self._roofline.roofline_pps if self._roofline is not None else None
        )
        return FleetHealth(
            now=now,
            streams=len(self._stream_rates),
            queue_depth=q.qsize() if q is not None else 0,
            queue_capacity=self.queue_depth,
            chunks=self._chunks_total,
            packets=self._packets_total,
            windowed_pps=windowed,
            per_stream_pps=tuple(
                r.rate(now) for r in self._stream_rates
            ),
            chunk_p99_s=self._chunk_delay.p99(now),
            overlap_ratio=(
                self._last_result.overlap_ratio
                if self._last_result is not None else None
            ),
            slo=self._slo.status(now) if self._slo is not None else None,
            breach_events=(
                tuple(self._slo.events) if self._slo is not None else ()
            ),
            roofline_pps_bound=bound,
            roofline_fraction=(
                windowed / bound if bound else None
            ),
        )

    def _observe_block(self, now: float, dt: float, valid, served: int) -> None:
        """Fold one dispatched block into the health windows (main thread
        only; ``now`` comes from the injectable engine clock)."""
        self._last_now = now
        self._chunks_total += 1
        self._packets_total += served
        self._agg_rate.add(now, served)
        self._chunk_delay.observe(now, dt, count=1)
        for i, rate in enumerate(self._stream_rates):
            v = int(valid[i])
            if v:
                rate.add(now, v)
        if self._slo is not None:
            self._slo.observe_packets(now, served)
            # One fused dispatch serves the whole block: every packet in it
            # waits exactly the dispatch latency.
            self._slo.observe_queue_delay(now, dt, count=served)
            self._slo.update(now)

    def serve(self, streams, *, collect: bool = False) -> FleetServeResult:
        """Drain every stream through the pipelined fleet; bit-exact per
        stream with ``executor.execute`` (the pipeline reorders block
        *assembly*, never block contents)."""
        its = _fleet._normalize_streams(streams, self.plan.fleet)
        n_streams = len(its)
        if self.plan.devices is not None and n_streams % self.plan.devices:
            raise ValueError(
                f"fleet of {n_streams} streams does not shard evenly over "
                f"{self.plan.devices} devices"
            )
        if len(self._stream_rates) != n_streams:  # fleet size changed: reset
            self._stream_rates = [
                WindowedRate(self.window_s, buckets=self._window_buckets)
                for _ in range(n_streams)
            ]
        q: _queue.Queue = _queue.Queue(maxsize=self.queue_depth)
        self._queue = q
        ingest = [0.0]
        errors: list[BaseException] = []

        def produce() -> None:
            try:
                mark = time.perf_counter()
                for block in _fleet.fleet_blocks(
                    its, self.chunk, self.lowered.input_bits
                ):
                    # Time spent *building* the block (featurization, pcap
                    # pulls, re-chunking) — not time blocked on a full queue.
                    ingest[0] += time.perf_counter() - mark
                    q.put(block)
                    mark = time.perf_counter()
            except BaseException as e:  # surfaced after join
                errors.append(e)
            finally:
                q.put(None)

        per_stream = np.zeros(n_streams, np.int64)
        collected = [[] for _ in range(n_streams)] if collect else None
        execute_seconds = 0.0
        warmup = 0.0
        n_blocks = 0
        producer = threading.Thread(target=produce, name="fleet-ingest")
        with obs.span(
            "stream:fleet_serve", cat="stream",
            streams=n_streams, backend=self.backend, chunk_size=self.chunk,
        ):
            producer.start()
            t_start = time.perf_counter()
            while True:
                item = q.get()
                if item is None:
                    break
                blocks, valid = item
                dev = jnp.asarray(blocks)
                if n_blocks == 0:  # warm the compile cache outside the clock
                    with obs.span(
                        "compile:fleet_chunk", cat="compile",
                        streams=n_streams,
                    ):
                        w0 = time.perf_counter()
                        self.fn(dev).block_until_ready()
                        warmup = time.perf_counter() - w0
                    if obs.enabled():  # cost the compiled dispatch, once
                        self._roofline = _fleet._probe_fleet_roofline(
                            self.lowered, self.backend, n_streams,
                            self.chunk, self.plan,
                        )
                served_now = int(valid.sum())
                with obs.span(
                    "execute:fleet_chunk", cat="execute",
                    packets=served_now,
                ):
                    # Health observations use the engine clock on both sides
                    # of the dispatch (two calls per block, main thread only)
                    # so an injected deterministic clock makes every windowed
                    # health field reproducible; wall-clock bookkeeping for
                    # the serve result stays on perf_counter.
                    h0 = self._clock()
                    t0 = time.perf_counter()
                    res = np.asarray(self.fn(dev))
                    execute_seconds += time.perf_counter() - t0
                    h1 = self._clock()
                n_blocks += 1
                self._observe_block(h1, max(h1 - h0, 0.0), valid, served_now)
                for i in range(n_streams):
                    v = int(valid[i])
                    if not v:
                        continue
                    per_stream[i] += v
                    if collected is not None:
                        collected[i].append(res[i, :v].astype(np.uint8))
            wall = time.perf_counter() - t_start - warmup
            producer.join()
        if errors:
            raise errors[0]
        total = int(per_stream.sum())
        if obs.enabled() and wall > 0:
            obs.registry().gauge("fleet.serve_pps").set(total / wall)
            if self._roofline is not None:
                _fleet._executor._record_roofline(
                    self._roofline, total / wall
                )
        outputs = None
        if collected is not None:
            outputs = [
                np.concatenate(c, axis=0)
                if c
                else np.zeros((0, self.lowered.output_bits), np.uint8)
                for c in collected
            ]
        result = FleetServeResult(
            streams=n_streams,
            packets=total,
            chunks=n_blocks,
            wall_seconds=wall,
            ingest_seconds=ingest[0],
            execute_seconds=execute_seconds,
            warmup_seconds=warmup,
            per_stream_packets=per_stream,
            outputs=outputs,
        )
        self._last_result = result
        return result


def _set_index(cache, value: int):
    import dataclasses as dc

    def fix(obj):
        if hasattr(obj, "index") and dc.is_dataclass(obj):
            kw = {}
            for f in dc.fields(obj):
                v = getattr(obj, f.name)
                if f.name == "index":
                    kw[f.name] = jnp.asarray(value, jnp.int32)
                elif dc.is_dataclass(v):
                    kw[f.name] = fix(v)
                else:
                    kw[f.name] = v
            return dc.replace(obj, **kw)
        return obj

    return fix(cache)
