"""Batched serving engine: prefill + decode with slot-based continuous
batching.

The engine keeps ``max_batch`` decode slots.  Requests are prefilled (cache
seeded at prompt length, right-padded to the decode budget) and inserted
into free slots; every engine step decodes ALL active slots in one batched
``decode_step`` call; finished sequences (EOS or length budget) free their
slot for the next queued request.  This is the N2Net deployment shape: a
stream of "packets" (requests) classified/extended at a fixed batched rate.

Single-cache-per-slot variant: the batched cache is a pytree whose batch dim
is the slot axis; prefill writes a slot by dynamic_update on that axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        mesh=None,
        sampler: Optional[Callable] = None,
    ):
        if cfg.encoder_only:
            raise ValueError("encoder-only architectures cannot be served")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.mesh = mesh
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, axis=-1))

        self.cache = init_cache(cfg, max_batch, max_len)
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)       # next write index
        self.slot_budget = np.zeros(max_batch, np.int32)
        self.last_token = np.zeros(max_batch, np.int32)
        self.queue: list[Request] = []
        self.completed: list[Request] = []

        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, t, c, cfg, mesh=mesh)
        )
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, mesh=mesh)
        )

    # -- API -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Process until queue + slots drain (or step budget)."""
        for _ in range(max_steps):
            self._admit()
            if not any(s is not None for s in self.slots):
                if not self.queue:
                    break
                continue
            self._step()
        return self.completed

    # -- internals ---------------------------------------------------------

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            s = len(req.prompt)
            logits, pcache = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None, :])}
            )
            tok = int(np.asarray(self.sampler(logits))[0])
            req.output.append(tok)
            # EOS / budget may already hit on the prefill-sampled token
            if (req.eos_id is not None and tok == req.eos_id) or req.max_new_tokens <= 1:
                req.done = True
                self.completed.append(req)
                continue
            self._install(slot, pcache, s)
            self.slots[slot] = req
            self.slot_pos[slot] = s
            self.slot_budget[slot] = req.max_new_tokens - 1
            self.last_token[slot] = tok

    def _install(self, slot: int, pcache, prompt_len: int) -> None:
        """Copy a prefilled (batch=1, len=S) cache into the slot axis of the
        batched cache, right-padding the sequence axis to max_len."""

        def put(dst, src):
            if src.ndim == 0:
                return dst
            # src: (L, 1, S, ...) or (L, 1, ...); dst: (L, B, max_len, ...)
            pad = [(0, 0)] * src.ndim
            if src.ndim >= 3 and dst.shape[2] != src.shape[2]:
                pad[2] = (0, dst.shape[2] - src.shape[2])
                src = jnp.pad(src, pad)
            idx = (slice(None), slice(slot, slot + 1))
            return dst.at[idx].set(src.astype(dst.dtype))

        self.cache = jax.tree.map(put, self.cache, pcache)
        # index field lives per-cache (scalar): decode uses per-slot positions
        # via the max — single-sequence engines keep them aligned; mixed-length
        # slots decode against the padded region masked by position index.
        self.cache = _set_index(self.cache, int(max(self.slot_pos.max(), prompt_len)))

    def _step(self) -> None:
        tokens = jnp.asarray(self.last_token)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        next_tok = np.asarray(self.sampler(logits))
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(next_tok[slot])
            req.output.append(tok)
            self.slot_pos[slot] += 1
            self.slot_budget[slot] -= 1
            if (req.eos_id is not None and tok == req.eos_id) or (
                self.slot_budget[slot] <= 0
                or self.slot_pos[slot] >= self.max_len - 1
            ):
                req.done = True
                self.completed.append(req)
                self.slots[slot] = None


def _set_index(cache, value: int):
    import dataclasses as dc

    def fix(obj):
        if hasattr(obj, "index") and dc.is_dataclass(obj):
            kw = {}
            for f in dc.fields(obj):
                v = getattr(obj, f.name)
                if f.name == "index":
                    kw[f.name] = jnp.asarray(value, jnp.int32)
                elif dc.is_dataclass(v):
                    kw[f.name] = fix(v)
                else:
                    kw[f.name] = v
            return dc.replace(obj, **kw)
        return obj

    return fix(cache)
