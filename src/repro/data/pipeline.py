"""Deterministic, restartable synthetic data pipeline.

Production posture: every batch is a pure function of (seed, step), so
  * restart/resume needs only the step counter (stored in checkpoints);
  * every data-parallel host can materialize exactly its shard
    (``host_slice``) without coordination;
  * there is no filesystem or network dependency in this offline container —
    the token stream is a mixture of Zipf-distributed unigrams and repeated
    n-gram motifs so models have real structure to learn (loss decreases).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(int(d["seed"]), int(d["step"]))


class SyntheticTokens:
    """Zipf unigrams + planted n-gram motifs; next-token labels."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        global_batch: int,
        seq_len: int,
        seed: int = 0,
        motif_len: int = 8,
        num_motifs: int = 64,
    ):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.state = PipelineState(seed, 0)
        rng = np.random.default_rng(seed)
        v = cfg.vocab_size
        self.motifs = rng.integers(0, v, size=(num_motifs, motif_len))
        self.motif_len = motif_len

    def _tokens(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab_size
        # Zipf-ish unigram draw (bounded to vocab)
        toks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64) % v
        # plant motifs: ~25% of positions covered by repeated n-grams
        n_plants = max(1, s // (self.motif_len * 4))
        for i in range(b):
            ids = rng.integers(0, len(self.motifs), size=n_plants)
            pos = rng.integers(0, s + 1 - self.motif_len, size=n_plants)
            for m, p in zip(ids, pos):
                toks[i, p : p + self.motif_len] = self.motifs[m]
        return toks

    def next_batch(self, host_index: int = 0, host_count: int = 1) -> dict:
        """Materialize this host's slice of the global batch for the current
        step, then advance.  Deterministic in (seed, step, host)."""
        st = self.state
        rng = np.random.default_rng((st.seed, st.step))
        b, s = self.global_batch, self.seq_len
        assert b % host_count == 0, "global batch must divide host count"
        toks = self._tokens(rng, b, s)
        lo = host_index * (b // host_count)
        hi = lo + b // host_count
        self.state = PipelineState(st.seed, st.step + 1)

        cfg = self.cfg
        if cfg.input_mode == "frames":
            frng = np.random.default_rng((st.seed, st.step, 1))
            frames = frng.standard_normal((hi - lo, s, cfg.d_model)).astype(np.float32)
            return {
                "frames": frames,
                "labels": toks[lo:hi, 1:],
            }
        if cfg.input_mode == "tokens+patches":
            prng = np.random.default_rng((st.seed, st.step, 2))
            st_text = s - cfg.num_patches
            patches = prng.standard_normal(
                (hi - lo, cfg.num_patches, cfg.d_model)
            ).astype(np.float32)
            return {
                "tokens": toks[lo:hi, :st_text],
                "patches": patches,
                "labels": toks[lo:hi, 1 : st_text + 1],
            }
        return {"tokens": toks[lo:hi, :-1], "labels": toks[lo:hi, 1:]}
