"""``repro.dataplane`` — the switch fleet in software.

Line-rate simulation layer over the N2Net core: programs are lowered to
dense op-tables (``lowering``), executed fused and batched (``executor``,
with a Pallas kernel in ``kernels.optable_exec``), fed from a traffic
scenario library (``traffic``) or from real capture files (``pcap``),
scaled past one chip's element budget by a simulated multi-switch fabric
with per-stage telemetry (``fabric``, ``telemetry``), shared between
independently compiled programs by a multi-tenant scheduler
(``multitenant``), and batched fleet-wide — N independent streams through
one vmapped/shard_map-ed dispatch (``fleet``).

The one entry point that reaches every executor is :func:`run` with a typed
:class:`ExecutionPlan` (``plan``); the per-module keyword surfaces remain
as thin shims.
"""
from repro.dataplane import (
    executor,
    fabric,
    factory,
    fleet,
    lowering,
    multitenant,
    pcap,
    plan,
    telemetry,
    traffic,
)
from repro.dataplane.executor import DEFAULT_CHUNK, execute, execute_stream
from repro.dataplane.fabric import MODES, SwitchFabric
from repro.dataplane.factory import Fleet, FleetSpec, TenantSpec, build_fleet
from repro.dataplane.fleet import (
    DEFAULT_STREAM_CHUNK,
    FleetRunResult,
    execute_fleet,
    fleet_fn,
)
from repro.dataplane.lowering import (
    LoweredProgram,
    PackedLayer,
    PackedProgram,
    StackedHops,
    lower_program,
    pack_bit_rows,
    stack_hops,
)
from repro.dataplane.multitenant import (
    AdmissionError,
    MERGED_LAYOUTS,
    MergedProgram,
    SCHEDULER_MODES,
    SwitchScheduler,
    interleave_lowered,
    merge_lowered,
)
from repro.dataplane.pcap import (
    Capture,
    PcapFormatError,
    featurize,
    parse_headers,
    read_pcap,
    register_pcap_scenario,
    synthesize_capture,
    write_pcap,
    write_pcapng,
)
from repro.dataplane.plan import Backend, ExecutionPlan, run
from repro.dataplane.telemetry import FabricTelemetry, stage_telemetry
from repro.dataplane.traffic import (
    SCENARIOS,
    TenantTrafficSpec,
    generate,
    get_scenario,
    mixed_tenant_generate,
    mixed_tenant_stream,
    register_scenario,
    stream,
)

__all__ = [
    "AdmissionError",
    "Backend",
    "Capture",
    "DEFAULT_CHUNK",
    "DEFAULT_STREAM_CHUNK",
    "ExecutionPlan",
    "FabricTelemetry",
    "Fleet",
    "FleetRunResult",
    "FleetSpec",
    "LoweredProgram",
    "MERGED_LAYOUTS",
    "MODES",
    "MergedProgram",
    "PackedLayer",
    "PackedProgram",
    "PcapFormatError",
    "SCENARIOS",
    "SCHEDULER_MODES",
    "StackedHops",
    "SwitchFabric",
    "SwitchScheduler",
    "TenantSpec",
    "TenantTrafficSpec",
    "build_fleet",
    "execute",
    "execute_fleet",
    "execute_stream",
    "executor",
    "fabric",
    "factory",
    "featurize",
    "fleet",
    "fleet_fn",
    "generate",
    "get_scenario",
    "interleave_lowered",
    "lower_program",
    "lowering",
    "merge_lowered",
    "mixed_tenant_generate",
    "mixed_tenant_stream",
    "multitenant",
    "pack_bit_rows",
    "parse_headers",
    "pcap",
    "plan",
    "read_pcap",
    "register_pcap_scenario",
    "register_scenario",
    "run",
    "stack_hops",
    "stage_telemetry",
    "stream",
    "synthesize_capture",
    "telemetry",
    "traffic",
    "write_pcap",
    "write_pcapng",
]
