"""``repro.dataplane`` — the switch fleet in software.

Line-rate simulation layer over the N2Net core: programs are lowered to
dense op-tables (``lowering``), executed fused and batched (``executor``,
with a Pallas kernel in ``kernels.optable_exec``), fed from a traffic
scenario library (``traffic``) or from real capture files (``pcap``),
scaled past one chip's element budget by a simulated multi-switch fabric
with per-stage telemetry (``fabric``, ``telemetry``), and shared between
independently compiled programs by a multi-tenant scheduler
(``multitenant``).
"""
from repro.dataplane import (
    executor,
    fabric,
    lowering,
    multitenant,
    pcap,
    telemetry,
    traffic,
)
from repro.dataplane.executor import DEFAULT_CHUNK, execute, execute_stream
from repro.dataplane.fabric import MODES, SwitchFabric
from repro.dataplane.lowering import (
    LoweredProgram,
    PackedLayer,
    PackedProgram,
    lower_program,
    pack_bit_rows,
)
from repro.dataplane.multitenant import (
    AdmissionError,
    SCHEDULER_MODES,
    SwitchScheduler,
)
from repro.dataplane.pcap import (
    Capture,
    PcapFormatError,
    featurize,
    parse_headers,
    read_pcap,
    register_pcap_scenario,
    synthesize_capture,
    write_pcap,
    write_pcapng,
)
from repro.dataplane.telemetry import FabricTelemetry, stage_telemetry
from repro.dataplane.traffic import (
    SCENARIOS,
    TenantTrafficSpec,
    generate,
    get_scenario,
    mixed_tenant_generate,
    mixed_tenant_stream,
    register_scenario,
    stream,
)

__all__ = [
    "AdmissionError",
    "Capture",
    "DEFAULT_CHUNK",
    "FabricTelemetry",
    "LoweredProgram",
    "MODES",
    "PackedLayer",
    "PackedProgram",
    "PcapFormatError",
    "SCENARIOS",
    "SCHEDULER_MODES",
    "SwitchFabric",
    "SwitchScheduler",
    "TenantTrafficSpec",
    "execute",
    "execute_stream",
    "executor",
    "fabric",
    "featurize",
    "generate",
    "get_scenario",
    "lower_program",
    "lowering",
    "mixed_tenant_generate",
    "mixed_tenant_stream",
    "multitenant",
    "pack_bit_rows",
    "parse_headers",
    "pcap",
    "read_pcap",
    "register_pcap_scenario",
    "register_scenario",
    "stage_telemetry",
    "stream",
    "synthesize_capture",
    "telemetry",
    "traffic",
    "write_pcap",
    "write_pcapng",
]
