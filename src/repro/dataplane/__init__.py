"""``repro.dataplane`` — the switch fleet in software.

Line-rate simulation layer over the N2Net core: programs are lowered to
dense op-tables (``lowering``), executed fused and batched (``executor``,
with a Pallas kernel in ``kernels.optable_exec``), fed from a traffic
scenario library (``traffic``), and scaled past one chip's element budget by
a simulated multi-switch fabric with per-stage telemetry (``fabric``,
``telemetry``).
"""
from repro.dataplane import executor, fabric, lowering, telemetry, traffic
from repro.dataplane.executor import DEFAULT_CHUNK, execute, execute_stream
from repro.dataplane.fabric import MODES, SwitchFabric
from repro.dataplane.lowering import LoweredProgram, lower_program
from repro.dataplane.telemetry import FabricTelemetry, stage_telemetry
from repro.dataplane.traffic import SCENARIOS, generate, get_scenario, stream

__all__ = [
    "DEFAULT_CHUNK",
    "FabricTelemetry",
    "LoweredProgram",
    "MODES",
    "SCENARIOS",
    "SwitchFabric",
    "execute",
    "execute_stream",
    "executor",
    "fabric",
    "generate",
    "get_scenario",
    "lower_program",
    "lowering",
    "stage_telemetry",
    "stream",
    "telemetry",
    "traffic",
]
