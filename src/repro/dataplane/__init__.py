"""``repro.dataplane`` — the switch fleet in software.

Line-rate simulation layer over the N2Net core: programs are lowered to
dense op-tables (``lowering``), executed fused and batched (``executor``,
with a Pallas kernel in ``kernels.optable_exec``), fed from a traffic
scenario library (``traffic``), scaled past one chip's element budget by a
simulated multi-switch fabric with per-stage telemetry (``fabric``,
``telemetry``), and shared between independently compiled programs by a
multi-tenant scheduler (``multitenant``).
"""
from repro.dataplane import (
    executor,
    fabric,
    lowering,
    multitenant,
    telemetry,
    traffic,
)
from repro.dataplane.executor import DEFAULT_CHUNK, execute, execute_stream
from repro.dataplane.fabric import MODES, SwitchFabric
from repro.dataplane.lowering import LoweredProgram, lower_program
from repro.dataplane.multitenant import (
    AdmissionError,
    SCHEDULER_MODES,
    SwitchScheduler,
)
from repro.dataplane.telemetry import FabricTelemetry, stage_telemetry
from repro.dataplane.traffic import (
    SCENARIOS,
    TenantTrafficSpec,
    generate,
    get_scenario,
    mixed_tenant_generate,
    mixed_tenant_stream,
    stream,
)

__all__ = [
    "AdmissionError",
    "DEFAULT_CHUNK",
    "FabricTelemetry",
    "LoweredProgram",
    "MODES",
    "SCENARIOS",
    "SCHEDULER_MODES",
    "SwitchFabric",
    "SwitchScheduler",
    "TenantTrafficSpec",
    "execute",
    "execute_stream",
    "executor",
    "fabric",
    "generate",
    "get_scenario",
    "lower_program",
    "lowering",
    "mixed_tenant_generate",
    "mixed_tenant_stream",
    "multitenant",
    "stage_telemetry",
    "stream",
    "telemetry",
    "traffic",
]
