"""Traffic scenario library: packet-trace generators for the dataplane.

Each scenario synthesizes packet headers whose bits are the BNN's input
activations, so benchmarks and differential tests exercise the executor on
realistic bit *distributions* — not just uniform noise.  The scenarios mirror
the workloads the in-network-NN literature actually classifies:

* ``flow_tuple``   — per-packet 5-tuples drawn from a heavy-tailed flow pool
  (flow classification: few elephants, many mice; headers repeat).
* ``ddos_burst``   — background traffic with periodic attack bursts of a
  jittered attacker signature (anomaly/DDoS detection: regime shifts).
* ``iot_telemetry``— a small device fleet reporting slowly-drifting
  Gray-coded sensor readings (low bit-entropy, strong temporal locality).
* ``adversarial_bitflip`` — prototype inputs with a few random bit flips
  (decision-boundary robustness probes).
* ``uniform_random`` — i.i.d. fair coin bits (the null workload).

Beyond the built-in synthetic five, :func:`register_scenario` admits new
scenarios at runtime — ``dataplane.pcap`` uses it to register *captured*
traffic (pcap/pcapng files featurized to activation bits) under the same
contract; see ``docs/TRAFFIC.md``.

A scenario is a ``setup`` (draw the trace's persistent world: flow pool,
attacker signature, device fleet) plus an ``emit`` over an absolute packet
range.  :func:`stream` runs setup **once** and emits successive ranges, so a
chunked stream keeps its cross-packet structure — the same elephants recur,
burst phase follows global packet position, sensor walks continue — instead
of resetting per chunk.  All generators are pure numpy, deterministic per
``seed``, and produce ``(n, input_bits)`` int32 arrays in {0,1}.

The packet sequence is *defined* over fixed canonical emission chunks
(``CANONICAL_CHUNK`` packets): the randomness for the chunk starting at
absolute position ``p`` derives from ``(seed, p)`` alone, and the world is
seeded separately.  Any consumer chunking — ``generate``, ``stream`` at any
``chunk_size``, a stream paused and resumed mid-trace — re-slices the same
canonical sequence.  (Earlier revisions threaded one rng through every emit
call, which made the sequence depend on chunk boundaries whenever an emitter
issues several differently-shaped draws: resuming a stream mid-scenario
changed the packets.  The canonical-chunk scheme makes the advertised
invariance hold by construction.)

Invariants:

* **Determinism** — same ``(scenario, n, input_bits, seed)`` means the same
  bits, on any platform; ``stream`` over ``[0, n)`` in any chunking equals
  ``generate(n, ...)`` of the same world.  The BNN trainer's train/held-out
  splits (``train.bnn_trainer.make_traffic_task``) depend on this to carve
  temporal splits out of one world.
* **Shape/domain** — every emitter returns exactly ``(n, input_bits)``
  int32 in {0,1}; ``_fold_bits`` makes any scenario usable at any model
  input width (fold is parity-preserving per column).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import numpy as np

# Canonical 5-tuple layout: src ip (32) dst ip (32) ports (16+16) proto (8).
_TUPLE_BITS = 104

# The packet sequence is defined over emission chunks of this many packets;
# chunk ``p`` draws from ``default_rng([seed, _EMIT_TAG, p])``.  Part of the
# sequence definition: changing it changes every scenario's packets.
CANONICAL_CHUNK = 1024
_SETUP_TAG = 0
_EMIT_TAG = 1
_ASSIGN_TAG = 2


def _fold_bits(bits: np.ndarray, width: int) -> np.ndarray:
    """XOR-fold (n, k) bit rows to exactly ``width`` columns.

    Wider rows fold back onto themselves (hash-like, parity-preserving per
    column); narrower rows tile.  Keeps every scenario usable at any model
    input width.
    """
    n, k = bits.shape
    if n == 0:
        return np.zeros((0, width), np.int32)
    if k < width:
        reps = -(-width // k)
        bits = np.tile(bits, (1, reps))
        k = bits.shape[1]
    if k == width:
        return bits.astype(np.int32)
    pad = (-k) % width
    if pad:
        bits = np.concatenate([bits, np.zeros((n, pad), bits.dtype)], axis=1)
    # XOR-reduce == per-column parity of the sum for {0,1} entries, at a
    # fraction of the cost (this is the pcap featurizer's hot loop too).
    return np.bitwise_xor.reduce(
        bits.reshape(n, -1, width).astype(np.int32), axis=1
    )


def _int_bits(vals: np.ndarray, width: int) -> np.ndarray:
    """(n,) unsigned ints -> (n, width) little-endian bits."""
    # uint32 math is exact for the bits we keep (<= 32) and much faster.
    dtype = np.uint32 if width <= 32 else np.uint64
    shifts = np.arange(width, dtype=dtype)
    return ((vals[:, None].astype(dtype) >> shifts) & 1).astype(np.int32)


def _gray(vals: np.ndarray) -> np.ndarray:
    v = vals.astype(np.uint64)
    return v ^ (v >> 1)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """``setup(rng, bits) -> state`` once per trace, then
    ``emit(state, rng, start, n, bits)`` over absolute packet positions
    ``[start, start + n)``.  ``state`` may be mutable (e.g. sensor walks).

    Emission happens in canonical ``CANONICAL_CHUNK``-packet chunks with a
    per-chunk rng derived from ``(seed, chunk position)`` — see the module
    docstring — so the sequence is identical under any consumer chunking.
    """

    name: str
    description: str
    _setup: Callable[[np.random.Generator, int], Any]
    _emit: Callable[[Any, np.random.Generator, int, int, int], np.ndarray]

    def iter_chunks(
        self, input_bits: int, seed: int = 0
    ) -> Iterator[np.ndarray]:
        """Infinite iterator over the canonical emission chunks of one world."""
        if input_bits <= 0:
            raise ValueError(f"input_bits must be positive, got {input_bits}")
        state = self._setup(
            np.random.default_rng([seed, _SETUP_TAG]), input_bits
        )
        start = 0
        while True:
            rng = np.random.default_rng([seed, _EMIT_TAG, start])
            out = self._emit(state, rng, start, CANONICAL_CHUNK, input_bits)
            assert (
                out.shape == (CANONICAL_CHUNK, input_bits)
                and out.dtype == np.int32
            )
            yield out
            start += CANONICAL_CHUNK

    def generate(self, n: int, input_bits: int, seed: int = 0) -> np.ndarray:
        """(n, input_bits) int32 {0,1} packet activation bits."""
        if n < 0 or input_bits <= 0:
            raise ValueError(f"bad trace shape n={n} input_bits={input_bits}")
        if n == 0:
            return np.zeros((0, input_bits), np.int32)
        chunks = []
        have = 0
        for c in self.iter_chunks(input_bits, seed):
            chunks.append(c)
            have += c.shape[0]
            if have >= n:
                break
        return np.concatenate(chunks, axis=0)[:n]

    def stream(
        self, n: int, input_bits: int, *, chunk_size: int, seed: int = 0
    ) -> Iterator[np.ndarray]:
        """Emit the same world (and exact packet sequence) as ``generate``,
        re-sliced into ``chunk_size``-packet chunks."""
        if chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        src = _Puller(self.iter_chunks(input_bits, seed))
        for start in range(0, n, chunk_size):
            yield src.pull(min(chunk_size, n - start))


class _Puller:
    """Re-slice an infinite chunk iterator into pull-sized pieces."""

    def __init__(self, it: Iterator[np.ndarray]):
        self._it = it
        self._buf: list[np.ndarray] = []
        self._have = 0

    def pull(self, k: int) -> np.ndarray:
        while self._have < k:
            c = next(self._it)
            self._buf.append(c)
            self._have += c.shape[0]
        flat = (
            np.concatenate(self._buf, axis=0)
            if len(self._buf) > 1
            else self._buf[0]
        )
        out, rest = flat[:k], flat[k:]
        self._buf = [rest] if rest.shape[0] else []
        self._have = rest.shape[0]
        return out


# -- scenario implementations -----------------------------------------------

def _uniform_emit(state, rng, start, n, bits):
    return rng.integers(0, 2, (n, bits), dtype=np.int32)


def _flow_setup(rng, bits):
    n_flows = 256
    # Flow pool: random 5-tuples; popularity ~ 1/rank (elephants and mice).
    pool = _fold_bits(
        rng.integers(0, 2, (n_flows, _TUPLE_BITS), dtype=np.int32), bits
    )
    rank = np.arange(1, n_flows + 1, dtype=np.float64)
    p = (1.0 / rank) / (1.0 / rank).sum()
    return pool, p


def _flow_emit(state, rng, start, n, bits):
    pool, p = state
    return pool[rng.choice(pool.shape[0], size=n, p=p)]


def _ddos_setup(rng, bits):
    return rng.integers(0, 2, bits, dtype=np.int32)  # attacker signature


def _ddos_emit(state, rng, start, n, bits):
    period, burst_len = 1024, 256
    out = rng.integers(0, 2, (n, bits), dtype=np.int32)  # background
    pos = start + np.arange(n)  # burst phase follows *global* position
    in_burst = (pos % period) < burst_len
    jitter = rng.random((n, bits)) < 0.02  # per-bit flip prob inside a burst
    attack = np.where(jitter, 1 - state[None, :], state[None, :])
    out[in_burst] = attack[in_burst]
    return out


def _iot_setup(rng, bits):
    n_dev = 32
    return {"level": rng.integers(0, 1 << 16, n_dev)}  # walks continue


def _iot_emit(state, rng, start, n, bits):
    n_dev = state["level"].shape[0]
    dev = rng.integers(0, n_dev, n)
    steps = rng.integers(-3, 4, n)
    drift = np.zeros(n, np.int64)
    for d in range(n_dev):  # per-device cumulative walk from carried level
        sel = dev == d
        walk = state["level"][d] + np.cumsum(steps[sel])
        drift[sel] = walk
        if walk.size:
            state["level"][d] = walk[-1]
    reading = _gray(drift.astype(np.uint64) & 0xFFFF)
    header = np.concatenate(
        [_int_bits(dev.astype(np.uint64), 8), _int_bits(reading, 16)], axis=1
    )
    return _fold_bits(header, bits)


def _adv_setup(rng, bits):
    return rng.integers(0, 2, (8, bits), dtype=np.int32)  # prototypes


def _adv_emit(state, rng, start, n, bits):
    out = state[rng.integers(0, state.shape[0], n)].copy()
    k = max(1, bits // 16)  # flips per packet
    flips = rng.integers(0, bits, (n, k))
    rows = np.repeat(np.arange(n), k)
    np.add.at(out, (rows, flips.ravel()), 1)
    return (out % 2).astype(np.int32)


SCENARIOS: dict[str, Scenario] = {
    s.name: s
    for s in (
        Scenario(
            "uniform_random",
            "i.i.d. fair-coin bits",
            lambda rng, bits: None,
            _uniform_emit,
        ),
        Scenario(
            "flow_tuple",
            "heavy-tailed 5-tuple flow pool (flow classification)",
            _flow_setup,
            _flow_emit,
        ),
        Scenario(
            "ddos_burst",
            "background + periodic jittered attack bursts",
            _ddos_setup,
            _ddos_emit,
        ),
        Scenario(
            "iot_telemetry",
            "small device fleet, Gray-coded drifting sensor readings",
            _iot_setup,
            _iot_emit,
        ),
        Scenario(
            "adversarial_bitflip",
            "prototype headers with sparse random bit flips",
            _adv_setup,
            _adv_emit,
        ),
    )
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; have {sorted(SCENARIOS)}"
        ) from None


def register_scenario(scenario: Scenario, *, overwrite: bool = False) -> Scenario:
    """Add a scenario to :data:`SCENARIOS` (e.g. a pcap-backed one from
    ``dataplane.pcap``), making it usable everywhere a scenario name is —
    ``generate``/``stream``, trainer tasks, and mixed-tenant specs.
    Registering a different scenario under an existing name requires
    ``overwrite=True``; re-registering the same object is a no-op."""
    existing = SCENARIOS.get(scenario.name)
    if existing is not None and existing is not scenario and not overwrite:
        raise ValueError(
            f"scenario {scenario.name!r} is already registered; pass "
            "overwrite=True to replace it"
        )
    SCENARIOS[scenario.name] = scenario
    return scenario


def generate(name: str, n: int, input_bits: int, seed: int = 0) -> np.ndarray:
    return get_scenario(name).generate(n, input_bits, seed)


def stream(
    name: str, n: int, input_bits: int, *, chunk_size: int, seed: int = 0
) -> Iterator[np.ndarray]:
    """Yield a scenario as bounded chunks sharing one persistent world."""
    return get_scenario(name).stream(
        n, input_bits, chunk_size=chunk_size, seed=seed
    )


# -- mixed-tenant traffic -----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantTrafficSpec:
    """One tenant's share of a mixed stream: which scenario generates its
    packets, how wide its model's input is, and its arrival weight."""

    scenario: str
    input_bits: int
    weight: float = 1.0

    def __post_init__(self) -> None:
        get_scenario(self.scenario)  # fail fast on unknown names
        if self.input_bits <= 0:
            raise ValueError(f"input_bits must be positive, got {self.input_bits}")
        if self.weight <= 0:
            raise ValueError(f"weight must be positive, got {self.weight}")


def tenant_stream_seed(seed: int, tid: int) -> int:
    """The derived seed of tenant ``tid``'s scenario sub-stream within a
    mixed trace.  Exposed so tests can reproduce one tenant's packets with
    plain :func:`generate` — tenant ``t``'s subsequence in a mixed stream IS
    ``generate(spec.scenario, count_t, spec.input_bits, seed=this)``."""
    return int(np.random.SeedSequence([seed, tid]).generate_state(1)[0])


def _assignment_chunks(
    n_tenants: int, weights: np.ndarray, seed: int
) -> Iterator[np.ndarray]:
    """Canonical-chunk iterator of weighted i.i.d. tenant-id draws."""
    start = 0
    while True:
        rng = np.random.default_rng([seed, _ASSIGN_TAG, start])
        yield rng.choice(n_tenants, size=CANONICAL_CHUNK, p=weights).astype(
            np.int32
        )
        start += CANONICAL_CHUNK


def mixed_tenant_stream(
    specs: list[TenantTrafficSpec] | tuple[TenantTrafficSpec, ...],
    n: int,
    *,
    chunk_size: int,
    seed: int = 0,
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Interleave per-tenant scenario streams into one tagged packet stream.

    Yields ``(tenant_ids, bits)`` chunks: ``tenant_ids`` is ``(k,)`` int32,
    ``bits`` is ``(k, max(input_bits))`` int32 {0,1} with each row generated
    by its tenant's scenario at the tenant's width and zero-padded to the
    common width.  Arrival order is an i.i.d. weighted draw; each tenant's
    *subsequence* is exactly that tenant's scenario stream under the seed
    :func:`tenant_stream_seed` derives (setup once, per-tenant world
    persists across chunks, positions are tenant-local).

    Determinism matches :meth:`Scenario.stream`: same ``(specs, n, seed)``
    gives the same packets under any chunking — assignment and every
    tenant's emission ride the canonical-chunk scheme, and each tenant's
    packet positions depend only on cumulative assignment counts.
    """
    if not specs:
        raise ValueError("mixed_tenant_stream needs at least one tenant spec")
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    width = max(s.input_bits for s in specs)
    weights = np.array([s.weight for s in specs], np.float64)
    weights = weights / weights.sum()

    assign = _Puller(_assignment_chunks(len(specs), weights, seed))
    pullers = [
        _Puller(
            get_scenario(sp.scenario).iter_chunks(
                sp.input_bits, seed=tenant_stream_seed(seed, t)
            )
        )
        for t, sp in enumerate(specs)
    ]

    for start in range(0, n, chunk_size):
        k = min(chunk_size, n - start)
        tids = assign.pull(k)
        bits = np.zeros((k, width), np.int32)
        for t, sp in enumerate(specs):
            rows = np.nonzero(tids == t)[0]
            if rows.size:
                bits[rows, : sp.input_bits] = pullers[t].pull(rows.size)
        yield tids, bits


def mixed_tenant_generate(
    specs: list[TenantTrafficSpec] | tuple[TenantTrafficSpec, ...],
    n: int,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """One-shot form of :func:`mixed_tenant_stream`: ``(tenant_ids, bits)``
    for the whole trace."""
    chunks = list(mixed_tenant_stream(specs, n, chunk_size=max(1, n), seed=seed))
    if not chunks:
        width = max(s.input_bits for s in specs)
        return np.zeros(0, np.int32), np.zeros((0, width), np.int32)
    return (
        np.concatenate([t for t, _ in chunks]),
        np.concatenate([b for _, b in chunks]),
    )
