"""Traffic scenario library: packet-trace generators for the dataplane.

Each scenario synthesizes packet headers whose bits are the BNN's input
activations, so benchmarks and differential tests exercise the executor on
realistic bit *distributions* — not just uniform noise.  The scenarios mirror
the workloads the in-network-NN literature actually classifies:

* ``flow_tuple``   — per-packet 5-tuples drawn from a heavy-tailed flow pool
  (flow classification: few elephants, many mice; headers repeat).
* ``ddos_burst``   — background traffic with periodic attack bursts of a
  jittered attacker signature (anomaly/DDoS detection: regime shifts).
* ``iot_telemetry``— a small device fleet reporting slowly-drifting
  Gray-coded sensor readings (low bit-entropy, strong temporal locality).
* ``adversarial_bitflip`` — prototype inputs with a few random bit flips
  (decision-boundary robustness probes).
* ``uniform_random`` — i.i.d. fair coin bits (the null workload).

A scenario is a ``setup`` (draw the trace's persistent world: flow pool,
attacker signature, device fleet) plus an ``emit`` over an absolute packet
range.  :func:`stream` runs setup **once** and emits successive ranges, so a
chunked stream keeps its cross-packet structure — the same elephants recur,
burst phase follows global packet position, sensor walks continue — instead
of resetting per chunk.  All generators are pure numpy, deterministic per
``seed``, and produce ``(n, input_bits)`` int32 arrays in {0,1}.

Invariants:

* **Determinism** — same ``(scenario, n, input_bits, seed)`` means the same
  bits, on any platform; ``stream`` over ``[0, n)`` in any chunking equals
  ``generate(n, ...)`` of the same world.  The BNN trainer's train/held-out
  splits (``train.bnn_trainer.make_traffic_task``) depend on this to carve
  temporal splits out of one world.
* **Shape/domain** — every emitter returns exactly ``(n, input_bits)``
  int32 in {0,1}; ``_fold_bits`` makes any scenario usable at any model
  input width (fold is parity-preserving per column).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import numpy as np

# Canonical 5-tuple layout: src ip (32) dst ip (32) ports (16+16) proto (8).
_TUPLE_BITS = 104


def _fold_bits(bits: np.ndarray, width: int) -> np.ndarray:
    """XOR-fold (n, k) bit rows to exactly ``width`` columns.

    Wider rows fold back onto themselves (hash-like, parity-preserving per
    column); narrower rows tile.  Keeps every scenario usable at any model
    input width.
    """
    n, k = bits.shape
    if k < width:
        reps = -(-width // k)
        bits = np.tile(bits, (1, reps))
        k = bits.shape[1]
    if k == width:
        return bits.astype(np.int32)
    pad = (-k) % width
    if pad:
        bits = np.concatenate([bits, np.zeros((n, pad), bits.dtype)], axis=1)
    return (
        bits.reshape(n, -1, width).sum(axis=1) % 2
    ).astype(np.int32)


def _int_bits(vals: np.ndarray, width: int) -> np.ndarray:
    """(n,) unsigned ints -> (n, width) little-endian bits."""
    shifts = np.arange(width, dtype=np.uint64)
    return ((vals[:, None].astype(np.uint64) >> shifts) & 1).astype(np.int32)


def _gray(vals: np.ndarray) -> np.ndarray:
    v = vals.astype(np.uint64)
    return v ^ (v >> 1)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """``setup(rng, bits) -> state`` once per trace, then
    ``emit(state, rng, start, n, bits)`` over absolute packet positions
    ``[start, start + n)``.  ``state`` may be mutable (e.g. sensor walks)."""

    name: str
    description: str
    _setup: Callable[[np.random.Generator, int], Any]
    _emit: Callable[[Any, np.random.Generator, int, int, int], np.ndarray]

    def generate(self, n: int, input_bits: int, seed: int = 0) -> np.ndarray:
        """(n, input_bits) int32 {0,1} packet activation bits."""
        if n < 0 or input_bits <= 0:
            raise ValueError(f"bad trace shape n={n} input_bits={input_bits}")
        rng = np.random.default_rng(seed)
        out = self._emit(self._setup(rng, input_bits), rng, 0, n, input_bits)
        assert out.shape == (n, input_bits) and out.dtype == np.int32
        return out

    def stream(
        self, n: int, input_bits: int, *, chunk_size: int, seed: int = 0
    ) -> Iterator[np.ndarray]:
        """Emit the same world as one trace, in bounded chunks."""
        if chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        rng = np.random.default_rng(seed)
        state = self._setup(rng, input_bits)
        for start in range(0, n, chunk_size):
            take = min(chunk_size, n - start)
            yield self._emit(state, rng, start, take, input_bits)


# -- scenario implementations -----------------------------------------------

def _uniform_emit(state, rng, start, n, bits):
    return rng.integers(0, 2, (n, bits), dtype=np.int32)


def _flow_setup(rng, bits):
    n_flows = 256
    # Flow pool: random 5-tuples; popularity ~ 1/rank (elephants and mice).
    pool = _fold_bits(
        rng.integers(0, 2, (n_flows, _TUPLE_BITS), dtype=np.int32), bits
    )
    rank = np.arange(1, n_flows + 1, dtype=np.float64)
    p = (1.0 / rank) / (1.0 / rank).sum()
    return pool, p


def _flow_emit(state, rng, start, n, bits):
    pool, p = state
    return pool[rng.choice(pool.shape[0], size=n, p=p)]


def _ddos_setup(rng, bits):
    return rng.integers(0, 2, bits, dtype=np.int32)  # attacker signature


def _ddos_emit(state, rng, start, n, bits):
    period, burst_len = 1024, 256
    out = rng.integers(0, 2, (n, bits), dtype=np.int32)  # background
    pos = start + np.arange(n)  # burst phase follows *global* position
    in_burst = (pos % period) < burst_len
    jitter = rng.random((n, bits)) < 0.02  # per-bit flip prob inside a burst
    attack = np.where(jitter, 1 - state[None, :], state[None, :])
    out[in_burst] = attack[in_burst]
    return out


def _iot_setup(rng, bits):
    n_dev = 32
    return {"level": rng.integers(0, 1 << 16, n_dev)}  # walks continue


def _iot_emit(state, rng, start, n, bits):
    n_dev = state["level"].shape[0]
    dev = rng.integers(0, n_dev, n)
    steps = rng.integers(-3, 4, n)
    drift = np.zeros(n, np.int64)
    for d in range(n_dev):  # per-device cumulative walk from carried level
        sel = dev == d
        walk = state["level"][d] + np.cumsum(steps[sel])
        drift[sel] = walk
        if walk.size:
            state["level"][d] = walk[-1]
    reading = _gray(drift.astype(np.uint64) & 0xFFFF)
    header = np.concatenate(
        [_int_bits(dev.astype(np.uint64), 8), _int_bits(reading, 16)], axis=1
    )
    return _fold_bits(header, bits)


def _adv_setup(rng, bits):
    return rng.integers(0, 2, (8, bits), dtype=np.int32)  # prototypes


def _adv_emit(state, rng, start, n, bits):
    out = state[rng.integers(0, state.shape[0], n)].copy()
    k = max(1, bits // 16)  # flips per packet
    flips = rng.integers(0, bits, (n, k))
    rows = np.repeat(np.arange(n), k)
    np.add.at(out, (rows, flips.ravel()), 1)
    return (out % 2).astype(np.int32)


SCENARIOS: dict[str, Scenario] = {
    s.name: s
    for s in (
        Scenario(
            "uniform_random",
            "i.i.d. fair-coin bits",
            lambda rng, bits: None,
            _uniform_emit,
        ),
        Scenario(
            "flow_tuple",
            "heavy-tailed 5-tuple flow pool (flow classification)",
            _flow_setup,
            _flow_emit,
        ),
        Scenario(
            "ddos_burst",
            "background + periodic jittered attack bursts",
            _ddos_setup,
            _ddos_emit,
        ),
        Scenario(
            "iot_telemetry",
            "small device fleet, Gray-coded drifting sensor readings",
            _iot_setup,
            _iot_emit,
        ),
        Scenario(
            "adversarial_bitflip",
            "prototype headers with sparse random bit flips",
            _adv_setup,
            _adv_emit,
        ),
    )
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; have {sorted(SCENARIOS)}"
        ) from None


def generate(name: str, n: int, input_bits: int, seed: int = 0) -> np.ndarray:
    return get_scenario(name).generate(n, input_bits, seed)


def stream(
    name: str, n: int, input_bits: int, *, chunk_size: int, seed: int = 0
) -> Iterator[np.ndarray]:
    """Yield a scenario as bounded chunks sharing one persistent world."""
    return get_scenario(name).stream(
        n, input_bits, chunk_size=chunk_size, seed=seed
    )
