"""Typed execution plans: one entry point for every dataplane executor.

Before this module, backend selection was stringly typed and scattered —
``execute(..., backend="jnp")``, ``fabric.run(..., backend="pallas")``,
``scheduler.run(..., backend="packed")`` — and each entry point grew its own
keyword surface (chunk sizes, interpret flags, collection switches).
:class:`ExecutionPlan` gathers the *how* of a run into one frozen value and
:func:`run` dispatches the *what* (a program, a fabric, a scheduler) with it:

    from repro.dataplane import Backend, ExecutionPlan, run

    result = run(program, stream,
                 plan=ExecutionPlan(backend=Backend.PACKED, fleet=64))

Dispatch is by program/stream type:

* ``PipelineProgram`` / ``LoweredProgram`` + a 2-D packet array ->
  ``executor.execute`` (returns the output bits);
* ... + a chunk iterator -> ``executor.execute_stream`` (returns
  :class:`~repro.dataplane.executor.StreamResult`);
* ... + ``plan.fleet`` set -> ``fleet.execute_fleet`` over N stream
  replicas (returns :class:`~repro.dataplane.fleet.FleetRunResult`);
* ``SwitchFabric`` -> ``fabric.run`` (hop-scanned when the plan allows);
* ``SwitchScheduler`` -> ``scheduler.run`` on a mixed tenant stream;
* ``Backend.INTERPRETER`` -> the legacy per-op reference interpreter
  (``core.interpreter.run_program``) — the correctness witness, only
  reachable through :func:`run` (the fused executors never accept it).

The legacy keyword surfaces remain as thin shims: every ``backend=`` string
the executors accepted still works (``executor.resolve_backend`` coerces
:class:`Backend` values and their string aliases alike), so existing call
sites and tests keep passing while new code states its plan once.
"""
from __future__ import annotations

import dataclasses
import enum


class Backend(enum.Enum):
    """Executor backend, replacing the stringly-typed ``backend=`` knob.

    Values are the legacy strings, so ``Backend.FUSED.value`` is a valid
    argument anywhere a string was accepted (and vice versa through
    :meth:`coerce`).
    """

    AUTO = "auto"
    FUSED = "jnp"          # fused op-table scan (alias: "fused")
    PALLAS = "pallas"      # kernels.optable_exec (interpret off-TPU)
    PACKED = "packed"      # bit-packed PHV XNOR+popcount
    INTERPRETER = "interpreter"  # legacy per-op reference (run() only)

    @classmethod
    def coerce(cls, value: "Backend | str") -> "Backend":
        """Accept a :class:`Backend`, its value, or a legacy alias."""
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            alias = _ALIASES.get(value.lower())
            if alias is not None:
                return alias
        raise ValueError(
            f"unknown backend {value!r}; expected one of "
            f"{sorted(_ALIASES)} or a Backend member"
        )


_ALIASES: dict[str, Backend] = {
    **{b.value: b for b in Backend},
    "fused": Backend.FUSED,
}


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Everything about *how* to run a program, in one frozen value.

    ``backend``     executor backend (:class:`Backend` or legacy string).
    ``chunk_size``  packets per device dispatch (None -> executor default;
                    for fleets this is the *per-stream* chunk).
    ``interpret``   force/disable Pallas interpreter mode (None -> auto:
                    interpret off-TPU).
    ``scan_hops``   fabric hop execution: True -> one ``lax.scan`` over
                    stacked hop tables, False -> unrolled per-hop dispatch,
                    None -> scan whenever the hops stack (same row/register
                    shapes; they always do for slices of one program).
    ``fleet``       batch this many independent streams through one
                    compiled executor (None -> single-stream paths).
    ``devices``     shard the fleet's stream axis over this many devices
                    via ``shard_map`` (None -> 1 when the stream count
                    does not divide the device count, else all local
                    devices).
    ``collect``     keep outputs (streaming paths default to stats-only).
    ``merged``      multi-tenant merged-table layout ("interleave" packs
                    tenants' elements onto shared stages, "concat" stacks
                    them; None -> the scheduler's configured layout).  Only
                    meaningful when running a ``SwitchScheduler``.
    """

    backend: Backend | str = Backend.AUTO
    chunk_size: int | None = None
    interpret: bool | None = None
    scan_hops: bool | None = None
    fleet: int | None = None
    devices: int | None = None
    collect: bool = False
    merged: str | None = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "backend", Backend.coerce(self.backend))
        if self.chunk_size is not None and self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.fleet is not None and self.fleet < 1:
            raise ValueError(f"fleet must be >= 1, got {self.fleet}")
        if self.devices is not None and self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        if self.merged is not None and self.merged not in (
            "interleave", "concat"
        ):
            raise ValueError(
                "merged must be 'interleave', 'concat', or None, "
                f"got {self.merged!r}"
            )

    @property
    def backend_str(self) -> str:
        """The legacy string the executor keyword surface expects."""
        return self.backend.value


def run(program, stream, *, plan: ExecutionPlan | None = None):
    """Execute ``stream`` through ``program`` according to ``plan``.

    See the module docstring for the dispatch table.  ``stream`` may be a
    ``(batch, input_bits)`` {0,1} array, an iterator of such chunks, a
    ``(tenant_ids, bits)`` mixed stream (for a scheduler), or a sequence of
    per-stream chunk iterators (for a fleet plan).
    """
    from repro.dataplane import executor as _executor
    from repro.dataplane import fabric as _fabric
    from repro.dataplane import fleet as _fleet
    from repro.dataplane import multitenant as _multitenant
    from repro.dataplane.lowering import LoweredProgram, lower_program

    plan = plan or ExecutionPlan()

    if isinstance(program, _multitenant.SwitchScheduler):
        if plan.backend is Backend.INTERPRETER:
            raise ValueError("the interpreter backend serves single programs")
        return program.run(
            stream,
            backend=plan.backend_str,
            chunk_size=plan.chunk_size,
            collect=True,
            interpret=plan.interpret,
            merged=plan.merged,
        )

    if isinstance(program, _fabric.SwitchFabric):
        if plan.backend is Backend.INTERPRETER:
            raise ValueError("the interpreter backend has no fabric form")
        return program.run(stream, plan=plan)

    if plan.backend is Backend.INTERPRETER:
        import jax.numpy as jnp
        import numpy as np

        from repro.core.interpreter import run_program

        if isinstance(program, LoweredProgram):
            raise ValueError(
                "the interpreter runs source PipelinePrograms; pass the "
                "un-lowered program for Backend.INTERPRETER"
            )
        return np.asarray(run_program(program, jnp.asarray(stream)))

    lowered = (
        program
        if isinstance(program, LoweredProgram)
        else lower_program(program)
    )

    if plan.fleet is not None:
        return _fleet.execute_fleet(lowered, stream, plan=plan)

    if hasattr(stream, "ndim") and getattr(stream, "ndim", 0) == 2:
        return _executor.execute(
            lowered,
            stream,
            backend=plan.backend_str,
            chunk_size=plan.chunk_size,
            interpret=plan.interpret,
        )

    return _executor.execute_stream(
        lowered,
        stream,
        backend=plan.backend_str,
        chunk_size=plan.chunk_size or _executor.DEFAULT_CHUNK,
        collect=plan.collect,
        interpret=plan.interpret,
    )
