"""Per-stage and per-hop telemetry for simulated switch fabrics.

Answers the operator questions a real RMT deployment would ask of its
pipeline: how full is the PHV at each element (occupancy), how many ALU
lanes does each element burn (utilization), and how does the *measured*
simulator rate compare with the chip's analytic packets/s from
``core.throughput``.

Occupancy comes from a def/use liveness pass over the program: a field is
live from the element that writes it (the parser, for inputs) through its
last reader (the deparser, for outputs).  An element's occupancy is
``max(live-in, live-out)`` bits — read-before-write means a stage's inputs
and outputs share the PHV transiently without both counting, which is the
same overlay discipline the compiler's allocator enforces, so the peak here
is bounded by ``PipelineProgram.peak_phv_bits``.

Invariants:

* **Observation only** — telemetry never influences execution; it is
  derived from the program (static footprints) and from timings a fabric
  run hands over (measured rates).
* **One liveness rule** — occupancy uses the same def/use pass as the
  lowering's register compaction (``lowering._liveness``), so
  ``max(occupancy_bits) <= PipelineProgram.peak_phv_bits <= chip.phv_bits``
  holds by construction.
* **Budgets judged against the running chip** — utilization denominators
  come from the fabric's ``ChipSpec`` (the switches actually executing),
  not the program's compile-time target.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import throughput
from repro.core.pipeline import ChipSpec, PipelineProgram
from repro.dataplane.lowering import _liveness
from repro.obs.slo import BreachEvent, SloStatus


@dataclasses.dataclass(frozen=True)
class StageTelemetry:
    """One pipeline element's static footprint."""

    index: int
    stage: str                # which of the paper's 5 steps
    ops: int
    written_bits: int
    alu_lanes: int            # 32-bit lanes consumed (sub-word ops share)
    alu_utilization: float    # lanes / chip budget
    live_in_bits: int
    live_out_bits: int

    @property
    def occupancy_bits(self) -> int:
        return max(self.live_in_bits, self.live_out_bits)


def stage_telemetry(
    prog: PipelineProgram, chip: ChipSpec | None = None
) -> list[StageTelemetry]:
    """Static per-element footprint.  ``chip`` is the hardware the budgets
    are judged against — defaults to the program's compile-time target, but a
    fabric running the program on different switches passes its own."""
    chip = chip or prog.chip
    num_el = len(prog.elements)
    # Same def/use pass the lowering's register compaction runs on — one
    # source of truth for the liveness rules.
    def_elem, last_use = _liveness(prog)
    widths: dict[int, int] = {f.fid: f.width for f in prog.input_fields}
    for el in prog.elements:
        for op in el.ops:
            widths[op.dst.fid] = op.dst.width

    # live-out[e] = sum of widths of fields defined at or before e and used
    # strictly after e; live-in[e] = live-out[e-1].
    live_out = [0] * num_el
    for fid, d in def_elem.items():
        for e in range(max(d, 0), min(last_use[fid], num_el)):
            live_out[e] += widths[fid]
    live_in = [sum(f.width for f in prog.input_fields)] + live_out[:-1]

    out = []
    for e, el in enumerate(prog.elements):
        bits = sum(op.dst.width for op in el.ops)
        lanes = math.ceil(bits / 32)
        out.append(
            StageTelemetry(
                index=e,
                stage=el.stage,
                ops=len(el.ops),
                written_bits=bits,
                alu_lanes=lanes,
                alu_utilization=lanes / chip.max_parallel_ops,
                live_in_bits=live_in[e],
                live_out_bits=live_out[e],
            )
        )
    return out


@dataclasses.dataclass(frozen=True)
class HopTelemetry:
    """One switch in the fabric chain (or one recirculation pass)."""

    hop: int
    elements: int
    element_range: tuple[int, int]
    peak_occupancy_bits: int
    peak_alu_utilization: float
    measured_pps: float | None = None   # simulator rate, if a run was timed


@dataclasses.dataclass(frozen=True)
class FabricTelemetry:
    """Fabric-level rollup: stages, hops, analytic vs measured rate."""

    mode: str
    chip_name: str
    stages: tuple[StageTelemetry, ...]
    hops: tuple[HopTelemetry, ...]
    analytic: throughput.ThroughputReport
    measured_pps: float | None = None

    @property
    def peak_occupancy_bits(self) -> int:
        return max((s.occupancy_bits for s in self.stages), default=0)

    _phv_bits: int = 4096

    @property
    def phv_utilization(self) -> float:
        return self.peak_occupancy_bits / self._phv_bits

    def render(self) -> str:
        """Human-readable telemetry table (the demo/benchmark printout)."""
        lines = [
            f"fabric[{self.chip_name}] mode={self.mode} "
            f"hops={len(self.hops)} elements={self.analytic.elements_used} "
            f"peak_phv={self.peak_occupancy_bits}b",
            f"  analytic: {self.analytic.packets_per_second:.3e} pkt/s "
            f"({self.analytic.passes} pass(es), "
            f"{self.analytic.neurons_per_second:.3e} neurons/s)",
        ]
        if self.measured_pps is not None:
            ratio = self.measured_pps / self.analytic.packets_per_second
            lines.append(
                f"  measured: {self.measured_pps:.3e} pkt/s "
                f"(simulator = {ratio:.2e} x ASIC model)"
            )
        lines.append(
            "  hop  elements   peak-PHV(b)  peak-ALU-util   measured pkt/s"
        )
        for h in self.hops:
            m = f"{h.measured_pps:.3e}" if h.measured_pps is not None else "-"
            lines.append(
                f"  {h.hop:>3}  {h.element_range[0]:>3}..{h.element_range[1]:<4} "
                f" {h.peak_occupancy_bits:>8}     {h.peak_alu_utilization:>6.1%}"
                f"        {m:>10}"
            )
        by_stage: dict[str, int] = {}
        for s in self.stages:
            key = s.stage.split("_l")[0].split("_x")[0]
            by_stage[key] = by_stage.get(key, 0) + 1
        lines.append(
            "  stages: "
            + ", ".join(f"{k}x{v}" for k, v in sorted(by_stage.items()))
        )
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class TenantTelemetry:
    """One tenant's view of a shared chip (``dataplane.multitenant``).

    Static fields come from the tenant's own program (stage occupancy and
    ALU budgets are per-program — merging relocates registers, it never
    changes a tenant's footprint); traffic fields come from a scheduler run.
    """

    tid: int
    name: str
    elements: int
    slot_window: tuple[int, int]       # register window in the shared file
    element_range: tuple[int, int] | None  # rows in the merged table (merged mode)
    weight: float
    analytic_pps: float                # chip-model rate under the active mode
    peak_occupancy_bits: int
    peak_alu_utilization: float
    packets: int = 0
    served: int = 0
    dropped: int = 0
    deferred: int = 0
    slices: int = 0                    # scheduling turns (time-sliced mode)
    measured_pps: float | None = None
    # SLO posture (repro.obs.slo), set when the scheduler has an SLO for
    # this tenant: windowed burn rates plus the deterministic breach log.
    slo: SloStatus | None = None
    breach_events: tuple[BreachEvent, ...] = ()

    @property
    def slo_breached(self) -> bool:
        return self.slo is not None and self.slo.breached


@dataclasses.dataclass(frozen=True)
class MultiTenantTelemetry:
    """Scheduler-level rollup: who shares the chip and what each got."""

    mode: str                          # "merged" | "time_sliced"
    chip_name: str
    elements_used: int                 # merged footprint (sum of tenants)
    elements_available: int
    phv_bits_used: int                 # sum of tenant peak PHV footprints
    phv_bits_available: int
    tenants: tuple[TenantTelemetry, ...]
    measured_pps: float | None = None  # aggregate over the mixed stream

    @property
    def total_packets(self) -> int:
        return sum(t.packets for t in self.tenants)

    @property
    def total_dropped(self) -> int:
        return sum(t.dropped for t in self.tenants)

    @property
    def total_deferred(self) -> int:
        return sum(t.deferred for t in self.tenants)

    @property
    def breached_tenants(self) -> tuple[str, ...]:
        """Names of tenants currently burning budget faster than allowed."""
        return tuple(t.name for t in self.tenants if t.slo_breached)

    def tenant(self, key: int | str) -> TenantTelemetry:
        """Look up one tenant's telemetry by tid or by name.

        The per-tenant query path: ``tel.tenant(0).dropped`` /
        ``tel.tenant("iot").deferred`` answer "who lost packets and who
        waited" without aggregating away the tenant axis — the counts that
        feed the per-tenant ``mt.dropped_total`` / ``mt.deferred_total``
        observability metrics.
        """
        for t in self.tenants:
            if (t.tid == key) if isinstance(key, int) else (t.name == key):
                return t
        raise KeyError(f"no tenant {key!r} in this telemetry")

    def dropped_for(self, key: int | str) -> int:
        """Tail-dropped packet count for one tenant (tid or name)."""
        return self.tenant(key).dropped

    def deferred_for(self, key: int | str) -> int:
        """Deferred packet-turn count for one tenant (tid or name)."""
        return self.tenant(key).deferred

    def render(self) -> str:
        lines = [
            f"scheduler[{self.chip_name}] mode={self.mode} "
            f"tenants={len(self.tenants)} "
            f"elements={self.elements_used}/{self.elements_available} "
            f"phv={self.phv_bits_used}/{self.phv_bits_available}b",
        ]
        if self.measured_pps is not None:
            lines.append(f"  aggregate measured: {self.measured_pps:.3e} pkt/s")
        lines.append(
            "  tid name             elems  window      weight  analytic pkt/s"
            "  packets  drop  defer  slices  measured pkt/s"
        )
        for t in self.tenants:
            m = f"{t.measured_pps:.3e}" if t.measured_pps is not None else "-"
            lines.append(
                f"  {t.tid:>3} {t.name:<16} {t.elements:>5} "
                f" {t.slot_window[0]:>4}..{t.slot_window[1]:<5} "
                f"{t.weight:>6.2f}  {t.analytic_pps:>14.3e} "
                f" {t.packets:>7}  {t.dropped:>4}  {t.deferred:>5} "
                f" {t.slices:>6}  {m:>14}"
            )
        with_slo = [t for t in self.tenants if t.slo is not None]
        if with_slo:
            lines.append(
                "  slo: tenant           state     delay-burn   pps-burn"
                "   breaches"
            )
            for t in with_slo:
                s = t.slo
                db = (
                    f"{s.delay_burn_rate:.2f}x"
                    if s.delay_burn_rate is not None else "-"
                )
                pb = (
                    f"{s.pps_burn_rate:.2f}x"
                    if s.pps_burn_rate is not None else "-"
                )
                state = "BREACHED" if s.breached else "ok"
                lines.append(
                    f"       {t.name:<16} {state:<9} {db:>10} {pb:>10} "
                    f" {len(t.breach_events):>8}"
                )
        return "\n".join(lines)


def fabric_telemetry(
    prog: PipelineProgram,
    mode: str,
    hop_ranges: list[tuple[int, int]],
    hop_pps: list[float] | None = None,
    measured_pps: float | None = None,
    chip: ChipSpec | None = None,
) -> FabricTelemetry:
    chip = chip or prog.chip
    stages = stage_telemetry(prog, chip)
    hops = []
    for i, (a, b) in enumerate(hop_ranges):
        seg = stages[a:b]
        hops.append(
            HopTelemetry(
                hop=i,
                elements=b - a,
                element_range=(a, b),
                peak_occupancy_bits=max(s.occupancy_bits for s in seg),
                peak_alu_utilization=max(s.alu_utilization for s in seg),
                measured_pps=hop_pps[i] if hop_pps else None,
            )
        )
    analytic = throughput.report_for_program(prog)
    return FabricTelemetry(
        mode=mode,
        chip_name=chip.name,
        stages=tuple(stages),
        hops=tuple(hops),
        analytic=analytic,
        measured_pps=measured_pps,
        _phv_bits=chip.phv_bits,
    )
