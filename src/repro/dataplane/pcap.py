"""Pcap ingestion: capture files -> activation-bit traces.

N2Net's premise is that the model input "is encoded in the network packets'
header" — this module is where *real* packets enter the reproduction.  It
reads classic pcap and pcapng capture files with zero dependencies beyond
numpy (no scapy, no libpcap, no network access), slices the Ethernet/IPv4/
TCP/UDP header fields of every packet into the same fixed-width
activation-bit matrices the synthetic ``traffic`` scenarios emit, and writes
deterministic synthetic captures so tests/CI round-trip real file bytes
without shipping binary fixtures.  See ``docs/TRAFFIC.md`` for the full
bit-encoding tables and usage guide.

The pieces, in pipeline order:

* :func:`read_pcap` — parse capture bytes (or a file path) into a
  :class:`Capture`: a padded ``(n, max_len)`` uint8 packet matrix plus
  per-packet lengths and float64 timestamps.  Classic pcap is supported in
  all four magic variants (micro/nanosecond x little/big endian); pcapng
  supports SHB/IDB/EPB/SPB blocks, both byte orders, and per-interface
  ``if_tsresol``.  Malformed or truncated input raises
  :class:`PcapFormatError` — never silently drops tail packets.
* :func:`parse_headers` / :func:`featurize` — the hot path: fully
  vectorized header slicing (no per-packet Python loop) from the packet
  matrix into ``FEATURE_LAYOUT`` fields — addresses, ports, protocol,
  length, TCP flags, and log-bucketed inter-arrival times — then into a
  ``(n, PCAP_FEATURE_BITS)`` {0,1} int32 matrix, XOR-foldable to any model
  input width exactly like every synthetic scenario.
* :func:`write_pcap` / :func:`write_pcapng` — byte-exact writers for both
  formats; :func:`synthesize_capture` emits a deterministic labeled
  two-class trace (IoT-style UDP telemetry vs TCP SYN flood) whose write ->
  read -> featurize round trip is the test/CI substrate.
* :func:`pcap_scenario` / :func:`register_pcap_scenario` — wrap a capture
  as a ``traffic.Scenario`` (cyclic replay) and register it in
  ``traffic.SCENARIOS``, which makes captures first-class everywhere
  scenarios already are: ``traffic.generate``/``stream``, the BNN trainer's
  task builder, and pcap-backed tenants in ``traffic.mixed_tenant_stream``.
* :func:`label_packets` — the labeling hook: apply a rule over parsed
  header fields to get per-packet int labels, feeding
  ``train.bnn_trainer.make_capture_task``'s temporal splits.

Invariants:

* **Determinism** — same capture bytes mean the same :class:`Capture`, the
  same features, and the same scenario packets on any platform; writers are
  deterministic functions of ``(packets, timestamps)``, and
  :func:`synthesize_capture` of ``(n, seed)`` alone.
* **Round trip** — ``read_pcap(write_pcap(pkts, ts))`` reproduces every
  packet byte-exactly and every timestamp to the written resolution; same
  for pcapng.
* **Scenario contract** — a registered pcap scenario obeys the
  canonical-chunk contract of ``traffic``: ``stream`` at any chunking (or
  paused and resumed mid-trace) replays exactly ``generate``'s packets.
  Replay is cyclic over the capture and *seed-independent* — the capture
  is the world.
* **Shape/domain** — :func:`featurize` returns ``(n, width)`` int32 in
  {0,1} for any requested width; fields absent from a packet (non-IPv4,
  non-TCP/UDP, truncated headers) contribute zero bits, never garbage.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import time
from typing import Callable, Sequence

import numpy as np

from repro import obs
from repro.dataplane import traffic

__all__ = [
    "Capture",
    "FEATURE_LAYOUT",
    "HeaderFields",
    "LINKTYPE_ETHERNET",
    "PCAP_FEATURE_BITS",
    "PcapFormatError",
    "featurize",
    "label_packets",
    "parse_headers",
    "pcap_scenario",
    "read_pcap",
    "register_pcap_scenario",
    "synthesize_capture",
    "write_pcap",
    "write_pcapng",
]

LINKTYPE_ETHERNET = 1

# Classic pcap magics, keyed by their little-endian read: value -> (endian
# of the whole file, timestamp fraction unit in seconds).
_PCAP_MAGIC_US = 0xA1B2C3D4
_PCAP_MAGIC_NS = 0xA1B23C4D
_CLASSIC_MAGICS = {
    _PCAP_MAGIC_US: ("<", 1e-6),
    _PCAP_MAGIC_NS: ("<", 1e-9),
    0xD4C3B2A1: (">", 1e-6),
    0x4D3CB2A1: (">", 1e-9),
}

# pcapng block types / byte-order magic.
_NG_SHB = 0x0A0D0D0A   # section header (palindromic: endian-independent)
_NG_IDB = 0x00000001   # interface description
_NG_SPB = 0x00000003   # simple packet
_NG_EPB = 0x00000006   # enhanced packet
_NG_BOM = 0x1A2B3C4D
_NG_SNAPLEN = 65535


class PcapFormatError(ValueError):
    """Capture bytes are not a well-formed pcap/pcapng file."""


# ---------------------------------------------------------------------------
# Capture container
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Capture:
    """A parsed capture: padded packet bytes + lengths + timestamps.

    ``data`` is ``(n, max_len)`` uint8, zero-padded past each packet's
    ``lengths[i]``; ``timestamps`` are float64 seconds (absolute, in capture
    order).  The padded-matrix layout is what makes :func:`parse_headers`
    one vectorized pass instead of a per-packet loop.

    float64 seconds resolve ~0.24 us at epoch scale (2**-22 s near 2**31),
    so nanosecond-resolution captures with absolute epoch timestamps
    quantize to that granularity on read; timestamps near 0 keep full
    precision.  IAT features bucket at >= 1 us boundaries, so this only
    matters to consumers doing their own sub-microsecond timing.
    """

    data: np.ndarray
    lengths: np.ndarray
    timestamps: np.ndarray
    linktype: int = LINKTYPE_ETHERNET
    fmt: str = "pcap"

    def __post_init__(self):
        n = self.lengths.shape[0]
        if self.data.shape[0] != n or self.timestamps.shape[0] != n:
            raise ValueError(
                f"inconsistent capture: {self.data.shape[0]} packet rows, "
                f"{n} lengths, {self.timestamps.shape[0]} timestamps"
            )

    @property
    def num_packets(self) -> int:
        return int(self.lengths.shape[0])

    def packet(self, i: int) -> bytes:
        """Packet ``i``'s exact captured bytes (padding stripped)."""
        return self.data[i, : int(self.lengths[i])].tobytes()

    def packets(self) -> list[bytes]:
        return [self.packet(i) for i in range(self.num_packets)]


def _pack_capture(
    pkts: list[bytes], ts: list[float], linktype: int, fmt: str
) -> Capture:
    n = len(pkts)
    max_len = max((len(p) for p in pkts), default=0)
    data = np.zeros((n, max_len), np.uint8)
    lengths = np.zeros(n, np.int32)
    for i, p in enumerate(pkts):
        lengths[i] = len(p)
        data[i, : len(p)] = np.frombuffer(p, np.uint8)
    return Capture(
        data=data,
        lengths=lengths,
        timestamps=np.asarray(ts, np.float64),
        linktype=linktype,
        fmt=fmt,
    )


# ---------------------------------------------------------------------------
# Readers
# ---------------------------------------------------------------------------

def _as_bytes(source) -> bytes:
    if isinstance(source, (bytes, bytearray, memoryview)):
        return bytes(source)
    if isinstance(source, (str, os.PathLike)):
        with open(source, "rb") as fh:
            return fh.read()
    raise TypeError(
        f"read_pcap wants bytes or a file path, got {type(source).__name__}"
    )


def read_pcap(source) -> Capture:
    """Parse a capture file (path or raw bytes), sniffing the format.

    Dispatches on the first 4 bytes: any classic-pcap magic (micro/nano,
    either endian) or a pcapng section header.  Raises
    :class:`PcapFormatError` on unknown magic, truncation, or structural
    corruption.
    """
    raw = _as_bytes(source)
    if len(raw) < 4:
        raise PcapFormatError(
            f"capture is {len(raw)} bytes — shorter than any magic number"
        )
    magic = struct.unpack_from("<I", raw, 0)[0]
    if magic == _NG_SHB:
        return _read_pcapng(raw)
    if magic in _CLASSIC_MAGICS:
        return _read_classic(raw)
    raise PcapFormatError(f"unknown capture magic 0x{magic:08X}")


def _read_classic(raw: bytes) -> Capture:
    endian, frac = _CLASSIC_MAGICS[struct.unpack_from("<I", raw, 0)[0]]
    if len(raw) < 24:
        raise PcapFormatError(
            f"classic pcap global header truncated ({len(raw)} < 24 bytes)"
        )
    _, _, _, _, _, _, network = struct.unpack_from(endian + "IHHiIII", raw, 0)
    pkts: list[bytes] = []
    ts: list[float] = []
    off = 24
    while off < len(raw):
        if len(raw) - off < 16:
            raise PcapFormatError(
                f"record header truncated at byte {off} "
                f"({len(raw) - off} of 16 bytes)"
            )
        sec, tfrac, incl, _orig = struct.unpack_from(endian + "IIII", raw, off)
        off += 16
        if len(raw) - off < incl:
            raise PcapFormatError(
                f"record {len(pkts)} data truncated at byte {off} "
                f"({len(raw) - off} of {incl} bytes)"
            )
        pkts.append(raw[off : off + incl])
        ts.append(sec + tfrac * frac)
        off += incl
    return _pack_capture(pkts, ts, int(network), "pcap")


def _ng_tsresol(options: bytes, endian: str) -> float:
    """Seconds per timestamp unit from an IDB option block (default 1e-6)."""
    off = 0
    while off + 4 <= len(options):
        code, olen = struct.unpack_from(endian + "HH", options, off)
        off += 4
        if code == 0:  # opt_endofopt
            break
        if off + olen > len(options):
            raise PcapFormatError(
                f"interface option {code} claims {olen} value bytes; only "
                f"{len(options) - off} remain in the block"
            )
        val = options[off : off + olen]
        off += olen + ((-olen) % 4)
        if code == 9 and olen == 1:  # if_tsresol
            v = val[0]
            return 2.0 ** -(v & 0x7F) if v & 0x80 else 10.0 ** -v
    return 1e-6


def _read_pcapng(raw: bytes) -> Capture:
    endian: str | None = None
    # (linktype, snaplen, res); snaplen 0 means unlimited.  Interface ids
    # are section-scoped, so a new SHB resets the list.
    interfaces: list[tuple[int, int, float]] = []
    linktype: int | None = None
    pkts: list[bytes] = []
    ts: list[float] = []
    off = 0
    while off < len(raw):
        if len(raw) - off < 12:
            raise PcapFormatError(
                f"pcapng block header truncated at byte {off}"
            )
        if struct.unpack_from("<I", raw, off)[0] == _NG_SHB:
            bom = struct.unpack_from("<I", raw, off + 8)[0]
            if bom == _NG_BOM:
                endian = "<"
            elif bom == struct.unpack(">I", struct.pack("<I", _NG_BOM))[0]:
                endian = ">"
            else:
                raise PcapFormatError(
                    f"pcapng byte-order magic 0x{bom:08X} at byte {off + 8} "
                    "is neither endianness"
                )
            interfaces = []  # new section: interface ids start over
        if endian is None:
            raise PcapFormatError("pcapng file does not start with a "
                                  "section header block")
        btype, blen = struct.unpack_from(endian + "II", raw, off)
        if blen < 12 or blen % 4:
            raise PcapFormatError(
                f"pcapng block at byte {off} has bad length {blen}"
            )
        if len(raw) - off < blen:
            raise PcapFormatError(
                f"pcapng block at byte {off} truncated "
                f"({len(raw) - off} of {blen} bytes)"
            )
        trailer = struct.unpack_from(endian + "I", raw, off + blen - 4)[0]
        if trailer != blen:
            raise PcapFormatError(
                f"pcapng block at byte {off}: trailing length {trailer} != "
                f"leading length {blen}"
            )
        body = raw[off + 8 : off + blen - 4]
        if btype == _NG_IDB:
            if len(body) < 8:
                raise PcapFormatError("interface description block too short")
            lt, _, snaplen = struct.unpack_from(endian + "HHI", body, 0)
            interfaces.append(
                (int(lt), snaplen, _ng_tsresol(body[8:], endian))
            )
        elif btype == _NG_EPB:
            if not interfaces:
                raise PcapFormatError(
                    "enhanced packet block before any interface description"
                )
            if len(body) < 20:
                raise PcapFormatError("enhanced packet block too short")
            iface, th, tl, cap, _orig = struct.unpack_from(
                endian + "IIIII", body, 0
            )
            if iface >= len(interfaces):
                raise PcapFormatError(
                    f"enhanced packet block names interface {iface}; only "
                    f"{len(interfaces)} declared"
                )
            if len(body) - 20 < cap:
                raise PcapFormatError(
                    f"packet {len(pkts)} data truncated "
                    f"({len(body) - 20} of {cap} bytes)"
                )
            linktype = _check_packet_linktype(
                linktype, interfaces[iface][0], len(pkts)
            )
            pkts.append(body[20 : 20 + cap])
            ts.append(((th << 32) | tl) * interfaces[iface][2])
        elif btype == _NG_SPB:
            if not interfaces:
                raise PcapFormatError(
                    "simple packet block before any interface description"
                )
            if len(body) < 4:
                raise PcapFormatError("simple packet block too short")
            orig = struct.unpack_from(endian + "I", body, 0)[0]
            snap = interfaces[0][1]
            cap = orig if snap == 0 else min(orig, snap)  # 0 = no limit
            if len(body) - 4 < cap:
                raise PcapFormatError(
                    f"packet {len(pkts)} data truncated "
                    f"({len(body) - 4} of {cap} bytes)"
                )
            linktype = _check_packet_linktype(
                linktype, interfaces[0][0], len(pkts)
            )
            pkts.append(body[4 : 4 + cap])
            ts.append(0.0)  # SPBs carry no timestamp
        # all other block types (NRB, ISB, custom) are skipped whole
        off += blen
    if linktype is None:  # no packets: fall back to the declared interface
        linktype = interfaces[0][0] if interfaces else LINKTYPE_ETHERNET
    return _pack_capture(pkts, ts, linktype, "pcapng")


def _check_packet_linktype(
    seen: int | None, lt: int, packet_index: int
) -> int:
    """One capture, one link type: a ``Capture`` carries a single
    ``linktype``, so packets from interfaces with mixed link types would be
    mis-featurized (e.g. raw-IP bytes sliced at Ethernet offsets) — refuse
    loudly instead."""
    if seen is not None and lt != seen:
        raise PcapFormatError(
            f"packet {packet_index} arrives on a linktype-{lt} interface "
            f"but earlier packets used linktype {seen}; mixed link types "
            "in one capture are not supported"
        )
    return lt


# ---------------------------------------------------------------------------
# Writers
# ---------------------------------------------------------------------------

def _snaplen_for(packets: Sequence[bytes]) -> int:
    """Declared snap length: nothing we serialize whole may exceed it
    (caplen > snaplen reads as corruption to libpcap-based tools)."""
    return max(_NG_SNAPLEN, max((len(p) for p in packets), default=0))


def _check_write_args(packets, timestamps) -> np.ndarray:
    ts = np.asarray(timestamps, np.float64)
    if ts.ndim != 1 or ts.shape[0] != len(packets):
        raise ValueError(
            f"{len(packets)} packets but timestamp shape {ts.shape}"
        )
    if ts.size and (ts < 0).any():
        raise ValueError("timestamps must be non-negative seconds")
    return ts


def write_pcap(
    packets: Sequence[bytes],
    timestamps,
    *,
    path: str | os.PathLike | None = None,
    nanosecond: bool = False,
    endian: str = "<",
) -> bytes:
    """Serialize packets to a classic pcap file; returns the bytes.

    ``timestamps`` are float seconds, stored at micro- (default) or
    nanosecond resolution; ``endian`` picks the file byte order (both are
    valid classic pcap and :func:`read_pcap` accepts either).  Writes to
    ``path`` as well when given.
    """
    if endian not in ("<", ">"):
        raise ValueError(f"endian must be '<' or '>', got {endian!r}")
    ts = _check_write_args(packets, timestamps)
    magic = _PCAP_MAGIC_NS if nanosecond else _PCAP_MAGIC_US
    unit = 1e9 if nanosecond else 1e6
    out = bytearray(
        struct.pack(
            endian + "IHHiIII", magic, 2, 4, 0, 0, _snaplen_for(packets),
            LINKTYPE_ETHERNET,
        )
    )
    for pkt, t in zip(packets, ts):
        # Split before scaling: (t - sec) is exact in float64, so epoch-scale
        # times keep their full sub-second precision (t * unit would not).
        sec = int(t)
        frac = int(round((t - sec) * unit))
        if frac >= int(unit):  # rounding carried into the next second
            sec, frac = sec + 1, 0
        out += struct.pack(endian + "IIII", sec, frac, len(pkt), len(pkt))
        out += pkt
    raw = bytes(out)
    if path is not None:
        with open(path, "wb") as fh:
            fh.write(raw)
    return raw


def write_pcapng(
    packets: Sequence[bytes],
    timestamps,
    *,
    path: str | os.PathLike | None = None,
    endian: str = "<",
) -> bytes:
    """Serialize packets to a pcapng file (SHB + one IDB + EPBs).

    Timestamps are stored at the pcapng default microsecond resolution.
    """
    if endian not in ("<", ">"):
        raise ValueError(f"endian must be '<' or '>', got {endian!r}")
    ts = _check_write_args(packets, timestamps)
    out = bytearray(
        struct.pack(
            endian + "IIIHHqI", _NG_SHB, 28, _NG_BOM, 1, 0, -1, 28
        )
    )
    out += struct.pack(
        endian + "IIHHII", _NG_IDB, 20, LINKTYPE_ETHERNET, 0,
        _snaplen_for(packets), 20,
    )
    for pkt, t in zip(packets, ts):
        ts64 = int(round(t * 1e6))
        pad = (-len(pkt)) % 4
        blen = 32 + len(pkt) + pad
        out += struct.pack(
            endian + "IIIIIII", _NG_EPB, blen, 0, (ts64 >> 32) & 0xFFFFFFFF,
            ts64 & 0xFFFFFFFF, len(pkt), len(pkt),
        )
        out += pkt
        out += b"\x00" * pad
        out += struct.pack(endian + "I", blen)
    raw = bytes(out)
    if path is not None:
        with open(path, "wb") as fh:
            fh.write(raw)
    return raw


# ---------------------------------------------------------------------------
# Header featurizer (the hot path — fully vectorized)
# ---------------------------------------------------------------------------

# Activation-bit layout: field order and width.  Integer fields encode
# little-endian (bit k of the value is column k of the field, matching
# ``traffic._int_bits``); ``iat_bucket`` is a one-hot over 8 log4-spaced
# inter-arrival buckets.  Documented bit-for-bit in docs/TRAFFIC.md.
FEATURE_LAYOUT = (
    ("src_ip", 32),
    ("dst_ip", 32),
    ("src_port", 16),
    ("dst_port", 16),
    ("proto", 8),
    ("ip_len", 16),
    ("tcp_flags", 8),
    ("iat_bucket", 8),
)
PCAP_FEATURE_BITS = sum(width for _, width in FEATURE_LAYOUT)  # 136

_ETHERTYPE_IPV4 = 0x0800
_ETHERTYPE_VLAN = 0x8100


@dataclasses.dataclass(frozen=True)
class HeaderFields:
    """Per-packet parsed header columns (all ``(n,)`` numpy arrays).

    Validity is explicit: ``src_ip``..``ip_len`` are zero wherever
    ``is_ipv4`` is false, ports wherever the packet is neither TCP nor UDP
    (or too short), ``tcp_flags`` wherever ``is_tcp`` is false.
    ``iat_bucket`` is ``clip(floor(log4(1 + iat_us)), 0, 7)`` — log-spaced
    inter-arrival buckets; the first packet's IAT is 0.
    """

    is_ipv4: np.ndarray
    is_tcp: np.ndarray
    is_udp: np.ndarray
    src_ip: np.ndarray
    dst_ip: np.ndarray
    src_port: np.ndarray
    dst_port: np.ndarray
    proto: np.ndarray
    ip_len: np.ndarray
    tcp_flags: np.ndarray
    iat_bucket: np.ndarray


def _iat_buckets(timestamps: np.ndarray) -> np.ndarray:
    iat_us = np.diff(timestamps, prepend=timestamps[:1]) * 1e6
    iat_us = np.maximum(iat_us, 0.0)
    return np.clip(
        (np.log2(iat_us + 1.0) * 0.5).astype(np.int64), 0, 7
    ).astype(np.int32)


def parse_headers(cap: Capture) -> HeaderFields:
    """Vectorized Ethernet/IPv4/TCP/UDP header slicing over a capture.

    One pass of numpy gathers over the padded packet matrix — no per-packet
    Python loop.  Handles untagged Ethernet II and one 802.1Q VLAN tag;
    anything else (non-IPv4 L3, IPv6, truncated headers) yields zeroed
    fields with the validity masks false.
    """
    n = cap.num_packets
    if n == 0:
        z = np.zeros(0, np.uint32)
        zb = np.zeros(0, bool)
        return HeaderFields(zb, zb, zb, z, z, z, z, z, z, z,
                            np.zeros(0, np.int32))
    if cap.linktype != LINKTYPE_ETHERNET:
        raise PcapFormatError(
            f"featurizer supports LINKTYPE_ETHERNET (1); capture is "
            f"linktype {cap.linktype}"
        )
    data = cap.data
    lengths = cap.lengths.astype(np.int64)
    rows = np.arange(n)
    width = data.shape[1]

    def at(off):
        """Byte at per-packet offset ``off``; 0 past the captured length."""
        off = np.asarray(off, np.int64)
        if off.ndim == 0:
            off = np.full(n, off)
        idx = np.minimum(off, width - 1) if width else np.zeros(n, np.int64)
        val = data[rows, idx].astype(np.uint32) if width else np.zeros(
            n, np.uint32
        )
        return np.where(off < lengths, val, 0).astype(np.uint32)

    def be16(off):
        return (at(off) << 8) | at(np.asarray(off, np.int64) + 1)

    def be32(off):
        return (be16(off) << 16) | be16(np.asarray(off, np.int64) + 2)

    eth_type = be16(12)
    vlan = eth_type == _ETHERTYPE_VLAN
    l3 = np.where(vlan, 18, 14).astype(np.int64)
    eth_type = np.where(vlan, be16(16), eth_type)

    vihl = at(l3)
    version = vihl >> 4
    ihl = (vihl & 0xF).astype(np.int64)
    is_ipv4 = (
        (eth_type == _ETHERTYPE_IPV4)
        & (version == 4)
        & (ihl >= 5)
        & (lengths >= l3 + 4 * ihl)
    )
    l4 = l3 + 4 * ihl

    proto = np.where(is_ipv4, at(l3 + 9), 0)
    has_ports = (
        is_ipv4 & np.isin(proto, (6, 17)) & (lengths >= l4 + 4)
    )
    is_tcp = is_ipv4 & (proto == 6) & (lengths >= l4 + 14)
    is_udp = has_ports & (proto == 17)

    return HeaderFields(
        is_ipv4=is_ipv4,
        is_tcp=is_tcp,
        is_udp=is_udp,
        src_ip=np.where(is_ipv4, be32(l3 + 12), 0).astype(np.uint32),
        dst_ip=np.where(is_ipv4, be32(l3 + 16), 0).astype(np.uint32),
        src_port=np.where(has_ports, be16(l4), 0).astype(np.uint32),
        dst_port=np.where(has_ports, be16(l4 + 2), 0).astype(np.uint32),
        proto=proto.astype(np.uint32),
        ip_len=np.where(is_ipv4, be16(l3 + 2), 0).astype(np.uint32),
        tcp_flags=np.where(is_tcp, at(l4 + 13), 0).astype(np.uint32),
        iat_bucket=_iat_buckets(cap.timestamps),
    )


def featurize(cap: Capture, input_bits: int | None = None) -> np.ndarray:
    """Capture -> ``(n, width)`` {0,1} int32 activation-bit matrix.

    With ``input_bits=None`` the full ``PCAP_FEATURE_BITS``-column layout
    (``FEATURE_LAYOUT``) is returned; otherwise it is XOR-folded/tiled to
    exactly ``input_bits`` columns with the same ``traffic._fold_bits``
    transform every synthetic scenario uses.

    Instrumented through ``repro.obs`` (featurized-packet counter, per-call
    latency histogram, throughput gauge) — no-ops unless the global
    observability switch is on.
    """
    if obs.enabled():
        with obs.span(
            "execute:pcap_featurize", cat="execute", packets=cap.num_packets
        ):
            t0 = time.perf_counter()
            out = _featurize(cap, input_bits)
            dt = time.perf_counter() - t0
        m = obs.registry()
        m.counter("pcap.packets_featurized_total").inc(cap.num_packets)
        m.histogram("pcap.featurize_seconds").observe(dt)
        if dt > 0:
            m.gauge("pcap.featurize_pps").set(cap.num_packets / dt)
        return out
    return _featurize(cap, input_bits)


def _featurize(cap: Capture, input_bits: int | None = None) -> np.ndarray:
    f = parse_headers(cap)
    n = cap.num_packets
    if n == 0:
        bits = np.zeros((0, PCAP_FEATURE_BITS), np.int32)
    else:
        cols = {
            "src_ip": f.src_ip, "dst_ip": f.dst_ip,
            "src_port": f.src_port, "dst_port": f.dst_port,
            "proto": f.proto, "ip_len": f.ip_len, "tcp_flags": f.tcp_flags,
        }
        parts = []
        for name, fw in FEATURE_LAYOUT:
            if name == "iat_bucket":
                parts.append(
                    (f.iat_bucket[:, None] == np.arange(fw)).astype(np.int32)
                )
            else:
                parts.append(traffic._int_bits(cols[name], fw))
        bits = np.concatenate(parts, axis=1)
    if input_bits is None:
        return bits
    if input_bits <= 0:
        raise ValueError(f"input_bits must be positive, got {input_bits}")
    return traffic._fold_bits(bits, input_bits)


def label_packets(
    cap: Capture,
    rule: Callable[[HeaderFields], np.ndarray],
    *,
    fields: HeaderFields | None = None,
) -> np.ndarray:
    """Apply a labeling rule over parsed header fields.

    ``rule`` sees the capture's :class:`HeaderFields` and returns ``(n,)``
    integer labels — e.g. ``lambda f: (f.proto == 6).astype(int)`` labels
    TCP packets 1.  This is the hook that turns a raw capture into a
    supervised task for ``train.bnn_trainer.make_capture_task``.  Pass
    ``fields`` to reuse an existing :func:`parse_headers` result instead of
    re-parsing the capture.
    """
    labels = np.asarray(rule(fields if fields is not None else parse_headers(cap)))
    if labels.shape != (cap.num_packets,):
        raise ValueError(
            f"labeling rule returned shape {labels.shape} for "
            f"{cap.num_packets} packets"
        )
    return labels.astype(np.int32)


# ---------------------------------------------------------------------------
# Scenario integration
# ---------------------------------------------------------------------------

def pcap_scenario(
    source,
    *,
    name: str,
    description: str | None = None,
    features: np.ndarray | None = None,
) -> traffic.Scenario:
    """Wrap a capture (path, bytes, or :class:`Capture`) as a Scenario.

    The capture is featurized once; emission replays its feature rows
    cyclically by absolute packet position, so the scenario meets the
    canonical-chunk contract by construction (same packets under any
    chunking, pause, or resume) and ignores the stream seed — the capture
    is the world.  Pass ``features`` (a full-width :func:`featurize`
    result) to reuse work the caller already did instead of re-featurizing.
    """
    cap = source if isinstance(source, Capture) else read_pcap(source)
    if features is None:
        feats = featurize(cap)
    else:
        feats = np.asarray(features, np.int32)
        if feats.shape != (cap.num_packets, PCAP_FEATURE_BITS):
            raise ValueError(
                f"features must be ({cap.num_packets}, {PCAP_FEATURE_BITS}) "
                f"full-width featurize output, got {feats.shape}"
            )
    if feats.shape[0] == 0:
        raise PcapFormatError(
            f"cannot build scenario {name!r} from an empty capture"
        )

    def _setup(rng, bits):
        return traffic._fold_bits(feats, bits)

    def _emit(state, rng, start, n, bits):
        return state[(start + np.arange(n)) % state.shape[0]]

    return traffic.Scenario(
        name,
        description
        or f"pcap replay ({feats.shape[0]} packets, {cap.fmt})",
        _setup,
        _emit,
    )


def register_pcap_scenario(
    name: str,
    source,
    *,
    description: str | None = None,
    features: np.ndarray | None = None,
    overwrite: bool = False,
) -> traffic.Scenario:
    """Build a pcap scenario and register it in ``traffic.SCENARIOS``.

    Once registered, the capture is usable everywhere a scenario name is:
    ``traffic.generate``/``stream``, ``make_traffic_task``, and pcap-backed
    tenants in ``traffic.mixed_tenant_stream``.
    """
    return traffic.register_scenario(
        pcap_scenario(
            source, name=name, description=description, features=features
        ),
        overwrite=overwrite,
    )


# ---------------------------------------------------------------------------
# Deterministic synthetic captures (the test/CI substrate)
# ---------------------------------------------------------------------------

def synthesize_capture(
    n: int, seed: int = 0, *, flood_frac: float = 0.35
) -> tuple[list[bytes], np.ndarray, np.ndarray]:
    """A deterministic labeled two-class packet trace, as raw bytes.

    Class 0 (weight ``1 - flood_frac``) is IoT-style telemetry: a 32-device
    fleet sends UDP/5683 readings to a gateway at millisecond inter-arrival
    times.  Class 1 is a TCP SYN flood: spoofed random source addresses
    hammer one victim ``:80`` at microsecond IATs.  Returns ``(packets,
    timestamps, labels)`` ready for :func:`write_pcap` /
    :func:`write_pcapng`; everything derives from ``(n, seed)`` alone, so
    tests and CI can round-trip real capture *files* without shipping
    binary fixtures.
    """
    if n < 0:
        raise ValueError(f"packet count must be >= 0, got {n}")
    rng = np.random.default_rng([seed, 0x9CA9])
    labels = (rng.random(n) < flood_frac).astype(np.int32)
    flood = labels == 1

    def store8(d, col, vals):
        d[:, col] = np.asarray(vals, np.uint64) & 0xFF

    def store16(d, col, vals):
        v = np.asarray(vals, np.uint64)
        d[:, col] = (v >> 8) & 0xFF
        d[:, col + 1] = v & 0xFF

    def store32(d, col, vals):
        v = np.asarray(vals, np.uint64)
        store16(d, col, v >> 16)
        store16(d, col + 2, v & 0xFFFF)

    def eth_ip_common(d, total_len, ttl, proto, src_ip, dst_ip, df):
        d[:, 0:6] = (2, 0, 0, 0, 0, 1)        # gateway/victim MAC
        d[:, 6:12] = (2, 0, 0, 0, 0, 2)
        store16(d, 12, np.full(n, _ETHERTYPE_IPV4))
        store8(d, 14, np.full(n, 0x45))        # IPv4, IHL 5
        store16(d, 16, total_len)
        store16(d, 18, np.arange(n) & 0xFFFF)  # IP id
        store16(d, 20, np.full(n, 0x4000 if df else 0))
        store8(d, 22, ttl)
        store8(d, 23, proto)
        store32(d, 26, src_ip)
        store32(d, 30, dst_ip)

    # Telemetry template: Eth(14) + IPv4(20) + UDP(8) + 8B reading = 50.
    dev = rng.integers(0, 32, n)
    tele = np.zeros((n, 54), np.uint8)
    eth_ip_common(
        tele, np.full(n, 36), np.full(n, 64), np.full(n, 17),
        0x0A000100 + dev, np.full(n, 0x0A000001), df=True,
    )
    store16(tele, 34, 30000 + dev)             # src port per device
    store16(tele, 36, np.full(n, 5683))        # CoAP
    store16(tele, 38, np.full(n, 16))          # UDP length
    tele[:, 42:50] = rng.integers(0, 256, (n, 8))

    # Flood template: Eth(14) + IPv4(20) + TCP(20) = 54, SYN to victim:80.
    fl = np.zeros((n, 54), np.uint8)
    eth_ip_common(
        fl, np.full(n, 40), rng.integers(32, 129, n), np.full(n, 6),
        rng.integers(0, 1 << 32, n, dtype=np.uint64),
        np.full(n, 0xC0A80164), df=False,
    )
    store16(fl, 34, rng.integers(1024, 65536, n))
    store16(fl, 36, np.full(n, 80))
    store32(fl, 38, rng.integers(0, 1 << 32, n, dtype=np.uint64))  # seq
    store8(fl, 46, np.full(n, 0x50))           # data offset 5
    store8(fl, 47, np.full(n, 0x02))           # SYN
    store16(fl, 48, np.full(n, 1024))          # window

    data = np.where(flood[:, None], fl, tele)
    lengths = np.where(flood, 54, 50)
    iat_us = np.where(flood, rng.integers(1, 8, n), rng.integers(200, 5000, n))
    timestamps = np.cumsum(iat_us).astype(np.float64) * 1e-6
    packets = [data[i, : lengths[i]].tobytes() for i in range(n)]
    return packets, timestamps, labels
