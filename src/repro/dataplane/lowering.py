"""Lower a :class:`PipelineProgram` into dense uint32 op-tables.

The interpreter (``core.interpreter``) walks the compiled program op-by-op in
Python — fine as a correctness witness, hopeless as a traffic simulator.  This
module turns a program into a *table*: one row per primitive ALU operation,
stored as flat ``(num_elements, max_rows)`` numpy arrays (opcode / dst / src /
imm / width-mask), so an executor can run the whole program as data, with no
per-op Python dispatch (``dataplane.executor``).

Two transformations happen on the way down:

* **Opcode normalization** — the 8 front-end opcodes collapse onto 6 dense
  ALU ops.  ``COPY`` is ``XOR imm=0``; ``XNOR_IMM w`` is ``XOR imm=~w``
  (``~(r ^ w) == r ^ ~w`` in uint32); ``AND_IMM m`` is ``SHR_AND imm=(0, m)``.
  ``FOLD`` (variadic deposit) is decomposed into one ``SHL`` micro-row per
  sign bit; the executor combines same-destination rows additively, which
  equals OR because each row contributes disjoint bits.
* **Register compaction** — the compiler allocates an SSA-style fresh field
  id per value, so ``PipelineProgram.num_fields`` counts every temporary ever
  created (thousands for a paper-sized net).  A liveness pass renames fields
  onto a small recycled slot file sized by the *peak* number of simultaneously
  live fields (hundreds), cutting executor memory and gather width ~10x.
  Read-before-write element semantics make it safe for an element's outputs
  to reuse slots its own inputs die in, mirroring RMT's PHV overlay.

Row layout invariants (relied on by executor + Pallas kernel):

* every row of element ``e`` reads the register file as it stood *entering*
  ``e`` and rows writing the same destination slot are additive after the
  first (``first_write`` flag);
* slot ``num_slots`` (one past the compacted file) is the always-zero null
  register: padding rows write 0 to it and absent src1 operands read it.

Cross-module invariants:

* **Bit-exactness** — executing the lowered tables (any backend, any
  compaction mode, any chunking) equals ``core.interpreter.run_program`` on
  the source program, bit for bit.  Compaction changes slot numbering only,
  never results.
* **Opcode-table stability** — the dense opcode ids below are a contract
  with ``executor.alu_variants`` and ``kernels.optable_exec``; extend the
  ISA by appending ids, never by renumbering.  The compaction mode is part
  of ``LoweredProgram.fingerprint()``, which keys executor device caches.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.pipeline import Op, OpCode, PipelineProgram

# Dense ALU opcodes (the executor's instruction set).
XOR_IMM = 0      # dst = src0 ^ imm0            (COPY, XNOR_IMM)
SHR_AND_IMM = 1  # dst = (src0 >> imm0) & imm1  (AND_IMM, HAKMEM marshal, pad)
ADD = 2          # dst = src0 + src1
GE_IMM = 3       # dst = src0 >= imm0
SHL_IMM = 4      # dst = src0 << imm0           (FOLD micro-op)
POPCNT = 5       # dst = popcount(src0)

DENSE_OPCODE_NAMES = ("xor", "shr_and", "add", "ge", "shl", "popcnt")
NUM_DENSE_OPCODES = len(DENSE_OPCODE_NAMES)
U32 = np.uint32
FULL = np.uint32(0xFFFFFFFF)
WORD = 32


def _mask(width: int) -> np.uint32:
    return FULL if width >= 32 else U32((1 << width) - 1)


def pack_bit_rows(bits: np.ndarray, n_words: int | None = None) -> np.ndarray:
    """Pack ``(..., n)`` {0,1} bits into ``(..., n_words)`` uint32 words.

    Little-endian within a word: logical bit ``k`` lands in word ``k // 32``
    at shift ``k % 32`` — the packed-PHV word layout shared by the executor's
    packed backend and ``kernels.bitpack`` (see docs/DATAPLANE.md).  Bits past
    ``n`` are zero padding.
    """
    bits = np.asarray(bits)
    n = bits.shape[-1]
    words = n_words if n_words is not None else max(1, -(-n // WORD))
    if words * WORD < n:
        raise ValueError(f"{n} bits do not fit {words} words")
    pad = words * WORD - n
    b = np.pad(bits.astype(np.uint32), [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    b = b.reshape(bits.shape[:-1] + (words, WORD))
    weights = U32(1) << np.arange(WORD, dtype=np.uint32)
    return (b * weights).sum(axis=-1, dtype=np.uint64).astype(np.uint32)


@dataclasses.dataclass(frozen=True, eq=False)
class PackedLayer:
    """One BNN layer in bit-packed form: a packed weight matrix plus the
    word layout its input bits occupy.

    Execution contract (``executor`` packed backend): scatter the layer's
    input bits into ``n_words`` uint32 lanes via ``in_word``/``in_shift``,
    then per neuron ``j`` the agreement count is
    ``popcount(~(x_words ^ weights[j]) & mask[j]).sum()`` and the output bit
    is ``count >= thresholds[j]``.  ``mask`` zeroes padding lanes (x-pad and
    w-pad are both 0, so unmasked ``~(0 ^ 0)`` would inflate counts) and, for
    merged multi-tenant layers, every word outside the neuron's tenant
    window.
    """

    weights: np.ndarray     # (n_out, n_words) uint32 packed weight bits
    thresholds: np.ndarray  # (n_out,) uint32: fire iff agreement >= thr
    mask: np.ndarray        # (n_out, n_words) uint32 valid-bit mask
    in_word: np.ndarray     # (n_in,) int32: input bit k -> word index
    in_shift: np.ndarray    # (n_in,) uint32: input bit k -> shift in word
    n_in: int
    n_out: int
    n_words: int

    @classmethod
    def from_dense(cls, w_bits: np.ndarray, thresholds: np.ndarray) -> "PackedLayer":
        """Pack a dense ``(n_out, n_in)`` {0,1} weight matrix with the
        trivial contiguous word layout."""
        w = np.asarray(w_bits)
        if w.ndim != 2:
            raise ValueError(f"weights must be (n_out, n_in), got {w.shape}")
        n_out, n_in = w.shape
        n_words = max(1, -(-n_in // WORD))
        bit = np.arange(n_in)
        mask_row = pack_bit_rows(np.ones((1, n_in), np.uint8), n_words)
        return cls(
            weights=pack_bit_rows(w, n_words),
            thresholds=np.asarray(thresholds, np.uint32).reshape(n_out),
            mask=np.broadcast_to(mask_row, (n_out, n_words)).copy(),
            in_word=(bit // WORD).astype(np.int32),
            in_shift=(bit % WORD).astype(np.uint32),
            n_in=n_in,
            n_out=n_out,
            n_words=n_words,
        )

    @classmethod
    def identity(cls, width: int) -> "PackedLayer":
        """A pass-through layer: neuron ``j`` reproduces input bit ``j``
        (single-bit weight, threshold 1).  Used to depth-pad shallower
        tenants in a merged packed program."""
        if width < 1:
            raise ValueError(f"identity layer needs width >= 1, got {width}")
        n_words = max(1, -(-width // WORD))
        eye = np.zeros((width, n_words), np.uint32)
        bit = np.arange(width)
        eye[bit, bit // WORD] = U32(1) << (bit % WORD).astype(np.uint32)
        return cls(
            weights=eye,
            thresholds=np.ones(width, np.uint32),
            mask=eye.copy(),
            in_word=(bit // WORD).astype(np.int32),
            in_shift=(bit % WORD).astype(np.uint32),
            n_in=width,
            n_out=width,
            n_words=n_words,
        )


@dataclasses.dataclass(frozen=True, eq=False)
class PackedProgram:
    """A whole program as a chain of :class:`PackedLayer`s — the bit-packed
    execution plan the ``"packed"`` executor backend runs instead of the
    op-table scan.  Layer ``l``'s ``n_in`` equals layer ``l-1``'s total
    ``n_out``; output bits are in neuron order (== deparser order == oracle
    order)."""

    layers: tuple[PackedLayer, ...]
    input_bits: int
    output_bits: int


def _packed_program(prog: PipelineProgram) -> PackedProgram | None:
    """Build the packed plan from compiler-attached layer metadata (weights
    + SIGN thresholds); ``None`` when the program carries none (hand-built
    programs, merged tables — those get plans elsewhere or fall back to the
    op-table path)."""
    meta = getattr(prog, "packed_layers", None)
    if meta is None:
        return None
    layers = []
    n_bits = prog.input_bits
    for li, (w, thr) in enumerate(meta):
        w = np.asarray(w)
        if w.shape[1] != n_bits:
            raise ValueError(
                f"packed layer {li}: fan-in {w.shape[1]} != incoming "
                f"{n_bits} bits"
            )
        layers.append(PackedLayer.from_dense(w, thr))
        n_bits = w.shape[0]
    if n_bits != prog.output_bits:
        raise ValueError(
            f"packed plan ends at {n_bits} bits; program outputs "
            f"{prog.output_bits}"
        )
    return PackedProgram(tuple(layers), prog.input_bits, prog.output_bits)


@dataclasses.dataclass(frozen=True)
class LoweredProgram:
    """A pipeline program as dense data.  All tables are numpy; the executor
    moves them on-device once per program (see ``executor._device_tables``)."""

    source_fingerprint: str
    chip_name: str
    num_slots: int               # compacted register file size (excl. null)
    input_bits: int
    output_bits: int

    # (num_elements, max_rows) tables; rows past rows_per_element[e] are pads.
    opcode: np.ndarray           # int32
    dst: np.ndarray              # int32 slot index
    src0: np.ndarray             # int32 slot index
    src1: np.ndarray             # int32 slot index (null slot when unused)
    imm0: np.ndarray             # uint32
    imm1: np.ndarray             # uint32
    mask: np.ndarray             # uint32 destination width mask (0 for pads)
    first_write: np.ndarray      # int32 — 0 only for FOLD continuation rows

    rows_per_element: np.ndarray  # (num_elements,) int32, true rows per element
    element_stages: tuple[str, ...]
    num_ops: int                  # true (unpadded) row count

    # Parser / deparser tables: one entry per packet bit.
    in_slot_per_bit: np.ndarray   # (input_bits,) int32
    in_shift_per_bit: np.ndarray  # (input_bits,) uint32
    out_slot_per_bit: np.ndarray  # (output_bits,) int32
    out_shift_per_bit: np.ndarray  # (output_bits,) uint32

    # (num_elements, NUM_DENSE_OPCODES) int32 — true-row opcode histogram per
    # element (pads excluded).  Rows within an element are stably sorted by
    # dense opcode (see lower_program), so opcode_runs() can hand executors
    # opcode-homogeneous element ranges.  None for hand-assembled tables.
    opcode_counts: np.ndarray | None = None
    # Bit-packed execution plan (the "packed" backend); None when the source
    # program carried no layer metadata or after element slicing.
    packed: PackedProgram | None = None

    @property
    def num_elements(self) -> int:
        return self.opcode.shape[0]

    @property
    def max_rows(self) -> int:
        return self.opcode.shape[1]

    @property
    def num_regs(self) -> int:
        """Register-file width including the trailing null register."""
        return self.num_slots + 1

    @property
    def null_slot(self) -> int:
        return self.num_slots

    def fingerprint(self) -> str:
        return self.source_fingerprint

    def slice_elements(self, start: int, stop: int) -> "LoweredProgram":
        """A view of elements ``[start, stop)`` — one fabric hop's table.

        Parser/deparser tables and the register file are inherited whole: the
        register file *is* the PHV carried between hops, so a hop executes its
        element range over the same slot space.
        """
        if not (0 <= start < stop <= self.num_elements):
            raise ValueError(
                f"element slice [{start}, {stop}) out of range "
                f"[0, {self.num_elements})"
            )
        rows = self.rows_per_element[start:stop]
        return dataclasses.replace(
            self,
            source_fingerprint=f"{self.source_fingerprint}[{start}:{stop}]",
            opcode=self.opcode[start:stop],
            dst=self.dst[start:stop],
            src0=self.src0[start:stop],
            src1=self.src1[start:stop],
            imm0=self.imm0[start:stop],
            imm1=self.imm1[start:stop],
            mask=self.mask[start:stop],
            first_write=self.first_write[start:stop],
            rows_per_element=rows,
            element_stages=self.element_stages[start:stop],
            num_ops=int(rows.sum()),
            opcode_counts=(
                None if self.opcode_counts is None
                else self.opcode_counts[start:stop]
            ),
            # A slice is one hop of a layer-level plan; the whole-program
            # packed shortcut no longer applies.
            packed=None,
        )

    def with_slot_window(self, offset: int, total_slots: int) -> "LoweredProgram":
        """Relocate this program's register file to slots ``[offset, offset +
        num_slots)`` of a ``total_slots``-wide shared file.

        Every slot reference (dst/src/parser/deparser) shifts by ``offset``;
        references to this program's own null register retarget the shared
        file's null (``total_slots``).  This is the table half of multi-tenant
        merging (``dataplane.multitenant``): programs relocated to disjoint
        windows can share one register file — and one executor pass — without
        interfering, because no remapped row can address another window.
        """
        if offset < 0 or offset + self.num_slots > total_slots:
            raise ValueError(
                f"window [{offset}, {offset + self.num_slots}) does not fit "
                f"a {total_slots}-slot file"
            )

        def remap(tbl: np.ndarray) -> np.ndarray:
            return np.where(
                tbl == self.null_slot, np.int32(total_slots), tbl + offset
            ).astype(np.int32)

        return dataclasses.replace(
            self,
            source_fingerprint=(
                f"{self.source_fingerprint}@{offset}/{total_slots}"
            ),
            num_slots=total_slots,
            dst=remap(self.dst),
            src0=remap(self.src0),
            src1=remap(self.src1),
            in_slot_per_bit=remap(self.in_slot_per_bit),
            out_slot_per_bit=remap(self.out_slot_per_bit),
        )

    def pad_rows(self, max_rows: int) -> "LoweredProgram":
        """Widen the row axis to ``max_rows`` with no-op pad rows (write 0 to
        the null register, mask 0).  Needed before concatenating programs
        whose elements have different row widths."""
        if max_rows < self.max_rows:
            raise ValueError(
                f"cannot shrink row axis {self.max_rows} -> {max_rows}"
            )
        if max_rows == self.max_rows:
            return self
        extra = max_rows - self.max_rows
        null = self.null_slot

        def pad(tbl: np.ndarray, value) -> np.ndarray:
            return np.pad(tbl, ((0, 0), (0, extra)), constant_values=value)

        return dataclasses.replace(
            self,
            source_fingerprint=f"{self.source_fingerprint}|rows{max_rows}",
            opcode=pad(self.opcode, SHR_AND_IMM),
            dst=pad(self.dst, null),
            src0=pad(self.src0, null),
            src1=pad(self.src1, null),
            imm0=pad(self.imm0, U32(0)),
            imm1=pad(self.imm1, U32(0)),
            mask=pad(self.mask, U32(0)),
            first_write=pad(self.first_write, 1),
        )

    def used_opcodes(self) -> tuple[int, ...]:
        """Dense opcodes actually present (pads are SHR_AND; always included
        so padded rows evaluate)."""
        present = set(np.unique(self.opcode).tolist())
        present.add(SHR_AND_IMM)
        return tuple(sorted(present))

    def opcode_runs(
        self, max_variants: int = 3
    ) -> tuple[tuple[int, int, tuple[int, ...]], ...]:
        """Opcode-homogeneous element runs for narrowed-ALU execution.

        Returns ``(start_element, stop_element, used_opcodes)`` triples
        covering ``[0, num_elements)`` in order.  Executors evaluate each run
        with an ALU narrowed to that run's opcodes, killing the
        branchless-select overhead of materialising all six variants per row
        (the op-table scan's dominant cost for single-opcode elements, which
        is what the compiler emits).  Elements carrying pad rows include
        ``SHR_AND_IMM`` (the pad opcode) so padded rows still evaluate.

        Consecutive elements coalesce greedily while the merged opcode set
        stays within ``max_variants`` — the select chain stays short while
        the dispatch/compile count stays bounded (a compiled BNN alternates
        marshal/ADD elements; exact runs would mean one dispatch per
        element).  Falls back to one whole-table run when ``opcode_counts``
        is absent.
        """
        if self.opcode_counts is None:
            return ((0, self.num_elements, self.used_opcodes()),)
        has_pad = self.rows_per_element < self.max_rows
        runs: list[tuple[int, int, tuple[int, ...]]] = []
        start = 0
        cur: frozenset[int] | None = None
        for e in range(self.num_elements):
            used = frozenset(np.nonzero(self.opcode_counts[e])[0].tolist())
            if has_pad[e] or not used:
                used |= {SHR_AND_IMM}
            if cur is None:
                cur, start = used, e
            elif not (used <= cur) and len(cur | used) > max_variants:
                runs.append((start, e, tuple(sorted(cur))))
                cur, start = used, e
            else:
                cur = cur | used
        if cur is not None:
            runs.append((start, self.num_elements, tuple(sorted(cur))))
        return tuple(runs)

    def summary(self) -> str:
        return (
            f"lowered[{self.chip_name}]: elements={self.num_elements} "
            f"ops={self.num_ops} max_rows={self.max_rows} "
            f"regs={self.num_regs} io={self.input_bits}b->{self.output_bits}b"
        )


# ---------------------------------------------------------------------------
# Stacked execution plans (scan-over-hops / scan-over-layers)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class StackedHops:
    """Fabric-hop tables stacked on a leading hop axis.

    The executor compiles the hop body ONCE and runs all hops as a single
    ``lax.scan`` over this stack (one device dispatch per chunk instead of
    one per hop) — the scan-over-layers idiom applied to switch chains.
    Hops shorter than ``elements_per_hop`` are element-padded with whole
    no-op elements (every row the standard pad row: ``SHR_AND`` writing 0 to
    the null register), so padding can never change results, only waste
    lanes.  Built by :func:`stack_hops`; ``None`` from there means the hop
    shapes genuinely differ and callers must fall back to unrolled dispatch.
    """

    fingerprint: str
    num_hops: int
    elements_per_hop: int        # padded per-hop element count
    num_regs: int
    used: tuple[int, ...]        # union of per-hop used opcodes (+ pad op)

    # (num_hops, elements_per_hop, max_rows) tables.
    opcode: np.ndarray           # int32
    dst: np.ndarray              # int32
    src0: np.ndarray             # int32
    src1: np.ndarray             # int32
    imm0: np.ndarray             # uint32
    imm1: np.ndarray             # uint32
    mask: np.ndarray             # uint32
    first_write: np.ndarray      # int32


def stack_hops(hops: "list[LoweredProgram]") -> StackedHops | None:
    """Stack fabric-hop table slices into one scan-compatible plan.

    Returns ``None`` when the hops cannot share one compiled body: different
    row widths or different register files (never the case for
    ``slice_elements`` views of one program, always the case for slices of
    *different* programs).  Differing element counts (the last hop of a
    partition is short) are fine — short hops are padded with no-op
    elements.
    """
    if not hops:
        return None
    head = hops[0]
    if any(
        h.max_rows != head.max_rows or h.num_regs != head.num_regs
        for h in hops
    ):
        return None
    e_pad = max(h.num_elements for h in hops)
    null = head.null_slot
    pads = {
        "opcode": (np.int32, SHR_AND_IMM),
        "dst": (np.int32, null),
        "src0": (np.int32, null),
        "src1": (np.int32, null),
        "imm0": (np.uint32, 0),
        "imm1": (np.uint32, 0),
        "mask": (np.uint32, 0),
        "first_write": (np.int32, 1),
    }
    stacked: dict[str, np.ndarray] = {}
    for name, (dtype, fill) in pads.items():
        planes = []
        for h in hops:
            a = np.asarray(getattr(h, name), dtype)
            short = e_pad - a.shape[0]
            if short:
                a = np.concatenate(
                    [a, np.full((short, a.shape[1]), fill, dtype)]
                )
            planes.append(a)
        stacked[name] = np.stack(planes)
    used: set[int] = {SHR_AND_IMM}  # pad elements/rows always evaluate
    for h in hops:
        used.update(h.used_opcodes())
    return StackedHops(
        fingerprint="stack(" + "+".join(h.fingerprint() for h in hops) + ")",
        num_hops=len(hops),
        elements_per_hop=e_pad,
        num_regs=head.num_regs,
        used=tuple(sorted(used)),
        **stacked,
    )


@dataclasses.dataclass(frozen=True, eq=False)
class StackedPackedLayers:
    """A :class:`PackedProgram` with every layer padded to common shapes and
    stacked on a leading layer axis — the packed backend's scan plan.

    Padding is inert by construction: pad neurons carry an all-zero mask and
    a never-reachable threshold (``0xFFFFFFFF`` agreements, far above the
    ``32 * n_words`` maximum), so their output bits are always 0; pad input
    bits scatter a guaranteed-zero bit into word 0 (the carried bit vector
    is zero beyond every layer's true width).  Built by
    :func:`stack_packed_layers`.
    """

    num_layers: int
    max_bits: int                # carried bit-vector width (>= every n_in/n_out)
    max_words: int
    max_out: int
    input_bits: int
    output_bits: int

    # (num_layers, ...) stacked layer parameters.
    weights: np.ndarray          # (L, max_out, max_words) uint32
    thresholds: np.ndarray       # (L, max_out) uint32
    mask: np.ndarray             # (L, max_out, max_words) uint32
    in_word: np.ndarray          # (L, max_bits) int32
    in_shift: np.ndarray         # (L, max_bits) uint32


def stack_packed_layers(pp: PackedProgram) -> StackedPackedLayers:
    """Pad + stack a packed program's layers for ``lax.scan`` execution."""
    layers = pp.layers
    max_out = max(pl.n_out for pl in layers)
    max_words = max(pl.n_words for pl in layers)
    max_bits = max(
        max(pl.n_in for pl in layers), max(pl.n_out for pl in layers)
    )
    L = len(layers)
    weights = np.zeros((L, max_out, max_words), np.uint32)
    mask = np.zeros((L, max_out, max_words), np.uint32)
    # Pad neurons never fire: agreement counts are bounded by 32*max_words.
    thresholds = np.full((L, max_out), FULL, np.uint32)
    in_word = np.zeros((L, max_bits), np.int32)
    in_shift = np.zeros((L, max_bits), np.uint32)
    for li, pl in enumerate(layers):
        weights[li, : pl.n_out, : pl.n_words] = pl.weights
        mask[li, : pl.n_out, : pl.n_words] = pl.mask
        thresholds[li, : pl.n_out] = pl.thresholds
        in_word[li, : pl.n_in] = pl.in_word
        in_shift[li, : pl.n_in] = pl.in_shift
    return StackedPackedLayers(
        num_layers=L,
        max_bits=max_bits,
        max_words=max_words,
        max_out=max_out,
        input_bits=pp.input_bits,
        output_bits=pp.output_bits,
        weights=weights,
        thresholds=thresholds,
        mask=mask,
        in_word=in_word,
        in_shift=in_shift,
    )


# ---------------------------------------------------------------------------
# Interleaved multi-tenant merge planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class InterleavedTables:
    """Op-tables for N relocated programs interleaved onto shared stages.

    Merged stage ``e`` carries element ``e`` of *every* part at once — the
    multi-tenant analogue of RMT packing several match-action entries into
    one physical stage — so the merged element count is the *deepest* part,
    not the sum of parts.  ``row_part``/``row_src_elem``/``row_src_row``
    record where each merged row came from (-1 for pad rows), which is what
    makes the merge auditable: un-interleaving by provenance must reproduce
    every part's tables exactly (property-tested in
    ``tests/test_multitenant.py``).  Built by :func:`interleave_tables`.
    """

    opcode: np.ndarray           # (max_elements, peak_rows) int32
    dst: np.ndarray              # int32
    src0: np.ndarray             # int32
    src1: np.ndarray             # int32
    imm0: np.ndarray             # uint32
    imm1: np.ndarray             # uint32
    mask: np.ndarray             # uint32
    first_write: np.ndarray      # int32
    rows_per_element: np.ndarray  # (max_elements,) int32 true rows per stage
    element_stages: tuple[str, ...]
    num_ops: int
    opcode_counts: np.ndarray | None
    row_part: np.ndarray         # (max_elements, peak_rows) int32, -1 = pad
    row_src_elem: np.ndarray     # source element within the part, -1 = pad
    row_src_row: np.ndarray      # source row within that element, -1 = pad


def peak_stage_rows(lowereds: Sequence[LoweredProgram]) -> int:
    """Widest shared stage of an element-interleaved merge: the max over
    stages of the summed true row counts of every program's element at that
    stage.  This is the quantity admission control holds against
    ``ChipSpec.max_parallel_ops`` — the per-stage ALU budget all tenants
    share once their elements occupy the same physical stage."""
    if not lowereds:
        return 0
    max_e = max(lp.num_elements for lp in lowereds)
    totals = np.zeros(max_e, np.int64)
    for lp in lowereds:
        totals[: lp.num_elements] += lp.rows_per_element
    return max(1, int(totals.max()))


def interleave_tables(parts: Sequence[LoweredProgram]) -> InterleavedTables:
    """Interleave relocated programs' elements onto shared physical stages.

    Merged stage ``e`` concatenates the true rows of every part's element
    ``e`` (parts shallower than ``e`` contribute nothing), stably re-sorts
    the combined rows by dense opcode — preserving the opcode-run coalescing
    contract ``lower_program`` established per program — and pads the stage
    to the global peak row count.  The re-sort is safe: every row reads the
    register state *entering* the stage, parts write disjoint slot windows,
    and the stable sort keeps each part's FOLD first-write -> continuation
    order intact (all FOLD micro-rows share opcode ``SHL_IMM``), which the
    Pallas kernel's sequential write pass relies on.

    Parts must already share one register file: callers relocate each onto
    a disjoint window via ``with_slot_window`` first.
    """
    if not parts:
        raise ValueError("interleave_tables needs at least one program")
    num_slots = parts[0].num_slots
    if any(p.num_slots != num_slots for p in parts):
        raise ValueError(
            "interleave parts must share one relocated register file "
            "(apply with_slot_window onto disjoint windows first)"
        )
    max_e = max(p.num_elements for p in parts)
    peak = peak_stage_rows(parts)
    null = num_slots
    specs = (
        ("opcode", np.int32, SHR_AND_IMM),
        ("dst", np.int32, null),
        ("src0", np.int32, null),
        ("src1", np.int32, null),
        ("imm0", np.uint32, 0),
        ("imm1", np.uint32, 0),
        ("mask", np.uint32, 0),
        ("first_write", np.int32, 1),
    )
    tables = {n: np.full((max_e, peak), fill, dt) for n, dt, fill in specs}
    row_part = np.full((max_e, peak), -1, np.int32)
    row_src_elem = np.full((max_e, peak), -1, np.int32)
    row_src_row = np.full((max_e, peak), -1, np.int32)
    rows_per = np.zeros(max_e, np.int32)
    have_counts = all(p.opcode_counts is not None for p in parts)
    counts = (
        np.zeros((max_e, NUM_DENSE_OPCODES), np.int32) if have_counts else None
    )
    stages: list[str] = []
    for e in range(max_e):
        cols: dict[str, list[np.ndarray]] = {n: [] for n, _, _ in specs}
        prov_p: list[np.ndarray] = []
        prov_r: list[np.ndarray] = []
        names: list[str] = []
        for pi, p in enumerate(parts):
            if e >= p.num_elements:
                continue
            if counts is not None:
                counts[e] += p.opcode_counts[e]
            names.append(f"p{pi}:{p.element_stages[e]}")
            r = int(p.rows_per_element[e])
            if r == 0:
                continue
            for n, _, _ in specs:
                cols[n].append(getattr(p, n)[e, :r])
            prov_p.append(np.full(r, pi, np.int32))
            prov_r.append(np.arange(r, dtype=np.int32))
        stages.append("+".join(names) if names else "pad")
        if not prov_p:
            continue
        order = np.argsort(np.concatenate(cols["opcode"]), kind="stable")
        k = order.size
        rows_per[e] = k
        for n, _, _ in specs:
            tables[n][e, :k] = np.concatenate(cols[n])[order]
        row_part[e, :k] = np.concatenate(prov_p)[order]
        row_src_elem[e, :k] = e
        row_src_row[e, :k] = np.concatenate(prov_r)[order]
    return InterleavedTables(
        rows_per_element=rows_per,
        element_stages=tuple(stages),
        num_ops=int(rows_per.sum()),
        opcode_counts=counts,
        row_part=row_part,
        row_src_elem=row_src_elem,
        row_src_row=row_src_row,
        **tables,
    )


# ---------------------------------------------------------------------------
# Liveness + slot renaming
# ---------------------------------------------------------------------------

def _liveness(prog: PipelineProgram) -> tuple[dict[int, int], dict[int, int]]:
    """Per-field ``def`` element (-1 for inputs) and last-use element
    (``num_elements`` for outputs — the deparser reads them)."""
    def_elem: dict[int, int] = {f.fid: -1 for f in prog.input_fields}
    last_use: dict[int, int] = {}
    for e, el in enumerate(prog.elements):
        for op in el.ops:
            for s in op.srcs:
                last_use[s.fid] = e
            def_elem.setdefault(op.dst.fid, e)
    for fid, d in def_elem.items():
        last_use.setdefault(fid, d)  # never-read values die where they're born
    for f in prog.output_fields:
        last_use[f.fid] = len(prog.elements)
    return def_elem, last_use


class _SlotFile:
    """Recycling slot allocator.  ``assigned`` records every fid's slot
    permanently (a fid occupies exactly one slot for its whole lifetime);
    ``release`` only returns the slot to the free pool for a *later* fid."""

    def __init__(self) -> None:
        self._free: list[int] = []
        self._next = 0
        self._live: set[int] = set()
        self.assigned: dict[int, int] = {}

    def alloc(self, fid: int) -> int:
        if self._free:
            self._free.sort()
            s = self._free.pop(0)
        else:
            s = self._next
            self._next += 1
        self.assigned[fid] = s
        self._live.add(fid)
        return s

    def release(self, fid: int) -> None:
        if fid in self._live:
            self._live.discard(fid)
            self._free.append(self.assigned[fid])

    @property
    def high_water(self) -> int:
        return self._next


def _rename_fields(prog: PipelineProgram) -> tuple[dict[int, int], int]:
    """Liveness-driven rename: fid -> compact executor slot."""
    def_elem, last_use = _liveness(prog)
    # Group deaths by element so each element's pass is O(deaths), not O(fields).
    deaths: dict[int, list[int]] = {}
    for fid, lu in last_use.items():
        deaths.setdefault(lu, []).append(fid)

    slots = _SlotFile()
    for f in prog.input_fields:
        slots.alloc(f.fid)
    for e, el in enumerate(prog.elements):
        # Reads of element e happen before its writes (read-before-write), so
        # anything last *read* at or before e frees before e's dsts allocate.
        # A never-read value written at e (last_use == def == e) must survive
        # its own write; it frees one element later.
        for fid in deaths.get(e, ()):
            if def_elem.get(fid, -1) < e:
                slots.release(fid)
            else:
                deaths.setdefault(e + 1, []).append(fid)
        for op in el.ops:
            if op.dst.fid not in slots.assigned:
                slots.alloc(op.dst.fid)
    return slots.assigned, slots.high_water


# ---------------------------------------------------------------------------
# Lowering proper
# ---------------------------------------------------------------------------

def _lower_op(op: Op, slot: dict[int, int], null: int) -> list[tuple]:
    """One front-end op -> dense rows (opcode, dst, s0, s1, i0, i1, mask, first)."""
    m = _mask(op.dst.width)
    d = slot.get(op.dst.fid, op.dst.fid)

    def s(i: int) -> int:
        return slot.get(op.srcs[i].fid, op.srcs[i].fid)

    code = op.opcode
    if code == OpCode.COPY:
        return [(XOR_IMM, d, s(0), null, U32(0), U32(0), m, 1)]
    if code == OpCode.XNOR_IMM:
        return [(XOR_IMM, d, s(0), null, ~U32(op.imm[0]), U32(0), m, 1)]
    if code == OpCode.AND_IMM:
        return [(SHR_AND_IMM, d, s(0), null, U32(0), U32(op.imm[0]), m, 1)]
    if code == OpCode.SHR_AND_IMM:
        return [(SHR_AND_IMM, d, s(0), null, U32(op.imm[0]), U32(op.imm[1]), m, 1)]
    if code == OpCode.ADD:
        return [(ADD, d, s(0), s(1), U32(0), U32(0), m, 1)]
    if code == OpCode.GE_IMM:
        return [(GE_IMM, d, s(0), null, U32(op.imm[0]), U32(0), m, 1)]
    if code == OpCode.POPCNT:
        return [(POPCNT, d, s(0), null, U32(0), U32(0), m, 1)]
    if code == OpCode.FOLD:
        # One SHL micro-row per sign bit; rows after the first accumulate
        # (additive == OR: each row deposits a disjoint bit).
        return [
            (SHL_IMM, d, s(k), null, U32(k), U32(0), m, 1 if k == 0 else 0)
            for k in range(len(op.srcs))
        ]
    raise ValueError(f"unknown opcode {code}")  # pragma: no cover


def lower_program(prog: PipelineProgram, compact: bool = True) -> LoweredProgram:
    """Lower ``prog`` to dense op-tables.

    ``compact=True`` (default) renames SSA field ids onto a recycled slot
    file; ``compact=False`` keeps slot == fid (debugging aid — bitwise
    identical results, much larger register file).
    """
    # The compaction mode changes slot numbering, so it is part of the
    # lowered identity (executor caches are keyed on this fingerprint).
    fingerprint = f"{prog.fingerprint()}:{'compact' if compact else 'full'}"
    num_el = len(prog.elements)

    if compact:
        slot_map, num_slots = _rename_fields(prog)
    else:
        slot_map, num_slots = {}, prog.num_fields
    null = num_slots

    per_element_rows: list[list[tuple]] = []
    stages: list[str] = []
    opcode_counts = np.zeros((num_el, NUM_DENSE_OPCODES), np.int32)
    for e, el in enumerate(prog.elements):
        rows: list[tuple] = []
        for op in el.ops:
            rows.extend(_lower_op(op, slot_map, null))
        # Opcode-sorted segments: within an element every row reads the
        # *incoming* register state and writes its own destination, so row
        # order is free — except FOLD continuation rows (first_write=0),
        # which must follow their first_write row on the sequential-write
        # Pallas path.  All of a FOLD's micro-rows share opcode SHL and
        # Python's sort is stable, so sorting by opcode preserves that order
        # while giving opcode_runs() homogeneous segments.
        rows.sort(key=lambda r: r[0])
        for r in rows:
            opcode_counts[e, r[0]] += 1
        per_element_rows.append(rows)
        stages.append(el.stage)

    num_ops = sum(len(r) for r in per_element_rows)
    max_rows = max((len(r) for r in per_element_rows), default=1)
    max_rows = max(max_rows, 1)
    pad_row = (SHR_AND_IMM, null, null, null, U32(0), U32(0), U32(0), 1)

    def table(idx: int, dtype) -> np.ndarray:
        out = np.empty((num_el, max_rows), dtype=dtype)
        for e, rows in enumerate(per_element_rows):
            padded = rows + [pad_row] * (max_rows - len(rows))
            out[e, :] = [r[idx] for r in padded]
        return out

    # Parser/deparser bit tables.
    in_slot, in_shift = [], []
    for f in prog.input_fields:
        s = slot_map.get(f.fid, f.fid)
        in_slot.extend([s] * f.width)
        in_shift.extend(range(f.width))
    out_slot, out_shift = [], []
    for f in prog.output_fields:
        s = slot_map.get(f.fid, f.fid)
        out_slot.extend([s] * f.width)
        out_shift.extend(range(f.width))
    if len(in_slot) != prog.input_bits or len(out_slot) != prog.output_bits:
        raise AssertionError("parser/deparser table width mismatch")

    return LoweredProgram(
        source_fingerprint=fingerprint,
        chip_name=prog.chip.name,
        num_slots=num_slots,
        input_bits=prog.input_bits,
        output_bits=prog.output_bits,
        opcode=table(0, np.int32),
        dst=table(1, np.int32),
        src0=table(2, np.int32),
        src1=table(3, np.int32),
        imm0=table(4, np.uint32),
        imm1=table(5, np.uint32),
        mask=table(6, np.uint32),
        first_write=table(7, np.int32),
        rows_per_element=np.array(
            [len(r) for r in per_element_rows], np.int32
        ),
        element_stages=tuple(stages),
        num_ops=num_ops,
        in_slot_per_bit=np.array(in_slot, np.int32),
        in_shift_per_bit=np.array(in_shift, np.uint32),
        out_slot_per_bit=np.array(out_slot, np.int32),
        out_shift_per_bit=np.array(out_shift, np.uint32),
        opcode_counts=opcode_counts,
        packed=_packed_program(prog),
    )
