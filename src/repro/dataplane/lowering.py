"""Lower a :class:`PipelineProgram` into dense uint32 op-tables.

The interpreter (``core.interpreter``) walks the compiled program op-by-op in
Python — fine as a correctness witness, hopeless as a traffic simulator.  This
module turns a program into a *table*: one row per primitive ALU operation,
stored as flat ``(num_elements, max_rows)`` numpy arrays (opcode / dst / src /
imm / width-mask), so an executor can run the whole program as data, with no
per-op Python dispatch (``dataplane.executor``).

Two transformations happen on the way down:

* **Opcode normalization** — the 8 front-end opcodes collapse onto 6 dense
  ALU ops.  ``COPY`` is ``XOR imm=0``; ``XNOR_IMM w`` is ``XOR imm=~w``
  (``~(r ^ w) == r ^ ~w`` in uint32); ``AND_IMM m`` is ``SHR_AND imm=(0, m)``.
  ``FOLD`` (variadic deposit) is decomposed into one ``SHL`` micro-row per
  sign bit; the executor combines same-destination rows additively, which
  equals OR because each row contributes disjoint bits.
* **Register compaction** — the compiler allocates an SSA-style fresh field
  id per value, so ``PipelineProgram.num_fields`` counts every temporary ever
  created (thousands for a paper-sized net).  A liveness pass renames fields
  onto a small recycled slot file sized by the *peak* number of simultaneously
  live fields (hundreds), cutting executor memory and gather width ~10x.
  Read-before-write element semantics make it safe for an element's outputs
  to reuse slots its own inputs die in, mirroring RMT's PHV overlay.

Row layout invariants (relied on by executor + Pallas kernel):

* every row of element ``e`` reads the register file as it stood *entering*
  ``e`` and rows writing the same destination slot are additive after the
  first (``first_write`` flag);
* slot ``num_slots`` (one past the compacted file) is the always-zero null
  register: padding rows write 0 to it and absent src1 operands read it.

Cross-module invariants:

* **Bit-exactness** — executing the lowered tables (any backend, any
  compaction mode, any chunking) equals ``core.interpreter.run_program`` on
  the source program, bit for bit.  Compaction changes slot numbering only,
  never results.
* **Opcode-table stability** — the dense opcode ids below are a contract
  with ``executor.alu_variants`` and ``kernels.optable_exec``; extend the
  ISA by appending ids, never by renumbering.  The compaction mode is part
  of ``LoweredProgram.fingerprint()``, which keys executor device caches.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.pipeline import Op, OpCode, PipelineProgram

# Dense ALU opcodes (the executor's instruction set).
XOR_IMM = 0      # dst = src0 ^ imm0            (COPY, XNOR_IMM)
SHR_AND_IMM = 1  # dst = (src0 >> imm0) & imm1  (AND_IMM, HAKMEM marshal, pad)
ADD = 2          # dst = src0 + src1
GE_IMM = 3       # dst = src0 >= imm0
SHL_IMM = 4      # dst = src0 << imm0           (FOLD micro-op)
POPCNT = 5       # dst = popcount(src0)

DENSE_OPCODE_NAMES = ("xor", "shr_and", "add", "ge", "shl", "popcnt")
U32 = np.uint32
FULL = np.uint32(0xFFFFFFFF)


def _mask(width: int) -> np.uint32:
    return FULL if width >= 32 else U32((1 << width) - 1)


@dataclasses.dataclass(frozen=True)
class LoweredProgram:
    """A pipeline program as dense data.  All tables are numpy; the executor
    moves them on-device once per program (see ``executor._device_tables``)."""

    source_fingerprint: str
    chip_name: str
    num_slots: int               # compacted register file size (excl. null)
    input_bits: int
    output_bits: int

    # (num_elements, max_rows) tables; rows past rows_per_element[e] are pads.
    opcode: np.ndarray           # int32
    dst: np.ndarray              # int32 slot index
    src0: np.ndarray             # int32 slot index
    src1: np.ndarray             # int32 slot index (null slot when unused)
    imm0: np.ndarray             # uint32
    imm1: np.ndarray             # uint32
    mask: np.ndarray             # uint32 destination width mask (0 for pads)
    first_write: np.ndarray      # int32 — 0 only for FOLD continuation rows

    rows_per_element: np.ndarray  # (num_elements,) int32, true rows per element
    element_stages: tuple[str, ...]
    num_ops: int                  # true (unpadded) row count

    # Parser / deparser tables: one entry per packet bit.
    in_slot_per_bit: np.ndarray   # (input_bits,) int32
    in_shift_per_bit: np.ndarray  # (input_bits,) uint32
    out_slot_per_bit: np.ndarray  # (output_bits,) int32
    out_shift_per_bit: np.ndarray  # (output_bits,) uint32

    @property
    def num_elements(self) -> int:
        return self.opcode.shape[0]

    @property
    def max_rows(self) -> int:
        return self.opcode.shape[1]

    @property
    def num_regs(self) -> int:
        """Register-file width including the trailing null register."""
        return self.num_slots + 1

    @property
    def null_slot(self) -> int:
        return self.num_slots

    def fingerprint(self) -> str:
        return self.source_fingerprint

    def slice_elements(self, start: int, stop: int) -> "LoweredProgram":
        """A view of elements ``[start, stop)`` — one fabric hop's table.

        Parser/deparser tables and the register file are inherited whole: the
        register file *is* the PHV carried between hops, so a hop executes its
        element range over the same slot space.
        """
        if not (0 <= start < stop <= self.num_elements):
            raise ValueError(
                f"element slice [{start}, {stop}) out of range "
                f"[0, {self.num_elements})"
            )
        rows = self.rows_per_element[start:stop]
        return dataclasses.replace(
            self,
            source_fingerprint=f"{self.source_fingerprint}[{start}:{stop}]",
            opcode=self.opcode[start:stop],
            dst=self.dst[start:stop],
            src0=self.src0[start:stop],
            src1=self.src1[start:stop],
            imm0=self.imm0[start:stop],
            imm1=self.imm1[start:stop],
            mask=self.mask[start:stop],
            first_write=self.first_write[start:stop],
            rows_per_element=rows,
            element_stages=self.element_stages[start:stop],
            num_ops=int(rows.sum()),
        )

    def with_slot_window(self, offset: int, total_slots: int) -> "LoweredProgram":
        """Relocate this program's register file to slots ``[offset, offset +
        num_slots)`` of a ``total_slots``-wide shared file.

        Every slot reference (dst/src/parser/deparser) shifts by ``offset``;
        references to this program's own null register retarget the shared
        file's null (``total_slots``).  This is the table half of multi-tenant
        merging (``dataplane.multitenant``): programs relocated to disjoint
        windows can share one register file — and one executor pass — without
        interfering, because no remapped row can address another window.
        """
        if offset < 0 or offset + self.num_slots > total_slots:
            raise ValueError(
                f"window [{offset}, {offset + self.num_slots}) does not fit "
                f"a {total_slots}-slot file"
            )

        def remap(tbl: np.ndarray) -> np.ndarray:
            return np.where(
                tbl == self.null_slot, np.int32(total_slots), tbl + offset
            ).astype(np.int32)

        return dataclasses.replace(
            self,
            source_fingerprint=(
                f"{self.source_fingerprint}@{offset}/{total_slots}"
            ),
            num_slots=total_slots,
            dst=remap(self.dst),
            src0=remap(self.src0),
            src1=remap(self.src1),
            in_slot_per_bit=remap(self.in_slot_per_bit),
            out_slot_per_bit=remap(self.out_slot_per_bit),
        )

    def pad_rows(self, max_rows: int) -> "LoweredProgram":
        """Widen the row axis to ``max_rows`` with no-op pad rows (write 0 to
        the null register, mask 0).  Needed before concatenating programs
        whose elements have different row widths."""
        if max_rows < self.max_rows:
            raise ValueError(
                f"cannot shrink row axis {self.max_rows} -> {max_rows}"
            )
        if max_rows == self.max_rows:
            return self
        extra = max_rows - self.max_rows
        null = self.null_slot

        def pad(tbl: np.ndarray, value) -> np.ndarray:
            return np.pad(tbl, ((0, 0), (0, extra)), constant_values=value)

        return dataclasses.replace(
            self,
            source_fingerprint=f"{self.source_fingerprint}|rows{max_rows}",
            opcode=pad(self.opcode, SHR_AND_IMM),
            dst=pad(self.dst, null),
            src0=pad(self.src0, null),
            src1=pad(self.src1, null),
            imm0=pad(self.imm0, U32(0)),
            imm1=pad(self.imm1, U32(0)),
            mask=pad(self.mask, U32(0)),
            first_write=pad(self.first_write, 1),
        )

    def used_opcodes(self) -> tuple[int, ...]:
        """Dense opcodes actually present (pads are SHR_AND; always included
        so padded rows evaluate)."""
        present = set(np.unique(self.opcode).tolist())
        present.add(SHR_AND_IMM)
        return tuple(sorted(present))

    def summary(self) -> str:
        return (
            f"lowered[{self.chip_name}]: elements={self.num_elements} "
            f"ops={self.num_ops} max_rows={self.max_rows} "
            f"regs={self.num_regs} io={self.input_bits}b->{self.output_bits}b"
        )


# ---------------------------------------------------------------------------
# Liveness + slot renaming
# ---------------------------------------------------------------------------

def _liveness(prog: PipelineProgram) -> tuple[dict[int, int], dict[int, int]]:
    """Per-field ``def`` element (-1 for inputs) and last-use element
    (``num_elements`` for outputs — the deparser reads them)."""
    def_elem: dict[int, int] = {f.fid: -1 for f in prog.input_fields}
    last_use: dict[int, int] = {}
    for e, el in enumerate(prog.elements):
        for op in el.ops:
            for s in op.srcs:
                last_use[s.fid] = e
            def_elem.setdefault(op.dst.fid, e)
    for fid, d in def_elem.items():
        last_use.setdefault(fid, d)  # never-read values die where they're born
    for f in prog.output_fields:
        last_use[f.fid] = len(prog.elements)
    return def_elem, last_use


class _SlotFile:
    """Recycling slot allocator.  ``assigned`` records every fid's slot
    permanently (a fid occupies exactly one slot for its whole lifetime);
    ``release`` only returns the slot to the free pool for a *later* fid."""

    def __init__(self) -> None:
        self._free: list[int] = []
        self._next = 0
        self._live: set[int] = set()
        self.assigned: dict[int, int] = {}

    def alloc(self, fid: int) -> int:
        if self._free:
            self._free.sort()
            s = self._free.pop(0)
        else:
            s = self._next
            self._next += 1
        self.assigned[fid] = s
        self._live.add(fid)
        return s

    def release(self, fid: int) -> None:
        if fid in self._live:
            self._live.discard(fid)
            self._free.append(self.assigned[fid])

    @property
    def high_water(self) -> int:
        return self._next


def _rename_fields(prog: PipelineProgram) -> tuple[dict[int, int], int]:
    """Liveness-driven rename: fid -> compact executor slot."""
    def_elem, last_use = _liveness(prog)
    # Group deaths by element so each element's pass is O(deaths), not O(fields).
    deaths: dict[int, list[int]] = {}
    for fid, lu in last_use.items():
        deaths.setdefault(lu, []).append(fid)

    slots = _SlotFile()
    for f in prog.input_fields:
        slots.alloc(f.fid)
    for e, el in enumerate(prog.elements):
        # Reads of element e happen before its writes (read-before-write), so
        # anything last *read* at or before e frees before e's dsts allocate.
        # A never-read value written at e (last_use == def == e) must survive
        # its own write; it frees one element later.
        for fid in deaths.get(e, ()):
            if def_elem.get(fid, -1) < e:
                slots.release(fid)
            else:
                deaths.setdefault(e + 1, []).append(fid)
        for op in el.ops:
            if op.dst.fid not in slots.assigned:
                slots.alloc(op.dst.fid)
    return slots.assigned, slots.high_water


# ---------------------------------------------------------------------------
# Lowering proper
# ---------------------------------------------------------------------------

def _lower_op(op: Op, slot: dict[int, int], null: int) -> list[tuple]:
    """One front-end op -> dense rows (opcode, dst, s0, s1, i0, i1, mask, first)."""
    m = _mask(op.dst.width)
    d = slot.get(op.dst.fid, op.dst.fid)

    def s(i: int) -> int:
        return slot.get(op.srcs[i].fid, op.srcs[i].fid)

    code = op.opcode
    if code == OpCode.COPY:
        return [(XOR_IMM, d, s(0), null, U32(0), U32(0), m, 1)]
    if code == OpCode.XNOR_IMM:
        return [(XOR_IMM, d, s(0), null, ~U32(op.imm[0]), U32(0), m, 1)]
    if code == OpCode.AND_IMM:
        return [(SHR_AND_IMM, d, s(0), null, U32(0), U32(op.imm[0]), m, 1)]
    if code == OpCode.SHR_AND_IMM:
        return [(SHR_AND_IMM, d, s(0), null, U32(op.imm[0]), U32(op.imm[1]), m, 1)]
    if code == OpCode.ADD:
        return [(ADD, d, s(0), s(1), U32(0), U32(0), m, 1)]
    if code == OpCode.GE_IMM:
        return [(GE_IMM, d, s(0), null, U32(op.imm[0]), U32(0), m, 1)]
    if code == OpCode.POPCNT:
        return [(POPCNT, d, s(0), null, U32(0), U32(0), m, 1)]
    if code == OpCode.FOLD:
        # One SHL micro-row per sign bit; rows after the first accumulate
        # (additive == OR: each row deposits a disjoint bit).
        return [
            (SHL_IMM, d, s(k), null, U32(k), U32(0), m, 1 if k == 0 else 0)
            for k in range(len(op.srcs))
        ]
    raise ValueError(f"unknown opcode {code}")  # pragma: no cover


def lower_program(prog: PipelineProgram, compact: bool = True) -> LoweredProgram:
    """Lower ``prog`` to dense op-tables.

    ``compact=True`` (default) renames SSA field ids onto a recycled slot
    file; ``compact=False`` keeps slot == fid (debugging aid — bitwise
    identical results, much larger register file).
    """
    # The compaction mode changes slot numbering, so it is part of the
    # lowered identity (executor caches are keyed on this fingerprint).
    fingerprint = f"{prog.fingerprint()}:{'compact' if compact else 'full'}"
    num_el = len(prog.elements)

    if compact:
        slot_map, num_slots = _rename_fields(prog)
    else:
        slot_map, num_slots = {}, prog.num_fields
    null = num_slots

    per_element_rows: list[list[tuple]] = []
    stages: list[str] = []
    for el in prog.elements:
        rows: list[tuple] = []
        for op in el.ops:
            rows.extend(_lower_op(op, slot_map, null))
        per_element_rows.append(rows)
        stages.append(el.stage)

    num_ops = sum(len(r) for r in per_element_rows)
    max_rows = max((len(r) for r in per_element_rows), default=1)
    max_rows = max(max_rows, 1)
    pad_row = (SHR_AND_IMM, null, null, null, U32(0), U32(0), U32(0), 1)

    def table(idx: int, dtype) -> np.ndarray:
        out = np.empty((num_el, max_rows), dtype=dtype)
        for e, rows in enumerate(per_element_rows):
            padded = rows + [pad_row] * (max_rows - len(rows))
            out[e, :] = [r[idx] for r in padded]
        return out

    # Parser/deparser bit tables.
    in_slot, in_shift = [], []
    for f in prog.input_fields:
        s = slot_map.get(f.fid, f.fid)
        in_slot.extend([s] * f.width)
        in_shift.extend(range(f.width))
    out_slot, out_shift = [], []
    for f in prog.output_fields:
        s = slot_map.get(f.fid, f.fid)
        out_slot.extend([s] * f.width)
        out_shift.extend(range(f.width))
    if len(in_slot) != prog.input_bits or len(out_slot) != prog.output_bits:
        raise AssertionError("parser/deparser table width mismatch")

    return LoweredProgram(
        source_fingerprint=fingerprint,
        chip_name=prog.chip.name,
        num_slots=num_slots,
        input_bits=prog.input_bits,
        output_bits=prog.output_bits,
        opcode=table(0, np.int32),
        dst=table(1, np.int32),
        src0=table(2, np.int32),
        src1=table(3, np.int32),
        imm0=table(4, np.uint32),
        imm1=table(5, np.uint32),
        mask=table(6, np.uint32),
        first_write=table(7, np.int32),
        rows_per_element=np.array(
            [len(r) for r in per_element_rows], np.int32
        ),
        element_stages=tuple(stages),
        num_ops=num_ops,
        in_slot_per_bit=np.array(in_slot, np.int32),
        in_shift_per_bit=np.array(in_shift, np.uint32),
        out_slot_per_bit=np.array(out_slot, np.int32),
        out_shift_per_bit=np.array(out_shift, np.uint32),
    )
