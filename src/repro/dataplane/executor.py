"""Fused batched executor for lowered pipeline programs.

Runs a :class:`~repro.dataplane.lowering.LoweredProgram` over ``(chunk,
num_regs)`` uint32 register files — the whole program as *data*: a single
``jax.lax.scan`` over the element axis of the op-tables, with a branchless
ALU (the per-row opcode selects between vectorized variants) replacing the
legacy interpreter's per-op Python dispatch.  Bit-exact with
``core.interpreter.run_program`` by construction: same read-before-write
element semantics (gather everything, then scatter), same width masking.

Backends:

* ``"jnp"``   — the scan executor above; production path on CPU.
* ``"pallas"``— ``kernels.optable_exec`` kernel; production path on TPU,
  ``interpret=True`` elsewhere (tests).
* ``"packed"``— bit-packed PHV path: activation bits are packed into uint32
  lanes at parse time (``kernels.bitpack`` on TPU, scatter-add elsewhere)
  and each neuron is one masked XNOR + ``population_count`` over 32 bits at
  a time instead of 32 op-table rows.  Requires a
  ``LoweredProgram.packed`` plan (compiler-built programs have one);
  operates on whole packets, so it has no ``run_hop`` form.
* ``"auto"``  — pallas on TPU, jnp otherwise (mirrors ``kernels.ops``).

The op-table backends execute in *opcode runs* (``LoweredProgram.
opcode_runs()``): consecutive elements sharing an opcode set are dispatched
with an ALU narrowed to exactly those opcodes, so the branchless
where-select chain collapses for the single-opcode elements the compiler
emits.

Streaming (:func:`execute_stream`) re-chunks any packet iterator into
fixed-size blocks so millions of packets run at constant device memory and a
single compiled executable.  The stream path is instrumented through
``repro.obs`` (packets/chunk counters, chunk-latency histogram, and
``compile:``/``execute:`` spans) — all no-ops unless the global
observability switch is on (see ``docs/OBSERVABILITY.md``).

Routed parse/deparse (:func:`parse_packets_routed`,
:func:`deparse_regs_routed`) generalize the parser to per-packet program
selection — the entry point ``dataplane.multitenant`` uses to serve several
merged programs from one register file in a single pass.

Invariants:

* **Bit-exactness** — every backend, chunking, and streaming path returns
  exactly what ``core.interpreter.run_program`` (and hence the
  ``core.bnn.forward`` oracle) returns for the same program and packets.
* **One ALU table** — both backends evaluate opcodes through
  :func:`alu_variants`; a new dense opcode is added there (and in the
  Pallas kernel's mirror) or nowhere.
* **Register file == PHV** — the ``(num_regs, batch)`` uint32 file produced
  by :func:`parse_packets` is the packet state on the wire: fabric hops
  thread it through :func:`run_hop` unchanged in meaning, and
  :func:`deparse_regs` only reads, never mutates.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.dataplane import lowering
from repro.dataplane.lowering import LoweredProgram

DEFAULT_CHUNK = 1 << 15  # 32768 packets per device dispatch

_BACKENDS = ("auto", "jnp", "pallas", "packed")
_BACKEND_ALIASES = {"fused": "jnp"}


def resolve_backend(backend="auto") -> str:
    """Normalize a backend choice to an executor backend string.

    Accepts the legacy strings, their aliases (``"fused"`` == ``"jnp"``),
    and :class:`repro.dataplane.plan.Backend` members — the typed
    :class:`~repro.dataplane.plan.ExecutionPlan` surface and the string
    keyword surface stay interchangeable.
    """
    backend = getattr(backend, "value", backend)  # plan.Backend -> str
    backend = _BACKEND_ALIASES.get(backend, backend)
    if backend == "interpreter":
        raise ValueError(
            "the interpreter backend is a reference path, not an executor: "
            "reach it through repro.dataplane.run(program, packets, "
            "plan=ExecutionPlan(backend=Backend.INTERPRETER))"
        )
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Device-side tables (moved once per program, keyed on content fingerprint)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _DeviceTables:
    """Everything the hot loop needs, uploaded/derived once per program."""

    ops: tuple          # 7 (num_elements, max_rows) arrays for the scan
    first_write: jax.Array
    io: tuple           # in_slot, in_shift, out_slot, out_shift
    used: tuple         # static dense-opcode set (union over all elements)
    runs: tuple         # static (start, stop, used) opcode-homogeneous runs


_TABLE_CACHE: dict[str, _DeviceTables] = {}


def _device_tables(lp: LoweredProgram) -> _DeviceTables:
    key = lp.fingerprint()
    t = _TABLE_CACHE.get(key)
    if obs.enabled():
        # A table-cache miss is the executor-side proxy for "this program
        # will trace + jit-compile on its next dispatch" — the counter pair
        # the obs report turns into a cache hit rate.
        obs.registry().counter(
            "dataplane.table_cache_hits_total"
            if t is not None
            else "dataplane.table_cache_misses_total"
        ).inc()
    if t is None:
        t = _DeviceTables(
            ops=(
                jnp.asarray(lp.opcode),
                jnp.asarray(lp.dst),
                jnp.asarray(lp.src0),
                jnp.asarray(lp.src1),
                jnp.asarray(lp.imm0),
                jnp.asarray(lp.imm1),
                jnp.asarray(lp.mask),
            ),
            first_write=jnp.asarray(lp.first_write),
            io=(
                jnp.asarray(lp.in_slot_per_bit),
                jnp.asarray(lp.in_shift_per_bit),
                jnp.asarray(lp.out_slot_per_bit),
                jnp.asarray(lp.out_shift_per_bit),
            ),
            used=lp.used_opcodes(),
            runs=lp.opcode_runs(),
        )
        _TABLE_CACHE[key] = t
    return t


# ---------------------------------------------------------------------------
# Bit-packed PHV path (the "packed" backend)
# ---------------------------------------------------------------------------

_PACKED_CACHE: dict[str, object] = {}


def _packed_fn(lp: LoweredProgram):
    """Compile ``lp.packed`` into a jitted (batch, input_bits) {0,1} ->
    (batch, output_bits) int32 function, cached per program fingerprint.

    Per layer: scatter the incoming bits into ``n_words`` uint32 PHV lanes
    (via the ``kernels.bitpack`` pallas kernel on TPU when the layer has the
    trivial contiguous layout, a one-hot scatter-add otherwise), then for
    every neuron count agreements with one masked XNOR +
    ``population_count`` per 32-bit word and compare against the SIGN
    threshold.  Bit-exact with the op-table scan — the fuzz suite
    (tests/test_differential_fuzz.py) holds the two together.
    """
    key = lp.fingerprint()
    fn = _PACKED_CACHE.get(key)
    if fn is not None:
        return fn
    pp = lp.packed
    if pp is None:
        raise ValueError(
            "program has no bit-packed plan (LoweredProgram.packed is None "
            "for hand-assembled tables and element slices); use the "
            "op-table backends"
        )
    on_tpu = jax.default_backend() == "tpu"
    layers = []
    for pl_ in pp.layers:
        trivial = bool(
            np.array_equal(pl_.in_word, np.arange(pl_.n_in) // 32)
            and np.array_equal(pl_.in_shift, np.arange(pl_.n_in) % 32)
        )
        layers.append((
            jnp.asarray(pl_.weights),
            jnp.asarray(pl_.thresholds),
            jnp.asarray(pl_.mask),
            jnp.asarray(pl_.in_word),
            jnp.asarray(pl_.in_shift),
            pl_.n_words,
            trivial,
        ))
    layers = tuple(layers)

    @jax.jit
    def run(packets: jax.Array) -> jax.Array:
        h = packets.astype(jnp.uint32)  # (batch, bits in neuron order)
        for w, thr, mask, in_word, in_shift, n_words, trivial in layers:
            if trivial and on_tpu:
                from repro.kernels.bitpack import pack_bits_words

                words = pack_bits_words(h)
            else:
                words = jnp.zeros((h.shape[0], n_words), jnp.uint32)
                words = words.at[:, in_word].add(h << in_shift)
            agree = jax.lax.population_count(
                ~(words[:, None, :] ^ w[None, :, :]) & mask[None, :, :]
            )
            count = jnp.sum(agree, axis=-1, dtype=jnp.uint32)
            h = (count >= thr[None, :]).astype(jnp.uint32)
        return h.astype(jnp.int32)

    _PACKED_CACHE[key] = run
    return run


_PACKED_SCAN_CACHE: dict[str, object] = {}


def _packed_scan_fn(lp: LoweredProgram):
    """Scan-over-layers variant of the packed executor.

    Layers are padded to common shapes and stacked
    (``lowering.stack_packed_layers``), then the whole network runs as ONE
    ``lax.scan`` over the layer axis: the layer body compiles once however
    deep the network is — the recirculation analogue for the packed
    backend (each scan step is one hop's worth of packed compute carried in
    the packet's bit vector).  Bit-exact with :func:`_packed_fn` because
    padding is inert by construction (zero masks, unreachable thresholds);
    the differential fuzz suite holds the two together.
    """
    key = lp.fingerprint()
    fn = _PACKED_SCAN_CACHE.get(key)
    if fn is not None:
        return fn
    if lp.packed is None:
        raise ValueError(
            "program has no bit-packed plan (LoweredProgram.packed is None "
            "for hand-assembled tables and element slices); use the "
            "op-table backends"
        )
    sp = lowering.stack_packed_layers(lp.packed)
    stacked = (
        jnp.asarray(sp.weights),
        jnp.asarray(sp.thresholds),
        jnp.asarray(sp.mask),
        jnp.asarray(sp.in_word),
        jnp.asarray(sp.in_shift),
    )
    max_bits, max_words = sp.max_bits, sp.max_words
    out_bits = sp.output_bits

    @jax.jit
    def run(packets: jax.Array) -> jax.Array:
        h = packets.astype(jnp.uint32)
        pad = max_bits - h.shape[1]
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad)))

        def layer(h, tbl):
            w, thr, mask, in_word, in_shift = tbl
            words = jnp.zeros((h.shape[0], max_words), jnp.uint32)
            # Pad input bits carry 0 (outputs past a layer's true width
            # never fire), so their word-0 scatter adds nothing.
            words = words.at[:, in_word].add(h << in_shift)
            agree = jax.lax.population_count(
                ~(words[:, None, :] ^ w[None, :, :]) & mask[None, :, :]
            )
            count = jnp.sum(agree, axis=-1, dtype=jnp.uint32)
            nxt = (count >= thr[None, :]).astype(jnp.uint32)
            if max_bits > nxt.shape[1]:
                nxt = jnp.pad(nxt, ((0, 0), (0, max_bits - nxt.shape[1])))
            return nxt, None

        h, _ = jax.lax.scan(layer, h, stacked)
        return h[:, :out_bits].astype(jnp.int32)

    _PACKED_SCAN_CACHE[key] = run
    return run


# ---------------------------------------------------------------------------
# Parser / ALU scan / deparser (jnp backend)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_regs",))
def parse_packets(packets: jax.Array, in_slot, in_shift, *, num_regs: int):
    """(batch, input_bits) {0,1} -> (num_regs, batch) uint32 register files.

    The register file is transposed — registers on the leading axis — so the
    executor's per-row gathers and scatters are contiguous row copies instead
    of strided column accesses (and the layout matches the Pallas kernel's).
    """
    pkt = packets.astype(jnp.uint32).T  # (input_bits, batch)
    regs = jnp.zeros((num_regs, packets.shape[0]), jnp.uint32)
    return regs.at[in_slot, :].add(pkt << in_shift[:, None])


@jax.jit
def deparse_regs(regs: jax.Array, out_slot, out_shift) -> jax.Array:
    """(num_regs, batch) -> (batch, output_bits) {0,1} int32."""
    words = jnp.take(regs, out_slot, axis=0)  # (output_bits, batch)
    return ((words >> out_shift[:, None]) & jnp.uint32(1)).T.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_regs",))
def parse_packets_routed(
    packets: jax.Array,
    program_ids: jax.Array,
    slot_table: jax.Array,
    shift_table: jax.Array,
    valid_table: jax.Array,
    *,
    num_regs: int,
):
    """Per-packet-program parser for a shared register file.

    ``packets``: (batch, max_bits) {0,1}; ``program_ids``: (batch,) int32
    selecting each packet's row of the ``(num_programs, max_bits)`` parser
    tables.  Bits whose ``valid_table`` entry is 0 (width padding for
    narrower programs) land harmlessly in the null slot with value 0.  This
    is how a multi-tenant merge parses a mixed stream into disjoint
    register windows in one dispatch (``dataplane.multitenant``).
    """
    batch = packets.shape[0]
    pkt = packets.astype(jnp.uint32)                    # (batch, max_bits)
    slots = jnp.take(slot_table, program_ids, axis=0)   # (batch, max_bits)
    shifts = jnp.take(shift_table, program_ids, axis=0)
    valid = jnp.take(valid_table, program_ids, axis=0)
    vals = (pkt & valid) << shifts
    regs = jnp.zeros((num_regs, batch), jnp.uint32)
    cols = jnp.arange(batch, dtype=jnp.int32)[:, None]
    return regs.at[slots, cols].add(vals)


@jax.jit
def deparse_regs_routed(
    regs: jax.Array,
    program_ids: jax.Array,
    out_slot_table: jax.Array,
    out_shift_table: jax.Array,
) -> jax.Array:
    """(num_regs, batch) -> (batch, max_out_bits) {0,1} int32, reading each
    packet's bits through its own program's deparser table.  Width-padding
    entries point at the null register and deparse as 0."""
    batch = regs.shape[1]
    slots = jnp.take(out_slot_table, program_ids, axis=0)   # (batch, bits)
    shifts = jnp.take(out_shift_table, program_ids, axis=0)
    cols = jnp.arange(batch, dtype=jnp.int32)[:, None]
    words = regs[slots, cols]                               # (batch, bits)
    return ((words >> shifts) & jnp.uint32(1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("total_bits",))
def route_bits_in(
    packets: jax.Array,
    program_ids: jax.Array,
    bit_table: jax.Array,
    valid_table: jax.Array,
    *,
    total_bits: int,
) -> jax.Array:
    """Dense-bit analogue of :func:`parse_packets_routed` for the packed
    backend: scatter each packet's bits to its program's window of a
    ``(batch, total_bits)`` merged input-bit vector.

    ``bit_table``/``valid_table`` are ``(num_programs, max_bits)``; invalid
    (width-padding) entries carry index 0 and valid 0, so they add nothing.
    """
    pkt = packets.astype(jnp.uint32)
    idx = jnp.take(bit_table, program_ids, axis=0)      # (batch, max_bits)
    valid = jnp.take(valid_table, program_ids, axis=0)
    out = jnp.zeros((packets.shape[0], total_bits), jnp.uint32)
    cols = jnp.arange(packets.shape[0], dtype=jnp.int32)[:, None]
    return out.at[cols, idx].add(pkt & valid)


@jax.jit
def route_bits_out(
    bits: jax.Array,
    program_ids: jax.Array,
    bit_table: jax.Array,
) -> jax.Array:
    """Gather each packet's output bits back out of a merged dense bit
    vector through its program's ``(num_programs, max_out_bits)`` routing
    table.  Width-padding entries gather bit 0; callers slice them off per
    tenant just as with :func:`deparse_regs_routed`."""
    idx = jnp.take(bit_table, program_ids, axis=0)      # (batch, max_out)
    return jnp.take_along_axis(bits, idx, axis=1).astype(jnp.int32)


def alu_variants(r0, r1, i0, i1, used: tuple) -> list:
    """The dense-opcode ALU: ``[(code, value), ...]`` for the opcodes in
    ``used``.  Shared by the jnp scan executor and the Pallas kernel so both
    backends compute from one opcode->expression table (the bit-exactness
    contract between them hangs on these staying identical)."""
    table = (
        (lowering.XOR_IMM, lambda: r0 ^ i0),
        (lowering.SHR_AND_IMM, lambda: (r0 >> i0) & i1),
        (lowering.ADD, lambda: r0 + r1),
        (lowering.GE_IMM, lambda: (r0 >= i0).astype(jnp.uint32)),
        (lowering.SHL_IMM, lambda: r0 << i0),
        (lowering.POPCNT, lambda: jax.lax.population_count(r0)),
    )
    return [(code, expr()) for code, expr in table if code in used]


def _element_scan(regs: jax.Array, tables: tuple, used: tuple) -> jax.Array:
    """The fused inner loop body: scan the op-table over the register file.

    Traceable (not jitted here) so both :func:`run_elements` and the
    stacked-hop scan (:func:`run_hops_scanned`) compile the SAME element
    step — bit-exactness between the unrolled and scanned fabric paths is
    by shared construction, then fuzz-proven.
    """

    def step(regs, tbl):
        opc, dst, s0, s1, i0, i1, m = tbl
        r0 = jnp.take(regs, s0, axis=0)  # (rows, batch), contiguous rows
        r1 = jnp.take(regs, s1, axis=0)

        variants = alu_variants(r0, r1, i0[:, None], i1[:, None], used)
        _, val = variants[0]
        for code, v in variants[1:]:
            val = jnp.where((opc == code)[:, None], v, val)
        val = val & m[:, None]

        # Element write-back: zero every written slot, then scatter-add.  One
        # writer per slot except FOLD micro-rows, whose contributions carry
        # disjoint bits (add == OR).  Pad rows add 0 to the null register.
        regs = regs.at[dst, :].set(jnp.uint32(0)).at[dst, :].add(val)
        return regs, None

    regs, _ = jax.lax.scan(step, regs, tables)
    return regs


@functools.partial(jax.jit, static_argnames=("used",))
def run_elements(regs: jax.Array, tables: tuple, *, used: tuple):
    """Scan the op-table over the register file (the fused inner loop).

    ``regs``: (num_regs, batch).  ``used`` is the static tuple of dense
    opcodes present, so the branchless ALU only materializes variants the
    program can select.
    """
    return _element_scan(regs, tables, used)


@functools.partial(jax.jit, static_argnames=("used",))
def _run_hops_stacked(regs: jax.Array, tables: tuple, *, used: tuple):
    """Nested scan: hops on the outside, elements inside — the whole fabric
    chain as ONE compiled dispatch over ``(H, E, rows)`` stacked tables."""

    def hop(regs, tbl):
        return _element_scan(regs, tbl, used), None

    regs, _ = jax.lax.scan(hop, regs, tables)
    return regs


_STACKED_CACHE: dict[tuple, object] = {}


def run_hops_scanned(
    stacked,
    regs: jax.Array,
    *,
    backend: str = "jnp",
    interpret: bool | None = None,
) -> jax.Array:
    """Run a :class:`~repro.dataplane.lowering.StackedHops` chain over parsed
    register files as a single ``lax.scan`` over the hop axis.

    Bit-exact with calling :func:`run_hop` per hop slice: the scan body IS
    the shared element step (op-table backends) or the Pallas kernel with
    the hop tables as scan-carried operands.  The union opcode set trades
    the per-run ALU narrowing of the unrolled path for one compiled body —
    results are identical either way.
    """
    backend = resolve_backend(backend)
    if backend == "packed":
        raise ValueError(
            "the packed backend scans layers, not register-file hops "
            "(see execute(..., scan_hops=True))"
        )
    if backend == "pallas" and interpret is None:
        interpret = jax.default_backend() != "tpu"
    key = (stacked.fingerprint, backend, bool(interpret))
    entry = _STACKED_CACHE.get(key)
    if entry is None:
        tables = tuple(
            jnp.asarray(getattr(stacked, name))
            for name in (
                "opcode", "dst", "src0", "src1", "imm0", "imm1", "mask",
            )
        )
        first_write = jnp.asarray(stacked.first_write)
        used = stacked.used
        if backend == "pallas":
            from repro.kernels.optable_exec import optable_run

            interp = bool(interpret)

            @jax.jit
            def scanned(regs, tabs, fw):
                def hop(regs, tbl):
                    t, f = tbl
                    return (
                        optable_run(regs, *t, f, used=used, interpret=interp),
                        None,
                    )

                regs, _ = jax.lax.scan(hop, regs, (tabs, fw))
                return regs

            entry = (lambda r: scanned(r, tables, first_write))
        else:
            entry = (
                lambda r: _run_hops_stacked(r, tables, used=used)
            )
        _STACKED_CACHE[key] = entry
    return entry(regs)


def run_hop(
    lowered: LoweredProgram,
    regs: jax.Array,
    *,
    backend: str = "jnp",
    interpret: bool | None = None,
) -> jax.Array:
    """Run one program (or fabric-hop slice) over parsed register files.

    The (num_regs, batch) register file in/out *is* the PHV on the wire —
    ``fabric.SwitchFabric`` chains hops by threading it through here.
    """
    backend = resolve_backend(backend)
    if backend == "packed":
        raise ValueError(
            "the packed backend consumes whole packets (execute / "
            "execute_stream), not register-file hops"
        )
    t = _device_tables(lowered)
    if backend == "pallas":
        from repro.kernels.optable_exec import optable_run_segmented

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return optable_run_segmented(
            regs, *t.ops, t.first_write, runs=t.runs, interpret=interpret
        )
    for start, stop, used in t.runs:
        regs = run_elements(
            regs, tuple(a[start:stop] for a in t.ops), used=used
        )
    return regs


# ---------------------------------------------------------------------------
# Fused routed dispatch (merged multi-tenant programs)
# ---------------------------------------------------------------------------

_ROUTED_CACHE: dict[tuple, object] = {}


def _routing_key(*tables: np.ndarray) -> int:
    """Content hash of per-tenant routing tables.

    Merged-program fingerprints are insertion-order canonical (so the table
    caches dedupe permuted tenant sets), but the tenant-id-indexed routing
    tables are NOT order-invariant — two schedulers admitting the same
    programs in different orders share op-tables yet route differently.  The
    routed caches therefore key on fingerprint *plus* routing content.
    """
    return hash(tuple(np.asarray(t).tobytes() for t in tables))


def _invert_bit_routing(bit_table, valid_table, total_bits: int):
    """Forward scatter tables -> inverse gather tables.

    :func:`route_bits_in`'s per-packet scatter (``out.at[cols, idx].add``)
    serializes on CPU/GPU — XLA lowers dynamic-index scatter-add to a
    sequential loop.  The routing is a bijection from each program's valid
    packet columns onto its disjoint window, so it inverts exactly: for
    every dense position, which packet column feeds it (``src``) and
    whether it is fed at all (``ok``).  The fused dispatch then needs only
    ``take_along_axis`` gathers, which vectorize.
    """
    bt = np.asarray(bit_table)
    vt = np.asarray(valid_table).astype(bool)
    src = np.zeros((bt.shape[0], total_bits), np.int32)
    ok = np.zeros((bt.shape[0], total_bits), np.uint32)
    for p in range(bt.shape[0]):
        cols = np.nonzero(vt[p])[0]
        src[p, bt[p, cols]] = cols
        ok[p, bt[p, cols]] = 1
    return src, ok


def _invert_parse_routing(slot_table, shift_table, valid_table):
    """Register-file analogue of :func:`_invert_bit_routing`.

    Each program maps its valid packet columns onto distinct
    ``(slot, shift)`` pairs; only a handful of slots (the input registers)
    ever receive parser bits.  Returns ``(slots, col, ok)`` where ``slots``
    is that receiving set and ``col``/``ok`` are ``(programs, len(slots),
    32)`` gather tables: word ``s`` of a packet's register file is the
    OR over ``k`` of ``packet[col[p, s, k]] << k``.
    """
    st = np.asarray(slot_table)
    sh = np.asarray(shift_table)
    vt = np.asarray(valid_table).astype(bool)
    num_programs = st.shape[0]
    slots = np.unique(st[vt]) if vt.any() else np.zeros(1, np.int64)
    index_of = {int(s): i for i, s in enumerate(slots)}
    col = np.zeros((num_programs, len(slots), 32), np.int32)
    ok = np.zeros((num_programs, len(slots), 32), np.uint32)
    for p in range(num_programs):
        for c in np.nonzero(vt[p])[0]:
            col[p, index_of[int(st[p, c])], int(sh[p, c])] = c
            ok[p, index_of[int(st[p, c])], int(sh[p, c])] = 1
    return slots.astype(np.int32), col, ok


def routed_fn(
    lp: LoweredProgram,
    in_slot: np.ndarray,
    in_shift: np.ndarray,
    in_valid: np.ndarray,
    out_slot: np.ndarray,
    out_shift: np.ndarray,
    *,
    backend: str = "jnp",
    interpret: bool | None = None,
):
    """One-jit merged dispatch: routed parse -> opcode-run execution ->
    routed deparse, compiled as a single ``(packets, program_ids) ->
    output bits`` executable.

    Fusing the three phases removes the per-chunk multi-dispatch overhead of
    calling :func:`parse_packets_routed` / :func:`run_hop` /
    :func:`deparse_regs_routed` separately (one device round-trip per opcode
    run) — the register file never leaves the compiled computation.  Cached
    per (program fingerprint, backend, interpret, routing content).
    """
    backend = resolve_backend(backend)
    if backend == "packed":
        raise ValueError(
            "the packed backend routes dense bits, not register files; use "
            "routed_packed_fn"
        )
    if backend == "pallas" and interpret is None:
        interpret = jax.default_backend() != "tpu"
    key = (
        lp.fingerprint(), backend, bool(interpret),
        _routing_key(in_slot, in_shift, in_valid, out_slot, out_shift),
    )
    fn = _ROUTED_CACHE.get(key)
    if fn is not None:
        return fn
    t = _device_tables(lp)
    reg_slots, parse_col, parse_ok = _invert_parse_routing(
        in_slot, in_shift, in_valid
    )
    n_slots = len(reg_slots)
    d_reg_slots = jnp.asarray(reg_slots)
    d_col = jnp.asarray(parse_col.reshape(parse_col.shape[0], -1))
    d_ok = jnp.asarray(parse_ok.reshape(parse_ok.shape[0], -1))
    d_out_slot = jnp.asarray(out_slot)
    d_out_shift = jnp.asarray(out_shift)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    num_regs = lp.num_regs

    def parse(packets: jax.Array, program_ids: jax.Array) -> jax.Array:
        batch = packets.shape[0]
        pkt = packets.astype(jnp.uint32)
        cols = jnp.take(d_col, program_ids, axis=0)   # (batch, slots*32)
        ok = jnp.take(d_ok, program_ids, axis=0)
        bits = jnp.take_along_axis(pkt, cols, axis=1) & ok
        words = jnp.sum(
            bits.reshape(batch, n_slots, 32) << shifts[None, None, :],
            axis=2,
            dtype=jnp.uint32,
        )
        regs = jnp.zeros((num_regs, batch), jnp.uint32)
        return regs.at[d_reg_slots].set(words.T)

    if backend == "pallas":
        from repro.kernels.optable_exec import optable_run_segmented

        runs = t.runs
        interp = bool(interpret)

        @jax.jit
        def fn(packets: jax.Array, program_ids: jax.Array) -> jax.Array:
            regs = parse(packets, program_ids)
            regs = optable_run_segmented(
                regs, *t.ops, t.first_write, runs=runs, interpret=interp
            )
            return deparse_regs_routed(
                regs, program_ids, d_out_slot, d_out_shift
            )

    else:
        @jax.jit
        def fn(packets: jax.Array, program_ids: jax.Array) -> jax.Array:
            regs = parse(packets, program_ids)
            for start, stop, used in t.runs:
                regs = _element_scan(
                    regs, tuple(a[start:stop] for a in t.ops), used
                )
            return deparse_regs_routed(
                regs, program_ids, d_out_slot, d_out_shift
            )

    _ROUTED_CACHE[key] = fn
    return fn


def routed_packed_fn(
    lp: LoweredProgram,
    packed_in_bit: np.ndarray,
    packed_out_bit: np.ndarray,
    in_valid: np.ndarray,
):
    """Packed-backend twin of :func:`routed_fn`: route dense bits into the
    merged packed program's input window, run the block-diagonal XNOR/popcnt
    chain, and gather each packet's bits back out — one jit end to end."""
    pp = lp.packed
    if pp is None:
        raise ValueError(
            "merged program has no packed plan; every tenant must carry one "
            "(compiler-built programs do)"
        )
    key = (
        lp.fingerprint(), "packed",
        _routing_key(packed_in_bit, in_valid, packed_out_bit),
    )
    fn = _ROUTED_CACHE.get(key)
    if fn is not None:
        return fn
    inner = _packed_fn(lp)
    src_tbl, ok_tbl = _invert_bit_routing(
        packed_in_bit, in_valid, pp.input_bits
    )
    d_src = jnp.asarray(src_tbl)
    d_ok = jnp.asarray(ok_tbl)
    d_out = jnp.asarray(packed_out_bit)

    @jax.jit
    def fn(packets: jax.Array, program_ids: jax.Array) -> jax.Array:
        pkt = packets.astype(jnp.uint32)
        src = jnp.take(d_src, program_ids, axis=0)
        ok = jnp.take(d_ok, program_ids, axis=0)
        dense = jnp.take_along_axis(pkt, src, axis=1) & ok
        return route_bits_out(inner(dense), program_ids, d_out)

    _ROUTED_CACHE[key] = fn
    return fn


_ROUTED_STACK_CACHE: dict[tuple, object] = {}


def routed_packed_stacked_fn(lowereds: tuple):
    """Widest-tenant packed dispatch for an interleaved merge.

    The block-diagonal merged packed program (``routed_packed_fn``) makes
    every packet XNOR against every tenant's words — per-chunk work scales
    with the *sum* of tenant widths.  Here each tenant's packed layers are
    instead stacked along a leading tenant axis, padded to the widest
    block per stage (pad neurons carry unreachable thresholds, so they
    emit 0), and each packet gathers its own tenant's weight block by
    ``program_id`` — per-chunk work scales with the *widest/deepest*
    tenant per stage.  Inputs and outputs stay tenant-local (bit ``i`` of
    tenant ``t`` lives at position ``i`` for every tenant), so no bit
    routing is needed at either end.

    Returns ``None`` when any tenant lacks a packed plan or uses a
    non-trivial word layout (hand-assembled programs) — callers fall back
    to the block-diagonal merged plan.
    """
    key = tuple(lp.fingerprint() for lp in lowereds)
    fn = _ROUTED_STACK_CACHE.get(key)
    if fn is not None:
        return fn
    packs = [lp.packed for lp in lowereds]
    if any(pp is None for pp in packs):
        return None
    depth = max(len(pp.layers) for pp in packs)
    columns = []
    for pp in packs:
        ls = list(pp.layers)
        while len(ls) < depth:
            ls.append(lowering.PackedLayer.identity(ls[-1].n_out))
        for pl in ls:
            bit = np.arange(pl.n_in)
            if not (
                np.array_equal(pl.in_word, bit // 32)
                and np.array_equal(pl.in_shift, bit % 32)
            ):
                return None
        columns.append(ls)
    stacked = []
    for layer_idx in range(depth):
        pls = [c[layer_idx] for c in columns]
        max_n = max(pl.n_out for pl in pls)
        max_w = max(pl.n_words for pl in pls)
        w = np.zeros((len(pls), max_n, max_w), np.uint32)
        m = np.zeros((len(pls), max_n, max_w), np.uint32)
        # Pad neurons can never fire: agreement tops out at 32 * words.
        thr = np.full((len(pls), max_n), 0xFFFFFFFF, np.uint32)
        for t, pl in enumerate(pls):
            w[t, : pl.n_out, : pl.n_words] = pl.weights
            m[t, : pl.n_out, : pl.n_words] = pl.mask
            thr[t, : pl.n_out] = pl.thresholds
        stacked.append(
            (jnp.asarray(w), jnp.asarray(thr), jnp.asarray(m), max_w)
        )
    stacked = tuple(stacked)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    @jax.jit
    def fn(packets: jax.Array, program_ids: jax.Array) -> jax.Array:
        h = packets.astype(jnp.uint32)   # (batch, bits), tenant-local
        for w_tbl, thr_tbl, m_tbl, n_words in stacked:
            need = n_words * 32
            if h.shape[1] < need:
                h = jnp.pad(h, ((0, 0), (0, need - h.shape[1])))
            else:
                h = h[:, :need]
            words = jnp.sum(
                h.reshape(h.shape[0], n_words, 32) << shifts[None, None, :],
                axis=2,
                dtype=jnp.uint32,
            )
            w = jnp.take(w_tbl, program_ids, axis=0)  # (batch, maxN, maxW)
            m = jnp.take(m_tbl, program_ids, axis=0)
            thr = jnp.take(thr_tbl, program_ids, axis=0)
            agree = jax.lax.population_count(
                ~(words[:, None, :] ^ w) & m
            )
            count = jnp.sum(agree, axis=-1, dtype=jnp.uint32)
            h = (count >= thr).astype(jnp.uint32)
        return h.astype(jnp.int32)

    _ROUTED_STACK_CACHE[key] = fn
    return fn


def _run_chunk(
    lp: LoweredProgram,
    packets: jax.Array,
    backend: str,
    interpret: bool | None,
    scan_hops: bool = False,
) -> jax.Array:
    if backend == "packed":
        fn = _packed_scan_fn(lp) if scan_hops else _packed_fn(lp)
        return fn(packets)
    t = _device_tables(lp)
    in_slot, in_shift, out_slot, out_shift = t.io
    regs = parse_packets(packets, in_slot, in_shift, num_regs=lp.num_regs)
    regs = run_hop(lp, regs, backend=backend, interpret=interpret)
    return deparse_regs(regs, out_slot, out_shift)


# ---------------------------------------------------------------------------
# Public batch / streaming API
# ---------------------------------------------------------------------------

def execute(
    lowered: LoweredProgram,
    packets,
    *,
    backend: str = "auto",
    chunk_size: int | None = None,
    interpret: bool | None = None,
    scan_hops: bool = False,
) -> np.ndarray:
    """Run ``packets`` (N, input_bits) {0,1} through the program.

    Returns (N, output_bits) int32, bit-exact with
    ``interpreter.run_program``.  Batches larger than ``chunk_size`` stream
    in fixed-size chunks (constant device memory, one compiled executable).
    ``scan_hops=True`` runs the packed backend's scan-over-layers plan
    (``_packed_scan_fn``) instead of the unrolled layer loop; op-table
    backends ignore it (their hop structure lives in ``fabric``).
    """
    packets = np.asarray(packets)
    if packets.ndim != 2 or packets.shape[1] != lowered.input_bits:
        raise ValueError(
            f"expected (batch, {lowered.input_bits}) packet bits, "
            f"got {packets.shape}"
        )
    backend = resolve_backend(backend)
    n = packets.shape[0]
    chunk = chunk_size or DEFAULT_CHUNK
    if n <= chunk:
        return np.asarray(
            _run_chunk(lowered, jnp.asarray(packets), backend, interpret, scan_hops)
        )[:n]

    out = np.empty((n, lowered.output_bits), np.int32)
    for start in range(0, n, chunk):
        block = packets[start : start + chunk]
        pad = chunk - block.shape[0]
        if pad:
            block = np.pad(block, ((0, pad), (0, 0)))
        res = _run_chunk(lowered, jnp.asarray(block), backend, interpret, scan_hops)
        out[start : start + chunk] = np.asarray(res)[: chunk - pad]
    return out


@dataclasses.dataclass
class StreamResult:
    """Outcome of a streamed run — the simulator's line-rate measurement."""

    packets: int
    chunks: int
    seconds: float
    bit_counts: np.ndarray            # (output_bits,) int64: ones per Y bit
    outputs: np.ndarray | None = None  # (packets, output_bits) uint8 if collected
    warmup_seconds: float = 0.0        # first-chunk warm call (incl. jit compile)

    @property
    def packets_per_second(self) -> float:
        return self.packets / self.seconds if self.seconds > 0 else float("inf")


def _rechunk(chunks: Iterable[np.ndarray], chunk_size: int) -> Iterator[np.ndarray]:
    """Re-slice an arbitrary chunk stream into exactly-``chunk_size`` blocks
    (last block may be short)."""
    buf: list[np.ndarray] = []
    have = 0
    for c in chunks:
        c = np.asarray(c)
        while c.shape[0]:
            take = min(chunk_size - have, c.shape[0])
            buf.append(c[:take])
            have += take
            c = c[take:]
            if have == chunk_size:
                yield np.concatenate(buf, axis=0) if len(buf) > 1 else buf[0]
                buf, have = [], 0
    if have:
        yield np.concatenate(buf, axis=0) if len(buf) > 1 else buf[0]


def _probe_roofline(lowered, backend, chunk, interpret, scan_hops):
    """Fail-soft ``roofline.dataplane`` probe of the compiled dispatch —
    obs-only bookkeeping, never allowed to affect an execution path."""
    try:
        from repro.roofline import dataplane as _roofline_dp

        return _roofline_dp.probe_stream(
            lowered,
            backend=backend,
            chunk=chunk,
            interpret=interpret,
            scan_hops=scan_hops,
        )
    except Exception:  # noqa: BLE001 - observation must not break runs
        return None


def _record_roofline(roofline, measured_pps):
    """Fail-soft gauge publication for a probe (see ``_probe_roofline``)."""
    try:
        from repro.roofline import dataplane as _roofline_dp

        _roofline_dp.record(roofline, measured_pps=measured_pps)
    except Exception:  # noqa: BLE001 - observation must not break runs
        pass


def execute_stream(
    lowered: LoweredProgram,
    chunks: Iterable[np.ndarray],
    *,
    backend: str = "auto",
    chunk_size: int = DEFAULT_CHUNK,
    collect: bool = False,
    interpret: bool | None = None,
    scan_hops: bool = False,
) -> StreamResult:
    """Stream a packet-chunk iterator through the executor.

    With ``collect=False`` (default) only aggregate statistics are kept —
    memory stays constant no matter how many packets flow.  Timing covers
    device execution including host transfer (``block_until_ready`` via
    ``np.asarray``), not trace/compile of the first chunk — that warm call
    is reported separately as ``warmup_seconds``.
    """
    backend = resolve_backend(backend)
    bit_counts = np.zeros(lowered.output_bits, np.int64)
    collected: list[np.ndarray] = []
    total = 0
    n_chunks = 0
    seconds = 0.0
    warmup = 0.0
    roofline = None
    with obs.span(
        "stream:execute_stream", cat="stream",
        backend=backend, chunk_size=chunk_size,
    ):
        for block in _rechunk(chunks, chunk_size):
            n = block.shape[0]
            pad = chunk_size - n
            if pad:
                block = np.pad(block, ((0, pad), (0, 0)))
            dev = jnp.asarray(block)
            if n_chunks == 0:  # warm the compile cache outside the clock
                with obs.span(
                    "compile:stream_chunk", cat="compile",
                    backend=backend, packets=chunk_size,
                ):
                    w0 = time.perf_counter()
                    _run_chunk(
                        lowered, dev, backend, interpret, scan_hops
                    ).block_until_ready()
                    warmup = time.perf_counter() - w0
                if obs.enabled():  # cost the compiled dispatch, once
                    roofline = _probe_roofline(
                        lowered, backend, chunk_size, interpret, scan_hops
                    )
            with obs.span("execute:stream_chunk", cat="execute", packets=n):
                t0 = time.perf_counter()
                res = np.asarray(
                    _run_chunk(lowered, dev, backend, interpret, scan_hops)
                )
                dt = time.perf_counter() - t0
            seconds += dt
            res = res[:n]
            bit_counts += res.sum(axis=0, dtype=np.int64)
            if collect:
                collected.append(res.astype(np.uint8))
            total += n
            n_chunks += 1
            if obs.enabled():
                m = obs.registry()
                m.counter("dataplane.packets_total").inc(n)
                m.counter("dataplane.chunks_total").inc()
                m.histogram("dataplane.chunk_seconds").observe(dt)
    if obs.enabled() and seconds > 0:
        obs.registry().gauge("dataplane.stream_pps").set(total / seconds)
        if roofline is not None:
            _record_roofline(roofline, total / seconds)
    return StreamResult(
        packets=total,
        chunks=n_chunks,
        seconds=seconds,
        bit_counts=bit_counts,
        outputs=np.concatenate(collected, axis=0) if collected else None,
        warmup_seconds=warmup,
    )
