"""Fleet execution: N independent packet streams through ONE dispatch.

The paper's premise is a chip forwarding billions of packets per second;
what starves the simulator is not compute but orchestration — one Python
dispatch per stream per chunk.  This module batches a *fleet* of independent
simulated switches (each with its own packet stream) through a single
compiled executor:

* the per-stream chunk function (parse -> op-table scan -> deparse, or the
  bit-packed XNOR/popcount path) is ``jax.vmap``-ed over a leading stream
  axis, so a ``(streams, chunk, bits)`` block is one device dispatch however
  many switches it carries;
* with ``ExecutionPlan.devices`` set, the stream axis is sharded over a 1-D
  ``fleet`` device mesh via ``shard_map`` (``repro.sharding.fleet_mesh`` /
  ``shard_streams``) — no collectives, streams never communicate;
* per-stream chunk iterators of *different* lengths are zipped into fleet
  blocks by :func:`fleet_blocks`, zero-padding exhausted or short streams
  (every executor backend maps packet rows independently, so pad rows cannot
  perturb real ones — the same argument that makes chunk padding safe in
  ``executor.execute``).

Because every backend is packet-row-independent, the vmapped fleet is
bit-exact with running each stream alone through ``executor.execute`` — the
fuzz suite (``tests/test_fleet.py``) holds fleet, single-stream, and the
interpreter oracle together, including mid-stream resume.

Entry points: :func:`execute_fleet` (stats + optional per-stream outputs,
same warmup-outside-the-clock timing discipline as ``execute_stream``) and
:func:`fleet_fn` (the raw compiled callable, used by ``serving.engine``'s
async pipeline).  Reach both through ``repro.dataplane.run(program, streams,
plan=ExecutionPlan(fleet=N, ...))``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro import sharding as _sharding
from repro.dataplane import executor as _executor
from repro.dataplane.lowering import LoweredProgram, lower_program
from repro.dataplane.plan import ExecutionPlan

# Per-stream packets per dispatch.  Smaller than executor.DEFAULT_CHUNK on
# purpose: the fleet dimension restores the device-saturating batch size
# (64 streams x 4096 = 256k packet rows per dispatch).
DEFAULT_STREAM_CHUNK = 1 << 12

_FLEET_CACHE: dict[tuple, object] = {}


def _chunk_fn(lp: LoweredProgram, backend: str, interpret, scan_hops: bool):
    """Traceable (chunk, bits) {0,1} -> (chunk, out_bits) int32 for one
    stream — the body :func:`fleet_fn` vmaps over the stream axis."""
    if backend == "packed":
        return (
            _executor._packed_scan_fn(lp)
            if scan_hops
            else _executor._packed_fn(lp)
        )
    t = _executor._device_tables(lp)
    in_slot, in_shift, out_slot, out_shift = t.io

    def run(block: jax.Array) -> jax.Array:
        regs = _executor.parse_packets(
            block, in_slot, in_shift, num_regs=lp.num_regs
        )
        regs = _executor.run_hop(lp, regs, backend=backend, interpret=interpret)
        return _executor.deparse_regs(regs, out_slot, out_shift)

    return run


def fleet_fn(
    lowered: LoweredProgram,
    *,
    backend: str = "auto",
    interpret: bool | None = None,
    scan_hops: bool = False,
    devices: int | None = None,
):
    """The compiled fleet executable: ``(streams, chunk, bits)`` {0,1} ->
    ``(streams, chunk, out_bits)`` int32, cached per (program fingerprint,
    backend, interpret, scan_hops, devices).

    ``devices=None`` is pure vmap on the default device; an integer shards
    the stream axis over that many local devices (which must divide the
    stream count at call time).
    """
    backend = _executor.resolve_backend(backend)
    key = (
        lowered.fingerprint(),
        backend,
        None if interpret is None else bool(interpret),
        bool(scan_hops),
        devices,
    )
    fn = _FLEET_CACHE.get(key)
    if fn is not None:
        return fn
    batched = jax.vmap(_chunk_fn(lowered, backend, interpret, scan_hops))
    if devices is not None:
        batched = _sharding.shard_streams(
            batched, _sharding.fleet_mesh(devices)
        )
    fn = jax.jit(batched)
    _FLEET_CACHE[key] = fn
    return fn


def fleet_blocks(
    streams: Sequence, chunk: int, input_bits: int
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Zip per-stream chunk iterators into ``(S, chunk, input_bits)`` int32
    blocks plus ``(S,)`` valid-row counts, until every stream is exhausted.

    Streams may have different lengths and chunkings: each is re-sliced to
    exactly ``chunk`` rows per block, and a stream that runs dry (or yields
    a short final chunk) is zero-padded — pad rows are dead weight the
    caller slices off via the valid counts.
    """
    its = [_executor._rechunk(s, chunk) for s in streams]
    n = len(its)
    done = [False] * n
    while True:
        blocks = np.zeros((n, chunk, input_bits), np.int32)
        valid = np.zeros(n, np.int64)
        got = False
        for i, it in enumerate(its):
            if done[i]:
                continue
            try:
                b = next(it)
            except StopIteration:
                done[i] = True
                continue
            blocks[i, : b.shape[0]] = b
            valid[i] = b.shape[0]
            got = True
        if not got:
            return
        yield blocks, valid


@dataclasses.dataclass
class FleetRunResult:
    """Outcome of a fleet run — the simulator's *aggregate* line rate."""

    streams: int
    packets: int                      # total across the fleet
    chunks: int                       # fleet blocks dispatched
    seconds: float
    per_stream_packets: np.ndarray    # (streams,) int64
    bit_counts: np.ndarray            # (output_bits,) int64, fleet-wide
    outputs: list | None = None       # per-stream (n_i, out_bits) uint8
    warmup_seconds: float = 0.0       # first-block warm call (incl. compile)
    backend: str = "auto"
    devices: int = 1

    @property
    def packets_per_second(self) -> float:
        return self.packets / self.seconds if self.seconds > 0 else float("inf")

    @property
    def pps_per_stream(self) -> float:
        return self.packets_per_second / self.streams if self.streams else 0.0


def _normalize_streams(streams, fleet: int | None) -> list:
    """Accept a (S, n, bits) array, a (n, bits) array (replicated to
    ``fleet`` switches), or a sequence of per-stream arrays/chunk-iterables;
    return a list of per-stream chunk iterables."""
    if hasattr(streams, "ndim"):
        arr = np.asarray(streams)
        if arr.ndim == 3:
            streams = [arr[i] for i in range(arr.shape[0])]
        elif arr.ndim == 2:
            if fleet is None:
                raise ValueError(
                    "a single (batch, bits) array needs plan.fleet to say "
                    "how many switches replicate it"
                )
            streams = [arr] * fleet
        else:
            raise ValueError(f"expected 2-D or 3-D packets, got {arr.shape}")
    streams = list(streams)
    if fleet is not None and len(streams) != fleet:
        if len(streams) == 1:
            streams = streams * fleet
        else:
            raise ValueError(
                f"plan.fleet={fleet} but {len(streams)} streams were given"
            )
    return [
        [np.asarray(s)] if hasattr(s, "ndim") else s for s in streams
    ]


def _probe_fleet_roofline(lowered, backend, n_streams, chunk, plan):
    """Fail-soft roofline probe of the vmapped fleet dispatch — obs-only
    bookkeeping, never allowed to affect an execution path."""
    try:
        from repro.roofline import dataplane as _roofline_dp

        return _roofline_dp.probe_fleet(
            lowered,
            backend=backend,
            streams=n_streams,
            chunk=chunk,
            interpret=plan.interpret,
            scan_hops=bool(plan.scan_hops),
            devices=plan.devices,
        )
    except Exception:  # noqa: BLE001 - observation must not break runs
        return None


def execute_fleet(
    lowered,
    streams,
    *,
    plan: ExecutionPlan | None = None,
) -> FleetRunResult:
    """Run N independent streams through one vmapped (optionally
    shard_map-ed) executor; bit-exact per stream with
    ``executor.execute(lowered, stream_i)``.

    Timing follows ``execute_stream``'s discipline: the first block's warm
    call (trace + compile) happens outside the clock and is reported as
    ``warmup_seconds``; host->device transfer of each block is also outside
    the per-block timer.
    """
    if not isinstance(lowered, LoweredProgram):
        lowered = lower_program(lowered)
    plan = plan or ExecutionPlan()
    backend = _executor.resolve_backend(plan.backend_str)
    chunk = plan.chunk_size or DEFAULT_STREAM_CHUNK
    its = _normalize_streams(streams, plan.fleet)
    n_streams = len(its)
    if plan.devices is not None and n_streams % plan.devices != 0:
        raise ValueError(
            f"fleet of {n_streams} streams does not shard evenly over "
            f"{plan.devices} devices"
        )
    fn = fleet_fn(
        lowered,
        backend=backend,
        interpret=plan.interpret,
        scan_hops=bool(plan.scan_hops),
        devices=plan.devices,
    )

    bit_counts = np.zeros(lowered.output_bits, np.int64)
    per_stream = np.zeros(n_streams, np.int64)
    collected = [[] for _ in range(n_streams)] if plan.collect else None
    seconds = 0.0
    warmup = 0.0
    n_blocks = 0
    roofline = None
    with obs.span(
        "stream:fleet_run", cat="stream",
        streams=n_streams, backend=backend, chunk_size=chunk,
        devices=plan.devices or 1,
    ):
        for blocks, valid in fleet_blocks(its, chunk, lowered.input_bits):
            dev = jnp.asarray(blocks)
            if n_blocks == 0:  # warm the compile cache outside the clock
                with obs.span(
                    "compile:fleet_chunk", cat="compile",
                    streams=n_streams, packets=n_streams * chunk,
                ):
                    w0 = time.perf_counter()
                    fn(dev).block_until_ready()
                    warmup = time.perf_counter() - w0
                if obs.enabled():  # cost the compiled dispatch, once
                    roofline = _probe_fleet_roofline(
                        lowered, backend, n_streams, chunk, plan
                    )
            served = int(valid.sum())
            with obs.span(
                "execute:fleet_chunk", cat="execute", packets=served
            ):
                t0 = time.perf_counter()
                res = np.asarray(fn(dev))
                dt = time.perf_counter() - t0
            seconds += dt
            n_blocks += 1
            for i in range(n_streams):
                v = int(valid[i])
                if not v:
                    continue
                rows = res[i, :v]
                bit_counts += rows.sum(axis=0, dtype=np.int64)
                per_stream[i] += v
                if collected is not None:
                    collected[i].append(rows.astype(np.uint8))
            if obs.enabled():
                m = obs.registry()
                m.counter("fleet.packets_total").inc(served)
                m.counter("fleet.chunks_total").inc()
                m.histogram("fleet.chunk_seconds").observe(dt)
    total = int(per_stream.sum())
    if obs.enabled() and seconds > 0:
        obs.registry().gauge("fleet.agg_pps").set(total / seconds)
        if roofline is not None:
            _executor._record_roofline(roofline, total / seconds)
    outputs = None
    if collected is not None:
        outputs = [
            np.concatenate(c, axis=0)
            if c
            else np.zeros((0, lowered.output_bits), np.uint8)
            for c in collected
        ]
    return FleetRunResult(
        streams=n_streams,
        packets=total,
        chunks=n_blocks,
        seconds=seconds,
        per_stream_packets=per_stream,
        bit_counts=bit_counts,
        outputs=outputs,
        warmup_seconds=warmup,
        backend=backend,
        devices=plan.devices or 1,
    )
