"""Multi-tenant switch scheduling: time-share one simulated chip across
several independently compiled programs.

A real deployment does not dedicate a switching chip to one classifier: the
same pipeline hosts a DDoS detector, an IoT profiler, and a flow tagger at
once (the Brain-on-Switch direction, arXiv:2403.11090).  This module is the
serving analogue of ``serving/engine.py`` for the dataplane: a
:class:`SwitchScheduler` admits N compiled :class:`PipelineProgram`s onto one
:class:`ChipSpec` and runs them over a *mixed* packet stream — packets tagged
with tenant ids (``traffic.mixed_tenant_stream``) — in one of two modes:

* **merged** — the tenants' op-tables fuse into one table over disjoint
  register windows (``LoweredProgram.with_slot_window``), so a *single*
  fused executor pass serves every tenant on the mixed stream at full line
  rate.  Windows are disjoint, so no tenant's rows can address another
  tenant's registers: per-tenant results are bit-exact with single-program
  runs by construction.  Two layouts (``merged=`` knob, default
  ``"interleave"``):

  - ``"interleave"`` — tenants' elements pack onto *shared physical
    stages*: merged stage ``e`` runs element ``e`` of every tenant at once
    (:func:`interleave_lowered`), so per-chunk work scales with the
    *deepest* tenant, not the sum — merging amortizes, which is the whole
    point of sharing a chip.  Budget: deepest tenant's elements <= element
    budget, summed peak PHV <= PHV bits, and the widest shared stage's
    summed rows <= ``ChipSpec.max_parallel_ops`` (the per-stage ALU count
    all co-resident elements share).
  - ``"concat"`` — tenants' tables concatenate stage-after-stage
    (:func:`merge_lowered`): per-chunk work scales with the *sum* of
    elements.  Budget: summed elements <= element budget, summed peak PHV
    <= PHV bits.  Wins only when tenants' opcode mixes are so heterogeneous
    that sharing stages would widen every opcode run (see
    docs/DATAPLANE.md).
* **time_sliced** — when the merged tables exceed the chip's element budget,
  the chip alternates between programs: packets are demultiplexed into
  per-tenant FIFO queues and served in weighted round-robin turns of at most
  ``quantum * weight / max(weight)`` packets each, each turn running the
  tenant's own program.  Queue overflow beyond ``max_queue`` drops packets
  (tail drop); backlog beyond a turn's quantum counts as *deferred* —
  per-tenant telemetry exposes both.

Invariants:

* **Per-tenant bit-exactness** — in both modes, each tenant's served packets
  produce exactly the outputs of a single-program ``executor.execute`` (and
  hence the interpreter and the ``bnn.forward`` oracle) on the same packets
  in the same order.  Merging relocates registers and interleaves element
  ranges; it never changes any tenant's results.
* **Admission before execution** — ``admit`` rejects programs that cannot
  run on the chip at all (elements or peak PHV over budget), and in forced
  ``merged`` mode programs whose merged footprint would overflow; ``auto``
  falls back to time-slicing instead of rejecting.
* **Conservation** — per tenant, ``arrived == served + dropped``; nothing is
  silently lost between the mixed stream and the per-tenant outputs.
* **Observation only** — both run paths are instrumented through
  ``repro.obs`` (per-tenant packet/drop/defer counters, queue-delay
  histograms with p50/p99, and ``compile:``/``execute:`` spans), all
  no-ops while the global switch is off; enabling observability never
  changes any tenant's outputs (see ``docs/OBSERVABILITY.md``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Iterator, Sequence

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.pipeline import RMT, ChipSpec, PipelineProgram
from repro.dataplane import executor as _executor
from repro.dataplane import telemetry as _telemetry
from repro.dataplane.lowering import (
    LoweredProgram,
    PackedLayer,
    PackedProgram,
    interleave_tables,
    lower_program,
    peak_stage_rows,
)
from repro.obs.slo import SloSpec, SloTracker

SCHEDULER_MODES = ("auto", "merged", "time_sliced")
MERGED_LAYOUTS = ("interleave", "concat")
DEFAULT_QUANTUM = 4096


class AdmissionError(Exception):
    """A program cannot be admitted onto the shared chip."""


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One admitted program and its share of the chip."""

    tid: int
    name: str
    program: PipelineProgram
    lowered: LoweredProgram
    weight: float


@dataclasses.dataclass(frozen=True)
class MergedProgram:
    """N tenants' op-tables fused into one executable table.

    ``lowered`` is a real :class:`LoweredProgram` over the shared register
    file (``executor.run_hop`` executes it unchanged); the extra columns are
    the multi-tenant bookkeeping: which tenant owns each element
    (``element_program`` — the program-id column) and the per-tenant
    parser/deparser routing tables consumed by
    ``executor.parse_packets_routed`` / ``deparse_regs_routed``.
    """

    lowered: LoweredProgram
    element_program: np.ndarray              # (num_elements,) int32 tenant id
    slot_windows: tuple[tuple[int, int], ...]
    element_ranges: tuple[tuple[int, int], ...]
    in_slot: np.ndarray                      # (T, max_in_bits) int32
    in_shift: np.ndarray                     # (T, max_in_bits) uint32
    in_valid: np.ndarray                     # (T, max_in_bits) uint32 {0,1}
    out_slot: np.ndarray                     # (T, max_out_bits) int32
    out_shift: np.ndarray                    # (T, max_out_bits) uint32
    in_bits: np.ndarray                      # (T,) int32 true input widths
    out_bits: np.ndarray                     # (T,) int32 true output widths
    # Packed-backend routing (None when any tenant lacks a packed plan):
    # per-tenant indices into the merged dense input/output bit vectors
    # consumed by executor.route_bits_in / route_bits_out.  Width-padding
    # entries are 0 (masked by in_valid on the way in, sliced off by
    # ``out_bits`` on the way out).
    packed_in_bit: np.ndarray | None = None   # (T, max_in_bits) int32
    packed_out_bit: np.ndarray | None = None  # (T, max_out_bits) int32
    # Table layout ("concat" stage-after-stage, "interleave" shared stages)
    # and, for interleaved layouts, per-merged-row provenance: which tenant
    # each row came from and its (element, row) coordinates in that tenant's
    # own table (-1 for pad rows).  Provenance is what makes the interleave
    # auditable — un-interleaving by it must reproduce every tenant's rows
    # exactly (tenant_rows below; property-tested in test_multitenant.py).
    layout: str = "concat"
    row_tenant: np.ndarray | None = None      # (E, R) int32, -1 = pad
    row_src_elem: np.ndarray | None = None    # (E, R) int32, -1 = pad
    row_src_row: np.ndarray | None = None     # (E, R) int32, -1 = pad

    @property
    def num_tenants(self) -> int:
        return len(self.slot_windows)

    def tenant_rows(self, tid: int):
        """Un-interleave one tenant: its merged-table rows in (source
        element, source row) order.

        Returns ``(src_elem, src_row, fields)`` where ``fields`` maps each
        op-table column name to that tenant's extracted values — comparing
        them against the tenant's relocated single-program table proves the
        interleave dropped, duplicated, and reordered nothing.
        """
        if self.row_tenant is None:
            raise ValueError(
                "row provenance only exists for layout='interleave'"
            )
        sel = self.row_tenant == tid
        e = self.row_src_elem[sel]
        r = self.row_src_row[sel]
        order = np.lexsort((r, e))
        fields = {
            name: getattr(self.lowered, name)[sel][order]
            for name in (
                "opcode", "dst", "src0", "src1", "imm0", "imm1", "mask",
                "first_write",
            )
        }
        return e[order], r[order], fields


def _merge_packed(
    lowereds: Sequence[LoweredProgram], max_in: int, max_out: int
):
    """Fuse per-tenant packed plans into one block-diagonal plan.

    Tenants shallower than the deepest are depth-padded with
    :class:`PackedLayer.identity` layers so every tenant's bits traverse the
    same number of merged layers.  Per merged layer, each tenant occupies a
    word-aligned window: weights/mask are block-diagonal over the word axis
    (mask zeros outside the window, so foreign lanes contribute nothing),
    thresholds concatenate, and ``in_word`` shifts by the tenant's word
    offset.  Layer ``l``'s merged input-bit order is the concatenation of
    tenant layer-``l`` inputs — exactly layer ``l-1``'s concatenated output
    order, so the layers chain without any inter-layer routing.

    Returns ``(PackedProgram, packed_in_bit, packed_out_bit)`` or
    ``(None, None, None)`` when any tenant has no packed plan.
    """
    plans = [lp.packed for lp in lowereds]
    if any(p is None for p in plans):
        return None, None, None
    depth = max(len(p.layers) for p in plans)
    per_tenant: list[list[PackedLayer]] = []
    for p in plans:
        layers = list(p.layers)
        while len(layers) < depth:
            layers.append(PackedLayer.identity(p.output_bits))
        per_tenant.append(layers)

    t_count = len(per_tenant)
    merged_layers = []
    for li in range(depth):
        parts = [per_tenant[t][li] for t in range(t_count)]
        word_off = np.concatenate(
            ([0], np.cumsum([pl.n_words for pl in parts]))
        )
        total_words = int(word_off[-1])
        n_out_total = sum(pl.n_out for pl in parts)
        weights = np.zeros((n_out_total, total_words), np.uint32)
        mask = np.zeros((n_out_total, total_words), np.uint32)
        row = 0
        for t, pl in enumerate(parts):
            lo, hi = int(word_off[t]), int(word_off[t + 1])
            weights[row : row + pl.n_out, lo:hi] = pl.weights
            mask[row : row + pl.n_out, lo:hi] = pl.mask
            row += pl.n_out
        merged_layers.append(PackedLayer(
            weights=weights,
            mask=mask,
            thresholds=np.concatenate([pl.thresholds for pl in parts]),
            in_word=np.concatenate([
                pl.in_word + np.int32(word_off[t])
                for t, pl in enumerate(parts)
            ]).astype(np.int32),
            in_shift=np.concatenate([pl.in_shift for pl in parts]),
            n_in=sum(pl.n_in for pl in parts),
            n_out=n_out_total,
            n_words=total_words,
        ))

    in_off = np.concatenate(
        ([0], np.cumsum([t[0].n_in for t in per_tenant]))
    )
    out_off = np.concatenate(
        ([0], np.cumsum([t[-1].n_out for t in per_tenant]))
    )
    packed_in_bit = np.zeros((t_count, max_in), np.int32)
    packed_out_bit = np.zeros((t_count, max_out), np.int32)
    for t, layers in enumerate(per_tenant):
        packed_in_bit[t, : layers[0].n_in] = in_off[t] + np.arange(
            layers[0].n_in, dtype=np.int32
        )
        packed_out_bit[t, : layers[-1].n_out] = out_off[t] + np.arange(
            layers[-1].n_out, dtype=np.int32
        )
    pp = PackedProgram(
        layers=tuple(merged_layers),
        input_bits=int(in_off[-1]),
        output_bits=int(out_off[-1]),
    )
    return pp, packed_in_bit, packed_out_bit


def merge_lowered(
    lowereds: Sequence[LoweredProgram], chip: ChipSpec
) -> MergedProgram:
    """Concatenate lowered programs into one table over disjoint register
    windows.  Purely structural — no budget checks (the scheduler's
    admission/mode logic owns those)."""
    if not lowereds:
        raise ValueError("merge_lowered needs at least one program")
    total_slots = sum(lp.num_slots for lp in lowereds)
    max_rows = max(lp.max_rows for lp in lowereds)
    null = total_slots

    parts: list[LoweredProgram] = []
    windows: list[tuple[int, int]] = []
    offset = 0
    for lp in lowereds:
        parts.append(lp.with_slot_window(offset, total_slots).pad_rows(max_rows))
        windows.append((offset, offset + lp.num_slots))
        offset += lp.num_slots

    ranges: list[tuple[int, int]] = []
    start = 0
    for lp in lowereds:
        ranges.append((start, start + lp.num_elements))
        start += lp.num_elements

    def cat(field: str) -> np.ndarray:
        return np.concatenate([getattr(p, field) for p in parts], axis=0)

    counts = [p.opcode_counts for p in parts]
    packed_plan, packed_in_bit, packed_out_bit = _merge_packed(
        lowereds,
        int(max(lp.input_bits for lp in lowereds)),
        int(max(lp.output_bits for lp in lowereds)),
    )

    merged = LoweredProgram(
        source_fingerprint=(
            "merged(" + "+".join(p.fingerprint() for p in parts) + ")"
        ),
        chip_name=chip.name,
        num_slots=total_slots,
        input_bits=int(max(lp.input_bits for lp in lowereds)),
        output_bits=int(max(lp.output_bits for lp in lowereds)),
        opcode=cat("opcode"),
        dst=cat("dst"),
        src0=cat("src0"),
        src1=cat("src1"),
        imm0=cat("imm0"),
        imm1=cat("imm1"),
        mask=cat("mask"),
        first_write=cat("first_write"),
        rows_per_element=cat("rows_per_element"),
        element_stages=tuple(
            f"t{tid}:{stage}"
            for tid, p in enumerate(parts)
            for stage in p.element_stages
        ),
        num_ops=sum(p.num_ops for p in parts),
        # Per-packet-bit parser tables are ill-defined for a merged program
        # (each packet routes through its own tenant's tables); the routed
        # tables below replace them.  Left empty so any accidental use of the
        # single-program parse path fails loudly on shape.
        in_slot_per_bit=np.zeros(0, np.int32),
        in_shift_per_bit=np.zeros(0, np.uint32),
        out_slot_per_bit=np.zeros(0, np.int32),
        out_shift_per_bit=np.zeros(0, np.uint32),
        opcode_counts=(
            None
            if any(c is None for c in counts)
            else np.concatenate(counts, axis=0)
        ),
        packed=packed_plan,
    )

    max_in = merged.input_bits
    max_out = merged.output_bits
    t_count = len(parts)
    in_slot = np.full((t_count, max_in), null, np.int32)
    in_shift = np.zeros((t_count, max_in), np.uint32)
    in_valid = np.zeros((t_count, max_in), np.uint32)
    out_slot = np.full((t_count, max_out), null, np.int32)
    out_shift = np.zeros((t_count, max_out), np.uint32)
    for t, (p, lp) in enumerate(zip(parts, lowereds)):
        in_slot[t, : lp.input_bits] = p.in_slot_per_bit
        in_shift[t, : lp.input_bits] = p.in_shift_per_bit
        in_valid[t, : lp.input_bits] = 1
        out_slot[t, : lp.output_bits] = p.out_slot_per_bit
        out_shift[t, : lp.output_bits] = p.out_shift_per_bit

    return MergedProgram(
        lowered=merged,
        element_program=np.concatenate(
            [
                np.full(lp.num_elements, t, np.int32)
                for t, lp in enumerate(lowereds)
            ]
        ),
        slot_windows=tuple(windows),
        element_ranges=tuple(ranges),
        in_slot=in_slot,
        in_shift=in_shift,
        in_valid=in_valid,
        out_slot=out_slot,
        out_shift=out_shift,
        in_bits=np.array([lp.input_bits for lp in lowereds], np.int32),
        out_bits=np.array([lp.output_bits for lp in lowereds], np.int32),
        packed_in_bit=packed_in_bit,
        packed_out_bit=packed_out_bit,
    )


def interleave_lowered(
    lowereds: Sequence[LoweredProgram], chip: ChipSpec
) -> MergedProgram:
    """Interleave lowered programs onto shared physical stages.

    Merged stage ``e`` carries element ``e`` of every tenant at once
    (``lowering.interleave_tables``), so the merged element count is the
    *deepest* tenant's — per-chunk executor work stops scaling with the
    tenant count.  Register windows stay disjoint exactly as in
    :func:`merge_lowered`, so per-tenant bit-exactness still holds by
    construction.  Purely structural — no budget checks (the scheduler's
    admission/mode logic owns those, including the shared-stage
    ``max_parallel_ops`` row budget).

    Canonical construction: parts are relocated and interleaved in
    fingerprint-sorted order, so the merged tables — and the merged
    fingerprint, which keys every executor device cache — are invariant to
    tenant insertion order.  The tenant-id-indexed routing tables produced
    alongside are permuted back to admission order (they are *not*
    order-invariant; ``executor._routing_key`` accounts for that).
    """
    if not lowereds:
        raise ValueError("interleave_lowered needs at least one program")
    t_count = len(lowereds)
    order = sorted(range(t_count), key=lambda t: lowereds[t].fingerprint())
    total_slots = sum(lp.num_slots for lp in lowereds)
    null = total_slots

    parts_canon: list[LoweredProgram] = []
    windows_canon: list[tuple[int, int]] = []
    offset = 0
    for t in order:
        lp = lowereds[t]
        parts_canon.append(lp.with_slot_window(offset, total_slots))
        windows_canon.append((offset, offset + lp.num_slots))
        offset += lp.num_slots

    it = interleave_tables(parts_canon)
    max_in = int(max(lp.input_bits for lp in lowereds))
    max_out = int(max(lp.output_bits for lp in lowereds))
    packed_plan, pk_in_canon, pk_out_canon = _merge_packed(
        [lowereds[t] for t in order], max_in, max_out
    )

    merged = LoweredProgram(
        source_fingerprint=(
            "interleave("
            + "+".join(p.fingerprint() for p in parts_canon)
            + ")"
        ),
        chip_name=chip.name,
        num_slots=total_slots,
        input_bits=max_in,
        output_bits=max_out,
        opcode=it.opcode,
        dst=it.dst,
        src0=it.src0,
        src1=it.src1,
        imm0=it.imm0,
        imm1=it.imm1,
        mask=it.mask,
        first_write=it.first_write,
        rows_per_element=it.rows_per_element,
        element_stages=it.element_stages,
        num_ops=it.num_ops,
        # As in merge_lowered: per-packet-bit parser tables are ill-defined
        # for a merged program; the routed tables below replace them.
        in_slot_per_bit=np.zeros(0, np.int32),
        in_shift_per_bit=np.zeros(0, np.uint32),
        out_slot_per_bit=np.zeros(0, np.int32),
        out_shift_per_bit=np.zeros(0, np.uint32),
        opcode_counts=it.opcode_counts,
        packed=packed_plan,
    )

    # Canonical part position -> admission-order tenant id, and back.
    order_arr = np.asarray(order, np.int32)
    pos = np.empty(t_count, np.int64)
    pos[order_arr] = np.arange(t_count)
    row_tenant = np.where(
        it.row_part >= 0, order_arr[it.row_part], np.int32(-1)
    ).astype(np.int32)

    in_slot = np.full((t_count, max_in), null, np.int32)
    in_shift = np.zeros((t_count, max_in), np.uint32)
    in_valid = np.zeros((t_count, max_in), np.uint32)
    out_slot = np.full((t_count, max_out), null, np.int32)
    out_shift = np.zeros((t_count, max_out), np.uint32)
    for tid in range(t_count):
        p = parts_canon[pos[tid]]
        lp = lowereds[tid]
        in_slot[tid, : lp.input_bits] = p.in_slot_per_bit
        in_shift[tid, : lp.input_bits] = p.in_shift_per_bit
        in_valid[tid, : lp.input_bits] = 1
        out_slot[tid, : lp.output_bits] = p.out_slot_per_bit
        out_shift[tid, : lp.output_bits] = p.out_shift_per_bit

    return MergedProgram(
        lowered=merged,
        # Shared stages have no single owning tenant: the program-id column
        # is -1 everywhere (routing happens per packet, not per element).
        element_program=np.full(merged.num_elements, -1, np.int32),
        slot_windows=tuple(
            windows_canon[pos[tid]] for tid in range(t_count)
        ),
        element_ranges=tuple(
            (0, lowereds[tid].num_elements) for tid in range(t_count)
        ),
        in_slot=in_slot,
        in_shift=in_shift,
        in_valid=in_valid,
        out_slot=out_slot,
        out_shift=out_shift,
        in_bits=np.array([lp.input_bits for lp in lowereds], np.int32),
        out_bits=np.array([lp.output_bits for lp in lowereds], np.int32),
        packed_in_bit=None if pk_in_canon is None else pk_in_canon[pos],
        packed_out_bit=None if pk_out_canon is None else pk_out_canon[pos],
        layout="interleave",
        row_tenant=row_tenant,
        row_src_elem=it.row_src_elem,
        row_src_row=it.row_src_row,
    )


# ---------------------------------------------------------------------------
# Run results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TenantRunStats:
    """One tenant's traffic accounting for a scheduler run."""

    tid: int
    name: str
    packets: int = 0        # arrived on the mixed stream
    served: int = 0         # executed (== packets - dropped)
    dropped: int = 0        # tail-dropped at queue admission (time-sliced)
    deferred: int = 0       # packet-turns spent waiting past a quantum
    slices: int = 0         # scheduling turns executed (time-sliced)
    seconds: float = 0.0    # device time attributed to this tenant
    outputs: np.ndarray | None = None  # (served, out_bits) int32 if collected

    @property
    def packets_per_second(self) -> float:
        return self.served / self.seconds if self.seconds > 0 else float("inf")


@dataclasses.dataclass
class SchedulerRunResult:
    """Outcome of pushing a mixed stream through the shared chip."""

    mode: str
    packets: int
    seconds: float
    chunks: int
    tenants: list[TenantRunStats]
    warmup_seconds: float = 0.0  # jit warm calls across programs (compile)
    merged_layout: str | None = None  # "interleave"/"concat" for merged runs

    @property
    def packets_per_second(self) -> float:
        served = sum(t.served for t in self.tenants)
        return served / self.seconds if self.seconds > 0 else float("inf")

    def stats_for(self, tid: int) -> TenantRunStats:
        for t in self.tenants:
            if t.tid == tid:
                return t
        raise KeyError(f"no tenant {tid} in this run")

    def outputs_for(self, tid: int) -> np.ndarray:
        out = self.stats_for(tid).outputs
        if out is None:
            raise ValueError("run was not collected; pass collect=True")
        return out


def _rechunk_mixed(
    chunks: Iterable[tuple[np.ndarray, np.ndarray]], chunk_size: int
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Re-slice a (tenant_ids, bits) chunk stream into exactly-``chunk_size``
    blocks (last may be short) — the mixed-stream twin of
    ``executor._rechunk``."""
    buf_t: list[np.ndarray] = []
    buf_b: list[np.ndarray] = []
    have = 0
    for tids, bits in chunks:
        tids, bits = np.asarray(tids), np.asarray(bits)
        if tids.shape[0] != bits.shape[0]:
            raise ValueError(
                f"tenant ids ({tids.shape[0]}) and packets ({bits.shape[0]}) "
                "disagree on chunk length"
            )
        while bits.shape[0]:
            take = min(chunk_size - have, bits.shape[0])
            buf_t.append(tids[:take])
            buf_b.append(bits[:take])
            have += take
            tids, bits = tids[take:], bits[take:]
            if have == chunk_size:
                yield np.concatenate(buf_t), np.concatenate(buf_b, axis=0)
                buf_t, buf_b, have = [], [], 0
    if have:
        yield np.concatenate(buf_t), np.concatenate(buf_b, axis=0)


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------

class SwitchScheduler:
    """Admit N compiled programs onto one chip and serve a mixed stream.

    ``mode="auto"`` merges while the combined footprint fits the chip and
    falls back to weighted-round-robin time-slicing when it does not;
    ``"merged"``/``"time_sliced"`` force one strategy (forced merge makes
    admission reject overflowing programs instead of falling back).
    ``merged`` picks the merged-table layout: ``"interleave"`` (default —
    tenants' elements share physical stages, work scales with the deepest
    tenant) or ``"concat"`` (stage-after-stage, work scales with the sum).
    """

    def __init__(
        self,
        chip: ChipSpec = RMT,
        *,
        mode: str = "auto",
        merged: str = "interleave",
        quantum: int = DEFAULT_QUANTUM,
        max_queue: int | None = None,
        clock=None,
    ):
        if mode not in SCHEDULER_MODES:
            raise ValueError(
                f"mode must be one of {SCHEDULER_MODES}, got {mode!r}"
            )
        if merged not in MERGED_LAYOUTS:
            raise ValueError(
                f"merged layout must be one of {MERGED_LAYOUTS}, "
                f"got {merged!r}"
            )
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.chip = chip
        self.mode = mode
        self.merged_layout = merged
        self.quantum = quantum
        self.max_queue = max_queue
        self.tenants: list[Tenant] = []
        self._merged: dict[str, MergedProgram] = {}
        self._last_run: SchedulerRunResult | None = None
        # SLO tracking (repro.obs.slo): per-tenant-name trackers fed from the
        # run paths with timestamps from ``clock`` (default perf_counter —
        # inject a deterministic clock to make burn rates reproducible).
        self._clock = clock or time.perf_counter
        self._slo_trackers: dict[str, SloTracker] = {}
        self._slo_last_now: float = 0.0

    # -- admission -----------------------------------------------------------

    def _merged_footprint(
        self,
        extra: PipelineProgram | None = None,
        layout: str | None = None,
    ):
        """(elements, PHV bits) one merged pass would occupy under
        ``layout``: interleaved stages host every tenant's element ``e`` at
        once, so elements is the *max* across tenants; concatenation stacks
        them, so it is the sum.  PHV windows are disjoint either way."""
        layout = layout or self.merged_layout
        progs = [t.program for t in self.tenants]
        if extra is not None:
            progs.append(extra)
        if not progs:
            return 0, 0
        elements = (
            max(p.num_elements for p in progs)
            if layout == "interleave"
            else sum(p.num_elements for p in progs)
        )
        return elements, sum(p.peak_phv_bits for p in progs)

    def _interleave_stage_rows(
        self, extra_lowered: LoweredProgram | None = None
    ) -> int:
        """Widest shared stage (summed op rows) an interleaved merge of the
        current tenants (plus ``extra_lowered``) would need — held against
        ``chip.max_parallel_ops``, the per-stage ALU budget."""
        lows = [t.lowered for t in self.tenants]
        if extra_lowered is not None:
            lows.append(extra_lowered)
        return peak_stage_rows(lows)

    def merge_feasible(
        self,
        extra: PipelineProgram | None = None,
        *,
        extra_lowered: LoweredProgram | None = None,
        layout: str | None = None,
    ) -> bool:
        """Would the current tenants (plus ``extra``) fit one merged pass
        under ``layout`` (default: the scheduler's configured layout)?

        Interleaved merges additionally hold the widest shared stage
        against ``chip.max_parallel_ops``; pass ``extra_lowered`` to reuse
        an already-lowered ``extra`` (it is lowered here otherwise).
        """
        layout = layout or self.merged_layout
        elements, phv = self._merged_footprint(extra, layout=layout)
        if elements > self.chip.num_elements or phv > self.chip.phv_bits:
            return False
        if layout == "interleave":
            if extra is not None and extra_lowered is None:
                extra_lowered = lower_program(extra, compact=True)
            return (
                self._interleave_stage_rows(extra_lowered)
                <= self.chip.max_parallel_ops
            )
        return True

    def admit(
        self,
        prog: PipelineProgram,
        *,
        name: str | None = None,
        weight: float = 1.0,
    ) -> Tenant:
        """Admit one compiled program, or raise :class:`AdmissionError`.

        Every program must fit the chip on its own (one pipeline pass, PHV
        within budget) — a program that cannot run alone cannot run in any
        shared mode.  Forced ``merged`` mode additionally requires the merged
        footprint to stay within the chip; ``auto`` falls back to
        time-slicing instead.
        """
        if weight <= 0:
            raise ValueError(f"weight must be positive, got {weight}")
        if prog.num_elements > self.chip.num_elements:
            raise AdmissionError(
                f"program needs {prog.num_elements} elements, chip "
                f"{self.chip.name!r} has {self.chip.num_elements} "
                "(recirculation is a single-program fabric concern, not a "
                "shared-chip one)"
            )
        if prog.peak_phv_bits > self.chip.phv_bits:
            raise AdmissionError(
                f"program peak PHV {prog.peak_phv_bits}b exceeds chip "
                f"{self.chip.name!r} PHV {self.chip.phv_bits}b"
            )
        lowered = lower_program(prog, compact=True)
        if self.mode == "merged" and not self.merge_feasible(
            prog, extra_lowered=lowered
        ):
            elements, phv = self._merged_footprint(prog)
            if (
                elements <= self.chip.num_elements
                and phv <= self.chip.phv_bits
            ):
                # Element and PHV budgets hold, so the interleave-specific
                # shared-stage row budget is what failed.
                rows = self._interleave_stage_rows(lowered)
                raise AdmissionError(
                    f"interleaved merge would need {rows} parallel ops in "
                    f"its widest shared stage against chip "
                    f"{self.chip.name!r} max_parallel_ops "
                    f"{self.chip.max_parallel_ops}; use mode='auto' to fall "
                    "back to time-slicing"
                )
            raise AdmissionError(
                f"merged footprint would be {elements} elements / {phv}b PHV "
                f"against a {self.chip.num_elements}-element / "
                f"{self.chip.phv_bits}b chip; use mode='auto' to fall back "
                "to time-slicing"
            )
        tenant = Tenant(
            tid=len(self.tenants),
            name=name or f"tenant{len(self.tenants)}",
            program=prog,
            lowered=lowered,
            weight=float(weight),
        )
        self.tenants.append(tenant)
        self._merged.clear()  # table layouts changed
        return tenant

    def set_slo(self, spec: SloSpec) -> SloTracker:
        """Attach (or replace) an SLO for the tenant named ``spec.tenant``.

        May be called before or after the tenant is admitted; the tracker
        starts collecting from the next run.  Burn rates and breach events
        surface through :meth:`telemetry` (``TenantTelemetry.slo`` /
        ``.breach_events``).
        """
        tracker = SloTracker(spec)
        self._slo_trackers[spec.tenant] = tracker
        return tracker

    def slo_tracker(self, tenant_name: str) -> SloTracker | None:
        """The live tracker for one tenant name (``None`` if no SLO set)."""
        return self._slo_trackers.get(tenant_name)

    def _slo_update_all(self) -> None:
        """End-of-run SLO evaluation: one deterministic update per tracker
        at a shared timestamp (breach events fire on ok -> breach here)."""
        if not self._slo_trackers:
            return
        now = self._clock()
        self._slo_last_now = now
        for tracker in self._slo_trackers.values():
            tracker.update(now)

    # -- mode / merged table -------------------------------------------------

    def resolve_mode(self) -> str:
        """The mode a run will actually use ("merged" or "time_sliced")."""
        if self.mode == "auto":
            return "merged" if self.merge_feasible() else "time_sliced"
        return self.mode

    def merged(self, layout: str | None = None) -> MergedProgram:
        """The fused table for the current tenant set (cached per layout)."""
        layout = layout or self.merged_layout
        if layout not in MERGED_LAYOUTS:
            raise ValueError(
                f"merged layout must be one of {MERGED_LAYOUTS}, "
                f"got {layout!r}"
            )
        if not self.tenants:
            raise ValueError("no tenants admitted")
        if self.mode != "merged" and not self.merge_feasible(layout=layout):
            raise ValueError(
                "merged footprint exceeds the chip; run() would time-slice"
            )
        mp = self._merged.get(layout)
        if mp is None:
            build = (
                interleave_lowered if layout == "interleave" else merge_lowered
            )
            mp = build([t.lowered for t in self.tenants], self.chip)
            self._merged[layout] = mp
        return mp

    def _quanta(self) -> list[int]:
        """Per-tenant packets per scheduling turn: the heaviest tenant gets
        the full quantum, the rest proportionally fewer (weighted RR)."""
        top = max(t.weight for t in self.tenants)
        return [
            max(1, int(round(self.quantum * t.weight / top)))
            for t in self.tenants
        ]

    # -- execution -----------------------------------------------------------

    def run(
        self,
        stream,
        *,
        mode: str | None = None,
        merged: str | None = None,
        backend: str = "auto",
        chunk_size: int | None = None,
        collect: bool = True,
        interpret: bool | None = None,
        plan=None,
    ) -> SchedulerRunResult:
        """Serve a mixed stream: an iterable of ``(tenant_ids, bits)`` chunks
        (e.g. ``traffic.mixed_tenant_stream``) or one such pair.

        Per-tenant outputs (``collect=True``) are bit-exact with each
        tenant's single-program ``executor.execute`` over its served packets.
        A ``plan`` (:class:`repro.dataplane.plan.ExecutionPlan`) overrides
        ``backend``/``chunk_size``/``interpret``/``merged``; ``collect`` and
        ``mode`` stay scheduler-level knobs.  ``merged`` overrides the
        scheduler's merged-table layout for this run only.
        """
        if plan is not None:
            backend = plan.backend_str
            if plan.chunk_size is not None:
                chunk_size = plan.chunk_size
            if plan.interpret is not None:
                interpret = plan.interpret
            if getattr(plan, "merged", None) is not None:
                merged = plan.merged
        if not self.tenants:
            raise ValueError("no tenants admitted")
        layout = merged or self.merged_layout
        if layout not in MERGED_LAYOUTS:
            raise ValueError(
                f"merged layout must be one of {MERGED_LAYOUTS}, "
                f"got {layout!r}"
            )
        mode = mode or self.resolve_mode()
        if mode not in ("merged", "time_sliced"):
            raise ValueError(
                f"run mode must be 'merged' or 'time_sliced', got {mode!r}"
            )
        if mode == "merged" and not self.merge_feasible(layout=layout):
            raise ValueError(
                "merged footprint exceeds the chip; use mode='time_sliced'"
            )
        backend = _executor.resolve_backend(backend)
        if isinstance(stream, tuple) and len(stream) == 2:
            stream = [stream]
        chunk = chunk_size or _executor.DEFAULT_CHUNK

        stats = [TenantRunStats(t.tid, t.name) for t in self.tenants]
        if mode == "merged":
            result = self._run_merged(
                stream, stats, backend, chunk, collect, interpret, layout
            )
        else:
            result = self._run_time_sliced(
                stream, stats, backend, collect, interpret
            )
        self._last_run = result
        self._slo_update_all()
        return result

    def _check_chunk(self, tids: np.ndarray, bits: np.ndarray, width: int):
        if bits.ndim != 2 or bits.shape[1] != width:
            raise ValueError(
                f"expected (batch, {width}) mixed packet bits, got {bits.shape}"
            )
        if tids.size and (tids.min() < 0 or tids.max() >= len(self.tenants)):
            raise ValueError(
                f"tenant ids out of range [0, {len(self.tenants)})"
            )

    def _run_merged(
        self, stream, stats, backend, chunk, collect, interpret, layout
    ) -> SchedulerRunResult:
        mp = self.merged(layout)
        lp = mp.lowered
        width = mp.in_slot.shape[1]
        collected: list[list[np.ndarray]] = [[] for _ in self.tenants]

        # One fused executable per chunk: routed parse -> run -> routed
        # deparse compiled together (executor.routed_fn / routed_packed_fn),
        # so the register file never leaves the device between phases.
        if backend == "packed":
            if lp.packed is None or mp.packed_in_bit is None:
                raise ValueError(
                    "packed backend needs every tenant to carry a packed "
                    "plan (compiler-built programs do); use an op-table "
                    "backend"
                )
            fn = None
            if layout == "interleave":
                # Widest-tenant dispatch: stack per-tenant packed layers and
                # gather each packet's weight block by tenant id, so chunk
                # work scales with the widest/deepest tenant instead of the
                # block-diagonal sum.  Declines (returns None) for
                # hand-assembled layouts; fall back to the merged plan.
                fn = _executor.routed_packed_stacked_fn(
                    tuple(t.lowered for t in self.tenants)
                )
            if fn is None:
                fn = _executor.routed_packed_fn(
                    lp, mp.packed_in_bit, mp.packed_out_bit, mp.in_valid
                )
        else:
            fn = _executor.routed_fn(
                lp,
                mp.in_slot, mp.in_shift, mp.in_valid,
                mp.out_slot, mp.out_shift,
                backend=backend, interpret=interpret,
            )

        def push(tids_dev, bits_dev):
            return fn(bits_dev, tids_dev)

        seconds = 0.0
        warmup = 0.0
        n_chunks = 0
        with obs.span(
            "stream:mt_merged", cat="stream",
            tenants=len(self.tenants), backend=backend, layout=layout,
        ):
            for tids, bits in _rechunk_mixed(stream, chunk):
                self._check_chunk(tids, bits, width)
                n = bits.shape[0]
                pad = chunk - n
                if pad:  # stable shapes: one compiled executable for the run
                    bits = np.pad(bits, ((0, pad), (0, 0)))
                    tids = np.pad(tids, (0, pad))
                bits_dev, tids_dev = jnp.asarray(bits), jnp.asarray(tids)
                if n_chunks == 0:  # warm the compile cache outside the clock
                    with obs.span(
                        "compile:mt_merged", cat="compile", backend=backend
                    ):
                        w0 = time.perf_counter()
                        push(tids_dev, bits_dev).block_until_ready()
                        warmup = time.perf_counter() - w0
                with obs.span("execute:mt_chunk", cat="execute", packets=n):
                    t0 = time.perf_counter()
                    res = np.asarray(push(tids_dev, bits_dev))
                    dt = time.perf_counter() - t0
                seconds += dt
                res, tids = res[:n], tids[:n]
                slo_now = (
                    self._clock() if self._slo_trackers else 0.0
                )
                for t, st in enumerate(stats):
                    rows = np.nonzero(tids == t)[0]
                    if not rows.size:
                        continue
                    st.packets += int(rows.size)
                    st.served += int(rows.size)
                    tracker = self._slo_trackers.get(self.tenants[t].name)
                    if tracker is not None:
                        tracker.observe_packets(slo_now, int(rows.size))
                        tracker.observe_queue_delay(
                            slo_now, dt, int(rows.size)
                        )
                    # Attribute this chunk's latency by the tenant's actual
                    # packet share of THIS chunk — bursty streams put a
                    # tenant in some chunks and not others, so assuming a
                    # run-uniform mix (the old ``st.seconds = seconds``)
                    # over/under-charged tenants whose packets cluster in
                    # fast or slow chunks.
                    st.seconds += dt * (rows.size / n)
                    if collect:
                        collected[t].append(res[rows, : mp.out_bits[t]])
                    if obs.enabled():
                        m = obs.registry()
                        name = self.tenants[t].name
                        m.counter("mt.packets_total", tenant=name).inc(
                            int(rows.size)
                        )
                        m.counter("mt.served_total", tenant=name).inc(
                            int(rows.size)
                        )
                        # One fused dispatch serves the whole chunk: every
                        # packet in it waits exactly the dispatch latency.
                        m.histogram(
                            "mt.queue_delay_seconds", tenant=name
                        ).observe(dt, count=int(rows.size))
                n_chunks += 1

        for t, st in enumerate(stats):
            if collect:
                st.outputs = (
                    np.concatenate(collected[t])
                    if collected[t]
                    else np.zeros((0, int(mp.out_bits[t])), np.int32)
                )
        return SchedulerRunResult(
            mode="merged",
            packets=sum(st.packets for st in stats),
            seconds=seconds,
            chunks=n_chunks,
            tenants=stats,
            warmup_seconds=warmup,
            merged_layout=layout,
        )

    def _run_time_sliced(
        self, stream, stats, backend, collect, interpret
    ) -> SchedulerRunResult:
        quanta = self._quanta()
        width = max(int(t.lowered.input_bits) for t in self.tenants)
        queues: list[list[np.ndarray]] = [[] for _ in self.tenants]
        queued = [0] * len(self.tenants)
        collected: list[list[np.ndarray]] = [[] for _ in self.tenants]
        warmed = [False] * len(self.tenants)
        seconds_total = 0.0
        warmup_total = 0.0
        n_chunks = 0
        observing = obs.enabled()
        # Per-packet enqueue timestamps (same chunking as ``queues``), kept
        # while observing or while any tenant carries an SLO: serve time
        # minus arrival time is the real queueing delay each packet
        # experienced in the simulator — the per-tenant p99 both the
        # ``mt.queue_delay_seconds`` histograms and the SLO burn rates key
        # on.  Timestamps come from the scheduler clock so an injected
        # deterministic clock reproduces them.
        track = observing or bool(self._slo_trackers)
        arrivals: list[list[np.ndarray]] = [[] for _ in self.tenants]

        def serve_turn(t: int) -> None:
            """One weighted-RR turn: run up to ``quanta[t]`` queued packets
            through tenant t's own program."""
            nonlocal warmup_total
            st = stats[t]
            take = min(queued[t], quanta[t])
            if take == 0:
                return
            deferred_now = queued[t] - take  # backlog waits >= 1 more turn
            st.deferred += deferred_now
            batch = np.concatenate(queues[t])[:queued[t]]
            head, tail = batch[:take], batch[take:]
            queues[t] = [tail] if tail.size else []
            if track:
                times = np.concatenate(arrivals[t])[:queued[t]]
                head_times, tail_times = times[:take], times[take:]
                arrivals[t] = [tail_times] if tail_times.size else []
            queued[t] -= take
            pad = quanta[t] - take  # fixed turn shape: one compile per tenant
            block = np.pad(head, ((0, pad), (0, 0))) if pad else head
            dev = jnp.asarray(block)
            tenant = self.tenants[t]
            lp = tenant.lowered
            if not warmed[t]:
                with obs.span(
                    "compile:mt_tenant", cat="compile",
                    tenant=tenant.name, backend=backend,
                ):
                    w0 = time.perf_counter()
                    np.asarray(
                        _executor._run_chunk(lp, dev, backend, interpret)
                    )
                    warmup_total += time.perf_counter() - w0
                warmed[t] = True
            with obs.span(
                "execute:mt_turn", cat="execute",
                tenant=tenant.name, packets=take,
            ):
                t0 = time.perf_counter()
                res = np.asarray(
                    _executor._run_chunk(lp, dev, backend, interpret)
                )
                t1 = time.perf_counter()
            st.seconds += t1 - t0
            st.served += take
            st.slices += 1
            if collect:
                collected[t].append(res[:take])
            if track:
                slo_t = self._clock()
                delays = np.maximum(slo_t - head_times, 0.0)
                if observing:
                    m = obs.registry()
                    m.counter("mt.served_total", tenant=tenant.name).inc(take)
                    m.counter("mt.slices_total", tenant=tenant.name).inc()
                    if deferred_now:
                        m.counter(
                            "mt.deferred_total", tenant=tenant.name
                        ).inc(deferred_now)
                    m.histogram(
                        "mt.queue_delay_seconds", tenant=tenant.name
                    ).observe_array(delays)
                tracker = self._slo_trackers.get(tenant.name)
                if tracker is not None:
                    tracker.observe_packets(slo_t, take)
                    # Arrival chunks share timestamps, so the delay array
                    # collapses to a few distinct values — feed those as
                    # weighted observations instead of a per-packet loop.
                    vals, cnts = np.unique(delays, return_counts=True)
                    for v, c in zip(vals.tolist(), cnts.tolist()):
                        tracker.observe_queue_delay(slo_t, float(v), int(c))

        with obs.span(
            "stream:mt_time_sliced", cat="stream",
            tenants=len(self.tenants), backend=backend,
        ):
            for tids, bits in stream:
                tids, bits = np.asarray(tids), np.asarray(bits)
                self._check_chunk(
                    tids, bits, bits.shape[1] if bits.ndim == 2 else -1
                )
                if bits.shape[1] < width:
                    raise ValueError(
                        f"mixed packets are {bits.shape[1]}b wide; widest "
                        f"tenant needs {width}b"
                    )
                n_chunks += 1
                now = self._clock() if track else 0.0
                for t, tenant in enumerate(self.tenants):
                    rows = np.nonzero(tids == t)[0]
                    if not rows.size:
                        continue
                    st = stats[t]
                    st.packets += int(rows.size)
                    arrived = bits[rows, : int(tenant.lowered.input_bits)]
                    dropped_now = 0
                    if self.max_queue is not None:
                        space = self.max_queue - queued[t]
                        if arrived.shape[0] > space:  # tail drop at admission
                            dropped_now = int(arrived.shape[0] - space)
                            st.dropped += dropped_now
                            arrived = arrived[:space]
                    if arrived.shape[0]:
                        queues[t].append(arrived)
                        queued[t] += int(arrived.shape[0])
                        if track:
                            arrivals[t].append(
                                np.full(arrived.shape[0], now, np.float64)
                            )
                    if observing:
                        m = obs.registry()
                        m.counter(
                            "mt.packets_total", tenant=tenant.name
                        ).inc(int(rows.size))
                        if dropped_now:
                            m.counter(
                                "mt.dropped_total", tenant=tenant.name
                            ).inc(dropped_now)
                # The chip alternates tenants while anyone has a full
                # quantum waiting; sub-quantum remainders wait for more
                # arrivals (they are served — quantum-padded — only in the
                # end-of-stream drain).
                while any(q >= quanta[t] for t, q in enumerate(queued)):
                    for t in range(len(self.tenants)):
                        if queued[t] >= quanta[t]:
                            serve_turn(t)

            while any(queued):  # end of stream: drain every backlog
                for t in range(len(self.tenants)):
                    serve_turn(t)

        for t, st in enumerate(stats):
            seconds_total += st.seconds
            if collect:
                st.outputs = (
                    np.concatenate(collected[t])
                    if collected[t]
                    else np.zeros(
                        (0, int(self.tenants[t].lowered.output_bits)), np.int32
                    )
                )
        return SchedulerRunResult(
            mode="time_sliced",
            packets=sum(st.packets for st in stats),
            seconds=seconds_total,
            chunks=n_chunks,
            tenants=stats,
            warmup_seconds=warmup_total,
        )

    # -- accounting ----------------------------------------------------------

    def analytic_pps(self, mode: str | None = None) -> list[float]:
        """Chip-model packets/s available to each tenant under ``mode``.

        Merged: one pass serves the mixed stream, so every tenant sees the
        full line rate (its *offered* load is governed by arrival shares).
        Time-sliced: the chip is a shared server — each tenant gets its
        weighted share of the line rate.
        """
        mode = mode or self.resolve_mode()
        if mode == "merged":
            return [self.chip.packets_per_second] * len(self.tenants)
        total = sum(t.weight for t in self.tenants)
        return [
            self.chip.packets_per_second * t.weight / total
            for t in self.tenants
        ]

    def telemetry(
        self, run: SchedulerRunResult | None = None
    ) -> _telemetry.MultiTenantTelemetry:
        """Per-tenant rollup (static footprints + the latest run's traffic)."""
        if not self.tenants:
            raise ValueError("no tenants admitted")
        run = run or self._last_run
        mode = run.mode if run is not None else self.resolve_mode()
        pps = self.analytic_pps(mode)
        merged_ok = self.merge_feasible()
        mp = self.merged() if (mode == "merged" and merged_ok) else None

        # Tenants admitted after the recorded run have no stats in it: report
        # them with zeroed traffic counters instead of failing the lookup.
        by_tid = {s.tid: s for s in run.tenants} if run is not None else {}
        rows = []
        for i, tenant in enumerate(self.tenants):
            stages = _telemetry.stage_telemetry(tenant.program, self.chip)
            st = by_tid.get(tenant.tid)
            if mp is not None:
                window = mp.slot_windows[i]
                el_range = mp.element_ranges[i]
            else:
                window = (0, tenant.lowered.num_slots)
                el_range = None
            tracker = self._slo_trackers.get(tenant.name)
            rows.append(
                _telemetry.TenantTelemetry(
                    tid=tenant.tid,
                    name=tenant.name,
                    elements=tenant.program.num_elements,
                    slot_window=window,
                    element_range=el_range,
                    weight=tenant.weight,
                    analytic_pps=pps[i],
                    peak_occupancy_bits=max(
                        s.occupancy_bits for s in stages
                    ),
                    peak_alu_utilization=max(
                        s.alu_utilization for s in stages
                    ),
                    packets=st.packets if st else 0,
                    served=st.served if st else 0,
                    dropped=st.dropped if st else 0,
                    deferred=st.deferred if st else 0,
                    slices=st.slices if st else 0,
                    measured_pps=st.packets_per_second if st else None,
                    slo=(
                        tracker.status(self._slo_last_now)
                        if tracker is not None else None
                    ),
                    breach_events=(
                        tuple(tracker.events)
                        if tracker is not None else ()
                    ),
                )
            )
        elements, phv = self._merged_footprint()
        return _telemetry.MultiTenantTelemetry(
            mode=mode,
            chip_name=self.chip.name,
            elements_used=elements,
            elements_available=self.chip.num_elements,
            phv_bits_used=phv,
            phv_bits_available=self.chip.phv_bits,
            tenants=tuple(rows),
            measured_pps=run.packets_per_second if run is not None else None,
        )
