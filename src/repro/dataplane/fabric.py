"""Multi-switch fabric: run programs that exceed one chip's element budget.

A compiled program with more elements than ``ChipSpec.num_elements`` cannot
execute in one pipeline pass.  The paper's answer is recirculation (the
packet re-enters the same switch, halving throughput per extra pass); the
scale-out answer from the follow-on literature is a *chain* of switches, each
executing a contiguous slice of the program at full line rate, with the PHV
carried hop to hop in the packet itself.  This module simulates both:

* ``mode="recirculate"`` — one switch, ``ceil(E / num_elements)`` passes;
  analytic throughput divides by the pass count.
* ``mode="multi_hop"``   — one switch per slice; every switch forwards at
  line rate, so throughput stays at the chip rate and only latency grows.

Both modes execute identically bit-for-bit (the register file is the wire
format between hops); they differ in the telemetry/throughput accounting —
which is exactly the trade the paper's §2 discussion is about.

Hop chains execute **scanned by default**: the hop slices are padded and
stacked (``lowering.stack_hops``) and the whole chain runs as one
``lax.scan`` over the hop axis — the hop body compiles once however long
the chain is, and per-chunk orchestration drops from ``num_hops`` Python
dispatches to one.  The unrolled per-hop loop remains behind
``scan_hops=False`` (and as the automatic fallback when hop shapes
genuinely differ and refuse to stack); the two paths are bit-exact by
shared construction and fuzz-proven in ``tests/test_fleet.py``.  The
packed backend — previously unreachable from the fabric — runs as the
scan-over-layers plan on whole packets.  In scanned runs the per-hop
wall-clock split is *attributed*: measured chunk time is divided across
hops proportionally to their element counts (one dispatch cannot be
timed per hop), keeping the ``hop_seconds``/telemetry shape contract.

Invariants:

* **Bit-exactness** — ``SwitchFabric.run`` equals single-switch
  ``executor.execute``, the interpreter, and the oracle for every
  partitioning: hop boundaries can never change results, only accounting.
* **Exact tiling** — hop element ranges are contiguous, disjoint, and cover
  ``[0, num_elements)``; each hop executes at most ``chip.num_elements``
  elements.
* **Shared slot space** — every hop runs over the same compacted register
  file (the PHV); parser/deparser tables are inherited whole from the
  unsliced program.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.pipeline import ChipSpec, PipelineProgram
from repro.core.throughput import report_for_program
from repro.dataplane import executor as _executor
from repro.dataplane import telemetry as _telemetry
from repro.dataplane.lowering import (
    LoweredProgram,
    StackedHops,
    lower_program,
    stack_hops,
)
from repro.dataplane.plan import ExecutionPlan

MODES = ("recirculate", "multi_hop")


@dataclasses.dataclass(frozen=True)
class SwitchHop:
    """One simulated switch (or one recirculation pass) in the chain."""

    index: int
    element_range: tuple[int, int]
    lowered: LoweredProgram      # table slice for this hop's elements


@dataclasses.dataclass
class FabricRunResult:
    outputs: np.ndarray          # (n, output_bits) int32
    packets: int
    seconds: float
    hop_seconds: list[float]     # attributed by element count when scanned
    warmup_seconds: float = 0.0  # whole-chain warm call (incl. jit compile)
    scanned: bool = False        # hops ran as one lax.scan dispatch

    @property
    def packets_per_second(self) -> float:
        return self.packets / self.seconds if self.seconds > 0 else float("inf")


class SwitchFabric:
    """A chain of simulated switches jointly executing one program."""

    def __init__(
        self,
        prog: PipelineProgram,
        hops: Sequence[SwitchHop],
        lowered: LoweredProgram,
        mode: str,
        chip: ChipSpec,
    ):
        self.program = prog
        self.hops = list(hops)
        self.lowered = lowered
        self.mode = mode
        self.chip = chip
        self._last_run: FabricRunResult | None = None
        self._analytic_memo = None
        self._stacked_memo: StackedHops | None | str = "unset"

    # -- construction -------------------------------------------------------

    @classmethod
    def partition(
        cls,
        prog: PipelineProgram,
        *,
        mode: str = "multi_hop",
        chip: ChipSpec | None = None,
        compact: bool = True,
    ) -> "SwitchFabric":
        """Slice ``prog`` into per-switch element ranges of at most
        ``chip.num_elements`` each.  ``chip`` defaults to the program's own
        target (pass a smaller one to force partitioning in tests)."""
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        chip = chip or prog.chip
        lowered = lower_program(prog, compact=compact)
        per_hop = chip.num_elements
        if per_hop < 1:
            raise ValueError("chip must have at least one element")
        hops = []
        for i, start in enumerate(range(0, lowered.num_elements, per_hop)):
            stop = min(start + per_hop, lowered.num_elements)
            hops.append(
                SwitchHop(
                    index=i,
                    element_range=(start, stop),
                    lowered=lowered.slice_elements(start, stop),
                )
            )
        return cls(prog, hops, lowered, mode, chip)

    @property
    def num_hops(self) -> int:
        return len(self.hops)

    def stacked_hops(self) -> StackedHops | None:
        """The chain's hop slices padded + stacked for ``lax.scan``, memoized
        (hops are fixed at partition time).  ``None`` when hop shapes
        genuinely differ and refuse to stack — the scanned path then falls
        back to unrolled dispatch."""
        if isinstance(self._stacked_memo, str):
            self._stacked_memo = stack_hops([h.lowered for h in self.hops])
        return self._stacked_memo

    # -- execution ----------------------------------------------------------

    def run(
        self,
        packets,
        *,
        backend: str = "auto",
        chunk_size: int | None = None,
        interpret: bool | None = None,
        scan_hops: bool | None = None,
        plan: ExecutionPlan | None = None,
    ) -> FabricRunResult:
        """Push packets through every hop; bit-exact with single-switch
        :func:`dataplane.executor.execute` (and the interpreter/oracle).

        ``plan`` (an :class:`~repro.dataplane.plan.ExecutionPlan`) overrides
        the individual keywords — the legacy keyword surface remains as a
        shim.  ``scan_hops``: True/None run the chain as one ``lax.scan``
        over stacked hop tables (None falls back to unrolled only when the
        hops refuse to stack); False forces the unrolled per-hop loop.  The
        packed backend always runs scanned (it has no per-hop form).
        """
        if plan is not None:
            backend = plan.backend_str
            if plan.chunk_size is not None:
                chunk_size = plan.chunk_size
            if plan.interpret is not None:
                interpret = plan.interpret
            if plan.scan_hops is not None:
                scan_hops = plan.scan_hops
        backend = _executor.resolve_backend(backend)
        packets = np.asarray(packets)
        if packets.ndim != 2 or packets.shape[1] != self.lowered.input_bits:
            raise ValueError(
                f"expected (batch, {self.lowered.input_bits}) packet bits, "
                f"got {packets.shape}"
            )
        chunk = chunk_size or _executor.DEFAULT_CHUNK
        n = packets.shape[0]
        out = np.empty((n, self.lowered.output_bits), np.int32)
        hop_seconds = [0.0] * self.num_hops
        total = 0.0
        lp = self.lowered
        in_slot, in_shift, out_slot, out_shift = _executor._device_tables(lp).io

        stacked = None
        if backend == "packed":
            if scan_hops is False:
                raise ValueError(
                    "the packed backend has no per-hop register-file form; "
                    "the fabric runs it as one scan over stacked layers "
                    "(scan_hops=True or None)"
                )
            scanned = True
        else:
            if scan_hops is not False:
                stacked = self.stacked_hops()
            scanned = stacked is not None

        # Wall-clock attribution for the scanned path: one dispatch cannot
        # be timed per hop, so chunk time is split proportionally to each
        # hop's element count (exact for the unrolled path by measurement).
        elems = np.array(
            [stop - start for (start, stop) in
             (h.element_range for h in self.hops)],
            np.float64,
        )
        hop_weights = elems / elems.sum()

        if scanned and backend == "packed":
            packed_run = _executor._packed_scan_fn(lp)

            def push(block: jax.Array, warm: bool = False) -> jax.Array:
                return packed_run(block)

        elif scanned:

            def push(block: jax.Array, warm: bool = False) -> jax.Array:
                regs = _executor.parse_packets(
                    block, in_slot, in_shift, num_regs=lp.num_regs
                )
                # One lax.scan carries the PHV through every hop's tables.
                regs = _executor.run_hops_scanned(
                    stacked, regs, backend=backend, interpret=interpret
                )
                return _executor.deparse_regs(regs, out_slot, out_shift)

        else:

            def push(block: jax.Array, warm: bool = False) -> jax.Array:
                regs = _executor.parse_packets(
                    block, in_slot, in_shift, num_regs=lp.num_regs
                )
                for hop in self.hops:
                    with obs.span(
                        "compile:hop" if warm else "execute:hop",
                        cat="compile" if warm else "execute",
                        hop=hop.index, mode=self.mode,
                    ):
                        h0 = time.perf_counter()
                        # The register file leaving this hop is the PHV on
                        # the wire.
                        regs = _executor.run_hop(
                            hop.lowered, regs,
                            backend=backend, interpret=interpret,
                        )
                        regs.block_until_ready()
                        h_dt = time.perf_counter() - h0
                    hop_seconds[hop.index] += h_dt
                    if obs.enabled() and not warm:
                        obs.registry().histogram(
                            "fabric.hop_seconds", hop=str(hop.index)
                        ).observe(h_dt)
                return _executor.deparse_regs(regs, out_slot, out_shift)

        with obs.span(
            "stream:fabric_run", cat="stream",
            mode=self.mode, hops=self.num_hops, packets=n, backend=backend,
            scanned=scanned,
        ):
            # Warm every hop's compiled executable outside the clock (each
            # hop slice has its own table shapes), so measured pkt/s reflects
            # the steady state — matching execute_stream's timing discipline.
            with obs.span(
                "compile:fabric_chain", cat="compile",
                hops=self.num_hops, backend=backend,
            ):
                w0 = time.perf_counter()
                push(
                    jnp.zeros((min(chunk, n), lp.input_bits), jnp.int32),
                    warm=True,
                ).block_until_ready()
                warmup = time.perf_counter() - w0
            hop_seconds = [0.0] * self.num_hops

            for start in range(0, n, chunk):
                block = packets[start : start + chunk]
                valid = block.shape[0]
                pad = chunk - valid if n > chunk else 0
                if pad:
                    block = np.pad(block, ((0, pad), (0, 0)))
                # H2D outside the clock, as execute_stream
                dev = jnp.asarray(block)
                with obs.span(
                    "execute:fabric_chunk", cat="execute", packets=valid
                ):
                    t0 = time.perf_counter()
                    res = np.asarray(push(dev))
                    dt = time.perf_counter() - t0
                total += dt
                out[start : start + valid] = res[:valid]
                if scanned:
                    for i, w in enumerate(hop_weights):
                        hop_seconds[i] += dt * w
                if obs.enabled():
                    m = obs.registry()
                    m.counter("fabric.packets_total").inc(valid)
                    m.histogram("fabric.chunk_seconds").observe(dt)
                    if scanned:
                        for i, w in enumerate(hop_weights):
                            m.histogram(
                                "fabric.hop_seconds", hop=str(i)
                            ).observe(dt * w)

        result = FabricRunResult(
            outputs=out,
            packets=n,
            seconds=total,
            hop_seconds=hop_seconds,
            warmup_seconds=warmup,
            scanned=scanned,
        )
        self._last_run = result
        return result

    # -- accounting ---------------------------------------------------------

    def analytic_report(self):
        """Chip-rate model under this fabric's mode.

        ``multi_hop`` pipelines hops, so the fabric forwards at the full chip
        rate regardless of depth; ``recirculate`` divides by the pass count
        (``num_hops`` passes — i.e. ``num_hops - 1`` recirculations — against
        this fabric's chip, not the program's compile-time target).

        Memoized per fabric: hops and chip are fixed at partition time, and
        the telemetry path calls this on every ``run`` — recomputing the
        report (an O(program) walk) per call was pure waste.
        """
        if self._analytic_memo is not None:
            return self._analytic_memo
        rep = report_for_program(self.program)
        if self.mode == "multi_hop":
            passes = 1
        else:
            passes = self.num_hops
        pps = self.chip.packets_per_second / passes
        self._analytic_memo = dataclasses.replace(
            rep,
            passes=passes,
            packets_per_second=pps,
            networks_per_second=pps,
            neurons_per_second=pps * sum(lp.n_out for lp in self.program.layer_plans),
            elements_available=self.chip.num_elements,
        )
        return self._analytic_memo

    def telemetry(
        self, run: FabricRunResult | None = None
    ) -> _telemetry.FabricTelemetry:
        run = run or self._last_run
        hop_pps = None
        measured = None
        if run is not None:
            hop_pps = [
                run.packets / s if s > 0 else float("inf")
                for s in run.hop_seconds
            ]
            measured = run.packets_per_second
        tel = _telemetry.fabric_telemetry(
            self.program,
            self.mode,
            [h.element_range for h in self.hops],
            hop_pps=hop_pps,
            measured_pps=measured,
            chip=self.chip,  # judge budgets against the fabric's switches
        )
        return dataclasses.replace(tel, analytic=self.analytic_report())
