"""Declarative fleet construction: one spec -> programs, chip, scheduler.

Every demo and benchmark used to hand-wire the same stack — init BNN params
per tenant, compile, sum element/PHV budgets into a shared ``ChipSpec``,
build a ``SwitchScheduler``, admit tenants in order, zip up
``TenantTrafficSpec``s for the stream generator.  That is construction
*policy* duplicated at every call site (and drift-prone: forget the ``+ 1``
headroom element and admission fails).  This module makes the whole stack a
value:

    fleet = build_fleet(FleetSpec(tenants=(
        TenantSpec("ddos", scenario="ddos_burst", shape=(32, 64, 32),
                   weight=2.0),
        TenantSpec("iot", scenario="iot_telemetry", shape=(16, 32, 8)),
    )))
    sched = fleet.scheduler(mode="merged")
    res = sched.run(fleet.stream(60_000, chunk_size=4096, seed=7),
                    chunk_size=4096)

``build_fleet`` also accepts the equivalent nested dict (config-file form).
A :class:`TenantSpec` either names a BNN ``shape`` to init+compile (seeded,
deterministic) or carries a pre-compiled ``program`` (e.g. a trained export
— the pcap replay example passes one).  The built :class:`Fleet` hands out
*fresh* schedulers (state like admission and telemetry is per run-mode) and
per-tenant fabrics, while programs/chip/traffic specs are built once.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import bnn, compile_bnn
from repro.core.pipeline import MAX_FIELDS, ChipSpec, PipelineProgram
from repro.dataplane import traffic as _traffic
from repro.dataplane.fabric import SwitchFabric
from repro.dataplane.lowering import lower_program, peak_stage_rows
from repro.dataplane.multitenant import SwitchScheduler


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: a program source plus its traffic identity.

    Exactly one of ``shape`` (BNN layer sizes, init+compiled with
    ``PRNGKey(seed)``) or ``program`` (pre-compiled) must be set.
    """

    name: str
    scenario: str
    shape: tuple | None = None
    weight: float = 1.0
    seed: int = 0
    program: PipelineProgram | None = None

    def __post_init__(self) -> None:
        if (self.shape is None) == (self.program is None):
            raise ValueError(
                f"tenant {self.name!r}: set exactly one of shape= or "
                "program="
            )


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """The whole shared-chip fleet, declaratively.

    ``chip=None`` sizes the chip to exactly fit the tenant sum (every
    program's elements plus one headroom element, summed peak PHV bits, and
    a per-stage ALU budget wide enough for an interleaved merge of every
    tenant) — the admission-always-succeeds default the examples want.
    ``mode``, ``merged`` (merged-table layout), and ``quantum`` are
    scheduler defaults; all can be overridden per ``Fleet.scheduler`` call.
    """

    tenants: tuple
    chip: ChipSpec | None = None
    mode: str | None = None
    merged: str | None = None
    quantum: int | None = None
    chip_name: str = "shared"

    def __post_init__(self) -> None:
        if not self.tenants:
            raise ValueError("a fleet needs at least one tenant")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")

    @classmethod
    def from_dict(cls, d: dict) -> "FleetSpec":
        """Config-file form: ``{"tenants": [{"name": ..., ...}, ...],
        "mode": ..., "quantum": ..., "chip": {...} | None}``."""
        d = dict(d)
        tenants = tuple(
            t if isinstance(t, TenantSpec) else TenantSpec(**t)
            for t in d.pop("tenants")
        )
        chip = d.pop("chip", None)
        if isinstance(chip, dict):
            chip = ChipSpec(**chip)
        return cls(tenants=tenants, chip=chip, **d)


@dataclasses.dataclass
class Fleet:
    """A built fleet: compiled programs + sized chip + stream/scheduler
    factories.  Construction happened once in :func:`build_fleet`; the
    methods here only wire pieces together."""

    spec: FleetSpec
    programs: list
    traffic_specs: list
    chip: ChipSpec

    @property
    def num_tenants(self) -> int:
        return len(self.programs)

    def scheduler(
        self,
        *,
        mode: str | None = None,
        merged: str | None = None,
        quantum: int | None = None,
    ) -> SwitchScheduler:
        """A fresh scheduler with every tenant admitted in spec order
        (fresh because admission/telemetry state is per run)."""
        kw = {}
        m = mode if mode is not None else self.spec.mode
        if m is not None:
            kw["mode"] = m
        lay = merged if merged is not None else self.spec.merged
        if lay is not None:
            kw["merged"] = lay
        q = quantum if quantum is not None else self.spec.quantum
        if q is not None:
            kw["quantum"] = q
        sched = SwitchScheduler(self.chip, **kw)
        for t, prog in zip(self.spec.tenants, self.programs):
            sched.admit(prog, name=t.name, weight=t.weight)
        return sched

    def stream(self, n: int, *, chunk_size: int = 4096, seed: int = 0):
        """The fleet's mixed tenant stream (weights from the spec)."""
        return _traffic.mixed_tenant_stream(
            self.traffic_specs, n, chunk_size=chunk_size, seed=seed
        )

    def tenant_stream(
        self, tid: int, n: int, *, chunk_size: int = 4096, seed: int = 0
    ):
        """One tenant's scenario as a single-program chunk stream."""
        ts = self.traffic_specs[tid]
        return _traffic.stream(
            ts.scenario, n, ts.input_bits, chunk_size=chunk_size, seed=seed
        )

    def fabric(
        self,
        tid: int = 0,
        *,
        hops: int | None = None,
        mode: str = "multi_hop",
        chip: ChipSpec | None = None,
    ) -> SwitchFabric:
        """Partition one tenant's program across a switch chain.  ``hops``
        sizes a per-hop chip to split the program into exactly that many
        slices (mutually exclusive with an explicit ``chip``)."""
        prog = self.programs[tid]
        if hops is not None:
            if chip is not None:
                raise ValueError("pass hops= or chip=, not both")
            per_hop = -(-prog.num_elements // hops)  # ceil
            chip = ChipSpec(
                num_elements=per_hop,
                phv_bits=prog.chip.phv_bits,
                name=f"{prog.chip.name}/{hops}hop",
            )
        return SwitchFabric.partition(prog, mode=mode, chip=chip)


def build_fleet(spec: FleetSpec | dict | Sequence) -> Fleet:
    """Construct the fleet a spec describes.

    Accepts a :class:`FleetSpec`, its dict form, or just a sequence of
    :class:`TenantSpec`/dicts (all other knobs defaulted).
    """
    if isinstance(spec, dict):
        spec = FleetSpec.from_dict(spec)
    elif not isinstance(spec, FleetSpec):
        spec = FleetSpec.from_dict({"tenants": list(spec)})

    programs = []
    tspecs = []
    for t in spec.tenants:
        if t.program is not None:
            prog = t.program
        else:
            import jax

            params = bnn.init_params(
                bnn.BnnSpec(tuple(t.shape)), jax.random.PRNGKey(t.seed)
            )
            prog = compile_bnn([np.asarray(w) for w in params])
        programs.append(prog)
        tspecs.append(
            _traffic.TenantTrafficSpec(
                t.scenario, prog.layer_plans[0].n_in, t.weight
            )
        )

    chip = spec.chip or ChipSpec(
        num_elements=sum(p.num_elements for p in programs) + 1,
        phv_bits=sum(p.peak_phv_bits for p in programs),
        # Wide enough for the interleaved merged layout: its widest shared
        # stage sums every tenant's rows at that stage, which can exceed
        # one real chip's per-stage ALU count at high tenant counts.
        max_parallel_ops=max(
            MAX_FIELDS,
            peak_stage_rows(
                [lower_program(p, compact=True) for p in programs]
            ),
        ),
        name=spec.chip_name,
    )
    return Fleet(
        spec=spec, programs=programs, traffic_specs=tspecs, chip=chip
    )
