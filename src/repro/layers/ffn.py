"""Feed-forward blocks: SwiGLU (gated) and GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.linear import dense_apply, dense_init


def ffn_init(key: jax.Array, d: int, f: int, act: str, *, std=0.02,
             dtype=jnp.float32, quant=None) -> dict:
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, f, std=std, dtype=dtype, quant=quant, tag="ffn"),
            "w_up": dense_init(ks[1], d, f, std=std, dtype=dtype, quant=quant, tag="ffn"),
            "w_down": dense_init(ks[2], f, d, std=std, dtype=dtype, quant=quant, tag="ffn"),
        }
    return {
        "w_in": dense_init(ks[0], d, f, std=std, dtype=dtype, quant=quant, tag="ffn"),
        "w_out": dense_init(ks[1], f, d, std=std, dtype=dtype, quant=quant, tag="ffn"),
    }


def ffn_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_gate" in params:
        g = dense_apply(params["w_gate"], x, quant=cfg.quant, tag="ffn")
        u = dense_apply(params["w_up"], x, quant=cfg.quant, tag="ffn")
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        out = dense_apply(params["w_down"], h, quant=cfg.quant, tag="ffn")
    else:
        h = dense_apply(params["w_in"], x, quant=cfg.quant, tag="ffn")
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        out = dense_apply(params["w_out"], h, quant=cfg.quant, tag="ffn")
    if cfg.ar_bf16:
        out = jax.lax.optimization_barrier(out)  # bf16 TP all-reduce
    return out
