"""Token embedding and output head."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_init(key: jax.Array, vocab: int, d: int, *, std=0.02, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * std).astype(dtype)}


def embed(params: dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits in f32 (vocab axis sharded over 'model' by the rule table)."""
    return (x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T)
