"""Mixture-of-Experts FFN with expert parallelism.

Design (TPU-native, see DESIGN.md §4):
  * routing (top-k over router logits) is computed replicated — it is cheap
    (T x E) and must agree across shards;
  * dispatch/compute/combine run inside ``jax.shard_map`` with the expert
    axis sharded over ``model`` (EP): each shard scatters its *local* tokens
    into the capacity buffers of its *local* experts, runs the batched
    expert FFNs, and contributes partial token outputs; a ``psum`` over
    ``model`` combines contributions from experts living on other shards.
    Communication per MoE layer = one all-reduce of the (tokens, d_model)
    output — the TP-style EP layout (bytes independent of top-k).
  * with ``cfg.fsdp`` the expert weights are additionally sharded over
    ``data`` and all-gathered just-in-time inside the block (ZeRO-3).
  * tokens over capacity ``C = ceil(T_local * k / E * capacity_factor)`` are
    dropped (contribute zero), standard capacity-based semantics; the aux
    load-balance loss keeps drop rates low.

The single-device path (``mesh=None`` or |model| == 1) runs the identical
math with all experts local — used by unit tests for parity.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.ffn import ffn_apply, ffn_init
from repro.layers.linear import dense_init

# jax >= 0.6 promotes shard_map to jax.shard_map (check_vma=); older releases
# ship it as jax.experimental.shard_map.shard_map (check_rep=).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_NO_CHECK = {"check_vma": False}
else:  # pragma: no cover - exercised on older jax only
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_NO_CHECK = {"check_rep": False}


def _pack_experts(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(E, out, in) latent -> ((E, out, ceil(in/32)) uint32, (E, out) alpha)."""
    e, o, i = w.shape
    bits = (w >= 0).astype(jnp.uint32)
    pad = (-i) % 32
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, 0), (0, pad)))
    grouped = bits.reshape(e, o, -1, 32)
    lanes = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    packed = jnp.sum(grouped * lanes, axis=-1, dtype=jnp.uint32)
    return packed, jnp.mean(jnp.abs(w), axis=-1)


def moe_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_ffn_dim, m.num_experts
    ks = jax.random.split(key, 5)
    std = cfg.init_std
    packed = cfg.quant.packed and "moe" in cfg.quant.targets

    def experts(k, out, inn):
        w = jax.random.normal(k, (e, out, inn), jnp.float32) * std
        if packed:
            pw, alpha = _pack_experts(w)
            return {"packed": pw, "alpha": alpha}
        return w.astype(dtype)

    p = {
        "router": dense_init(ks[0], d, e, std=std, dtype=jnp.float32),
        "w_gate": experts(ks[1], f, d),
        "w_up": experts(ks[2], f, d),
        "w_down": experts(ks[3], d, f),
    }
    if m.num_shared:
        p["shared"] = ffn_init(
            ks[4], d, m.num_shared * f, "swiglu", std=std, dtype=dtype,
            quant=cfg.quant,
        )
    return p


def _route(params: dict, x2: jax.Array, cfg: ModelConfig):
    """x2: (T, d) -> (idx (T,K), gates (T,K) f32, aux metrics)."""
    m = cfg.moe
    logits = (x2.astype(jnp.float32) @ params["router"]["w"].T.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, idx = jax.lax.top_k(logits, m.top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)

    # Switch-style load-balance loss + router z-loss.
    e = m.num_experts
    me = jnp.mean(probs, axis=0)                                   # (E,)
    assign = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1)       # (T, E)
    ce = jnp.mean(assign, axis=0) / m.top_k
    aux = e * jnp.sum(me * ce) * m.aux_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    return idx, gates, aux + z


def _packed_expert_mm(x: jax.Array, w: dict) -> jax.Array:
    """Batched XNOR-popcount contraction against packed expert weights.

    x: (E, C, K) real; w["packed"]: (E, O, Kw) uint32; -> (E, C, O).
    The xor/popcount broadcast stays inside one XLA reduce fusion at decode
    capacities (the prefill-scale variant belongs in the Pallas kernel — see
    EXPERIMENTS.md §Perf on the fusion-scale limit).
    """
    e, c, k = x.shape
    beta = jnp.mean(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    bits = (x >= 0).astype(jnp.uint32)
    pad = (-k) % 32
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, 0), (0, pad)))
    lanes = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    xp = jnp.sum(bits.reshape(e, c, -1, 32) * lanes, axis=-1, dtype=jnp.uint32)
    agree = jax.lax.population_count(xp[:, :, None, :] ^ ~w["packed"][:, None, :, :])
    acc = jnp.sum(agree.astype(jnp.int32), axis=-1)          # (E, C, O)
    kw = xp.shape[-1]
    dot = (2 * acc - 2 * kw * 32 + k).astype(jnp.float32)
    return (dot * w["alpha"][:, None, :] * beta).astype(x.dtype)


def _expert_compute(
    x2: jax.Array,
    idx: jax.Array,
    gates: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    e_lo,
    num_experts: int,
    capacity: int,
) -> jax.Array:
    """Capacity dispatch -> batched expert SwiGLU -> combine (local experts).

    x2: (T, d); idx/gates: (T, K); w_*: (E_loc, ...); ``e_lo``: first local
    expert id.  Returns this shard's partial output (T, d).
    """
    t, d = x2.shape
    k = idx.shape[1]
    e_loc = (w_gate["packed"] if isinstance(w_gate, dict) else w_gate).shape[0]
    dtype = x2.dtype

    slot_expert = idx.reshape(-1)                   # (T*K,) expert id per slot
    slot_gate = gates.reshape(-1)
    slot_token = jnp.repeat(jnp.arange(t), k)

    # position of each slot within its expert's capacity buffer (global order,
    # identical on every shard)
    onehot = jax.nn.one_hot(slot_expert, num_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(t * k), slot_expert]
    keep = pos < capacity
    local = keep & (slot_expert >= e_lo) & (slot_expert < e_lo + e_loc)

    flat_idx = jnp.where(local, (slot_expert - e_lo) * capacity + pos, 0)
    contrib = jnp.where(local[:, None], x2[slot_token], 0).astype(dtype)
    buf = jnp.zeros((e_loc * capacity, d), dtype).at[flat_idx].add(contrib)
    buf = buf.reshape(e_loc, capacity, d)

    if isinstance(w_gate, dict):  # N2Net packed experts (XNOR-popcount FFN)
        g = _packed_expert_mm(buf, w_gate)
        u = _packed_expert_mm(buf, w_up)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
        down = _packed_expert_mm(h, w_down).astype(dtype)
    else:
        g = jnp.einsum("ecd,efd->ecf", buf, w_gate.astype(dtype))
        u = jnp.einsum("ecd,efd->ecf", buf, w_up.astype(dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
        down = jnp.einsum("ecf,edf->ecd", h, w_down.astype(dtype))

    slot_out = down.reshape(e_loc * capacity, d)[flat_idx]
    slot_out = jnp.where(local[:, None], slot_out, 0)
    slot_out = slot_out * slot_gate[:, None].astype(dtype)
    return slot_out.reshape(t, k, d).sum(axis=1)


def moe_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
) -> tuple[jax.Array, jax.Array]:
    """MoE FFN.  x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    idx, gates, aux = _route(params, x2, cfg)

    use_shard_map = (
        mesh is not None
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
    )

    if not use_shard_map:
        t = x2.shape[0]
        capacity = max(1, math.ceil(t * m.top_k / m.num_experts * m.capacity_factor))
        y = _expert_compute(
            x2, idx, gates, params["w_gate"], params["w_up"], params["w_down"],
            e_lo=0, num_experts=m.num_experts, capacity=capacity,
        )
    else:
        y = _moe_shard_map(params, x2, idx, gates, cfg, mesh, (b, s))

    y = y.reshape(b, s, d)
    if m.num_shared:
        y = y + ffn_apply(params["shared"], x, cfg)
    return y, aux


def _moe_shard_map(params, x2, idx, gates, cfg: ModelConfig, mesh, bs) -> jax.Array:
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_size = mesh.shape["model"]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    t_global = x2.shape[0]
    t_local = t_global // dp_size if t_global % dp_size == 0 else t_global
    capacity = max(1, math.ceil(t_local * m.top_k / m.num_experts * m.capacity_factor))
    e_per_shard = m.num_experts // model_size

    tok_spec = P(dp_axes if t_global % dp_size == 0 else None)
    packed = isinstance(params["w_gate"], dict)

    def wspec(fsdp_dim: int):
        if packed:  # packed experts fit without FSDP: model-sharded only
            return {"packed": P("model", None, None), "alpha": P("model", None)}
        spec = [None, None, None]
        spec[0] = "model"
        if cfg.fsdp:
            spec[fsdp_dim] = "data"
        return P(*spec)

    def block(x_loc, idx_loc, gates_loc, wg, wu, wd):
        if cfg.fsdp and not packed:
            wg = jax.lax.all_gather(wg, "data", axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=1, tiled=True)
        e_lo = jax.lax.axis_index("model") * e_per_shard
        part = _expert_compute(
            x_loc, idx_loc, gates_loc, wg, wu, wd,
            e_lo=e_lo, num_experts=m.num_experts, capacity=capacity,
        )
        return jax.lax.psum(part, "model")

    return _shard_map(
        block,
        mesh=mesh,
        in_specs=(
            P(tok_spec[0], None),
            P(tok_spec[0], None),
            P(tok_spec[0], None),
            wspec(2),
            wspec(2),
            wspec(1),
        ),
        out_specs=P(tok_spec[0], None),
        **_SHARD_MAP_NO_CHECK,
    )(x2, idx, gates, params["w_gate"], params["w_up"], params["w_down"])
