"""Dense and BinaryDense projections.

``BinaryDense`` is the paper's technique as a framework feature, in two
regimes selected by the architecture's ``QuantConfig``:

  * training (``bnn_weight_only`` / ``bnn_xnor``): latent full-precision
    master weights, binarized forward, straight-through gradients
    (``repro.kernels.ops``).
  * inference (``bnn_packed``): weights are *stored* as packed uint32 sign
    words (32 weights per word, 16x less HBM than bf16) plus a per-channel
    alpha; the contraction is XNOR + popcount + affine — N2Net's arithmetic
    on the TPU.  Expressed as an xor/popcount/reduce chain so XLA fuses it
    without materializing the (M, N, Kw) intermediate; the Pallas kernel
    (``kernels/bnn_matmul.py``) is the hand-tiled TPU version of the same
    contraction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.kernels import ops as kops

WORD = 32


def dense_init(
    key: jax.Array, in_dim: int, out_dim: int, *, std: float = 0.02,
    dtype=jnp.float32, quant: QuantConfig | None = None, tag: str = "",
) -> dict:
    """Weights stored (out, in) — matches the kernels' (N, K) convention.

    With packed quantization active for ``tag``, stores
    {w_packed: (out, ceil(in/32)) uint32, alpha: (out,) f32} instead.
    """
    w = jax.random.normal(key, (out_dim, in_dim), jnp.float32) * std
    if quant is not None and quant.packed and tag in quant.targets:
        bits = (w >= 0).astype(jnp.uint32)
        pad = (-in_dim) % WORD
        if pad:
            bits = jnp.pad(bits, ((0, 0), (0, pad)))
        grouped = bits.reshape(out_dim, -1, WORD)
        weights = jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)
        packed = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)
        return {"w_packed": packed, "alpha": jnp.mean(jnp.abs(w), axis=-1)}
    return {"w": w.astype(dtype)}


def _packed_apply(params: dict, x: jax.Array) -> jax.Array:
    """XNOR-popcount contraction against packed weights (XNOR-Net scaling).

    x: (..., K) real -> binarized; w_packed: (N, Kw).  The xor/popcount
    broadcast stays inside one XLA reduce fusion: HBM traffic is the packed
    weights + packed activations + the (M, N) output.
    """
    wp = params["w_packed"]
    alpha = params["alpha"]
    lead, k = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, k)
    beta = jnp.mean(jnp.abs(x2.astype(jnp.float32)), axis=-1, keepdims=True)

    bits = (x2 >= 0).astype(jnp.uint32)
    pad = (-k) % WORD
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    grouped = bits.reshape(x2.shape[0], -1, WORD)
    lanes = jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)
    xp = jnp.sum(grouped * lanes, axis=-1, dtype=jnp.uint32)   # (M, Kw)

    agree = jax.lax.population_count(~(xp[:, None, :] ^ wp[None, :, :]))
    acc = jnp.sum(agree.astype(jnp.int32), axis=-1)            # (M, N)
    kw = xp.shape[-1]
    dot = (2 * acc - 2 * kw * WORD + k).astype(jnp.float32)
    y = dot * alpha[None, :] * beta
    return y.reshape(*lead, wp.shape[0]).astype(x.dtype)


def dense_apply(
    params: dict,
    x: jax.Array,
    *,
    quant: QuantConfig | None = None,
    tag: str = "",
) -> jax.Array:
    """y = x @ W.T, binarized when ``tag`` is in the quant targets.

    x: (..., K); W: (N, K); returns (..., N) in x.dtype.
    """
    if "w_packed" in params:
        return _packed_apply(params, x)
    w = params["w"]
    if quant is not None and quant.enabled and not quant.packed and tag in quant.targets:
        lead = x.shape[:-1]
        y = kops.binary_dense_train(
            x.reshape(-1, x.shape[-1]).astype(jnp.float32),
            w.astype(jnp.float32),
            scale=quant.scale,
        )
        return y.reshape(*lead, w.shape[0]).astype(x.dtype)
    return (x @ w.T.astype(x.dtype)).astype(x.dtype)
