"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

The KV cache stores only the compressed latent ``c_kv`` (kv_lora_rank) plus
the shared decoupled rope key (qk_rope_dim) per position — the technique's
whole point.  Decode uses the *absorbed* formulation: query nope components
are projected into latent space so scores are taken directly against the
cached latents (no per-step re-expansion of K/V).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers import rope
from repro.layers.linear import dense_apply, dense_init
from repro.layers.norms import rmsnorm, rmsnorm_init


@dataclasses.dataclass
class MlaCache:
    """c_kv: (L, B, S_max, kv_lora); k_rope: (L, B, S_max, rope_dim)."""

    c_kv: jax.Array
    k_rope: jax.Array
    index: jax.Array

    @staticmethod
    def init(cfg: ModelConfig, batch: int, max_len: int, layers: int) -> "MlaCache":
        m = cfg.mla
        return MlaCache(
            c_kv=jnp.zeros((layers, batch, max_len, m.kv_lora_rank), cfg.param_dtype()),
            k_rope=jnp.zeros((layers, batch, max_len, m.qk_rope_dim), cfg.param_dtype()),
            index=jnp.zeros((), jnp.int32),
        )


jax.tree_util.register_dataclass(MlaCache, ["c_kv", "k_rope", "index"], [])


def mla_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, m.q_lora_rank, std=cfg.init_std, dtype=dtype)
        p["q_norm"] = rmsnorm_init(m.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[1], m.q_lora_rank, h * qk, std=cfg.init_std, dtype=dtype)
    else:
        p["wq"] = dense_init(ks[1], d, h * qk, std=cfg.init_std, dtype=dtype)
    p["wkv_a"] = dense_init(
        ks[2], d, m.kv_lora_rank + m.qk_rope_dim, std=cfg.init_std, dtype=dtype
    )
    p["kv_norm"] = rmsnorm_init(m.kv_lora_rank, dtype)
    p["wkv_b"] = dense_init(
        ks[3], m.kv_lora_rank, h * (m.qk_nope_dim + m.v_head_dim),
        std=cfg.init_std, dtype=dtype,
    )
    p["wo"] = dense_init(ks[4], h * m.v_head_dim, d, std=cfg.init_std, dtype=dtype)
    return p


def _project_q(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    m = cfg.mla
    b, s, _ = x.shape
    if m.q_lora_rank:
        cq = dense_apply(params["wq_a"], x, quant=cfg.quant, tag="attn_proj")
        cq = rmsnorm(params["q_norm"], cq, cfg.norm_eps)
        q = dense_apply(params["wq_b"], cq, quant=cfg.quant, tag="attn_proj")
    else:
        q = dense_apply(params["wq"], x, quant=cfg.quant, tag="attn_proj")
    return q.reshape(b, s, cfg.num_heads, m.qk_nope_dim + m.qk_rope_dim)


def mla_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    layer_cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    causal: bool = True,
) -> tuple[jax.Array, Optional[dict]]:
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    scale = 1.0 / ((m.qk_nope_dim + m.qk_rope_dim) ** 0.5)

    q = _project_q(params, x, cfg)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = rope.rotate(q_rope, positions, theta=cfg.rope_theta)

    kv_a = dense_apply(params["wkv_a"], x, quant=cfg.quant, tag="attn_proj")
    c_kv, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank :]
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    # shared single-head rope key
    k_rope = rope.rotate(k_rope[:, :, None, :], positions, theta=cfg.rope_theta)[
        :, :, 0, :
    ]

    w_kv_b = params["wkv_b"]["w"].reshape(
        h, m.qk_nope_dim + m.v_head_dim, m.kv_lora_rank
    )
    w_uk = w_kv_b[:, : m.qk_nope_dim, :]   # (H, nope, lora)
    w_uv = w_kv_b[:, m.qk_nope_dim :, :]   # (H, v, lora)

    new_cache = None
    if layer_cache is not None:
        # absorbed decode against the READ-ONLY latent cache (positions <
        # index) plus the current token as an explicit extra column; the
        # caller commits the (B, 1, ·) entries with a single-position update.
        ckv_c, krope_c = layer_cache["c_kv"], layer_cache["k_rope"]
        q_lat = jnp.einsum("bshd,hdc->bshc", q_nope, w_uk.astype(q_nope.dtype))
        scores = (
            jnp.einsum("bshc,btc->bhst", q_lat, ckv_c, preferred_element_type=jnp.float32)
            + jnp.einsum("bshr,btr->bhst", q_rope, krope_c, preferred_element_type=jnp.float32)
        ) * scale  # (B, H, 1, S)
        mask = jnp.arange(ckv_c.shape[1]) < cache_index
        scores = jnp.where(mask[None, None, None, :], scores, -jnp.inf)
        s_new = (
            jnp.einsum("bshc,btc->bhst", q_lat, c_kv.astype(q_lat.dtype),
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bshr,btr->bhst", q_rope, k_rope.astype(q_rope.dtype),
                         preferred_element_type=jnp.float32)
        ) * scale  # (B, H, 1, 1)
        p = jax.nn.softmax(jnp.concatenate([scores, s_new], axis=-1), axis=-1)
        out_lat = jnp.einsum("bhst,btc->bshc", p[..., :-1], ckv_c.astype(jnp.float32))
        out_lat = out_lat + jnp.einsum(
            "bhst,btc->bshc", p[..., -1:], c_kv.astype(jnp.float32)
        )
        out = jnp.einsum("bshc,hvc->bshv", out_lat, w_uv.astype(jnp.float32))
        out = out.astype(x.dtype)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}  # (B, 1, ·): new position
    else:
        # prefill / train: expand K, V and run chunked attention
        kv = dense_apply(params["wkv_b"], c_kv, quant=cfg.quant, tag="attn_proj")
        kv = kv.reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
        k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim :]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.qk_rope_dim))],
            axis=-1,
        )
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        from repro.layers.attention import chunked_attention

        # pad v to qk dim for the shared kernel? no — v dim differs; chunked
        # attention handles arbitrary D via separate v argument.
        out = chunked_attention(
            qq, k, v, causal=causal, q_chunk=cfg.attn_q_chunk, scale=scale
        )
        if cache_index is not None:
            new_cache = {"c_kv": c_kv, "k_rope": k_rope}

    out = out.reshape(b, s, h * m.v_head_dim)
    return dense_apply(params["wo"], out, quant=cfg.quant, tag="attn_proj"), new_cache
