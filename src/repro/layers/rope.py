"""Rotary position embeddings: full, partial (GLM/StableLM), and
decoupled-rope helpers for MLA."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_frequencies(rot_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for a rot_dim-dimensional rotary block."""
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, jnp.float32) / rot_dim))


def rotate(
    x: jax.Array,
    positions: jax.Array,
    *,
    theta: float = 10_000.0,
    rotary_pct: float = 1.0,
) -> jax.Array:
    """Apply RoPE to ``x`` (..., S, H, D) at integer ``positions`` (..., S).

    ``rotary_pct < 1`` rotates only the leading ``pct * D`` dims (GLM's 2D
    RoPE and StableLM partial rotary), passing the rest through unchanged.
    """
    d = x.shape[-1]
    rot = int(d * rotary_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    inv = rope_frequencies(rot, theta)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(angles)[..., None, :]                      # broadcast heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = out.astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out
