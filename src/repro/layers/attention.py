"""Attention: GQA with chunked (flash-style) causal prefill and cached decode.

Memory discipline: prefill never materializes the full (S, S) score matrix —
a ``lax.scan`` over query chunks computes softmax rows per chunk (peak
activation O(S * q_chunk) per head), which is what makes the 32k-prefill
dry-run cells fit HBM.  GQA is computed in grouped form (no KV head
repetition in memory).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers import rope
from repro.layers.linear import dense_apply, dense_init


@dataclasses.dataclass
class KVCache:
    """Per-layer-stack KV cache: arrays stacked over layers.

    k, v: (L, B, S_max, KVH, D); ``index``: current length (scalar int32).
    """

    k: jax.Array
    v: jax.Array
    index: jax.Array

    @staticmethod
    def init(cfg: ModelConfig, batch: int, max_len: int, layers: int) -> "KVCache":
        d = cfg.resolved_head_dim
        shape = (layers, batch, max_len, cfg.num_kv_heads, d)
        return KVCache(
            k=jnp.zeros(shape, cfg.param_dtype()),
            v=jnp.zeros(shape, cfg.param_dtype()),
            index=jnp.zeros((), jnp.int32),
        )


jax.tree_util.register_dataclass(KVCache, ["k", "v", "index"], [])


def attention_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h, kvh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    q, t = cfg.quant, "attn_proj"
    return {
        "wq": dense_init(ks[0], d, h * hd, std=cfg.init_std, dtype=dtype, quant=q, tag=t),
        "wk": dense_init(ks[1], d, kvh * hd, std=cfg.init_std, dtype=dtype, quant=q, tag=t),
        "wv": dense_init(ks[2], d, kvh * hd, std=cfg.init_std, dtype=dtype, quant=q, tag=t),
        "wo": dense_init(ks[3], h * hd, d, std=cfg.init_std, dtype=dtype, quant=q, tag=t),
    }


def _grouped_scores(q: jax.Array, k: jax.Array, dtype=jnp.float32) -> jax.Array:
    """q: (B, Sq, KVH, G, D), k: (B, Sk, KVH, D) -> (B, KVH, G, Sq, Sk).

    ``dtype=bf16`` halves the materialized score-buffer HBM traffic; the
    softmax still reduces in f32 element-wise inside the consumer fusion.
    """
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=dtype)


def _grouped_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: (B, KVH, G, Sq, Sk), v: (B, Sk, KVH, D) -> (B, Sq, KVH, G, D) f32."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v, preferred_element_type=jnp.float32)


def _flash_full(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool, scale: float
) -> jax.Array:
    """Full-sequence attention through the fused Pallas kernel.

    q: (B, S, H, D); k/v: (B, S, KVH, D).  GQA KV heads are repeated to H
    (the kernel consumes flattened (B*H, S, D)); on TPU the repeat is a
    broadcast the compiler keeps virtual.  Interpret mode on CPU.
    """
    from repro.kernels.flash_attention import flash_attention

    b, s, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    block = 128
    while s % block:
        block //= 2
    out = flash_attention(
        qf, kf, vf, causal=causal, scale=scale,
        block_q=block, block_k=block,
        interpret=jax.default_backend() != "tpu",
    )
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_chunk: int,
    scale: float,
    scores_dtype=jnp.float32,
) -> jax.Array:
    """Softmax attention over full K/V, scanning query chunks.

    q: (B, S, H, D); k, v: (B, S, KVH, D).  Returns (B, S, H, D).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]  # may differ from d (MLA: v_head_dim != qk dim)
    g = h // kvh
    q_chunk = min(q_chunk, s)
    if s % q_chunk:
        # pad the query axis; padded rows are discarded after the scan
        pad = q_chunk - s % q_chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = q.shape[1] // q_chunk
    qg = q.reshape(b, nc, q_chunk, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)

    kpos = jnp.arange(k.shape[1])

    # The chunk index is a loop CARRY (not xs): were it an xs/iota, XLA's
    # while-loop wide-expansion would hoist the per-chunk causal mask out of
    # the chunk scan AND the layer scan, materializing an
    # O(layers * nc * Cq * S) predicate buffer (observed: 2.2 GiB/device).
    def body(ci, qc):  # qc: (B, Cq, KVH, G, D)
        scores = _grouped_scores(qc, k, scores_dtype) * jnp.asarray(scale, scores_dtype)
        if causal:
            qpos = ci * q_chunk + jnp.arange(q_chunk)
            mask = kpos[None, :] <= qpos[:, None]  # (Cq, Sk)
            scores = jnp.where(
                mask[None, None, None], scores, jnp.asarray(-jnp.inf, scores_dtype)
            )
        # bf16 probs: halves the score/prob HBM traffic and the backward
        # stash; standard practice (accumulation stays f32 in the PV dot).
        p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
        out = _grouped_out(p, v)  # (B, Cq, KVH, G, D)
        return ci + 1, out.astype(q.dtype)

    _, outs = jax.lax.scan(body, jnp.zeros((), jnp.int32), qg)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nc * q_chunk, h, dv)
    return out[:, :s]


def decode_attention_incremental(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    index: jax.Array,
    *,
    scale: float,
) -> jax.Array:
    """Decode attention WITHOUT writing the token into the cache first.

    The cache (positions < index) is read-only; the current token's k/v are
    attended as an explicit extra column.  This keeps the per-step HBM
    traffic at one cache *read* — the caller updates the cache with a
    single-position dynamic_update_slice (writes B*KVH*D bytes, not the
    whole (B, S, KVH, D) slice).

    q: (B, 1, H, D); caches: (B, S, KVH, D); k_new/v_new: (B, 1, KVH, D).
    """
    b, _, h, d = q.shape
    kvh = k_cache.shape[2]
    qg = q.reshape(b, 1, kvh, h // kvh, d)
    s_c = _grouped_scores(qg, k_cache) * scale          # (B, KVH, G, 1, S)
    mask = jnp.arange(k_cache.shape[1]) < index
    s_c = jnp.where(mask[None, None, None, None, :], s_c, -jnp.inf)
    s_n = _grouped_scores(qg, k_new) * scale            # (B, KVH, G, 1, 1)
    joint = jnp.concatenate([s_c, s_n], axis=-1)
    p = jax.nn.softmax(joint, axis=-1)
    out = _grouped_out(p[..., :-1], v_cache) + _grouped_out(p[..., -1:], v_new)
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    index: jax.Array,
    *,
    scale: float,
) -> jax.Array:
    """Single-position attention against a cache.

    q: (B, 1, H, D); caches: (B, S_max, KVH, D); ``index`` = position of the
    new token (attends to [0, index]).
    """
    b, _, h, d = q.shape
    kvh = k_cache.shape[2]
    qg = q.reshape(b, 1, kvh, h // kvh, d)
    scores = _grouped_scores(qg, k_cache) * scale  # (B, KVH, G, 1, S)
    mask = jnp.arange(k_cache.shape[1]) <= index
    scores = jnp.where(mask[None, None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(p, v_cache)
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


def attention_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    layer_cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    causal: bool = True,
) -> tuple[jax.Array, Optional[dict]]:
    """GQA block.  x: (B, S, d).

    Prefill/train: ``layer_cache=None`` -> full chunked attention; returns
    (out, None) or (out, fresh cache entries when ``cache_index`` is given).
    Decode: ``layer_cache={'k','v'}`` (B, S_max, KVH, D) and ``cache_index``
    -> writes the new position, attends against the cache.
    """
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = dense_apply(params["wq"], x, quant=cfg.quant, tag="attn_proj")
    k = dense_apply(params["wk"], x, quant=cfg.quant, tag="attn_proj")
    v = dense_apply(params["wv"], x, quant=cfg.quant, tag="attn_proj")
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if cfg.rotary_pct > 0:
        q = rope.rotate(q, positions, theta=cfg.rope_theta, rotary_pct=cfg.rotary_pct)
        k = rope.rotate(k, positions, theta=cfg.rope_theta, rotary_pct=cfg.rotary_pct)
    scale = 1.0 / (hd ** 0.5)

    new_cache = None
    if layer_cache is not None:
        if cfg.decode_cache_carry:
            # read-only cache + explicit current-token column; the caller
            # commits {k,v} via a single-position update (models.decode_step).
            out = decode_attention_incremental(
                q, layer_cache["k"], layer_cache["v"], k, v, cache_index,
                scale=scale,
            )
            new_cache = {"k": k, "v": v}  # (B, 1, KVH, D): new position only
        else:
            # ys path (sequence-sharded caches): commit into the slice, then
            # attend mask<=index — concatenating a score column onto the
            # sharded sequence axis forces a reshard, measured 7x worse.
            kc = jax.lax.dynamic_update_slice(
                layer_cache["k"], k.astype(layer_cache["k"].dtype),
                (0, cache_index, 0, 0),
            )
            vc = jax.lax.dynamic_update_slice(
                layer_cache["v"], v.astype(layer_cache["v"].dtype),
                (0, cache_index, 0, 0),
            )
            out = decode_attention(q, kc, vc, cache_index, scale=scale)
            new_cache = {"k": kc, "v": vc}  # full updated slice (scan ys)
    elif cfg.attn_impl == "pallas_flash":
        out = _flash_full(q, k, v, causal=causal, scale=scale)
    else:
        out = chunked_attention(
            q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk, scale=scale,
            scores_dtype=jnp.bfloat16 if cfg.attn_scores_dtype == "bf16" else jnp.float32,
        )
        if cache_index is not None:  # prefill that seeds a cache
            new_cache = {"k": k, "v": v}
    out = out.reshape(b, s, h * hd)
    out = dense_apply(params["wo"], out, quant=cfg.quant, tag="attn_proj")
    if cfg.ar_bf16:
        # keep the TP partial-sum all-reduce in bf16: the barrier stops XLA
        # from hoisting the downstream f32 upcast above the collective.
        out = jax.lax.optimization_barrier(out)
    return out, new_cache
