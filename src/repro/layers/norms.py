"""Normalization layers (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in f32 accumulation, cast back to input dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)
