from repro.layers import attention, embedding, ffn, linear, mamba2, mla, moe, norms, rope

__all__ = [
    "attention",
    "embedding",
    "ffn",
    "linear",
    "mamba2",
    "mla",
    "moe",
    "norms",
    "rope",
]
