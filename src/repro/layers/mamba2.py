"""Mamba2 block with the SSD (state-space duality) algorithm.

Chunked formulation (the paper's tensor-core-friendly algorithm, which maps
directly onto the TPU MXU): sequence split into chunks of ``cfg.ssm.chunk``;
within a chunk the recurrence is computed in closed quadratic
(attention-like) form, across chunks a tiny ``lax.scan`` carries the
(H, N, P) state.  Single-token decode is the O(1) recurrence update.

ngroups == 1 (B/C shared across heads), matching the assigned configs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.linear import dense_apply, dense_init
from repro.layers.norms import rmsnorm, rmsnorm_init


@dataclasses.dataclass
class SsmCache:
    """h: (L, B, H, N, P) SSD state; conv_x/conv_bc: (L, B, W-1, ·) window tails."""

    h: jax.Array
    conv_x: jax.Array
    conv_bc: jax.Array
    index: jax.Array

    @staticmethod
    def init(cfg: ModelConfig, batch: int, layers: int) -> "SsmCache":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        heads = d_inner // s.head_dim
        return SsmCache(
            h=jnp.zeros((layers, batch, heads, s.state_dim, s.head_dim), jnp.float32),
            conv_x=jnp.zeros((layers, batch, s.conv_width - 1, d_inner), cfg.param_dtype()),
            conv_bc=jnp.zeros((layers, batch, s.conv_width - 1, 2 * s.state_dim), cfg.param_dtype()),
            index=jnp.zeros((), jnp.int32),
        )


jax.tree_util.register_dataclass(SsmCache, ["h", "conv_x", "conv_bc", "index"], [])


def mamba2_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    """Projections are stored *separately* (z / x / bc / dt) instead of one
    fused in_proj so each shards cleanly under TP: z/x/dt are head-aligned
    (sharded over 'model'), bc (the shared B/C with ngroups=1) is replicated.
    The depthwise conv splits the same way (conv_x sharded, conv_bc
    replicated)."""
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    heads = d_inner // s.head_dim
    ks = jax.random.split(key, 6)
    return {
        "z_proj": dense_init(ks[0], d, d_inner, std=cfg.init_std, dtype=dtype),
        "x_proj": dense_init(ks[1], d, d_inner, std=cfg.init_std, dtype=dtype),
        "bc_proj": dense_init(ks[2], d, 2 * s.state_dim, std=cfg.init_std, dtype=dtype),
        "dt_proj": dense_init(ks[3], d, heads, std=cfg.init_std, dtype=dtype),
        "conv_x_w": (jax.random.normal(ks[4], (s.conv_width, d_inner), jnp.float32)
                     * (1.0 / s.conv_width)).astype(dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_w": (jax.random.normal(ks[5], (s.conv_width, 2 * s.state_dim), jnp.float32)
                      * (1.0 / s.conv_width)).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * s.state_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(ks[0], d_inner, d, std=cfg.init_std, dtype=dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W.  xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):  # W = 4: unrolled taps beat a conv op at this size
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunked(
    x: jax.Array, dt: jax.Array, a_neg: jax.Array,
    bmat: jax.Array, cmat: jax.Array, chunk: int,
    h0: jax.Array | None = None,
):
    """SSD scan.  x: (B,S,H,P); dt: (B,S,H); a_neg: (H,) negative;
    bmat/cmat: (B,S,N).  Returns (y (B,S,H,P) f32, h_final (B,H,N,P) f32)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    L = min(chunk, s)
    if s % L:
        pad = L - s % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // L

    xc = x.reshape(b, nc, L, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, L, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, L, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, L, n).astype(jnp.float32)

    ac = dtc * a_neg  # (B,nc,L,H) log-decay, <= 0
    cum = jnp.cumsum(ac, axis=2)
    dtx = xc * dtc[..., None]  # (B,nc,L,H,P)

    # --- intra-chunk (quadratic, attention-like) ---
    cb = jnp.einsum("bctn,bcsn->bcts", cc, bc)            # (B,nc,L,L)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Lt,Ls,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    m = decay * cb[:, :, :, :, None]                       # (B,nc,Lt,Ls,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", m, dtx)

    # --- chunk states ---
    last = cum[:, :, -1:, :]                               # (B,nc,1,H)
    state_decay = jnp.exp(last - cum)                      # (B,nc,L,H)
    states = jnp.einsum("bcsh,bcsn,bcshp->bchnp", state_decay, bc, dtx)

    # --- inter-chunk state scan ---
    lam = jnp.exp(last[:, :, 0, :])                        # (B,nc,H)
    h_init = (
        jnp.zeros((b, h, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )

    def body(hprev, args):
        lam_c, s_c = args                                  # (B,H), (B,H,N,P)
        hnew = hprev * lam_c[:, :, None, None] + s_c
        return hnew, hprev

    lam_t = jnp.moveaxis(lam, 1, 0)                        # (nc,B,H)
    st_t = jnp.moveaxis(states, 1, 0)                      # (nc,B,H,N,P)
    h_final, h_prevs = jax.lax.scan(body, h_init, (lam_t, st_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,H,N,P)

    y_inter = jnp.einsum("bctn,bchnp->bcthp", cc, h_prevs) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, nc * L, h, p)[:, :s]
    return y, h_final


def mamba2_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    layer_cache: dict | None = None,
    cache_index: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    """Mamba2 mixer.  x: (B, S, d) -> (out (B, S, d), new cache or None)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_inner = s_cfg.expand * d
    heads = d_inner // s_cfg.head_dim
    n = s_cfg.state_dim

    z = dense_apply(params["z_proj"], x, quant=cfg.quant, tag="ssm_proj")
    xp = dense_apply(params["x_proj"], x, quant=cfg.quant, tag="ssm_proj")
    bc = dense_apply(params["bc_proj"], x, quant=cfg.quant, tag="ssm_proj")
    dt_raw = dense_apply(params["dt_proj"], x, quant=cfg.quant, tag="ssm_proj")
    a_neg = -jnp.exp(params["A_log"])  # (H,)

    if layer_cache is not None:
        # ---- O(1) decode step (S == 1) ----
        win_x = jnp.concatenate(
            [layer_cache["conv_x"], xp.astype(layer_cache["conv_x"].dtype)], axis=1
        )  # (B, W, d_inner)
        win_bc = jnp.concatenate(
            [layer_cache["conv_bc"], bc.astype(layer_cache["conv_bc"].dtype)], axis=1
        )
        cx = jnp.einsum("bwc,wc->bc", win_x, params["conv_x_w"].astype(win_x.dtype))
        cx = jax.nn.silu((cx + params["conv_x_b"]).astype(jnp.float32))
        cbc = jnp.einsum("bwc,wc->bc", win_bc, params["conv_bc_w"].astype(win_bc.dtype))
        cbc = jax.nn.silu((cbc + params["conv_bc_b"]).astype(jnp.float32))

        xs = cx.reshape(b, heads, s_cfg.head_dim)
        bmat, cmat = cbc[:, :n], cbc[:, n:]
        dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32) + params["dt_bias"])
        lam = jnp.exp(dt * a_neg)                          # (B,H)
        dbx = jnp.einsum("bh,bn,bhp->bhnp", dt, bmat, xs.astype(jnp.float32))
        h_new = layer_cache["h"] * lam[:, :, None, None] + dbx
        y = jnp.einsum("bn,bhnp->bhp", cmat, h_new)
        y = y + params["D"][:, None] * xs.astype(jnp.float32)
        y = y.reshape(b, 1, d_inner)
        new_cache = {"h": h_new, "conv_x": win_x[:, 1:, :], "conv_bc": win_bc[:, 1:, :]}
    else:
        cx = _causal_conv(xp, params["conv_x_w"], params["conv_x_b"])
        cbc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"])
        xs = cx.reshape(b, s, heads, s_cfg.head_dim)
        bmat, cmat = cbc[..., :n], cbc[..., n:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
        y, h_final = _ssd_chunked(xs, dt, a_neg, bmat, cmat, s_cfg.chunk)
        y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(b, s, d_inner)
        new_cache = None
        if cache_index is not None:  # prefill that seeds a decode cache
            def tail(arr):
                w = s_cfg.conv_width - 1
                return jnp.pad(arr, ((0, 0), (max(0, w - s), 0), (0, 0)))[:, -w:, :]

            new_cache = {"h": h_final, "conv_x": tail(xp), "conv_bc": tail(bc)}

    gate = jax.nn.silu(z.astype(jnp.float32)) * y
    gate = rmsnorm(params["norm"], gate.astype(x.dtype), cfg.norm_eps)
    out = dense_apply(params["out_proj"], gate, quant=cfg.quant, tag="ssm_proj")
    return out, new_cache
