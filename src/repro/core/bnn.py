"""Binary neural network definition + mathematical oracle.

This is the *model-level* ground truth that both the switch-pipeline
interpreter (``core.interpreter``) and the Pallas kernels (``kernels/``) are
validated against.

Conventions (matching the paper):
  * activations and weights are signs in {-1,+1}, stored as {0,1} bits
    (bit 1 == +1);
  * a neuron computes ``y = SIGN(popcount(XNOR(x, w)) >= N/2)`` which is
    exactly ``sign(sum_i x_i * w_i)`` with the tie (sum == 0) resolving to +1;
  * layers are fully connected (the only kind N2Net compiles).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops


@dataclasses.dataclass(frozen=True)
class BnnSpec:
    """A fully-connected BNN: ``layer_sizes[0]`` inputs, then one entry per
    layer's neuron count.  E.g. the paper's headline model is
    ``BnnSpec((32, 64, 32))`` — 32b activations, layers of 64 and 32 neurons.
    """

    layer_sizes: tuple[int, ...]

    def __post_init__(self):
        if len(self.layer_sizes) < 2:
            raise ValueError("need at least (input_size, one layer)")
        for s in self.layer_sizes:
            if s <= 0:
                raise ValueError(f"layer size must be positive, got {s}")

    @property
    def num_layers(self) -> int:
        return len(self.layer_sizes) - 1

    @property
    def input_bits(self) -> int:
        return self.layer_sizes[0]

    @property
    def output_bits(self) -> int:
        return self.layer_sizes[-1]


def init_params(spec: BnnSpec, key: jax.Array) -> list[jax.Array]:
    """Random ±1 weights as {0,1} int32 bit matrices, one (out, in) per layer."""
    params = []
    for i in range(spec.num_layers):
        key, sub = jax.random.split(key)
        fan_in, fan_out = spec.layer_sizes[i], spec.layer_sizes[i + 1]
        params.append(jax.random.bernoulli(sub, 0.5, (fan_out, fan_in)).astype(jnp.int32))
    return params


def neuron_preact(x_bits: jax.Array, w_bits: jax.Array) -> jax.Array:
    """popcount(XNOR(x, w)) per neuron — the paper's pre-activation.

    x_bits: (..., n_in) in {0,1};  w_bits: (n_out, n_in) in {0,1}.
    Returns (..., n_out) int32 agreement counts.
    """
    agree = 1 - jnp.bitwise_xor(x_bits[..., None, :], w_bits)  # XNOR
    return jnp.sum(agree, axis=-1)


def layer_forward(
    x_bits: jax.Array, w_bits: jax.Array, thresholds=None
) -> jax.Array:
    """One BNN layer: SIGN(popcount(XNOR) >= thr), as {0,1} bits.

    Matches the paper's SIGN step: output bit is 1 iff the agreement count is
    >= half the activation-vector length.  Equivalent to
    ``sign(sum x_i*w_i) >= 0`` in ±1 arithmetic (2*pop - n >= 0).
    ``thresholds`` (scalar or ``(n_out,)``) overrides the default
    ``ceil(n_in/2)`` fire threshold — the learned-threshold variant the
    compiler expresses through the SIGN immediate.
    """
    n_in = x_bits.shape[-1]
    pre = neuron_preact(x_bits, w_bits)
    if thresholds is None:
        return (2 * pre >= n_in).astype(jnp.int32)
    return (pre >= jnp.asarray(thresholds)).astype(jnp.int32)


def forward(
    params: Sequence[jax.Array], x_bits: jax.Array, thresholds=None
) -> jax.Array:
    """Full BNN forward pass on {0,1} bit activations.

    ``thresholds`` optionally carries one entry per layer (``None``, scalar,
    or ``(n_out,)``) mirroring ``compile_bnn(..., thresholds=...)``.
    """
    h = x_bits
    if thresholds is None:
        thresholds = [None] * len(params)
    if len(thresholds) != len(params):
        raise ValueError(
            f"{len(thresholds)} threshold entries for {len(params)} layers"
        )
    for w, thr in zip(params, thresholds):
        h = layer_forward(h, w, thr)
    return h


def forward_pm1(params: Sequence[jax.Array], x_pm1: jax.Array) -> jax.Array:
    """Same network evaluated in ±1 arithmetic (float path, used to prove the
    XNOR-popcount identity: both paths must agree bit-for-bit)."""
    h = x_pm1
    for w in params:
        w_pm1 = bitops.bits_to_sign(w, h.dtype)
        pre = h @ w_pm1.T
        h = jnp.where(pre >= 0, 1.0, -1.0).astype(h.dtype)
    return h


def packed_forward(params: Sequence[jax.Array], x_bits: jax.Array) -> jax.Array:
    """Forward pass on bit-*packed* words via XNOR + HAKMEM popcount.

    This is the arithmetic the switch (and the packed Pallas kernel) actually
    performs; validated against :func:`forward`.
    """
    h = x_bits
    for w in params:
        n_in = h.shape[-1]
        hp = bitops.pack_bits(bitops.pad_to_word_multiple(h))
        wp = bitops.pack_bits(bitops.pad_to_word_multiple(w))
        dot = bitops.packed_dot(hp[..., None, :], wp, n_in)  # (..., n_out)
        h = (dot >= 0).astype(jnp.int32)
    return h


# ---------------------------------------------------------------------------
# Training support (BinaryNet-style straight-through estimator).  The paper is
# forward-only; STE training is the framework addition that makes BNN layers
# usable inside the assigned architectures (see kernels/ops.py for the
# custom_vjp used by BinaryDense).
# ---------------------------------------------------------------------------

def binarize_ste(w_latent: jax.Array) -> jax.Array:
    """sign(w) with identity gradient inside |w|<=1 (straight-through)."""
    w_bin = jnp.where(w_latent >= 0, 1.0, -1.0).astype(w_latent.dtype)
    # Gradient: pass-through where |w| <= 1, zero outside (BinaryNet clipping).
    gate = (jnp.abs(w_latent) <= 1.0).astype(w_latent.dtype)
    return w_latent * gate + jax.lax.stop_gradient(w_bin - w_latent * gate)


def params_from_latent(latent: Sequence[jax.Array]) -> list[jax.Array]:
    """Latent fp weights -> {0,1} bit matrices for inference export."""
    return [bitops.sign_to_bits(w) for w in latent]
