"""N2Net core: the paper's contribution.

``bitops``     — chip-legal bit primitives (HAKMEM popcount, packing).
``bnn``        — binary NN definition + mathematical oracle + STE.
``phv``        — 512B Packet Header Vector model and field allocator.
``pipeline``   — RMT instruction set, elements, chip spec, cost model.
``compiler``   — BNN weights -> pipeline program (the paper's 5 steps).
``interpreter``— JAX executor with exact RMT element semantics.
``p4gen``      — P4 source emission.
``throughput`` — analytic packets/s -> neurons/s model.
``export``     — trained weights -> verified deployable artifact.
"""
from repro.core import bitops, bnn, compiler, export, interpreter, p4gen, phv, pipeline, throughput
from repro.core.bnn import BnnSpec, forward, init_params
from repro.core.compiler import compile_bnn
from repro.core.export import ExportedModel, export_bits, export_latent, verify_roundtrip
from repro.core.interpreter import run_program
from repro.core.pipeline import RMT, RMT_NATIVE_POPCNT, ChipSpec

__all__ = [
    "BnnSpec",
    "ChipSpec",
    "ExportedModel",
    "RMT",
    "RMT_NATIVE_POPCNT",
    "bitops",
    "bnn",
    "compile_bnn",
    "compiler",
    "export",
    "export_bits",
    "export_latent",
    "forward",
    "init_params",
    "interpreter",
    "p4gen",
    "phv",
    "pipeline",
    "run_program",
    "throughput",
    "verify_roundtrip",
]
