"""Packet Header Vector (PHV) model and field allocator.

RMT's PHV is a 512-byte vector exposed to each pipeline element as a set of
containers.  We model fields at their true bit widths for *capacity
accounting* (the constraints that produce the paper's Table 1) while the
interpreter stores every logical field in its own uint32 slot for execution
simplicity — semantics are unaffected because RMT elements read the whole PHV
before writing (read-before-write), and each field is written at most once
per element.

Capacity rules enforced (from the paper / RMT):
  * total live bits at any pipeline stage <= 4096 (512 B);
  * one write per field per element;
  * per-element parallel-op budget accounted at 32-bit ALU granularity
    (sub-word fields share an ALU lane), max 224 ops.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

PHV_BYTES = 512
PHV_BITS = PHV_BYTES * 8  # 4096
MAX_FIELDS = 224           # RMT container count == per-element parallel ops


@dataclasses.dataclass(frozen=True)
class Field:
    """A logical PHV field: an id, a human-readable name, and a bit width."""

    fid: int
    name: str
    width: int  # bits, 1..32

    def __post_init__(self):
        if not (1 <= self.width <= 32):
            raise ValueError(f"field width must be in [1,32], got {self.width}")


class PhvAllocator:
    """Allocates logical fields and tracks live bits per pipeline stage.

    Fields are freed explicitly when a stage's outputs supersede its inputs
    (overlay reuse — RMT lets a stage's action results land in containers its
    match side already consumed).  ``peak_live_bits`` is the number the
    512-byte constraint applies to.
    """

    def __init__(self, phv_bits: int = PHV_BITS):
        self.phv_bits = phv_bits
        self._next = 0
        self._live: dict[int, Field] = {}
        self.peak_live_bits = 0
        self.peak_live_fields = 0

    def alloc(self, name: str, width: int) -> Field:
        f = Field(self._next, name, width)
        self._next += 1
        self._live[f.fid] = f
        self._update_peak()
        return f

    def alloc_vector(self, name: str, width: int, count: int) -> list[Field]:
        return [self.alloc(f"{name}[{i}]", width) for i in range(count)]

    def free(self, fields) -> None:
        for f in fields:
            self._live.pop(f.fid, None)

    def _update_peak(self) -> None:
        bits = sum(f.width for f in self._live.values())
        self.peak_live_bits = max(self.peak_live_bits, bits)
        self.peak_live_fields = max(self.peak_live_fields, len(self._live))

    @property
    def live_bits(self) -> int:
        return sum(f.width for f in self._live.values())

    @property
    def num_fields_created(self) -> int:
        return self._next

    def check(self) -> None:
        if self.peak_live_bits > self.phv_bits:
            raise PhvOverflowError(
                f"PHV overflow: peak live bits {self.peak_live_bits} > "
                f"{self.phv_bits} (512B)"
            )

    def iter_live(self) -> Iterator[Field]:
        return iter(self._live.values())


class PhvOverflowError(Exception):
    """Raised when a program's live fields exceed the 512-byte PHV."""
