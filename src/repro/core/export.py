"""Export trained BNNs into deployable switch-pipeline artifacts.

This is the deploy half of the train->deploy loop: latent float weights (or
{0,1} bit matrices from any source) are rounded to the chip's weight format,
compiled by :func:`~repro.core.compiler.compile_bnn` into a pipeline program,
lowered to the dataplane's dense op-tables, and *verified* — the exported
artifact is only trustworthy because :func:`verify_roundtrip` proves the
mathematical oracle (``bnn.forward``), the fused executor, and the simulated
switch fabric agree bit-for-bit on real packets.

Rounding convention (must match training): a latent weight binarizes to bit 1
iff it is ``>= 0`` — exactly :func:`repro.core.bitops.sign_to_bits`, and
exactly the sign :func:`repro.core.bnn.binarize_ste` takes in the training
forward pass.  Ties at 0.0 go to +1 on both sides, so a trained model and its
export can never disagree on the boundary.

The dataplane subsystem is imported lazily (inside functions) so ``core``
stays importable without it, mirroring ``PipelineProgram.lower``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core import bnn
from repro.core.bnn import BnnSpec
from repro.core.compiler import compile_bnn
from repro.core.pipeline import RMT, ChipSpec, PipelineProgram

if TYPE_CHECKING:  # pragma: no cover
    from repro.dataplane.fabric import SwitchFabric
    from repro.dataplane.lowering import LoweredProgram


class ExportError(Exception):
    """Weights cannot be exported, or a round-trip verification failed."""


def bit_weights_from_latent(latent: Sequence) -> list[np.ndarray]:
    """Latent float weights -> {0,1} int32 bit matrices (bit 1 iff w >= 0).

    Thin numpy wrapper over :func:`repro.core.bnn.params_from_latent` — one
    implementation of the rounding convention, shared with training.
    """
    return [np.asarray(w) for w in bnn.params_from_latent(latent)]


@dataclasses.dataclass(frozen=True)
class ExportedModel:
    """A deployable model: bit weights + compiled program + lowered tables.

    ``weights`` are the ground truth — ``program`` and ``lowered`` are
    deterministic functions of them and the chip, and :func:`load` proves it
    by recompiling and checking the program fingerprint against the manifest.
    """

    spec: BnnSpec
    weights: tuple[np.ndarray, ...]     # {0,1} int32, one (n_out, n_in) per layer
    chip: ChipSpec
    program: PipelineProgram
    lowered: "LoweredProgram"
    compile_seconds: float
    lower_seconds: float

    def oracle_forward(self, packets) -> np.ndarray:
        """Reference predictions from the mathematical oracle."""
        import jax.numpy as jnp

        return np.asarray(bnn.forward(list(self.weights), jnp.asarray(packets)))

    def fabric(
        self, *, mode: str = "multi_hop", chip: ChipSpec | None = None
    ) -> "SwitchFabric":
        """Partition the program onto simulated switches (deploy target)."""
        from repro.dataplane.fabric import SwitchFabric

        return SwitchFabric.partition(self.program, mode=mode, chip=chip)

    def save(self, directory: str) -> str:
        """Persist the bit matrices + a manifest binding them to the compile.

        Only the weights and metadata are stored; ``load`` recompiles and
        verifies the program fingerprint, so a stale or hand-edited artifact
        cannot silently masquerade as the trained model.
        """
        os.makedirs(directory, exist_ok=True)
        np.savez(
            os.path.join(directory, "weights.npz"),
            **{f"layer_{i}": w for i, w in enumerate(self.weights)},
        )
        manifest = {
            "layer_sizes": list(self.spec.layer_sizes),
            "chip": self.chip.name,
            "native_popcnt": self.chip.native_popcnt,
            "program_fingerprint": self.program.fingerprint(),
            "lowered_fingerprint": self.lowered.fingerprint(),
            "elements": self.program.num_elements,
        }
        with open(os.path.join(directory, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        return directory


def export_bits(
    weights: Sequence[np.ndarray], chip: ChipSpec = RMT
) -> ExportedModel:
    """Compile {0,1} bit matrices into a deployable :class:`ExportedModel`."""
    raw = [np.asarray(w) for w in weights]
    if not raw:
        raise ExportError("no weight matrices to export")
    for i, w in enumerate(raw):
        if w.ndim != 2:
            raise ExportError(f"layer {i}: weights must be 2-D, got {w.shape}")
        # Validate before the int32 cast: float latents passed by mistake
        # (export_latent is the rounding entry point) must not truncate to
        # {0,1}-looking garbage.
        if not np.isin(w, (0, 1)).all():
            raise ExportError(f"layer {i}: weights must be {{0,1}} bits")
    ws = tuple(w.astype(np.int32) for w in raw)
    for i, (a, b) in enumerate(zip(ws, ws[1:])):
        if b.shape[1] != a.shape[0]:
            raise ExportError(
                f"layer {i + 1} fan-in {b.shape[1]} != layer {i} fan-out {a.shape[0]}"
            )
    spec = BnnSpec((ws[0].shape[1],) + tuple(w.shape[0] for w in ws))

    t0 = time.perf_counter()
    program = compile_bnn(list(ws), chip)
    t1 = time.perf_counter()
    lowered = program.lower()
    t2 = time.perf_counter()
    return ExportedModel(
        spec=spec,
        weights=ws,
        chip=chip,
        program=program,
        lowered=lowered,
        compile_seconds=t1 - t0,
        lower_seconds=t2 - t1,
    )


def export_latent(latent: Sequence, chip: ChipSpec = RMT) -> ExportedModel:
    """Round latent float weights to bits and compile (the trainer's exit)."""
    return export_bits(bit_weights_from_latent(latent), chip)


def load(directory: str, chip: ChipSpec = RMT) -> ExportedModel:
    """Load a saved artifact, recompile, and verify the manifest fingerprint."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(directory, "weights.npz")) as z:
        ws = [z[f"layer_{i}"] for i in range(len(z.files))]
    exported = export_bits(ws, chip)
    want = manifest["program_fingerprint"]
    if exported.program.fingerprint() != want:
        raise ExportError(
            f"recompiled program fingerprint {exported.program.fingerprint()} "
            f"!= manifest {want} (artifact stale, or chip mismatch: saved for "
            f"{manifest['chip']!r}, loading for {chip.name!r})"
        )
    return exported


# ---------------------------------------------------------------------------
# Round-trip verification
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundTripReport:
    """Outcome of a train->compile->deploy bit-exactness check.

    A "mismatch" is a packet whose output bit *vector* differs anywhere —
    per-packet, not per-bit, because one wrong bit is one misclassified
    packet on the wire.
    """

    packets: int
    output_bits: int
    mode: str
    hops: int
    executor_mismatches: int     # oracle vs fused executor (single switch)
    fabric_mismatches: int       # oracle vs partitioned switch fabric
    reference_mismatches: int | None  # caller bits (e.g. STE fwd) vs fabric
    verify_seconds: float

    @property
    def ok(self) -> bool:
        return (
            self.executor_mismatches == 0
            and self.fabric_mismatches == 0
            and not self.reference_mismatches
        )

    def summary(self) -> str:
        ref = (
            "-"
            if self.reference_mismatches is None
            else str(self.reference_mismatches)
        )
        return (
            f"roundtrip[{self.mode}]: packets={self.packets} hops={self.hops} "
            f"mismatches(executor={self.executor_mismatches} "
            f"fabric={self.fabric_mismatches} reference={ref}) "
            f"{'BIT-EXACT' if self.ok else 'FAILED'}"
        )


def _row_mismatches(a: np.ndarray, b: np.ndarray) -> int:
    return int((np.asarray(a) != np.asarray(b)).any(axis=1).sum())


def verify_roundtrip(
    exported: ExportedModel,
    packets,
    *,
    mode: str = "multi_hop",
    fabric_chip: ChipSpec | None = None,
    fabric: "SwitchFabric | None" = None,
    backend: str = "jnp",
    chunk_size: int | None = None,
    reference_bits=None,
    check: bool = True,
) -> RoundTripReport:
    """Prove oracle == fused executor == switch fabric on ``packets``.

    ``reference_bits`` lets a caller pin a fourth witness — the trainer
    passes its STE forward-pass outputs, which is the acceptance criterion
    "train-time vs fabric-simulated predictions are bit-exact".  Pass a
    pre-built ``fabric`` to reuse one instance (e.g. to read its telemetry
    afterwards) instead of partitioning a fresh one from ``mode`` /
    ``fabric_chip``.  With ``check=True`` (default) any mismatch raises
    :class:`ExportError`; ``check=False`` returns the report for inspection.
    """
    from repro.dataplane.executor import execute

    packets = np.asarray(packets)
    if packets.ndim != 2 or packets.shape[1] != exported.spec.input_bits:
        raise ExportError(
            f"expected (n, {exported.spec.input_bits}) packets, got {packets.shape}"
        )
    t0 = time.perf_counter()
    want = exported.oracle_forward(packets)
    got_exec = execute(
        exported.lowered, packets, backend=backend, chunk_size=chunk_size
    )
    if fabric is not None and (
        fabric.program.fingerprint() != exported.program.fingerprint()
    ):
        raise ExportError(
            "supplied fabric was partitioned from a different program than "
            "this export (stale fabric after a retrain?)"
        )
    fab = fabric if fabric is not None else exported.fabric(mode=mode, chip=fabric_chip)
    got_fabric = fab.run(packets, backend=backend, chunk_size=chunk_size).outputs

    ref_mismatches = None
    if reference_bits is not None:
        reference_bits = np.asarray(reference_bits)
        if reference_bits.shape != got_fabric.shape:
            raise ExportError(
                f"reference bits shape {reference_bits.shape} != fabric "
                f"output shape {got_fabric.shape}"
            )
        ref_mismatches = _row_mismatches(reference_bits, got_fabric)

    report = RoundTripReport(
        packets=packets.shape[0],
        output_bits=exported.spec.output_bits,
        mode=fab.mode,
        hops=fab.num_hops,
        executor_mismatches=_row_mismatches(want, got_exec),
        fabric_mismatches=_row_mismatches(want, got_fabric),
        reference_mismatches=ref_mismatches,
        verify_seconds=time.perf_counter() - t0,
    )
    if check and not report.ok:
        raise ExportError(report.summary())
    return report
