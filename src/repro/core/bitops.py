"""Bit-level primitives used by N2Net.

Everything here restricts itself to operations a switching-chip ALU (or the
TPU VPU) supports natively: bitwise logic, shifts, and integer adds.  The
HAKMEM-style tree popcount (`hakmem_popcount`) is the *paper's* POPCNT
synthesis (it is what `core.compiler` schedules onto pipeline elements); the
packing helpers are shared with the Pallas kernels.

Bit order convention: bit ``j`` of word ``w`` holds element ``32*i + j`` of
the unpacked vector (little-endian within a word).  ``pack_bits`` /
``unpack_bits`` are exact inverses under this convention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32  # packing word width (uint32)

# HAKMEM / Hacker's-Delight tree-popcount masks, per level.
_POPCOUNT_MASKS = (
    np.uint32(0x55555555),
    np.uint32(0x33333333),
    np.uint32(0x0F0F0F0F),
    np.uint32(0x00FF00FF),
    np.uint32(0x0000FFFF),
)


def hakmem_popcount(x: jax.Array) -> jax.Array:
    """Tree popcount over uint32 using only shift / AND / add.

    This mirrors the algorithm N2Net schedules onto RMT elements: level ``l``
    ANDs the two shifted copies with the level mask and adds partial counts
    (the paper spends two pipeline elements per level: one for the parallel
    shift/AND pair on the duplicated PHV fields, one for the SUM).
    """
    if x.dtype != jnp.uint32:
        raise TypeError(f"hakmem_popcount expects uint32, got {x.dtype}")
    for level, mask in enumerate(_POPCOUNT_MASKS):
        shift = 1 << level
        # Two "copies" (the paper's duplication step): x and x >> shift.
        x = (x & mask) + ((x >> shift) & mask)
    return x


def pack_bits(bits: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a {0,1} (or boolean) array into uint32 words along ``axis``.

    The axis length must be a multiple of 32 (callers pad with
    ``pad_to_word_multiple`` first).  Little-endian bit order within a word.
    """
    bits = jnp.asarray(bits)
    axis = axis % bits.ndim
    n = bits.shape[axis]
    if n % WORD != 0:
        raise ValueError(f"pack axis length {n} not a multiple of {WORD}")
    bits = jnp.moveaxis(bits, axis, -1)
    new_shape = bits.shape[:-1] + (n // WORD, WORD)
    grouped = bits.reshape(new_shape).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    packed = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


def unpack_bits(words: jax.Array, axis: int = -1, count: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_bits`; optionally trim to ``count`` bits."""
    words = jnp.asarray(words)
    if words.dtype != jnp.uint32:
        raise TypeError(f"unpack_bits expects uint32, got {words.dtype}")
    axis = axis % words.ndim
    words = jnp.moveaxis(words, axis, -1)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD,))
    if count is not None:
        bits = bits[..., :count]
    return jnp.moveaxis(bits, -1, axis).astype(jnp.int32)


def pad_to_word_multiple(bits: jax.Array, axis: int = -1, value: int = 0) -> jax.Array:
    """Pad the bit axis up to the next multiple of 32 with ``value``."""
    axis = axis % bits.ndim
    n = bits.shape[axis]
    rem = (-n) % WORD
    if rem == 0:
        return bits
    pad = [(0, 0)] * bits.ndim
    pad[axis] = (0, rem)
    return jnp.pad(bits, pad, constant_values=value)


def sign_to_bits(x: jax.Array) -> jax.Array:
    """Map a ±1 (or real) array to {0,1} bits: bit = 1 iff x >= 0.

    N2Net's SIGN convention: the sign activation emits +1 for ``popcount >=
    N/2`` — i.e. non-negative pre-activations binarize to bit 1.
    """
    return (x >= 0).astype(jnp.int32)


def bits_to_sign(b: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Map {0,1} bits to ±1 values (0 -> -1, 1 -> +1)."""
    return (2 * b.astype(jnp.int32) - 1).astype(dtype)


def xnor(a: jax.Array, b: jax.Array) -> jax.Array:
    """Bitwise XNOR on packed words (agreement mask of two sign vectors)."""
    return ~(a ^ b)


def packed_dot(x_words: jax.Array, w_words: jax.Array, n_bits: int) -> jax.Array:
    """±1 dot product of two packed sign vectors via XNOR + popcount.

    ``x_words``/``w_words``: uint32 arrays whose last axis packs ``n_bits``
    sign bits (padded region must be *equal in both operands* so XNOR of the
    pad contributes popcount 1 per pad bit; we subtract the pad contribution).

    Returns ``sum(x_i * w_i)`` over the n_bits genuine positions, i.e.
    ``2 * popcount(XNOR) - n_bits`` with pad correction, as int32.
    """
    agree = hakmem_popcount(xnor(x_words, w_words))
    total = jnp.sum(agree.astype(jnp.int32), axis=-1)
    n_padded = x_words.shape[-1] * WORD
    pad = n_padded - n_bits
    # Pad bits are 0 in both operands -> XNOR gives 1 -> counted as agreement.
    return 2 * (total - pad) - n_bits
