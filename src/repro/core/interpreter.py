"""JAX executor for compiled RMT pipeline programs.

This is the "chip in software": it evaluates a :class:`PipelineProgram` on a
batch of packets using exactly the element semantics of RMT — every op of an
element reads the *incoming* PHV, all writes land simultaneously
(read-before-write), results are truncated to the destination field's width.

The interpreter is the correctness witness for the compiler: tests assert
bit-exact agreement with the mathematical BNN oracle (``core.bnn.forward``)
over random models and inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.pipeline import Op, OpCode, PipelineProgram


def _width_mask(width: int) -> jnp.uint32:
    return jnp.uint32((1 << width) - 1 if width < 32 else 0xFFFFFFFF)


def _eval_op(op: Op, regs: jax.Array) -> jax.Array:
    """Evaluate one op against the element's incoming register file.

    ``regs``: (batch, num_fields) uint32.  Returns the (batch,) result.
    """
    code = op.opcode
    if code == OpCode.COPY:
        val = regs[:, op.srcs[0].fid]
    elif code == OpCode.XNOR_IMM:
        val = ~(regs[:, op.srcs[0].fid] ^ jnp.uint32(op.imm[0]))
    elif code == OpCode.AND_IMM:
        val = regs[:, op.srcs[0].fid] & jnp.uint32(op.imm[0])
    elif code == OpCode.SHR_AND_IMM:
        val = (regs[:, op.srcs[0].fid] >> jnp.uint32(op.imm[0])) & jnp.uint32(op.imm[1])
    elif code == OpCode.ADD:
        val = regs[:, op.srcs[0].fid] + regs[:, op.srcs[1].fid]
    elif code == OpCode.GE_IMM:
        val = (regs[:, op.srcs[0].fid] >= jnp.uint32(op.imm[0])).astype(jnp.uint32)
    elif code == OpCode.FOLD:
        val = jnp.zeros(regs.shape[0], jnp.uint32)
        for k, src in enumerate(op.srcs):
            val = val | (regs[:, src.fid] << jnp.uint32(k))
    elif code == OpCode.POPCNT:
        val = jax.lax.population_count(regs[:, op.srcs[0].fid])
    else:  # pragma: no cover
        raise ValueError(f"unknown opcode {code}")
    return val & _width_mask(op.dst.width)


def _run(prog: PipelineProgram, packets: jax.Array) -> jax.Array:
    batch = packets.shape[0]
    regs = jnp.zeros((batch, prog.num_fields), jnp.uint32)

    # Load the input activation bits into the input fields (parser step).
    off = 0
    for f in prog.input_fields:
        bits = packets[:, off : off + f.width].astype(jnp.uint32)
        weights = jnp.uint32(1) << jnp.arange(f.width, dtype=jnp.uint32)
        regs = regs.at[:, f.fid].set(jnp.sum(bits * weights, axis=1, dtype=jnp.uint32))
        off += f.width

    # Execute elements: read-before-write within each element.
    for el in prog.elements:
        if not el.ops:
            continue
        vals = [_eval_op(op, regs) for op in el.ops]
        idx = jnp.array([op.dst.fid for op in el.ops])
        regs = regs.at[:, idx].set(jnp.stack(vals, axis=1))

    # Deparse: output fields -> flat bit vector.
    outs = []
    for f in prog.output_fields:
        word = regs[:, f.fid]
        shifts = jnp.arange(f.width, dtype=jnp.uint32)
        outs.append(((word[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32))
    return jnp.concatenate(outs, axis=1)


_RUNNER_CACHE: dict[str, object] = {}


def _compiled_runner(prog: PipelineProgram):
    # Keyed on the structural fingerprint, not id(prog): ids are reused after
    # GC, which could silently hand back a stale runner jitted for a *different*
    # program.  Fingerprints also dedupe identical recompilations.
    key = prog.fingerprint()
    fn = _RUNNER_CACHE.get(key)
    if fn is None:
        fn = jax.jit(functools.partial(_run, prog))
        _RUNNER_CACHE[key] = fn
    return fn


def run_program(prog: PipelineProgram, packets: jax.Array) -> jax.Array:
    """Run a compiled program on a batch of packets.

    ``packets``: (batch, input_bits) {0,1} array — the parsed activation bits.
    Returns (batch, output_bits) {0,1} int32 — the network's Y vector.
    """
    packets = jnp.asarray(packets)
    if packets.ndim != 2 or packets.shape[1] != prog.input_bits:
        raise ValueError(
            f"expected (batch, {prog.input_bits}) packet bits, got {packets.shape}"
        )
    return _run(prog, packets)


def run_program_jit(prog: PipelineProgram, packets: jax.Array) -> jax.Array:
    """Jitted variant (program is a static compile-time constant)."""
    return _compiled_runner(prog)(jnp.asarray(packets))
