"""The N2Net compiler: BNN weights -> RMT pipeline program.

Implements the paper's five-step schedule per neuron group:

  1. *Replication* — the layer's activation vector is copied once per neuron
     processed in parallel (1 element).
  2. *XNOR and Duplication* — each copy is XNOR-ed against that neuron's
     weight bits (weights are immediates, pre-configured like BrainWave);
     the result is written **twice** because the HAKMEM POPCNT needs two
     operand sets and an element applies one op per field (1 element).
     With a native POPCNT primitive (§3 ablation) duplication is skipped.
  3. *POPCNT* — HAKMEM tree: per level, element A marshals the two operand
     sets (shift/AND on the duplicated copies), element B sums and
     re-duplicates; cross-word levels pair up per-word counts the same way
     (2 elements per level, ``log2(N)`` levels).  Native-POPCNT path: one
     POPCNT element + an ADD tree (1 element per level).
  4. *SIGN* — compare the count against ``ceil(n_in/2)`` (1 element).
  5. *Folding* — deposit the parallel neurons' sign bits into the packed
     Y vector (1 element, only when parallel > 1).

Cost identity (validated in tests against ``pipeline.elements_for_neuron_group``
and the paper's Table 1): for power-of-two N and a single group,
``elements = 3 + 2*log2(N) + (parallel > 1)``.

PHV accounting uses free-before-alloc overlay (RMT elements read the whole
incoming PHV before writing, so a stage's outputs may land in containers its
inputs occupied).  This reproduces the paper's bound exactly: the duplication
stage holds ``2*P*N`` live bits, hence max activation length 2048 on a 512B
PHV (4096 with native POPCNT).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core import bnn
from repro.core.phv import PhvAllocator
from repro.core.pipeline import (
    RMT,
    ChipSpec,
    Element,
    LayerPlan,
    Op,
    OpCode,
    PipelineProgram,
    ProgramConstraintError,
)

_HAKMEM_MASKS = (0x55555555, 0x33333333, 0x0F0F0F0F, 0x00FF00FF, 0x0000FFFF)


@dataclasses.dataclass(frozen=True)
class _FieldRef:
    """A live field plus the bit-range of the logical vector it carries."""

    field: object          # phv.Field
    offset: int            # bit offset into the logical vector
    width: int


def _chunk_layout(n_bits: int) -> list[tuple[int, int]]:
    """Split an n-bit vector into (offset, width<=32) field chunks."""
    out, off = [], 0
    while off < n_bits:
        w = min(32, n_bits - off)
        out.append((off, w))
        off += w
    return out


def _imm_from_bits(bits: np.ndarray) -> int:
    """Little-endian bits -> immediate value."""
    return int(sum(int(b) << i for i, b in enumerate(bits)))


def _normalize_thresholds(
    weights: Sequence[np.ndarray], thresholds: Sequence | None
) -> list[np.ndarray]:
    """Per-layer ``(n_out,)`` int32 SIGN thresholds, defaults filled in.

    A neuron fires iff its XNOR-popcount agreement is ``>= thr``; the default
    ``ceil(n_in/2)`` is the paper's SIGN (``sum >= 0`` in ±1 arithmetic).
    """
    if thresholds is None:
        thresholds = [None] * len(weights)
    if len(thresholds) != len(weights):
        raise ValueError(
            f"{len(thresholds)} threshold entries for {len(weights)} layers"
        )
    out = []
    for li, (w, thr) in enumerate(zip(weights, thresholds)):
        n_out, n_in = w.shape
        if thr is None:
            vec = np.full(n_out, (n_in + 1) // 2, np.int32)
        else:
            vec = np.broadcast_to(
                np.asarray(thr, np.int64), (n_out,)
            ).astype(np.int32)
            if vec.size and (vec.min() < 0 or vec.max() > n_in + 1):
                raise ValueError(
                    f"layer {li}: thresholds must lie in [0, {n_in + 1}], "
                    f"got [{vec.min()}, {vec.max()}]"
                )
        out.append(vec)
    return out


class Compiler:
    """Compiles a fully-connected BNN into a :class:`PipelineProgram`."""

    def __init__(self, chip: ChipSpec = RMT):
        self.chip = chip
        self.alloc = PhvAllocator(chip.phv_bits)
        self.elements: list[Element] = []
        self.layer_plans: list[LayerPlan] = []
        self._thresholds: list[np.ndarray] = []

    # -- public -------------------------------------------------------------

    def compile(
        self,
        weights: Sequence[np.ndarray],
        thresholds: Sequence | None = None,
    ) -> PipelineProgram:
        """Compile weight bit-matrices; ``thresholds`` optionally overrides
        the SIGN step's per-neuron fire threshold (default ``ceil(n_in/2)``)
        — one entry per layer, each ``None``, a scalar, or ``(n_out,)`` ints
        in ``[0, n_in + 1]``."""
        weights = [np.asarray(w, dtype=np.int64) for w in weights]
        for w in weights:
            if w.ndim != 2:
                raise ValueError("each weight matrix must be (n_out, n_in)")
            if not np.isin(w, (0, 1)).all():
                raise ValueError("weights must be {0,1} bit matrices")
        self._thresholds = _normalize_thresholds(weights, thresholds)

        n_in = weights[0].shape[1]
        in_refs = [
            _FieldRef(self.alloc.alloc(f"x[{off}:{off + w}]", w), off, w)
            for off, w in _chunk_layout(n_in)
        ]
        input_fields = [r.field for r in in_refs]

        acts = in_refs
        for li, w in enumerate(weights):
            if w.shape[1] != sum(r.width for r in acts):
                raise ValueError(
                    f"layer {li}: weight fan-in {w.shape[1]} != activation "
                    f"bits {sum(r.width for r in acts)}"
                )
            acts = self._emit_layer(li, w, acts)

        prog = PipelineProgram(
            chip=self.chip,
            elements=self.elements,
            num_fields=self.alloc.num_fields_created,
            input_fields=input_fields,
            input_bits=n_in,
            output_fields=[r.field for r in acts],
            output_bits=sum(r.width for r in acts),
            layer_plans=self.layer_plans,
            peak_phv_bits=self.alloc.peak_live_bits,
            packed_layers=tuple(
                (w.astype(np.uint8), thr)
                for w, thr in zip(weights, self._thresholds)
            ),
        )
        prog.validate()
        return prog

    # -- internals ----------------------------------------------------------

    def _element(self, stage: str) -> Element:
        el = Element(stage=stage)
        self.elements.append(el)
        return el

    def _plan_parallel(self, n_act: int, remaining: int, extra_live: int) -> int:
        """How many neurons fit in one group given current PHV pressure.

        ``extra_live`` is what must stay resident besides this group's working
        set (layer input when more groups follow, accumulated Y bits, ...).
        The dup stage is the high-water mark: dup_factor * P * n_act bits,
        plus one sign bit per neuron.
        """
        dup = 1 if self.chip.native_popcnt else 2
        avail = self.chip.phv_bits - extra_live
        p = max(1, avail // (dup * n_act))
        return min(remaining, p)

    def _emit_layer(
        self, li: int, w: np.ndarray, in_refs: list[_FieldRef]
    ) -> list[_FieldRef]:
        n_out, n_in = w.shape
        out_refs: list[_FieldRef] = []
        done = 0
        groups = 0
        first_group_parallel = 0
        el_start = len(self.elements)

        while done < n_out:
            remaining = n_out - done
            produced_bits = sum(r.width for r in out_refs)
            # Input must survive this group's consumption unless this group
            # finishes the layer (free-at-last-use overlay): if freeing the
            # input lets the whole remainder fit one group, do that.
            p = self._plan_parallel(n_in, remaining, produced_bits)
            if p < remaining:  # not last group: input must stay resident
                p = self._plan_parallel(n_in, remaining, n_in + produced_bits)
            last_group = done + p >= n_out
            if groups == 0:
                first_group_parallel = p

            out_refs += self._emit_group(
                li, w[done : done + p], in_refs, done, last_group
            )
            done += p
            groups += 1

        self.layer_plans.append(
            LayerPlan(
                layer_index=li,
                n_in=n_in,
                n_out=n_out,
                parallel=first_group_parallel,
                groups=groups,
                elements_per_group=(len(self.elements) - el_start) // groups,
                element_range=(el_start, len(self.elements)),
            )
        )
        return out_refs

    def _emit_group(
        self,
        li: int,
        w_group: np.ndarray,
        in_refs: list[_FieldRef],
        neuron_base: int,
        last_group: bool,
    ) -> list[_FieldRef]:
        p, n_in = w_group.shape
        a = self.alloc
        name = f"L{li}g{neuron_base}"

        # ---- step 1: replication ------------------------------------------
        if last_group:
            a.free(r.field for r in in_refs)  # overlay: outputs may reuse input
        el = self._element("replication")
        repl = [
            [
                _FieldRef(a.alloc(f"{name}.r{j}.{r.offset}", r.width), r.offset, r.width)
                for r in in_refs
            ]
            for j in range(p)
        ]
        for j in range(p):
            for src, dst in zip(in_refs, repl[j]):
                el.add(Op(OpCode.COPY, dst.field, (src.field,)))

        # ---- step 2: XNOR (+ duplication) ---------------------------------
        a.free(f.field for row in repl for f in row)
        el = self._element("xnor_dup" if not self.chip.native_popcnt else "xnor")
        copies = 2 if not self.chip.native_popcnt else 1
        xn = [
            [
                [
                    _FieldRef(
                        a.alloc(f"{name}.x{j}c{c}.{r.offset}", r.width), r.offset, r.width
                    )
                    for r in in_refs
                ]
                for c in range(copies)
            ]
            for j in range(p)
        ]
        for j in range(p):
            for fi, r in enumerate(in_refs):
                imm = _imm_from_bits(w_group[j, r.offset : r.offset + r.width])
                for c in range(copies):
                    el.add(Op(OpCode.XNOR_IMM, xn[j][c][fi].field, (repl[j][fi].field,), (imm,)))

        # ---- step 3: POPCNT ------------------------------------------------
        if self.chip.native_popcnt:
            counts = self._emit_popcnt_native(name, p, xn)
        else:
            counts = self._emit_popcnt_hakmem(name, p, xn, in_refs)

        # ---- step 4: SIGN ---------------------------------------------------
        # Default: popcount >= ceil(n_in/2)  <=>  sum >= 0; learned per-neuron
        # thresholds (compile(..., thresholds=...)) override per SIGN imm.
        thr_vec = self._thresholds[li]
        a.free(counts)  # sign bits overlay the consumed count containers
        el = self._element("sign")
        signs = []
        for j in range(p):
            dst = a.alloc(f"{name}.s{j}", 1)
            thr = int(thr_vec[neuron_base + j])
            el.add(Op(OpCode.GE_IMM, dst, (counts[j],), (thr,)))
            signs.append(dst)

        # ---- step 5: folding -------------------------------------------------
        if p == 1:
            return [_FieldRef(signs[0], neuron_base, 1)]
        a.free(signs)
        el = self._element("folding")
        out: list[_FieldRef] = []
        for off in range(0, p, 32):
            chunk = signs[off : off + 32]
            dst = a.alloc(f"{name}.y{off}", len(chunk))
            el.add(Op(OpCode.FOLD, dst, tuple(chunk)))
            out.append(_FieldRef(dst, neuron_base + off, len(chunk)))
        return out

    def _emit_popcnt_hakmem(
        self, name: str, p: int, xn, in_refs: list[_FieldRef]
    ) -> list:
        """Paper POPCNT: per level, (marshal, sum+dup) element pairs.

        PHV overlay discipline: fields consumed by a level are freed *before*
        the level's outputs are allocated (read-before-write lets outputs land
        in the consumed containers), so the working set never exceeds the
        duplication stage's ``2*P*N`` bits.
        """
        a = self.alloc
        # cur[j] = (copyA_fields, copyB_fields) — per-word working counts.
        cur = [([r for r in xn[j][0]], [r for r in xn[j][1]]) for j in range(p)]
        max_w = max(r.width for r in in_refs)
        in_word_levels = max(1, math.ceil(math.log2(max_w))) if max_w > 1 else 0
        n_words = len(in_refs)
        cross_levels = max(0, math.ceil(math.log2(n_words))) if n_words > 1 else 0

        for lvl in range(in_word_levels):
            shift, mask = 1 << lvl, _HAKMEM_MASKS[lvl]
            # element A: marshal the two operand sets from the dup copies.
            active = [
                [fa.width > (1 << lvl) for fa in cur[j][0]] for j in range(p)
            ]
            a.free(
                f.field
                for j in range(p)
                for copy in cur[j]
                for f, act in zip(copy, active[j])
                if act
            )
            el_a = self._element(f"popcnt_l{lvl}a")
            nxt_a, nxt_b = [], []
            for j in range(p):
                ca, cb = cur[j]
                ra, rb = [], []
                for fa, fb, act in zip(ca, cb, active[j]):
                    if not act:  # field already fully counted; carried through
                        ra.append(fa)
                        rb.append(fb)
                        continue
                    da = _FieldRef(a.alloc(f"{name}.p{lvl}a{j}.{fa.offset}", fa.width), fa.offset, fa.width)
                    db = _FieldRef(a.alloc(f"{name}.p{lvl}b{j}.{fb.offset}", fb.width), fb.offset, fb.width)
                    el_a.add(Op(OpCode.AND_IMM, da.field, (fa.field,), (mask,)))
                    el_a.add(Op(OpCode.SHR_AND_IMM, db.field, (fb.field,), (shift, mask)))
                    ra.append(da)
                    rb.append(db)
                nxt_a.append(ra)
                nxt_b.append(rb)
            # element B: SUM, re-duplicated for the next level.
            last_level = lvl == in_word_levels - 1 and cross_levels == 0
            a.free(
                f.field
                for j in range(p)
                for row in (nxt_a[j], nxt_b[j])
                for f, act in zip(row, active[j])
                if act
            )
            el_b = self._element(f"popcnt_l{lvl}sum")
            new_cur = []
            for j in range(p):
                ca, cb, sa, sb = nxt_a[j], nxt_b[j], [], []
                for fa, fb, act in zip(ca, cb, active[j]):
                    if not act:
                        sa.append(fa)
                        sb.append(fb)
                        continue
                    na = _FieldRef(a.alloc(f"{name}.c{lvl}a{j}.{fa.offset}", fa.width), fa.offset, fa.width)
                    el_b.add(Op(OpCode.ADD, na.field, (fa.field, fb.field)))
                    sa.append(na)
                    if last_level:
                        sb.append(na)
                    else:
                        nb = _FieldRef(a.alloc(f"{name}.c{lvl}b{j}.{fa.offset}", fa.width), fa.offset, fa.width)
                        el_b.add(Op(OpCode.ADD, nb.field, (fa.field, fb.field)))
                        sb.append(nb)
                new_cur.append((sa, sb))
            cur = new_cur

        # cross-word levels: pair word counts, same (marshal, sum+dup) shape.
        for lvl in range(cross_levels):
            last_level = lvl == cross_levels - 1
            # Pre-compute pairings, free consumed fields, then allocate.
            n_pairs = {j: len(cur[j][0]) // 2 for j in range(p)}
            a.free(
                f.field
                for j in range(p)
                for copy in cur[j]
                for f in copy[: 2 * n_pairs[j]]
            )
            el_a = self._element(f"popcnt_x{lvl}a")
            # marshaled[j] = list of ("pair", da, db) | ("carry", fa, fb)
            marshaled: list[list[tuple]] = []
            for j in range(p):
                ca, cb = cur[j]
                row: list[tuple] = []
                for i in range(0, 2 * n_pairs[j], 2):
                    da = _FieldRef(a.alloc(f"{name}.q{lvl}a{j}.{i}", 16), ca[i].offset, 16)
                    db = _FieldRef(a.alloc(f"{name}.q{lvl}b{j}.{i}", 16), cb[i + 1].offset, 16)
                    el_a.add(Op(OpCode.COPY, da.field, (ca[i].field,)))
                    el_a.add(Op(OpCode.COPY, db.field, (cb[i + 1].field,)))
                    row.append(("pair", da, db))
                if len(ca) % 2:  # odd word carried through untouched
                    row.append(("carry", ca[-1], cb[-1]))
                marshaled.append(row)
            a.free(
                e[k].field
                for row in marshaled
                for e in row
                if e[0] == "pair"
                for k in (1, 2)
            )
            el_b = self._element(f"popcnt_x{lvl}sum")
            new_cur = []
            for j in range(p):
                sa, sb = [], []
                for kind, fa, fb in marshaled[j]:
                    if kind == "carry":
                        sa.append(fa)
                        sb.append(fb)
                        continue
                    na = _FieldRef(a.alloc(f"{name}.d{lvl}a{j}.{fa.offset}", 16), fa.offset, 16)
                    el_b.add(Op(OpCode.ADD, na.field, (fa.field, fb.field)))
                    sa.append(na)
                    if last_level:
                        sb.append(na)
                    else:
                        nb = _FieldRef(a.alloc(f"{name}.d{lvl}b{j}.{fa.offset}", 16), fa.offset, 16)
                        el_b.add(Op(OpCode.ADD, nb.field, (fa.field, fb.field)))
                        sb.append(nb)
                new_cur.append((sa, sb))
            cur = new_cur

        counts = []
        for j in range(p):
            sa, sb = cur[j]
            assert len(sa) == 1, f"popcount tree did not reduce: {len(sa)} words left"
            counts.append(sa[0].field)
            extra = {f.field.fid for f in sb if f.field.fid != sa[0].field.fid}
            self.alloc.free([f.field for f in sb if f.field.fid in extra])
        return counts

    def _emit_popcnt_native(self, name: str, p: int, xn) -> list:
        """§3 ablation: POPCNT primitive + plain ADD reduction tree."""
        a = self.alloc
        a.free(r.field for j in range(p) for r in xn[j][0])
        el = self._element("popcnt_native")
        cur = []
        for j in range(p):
            row = []
            for r in xn[j][0]:
                dst = _FieldRef(a.alloc(f"{name}.pc{j}.{r.offset}", 16), r.offset, 16)
                el.add(Op(OpCode.POPCNT, dst.field, (r.field,)))
                row.append(dst)
            cur.append(row)
        while max(len(row) for row in cur) > 1:
            a.free(
                f.field
                for row in cur
                for f in row[: 2 * (len(row) // 2)]
            )
            el = self._element("popcnt_add")
            new = []
            for j, row in enumerate(cur):
                nrow = []
                for i in range(0, len(row) - 1, 2):
                    dst = _FieldRef(a.alloc(f"{name}.ad{j}.{i}", 16), row[i].offset, 16)
                    el.add(Op(OpCode.ADD, dst.field, (row[i].field, row[i + 1].field)))
                    nrow.append(dst)
                if len(row) % 2:
                    nrow.append(row[-1])
                new.append(nrow)
            cur = new
        return [row[0].field for row in cur]


def compile_bnn(
    weights: Sequence[np.ndarray],
    chip: ChipSpec = RMT,
    *,
    thresholds: Sequence | None = None,
) -> PipelineProgram:
    """Compile {0,1} weight bit-matrices into an RMT pipeline program.

    ``thresholds`` optionally sets per-layer (scalar or per-neuron) SIGN fire
    thresholds; the default is the paper's ``ceil(n_in/2)``.
    """
    return Compiler(chip).compile(weights, thresholds=thresholds)


def compile_spec(
    spec: bnn.BnnSpec, params: Sequence, chip: ChipSpec = RMT
) -> PipelineProgram:
    """Compile a :class:`~repro.core.bnn.BnnSpec` with JAX bit params."""
    return compile_bnn([np.asarray(w) for w in params], chip)
