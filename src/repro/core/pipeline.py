"""RMT switching-chip model: instruction set, elements, pipeline programs.

The instruction set is restricted to what the paper says an RMT action unit
supports: bitwise logic, shifts, and simple arithmetic (increment/sum), plus
a compare-against-immediate (the SIGN step's ``>= N/2`` test, which RMT
expresses as a match/range-compare) and FOLD (deposit-bit placement — RMT
action units provide deposit-field/funnel-shift, which is what the paper's
folding step uses to concatenate the per-neuron sign bits into the Y vector).

An :class:`Element` models one match-action stage: every op in an element
reads the *incoming* PHV and writes a distinct destination field
(read-before-write, one write per field, parallel-op budget 224 at 32-bit ALU
granularity).
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
import math
from typing import Sequence

from repro.core.phv import MAX_FIELDS, PHV_BITS, Field


class OpCode(enum.Enum):
    COPY = "copy"            # dst = src0
    XNOR_IMM = "xnor_imm"    # dst = ~(src0 ^ imm)           (weights as immediates)
    AND_IMM = "and_imm"      # dst = src0 & imm
    SHR_AND_IMM = "shr_and"  # dst = (src0 >> imm0) & imm1   (HAKMEM level op)
    ADD = "add"              # dst = src0 + src1
    GE_IMM = "ge_imm"        # dst = (src0 >= imm) ? 1 : 0   (SIGN)
    FOLD = "fold"            # dst = sum_k (src_k << k)      (deposit sign bits)
    POPCNT = "popcnt"        # dst = popcount(src0)          (§3 ablation only)


@dataclasses.dataclass(frozen=True)
class Op:
    opcode: OpCode
    dst: Field
    srcs: tuple[Field, ...] = ()
    imm: tuple[int, ...] = ()

    def alu_words(self) -> int:
        """ALU lanes consumed, at 32-bit granularity (sub-word fields share)."""
        return 1


@dataclasses.dataclass
class Element:
    """One pipeline stage: a set of parallel ops."""

    stage: str                      # which of the paper's 5 steps this belongs to
    ops: list[Op] = dataclasses.field(default_factory=list)

    def add(self, op: Op) -> None:
        self.ops.append(op)

    def validate(self, max_parallel_ops: int = MAX_FIELDS) -> None:
        dsts = [op.dst.fid for op in self.ops]
        if len(dsts) != len(set(dsts)):
            raise ProgramConstraintError(
                f"element '{self.stage}': field written more than once"
            )
        # ALU budget at word granularity: sub-word fields written by ops of the
        # same stage pack into shared 32-bit lanes (RMT SIMD-in-word), which is
        # what lets 128 16-bit neurons XNOR in one element (Table 1, N=16).
        bits = sum(op.dst.width for op in self.ops)
        lanes = math.ceil(bits / 32)
        if lanes > max_parallel_ops:
            raise ProgramConstraintError(
                f"element '{self.stage}': {lanes} ALU lanes > budget "
                f"{max_parallel_ops}"
            )


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Hardware constants of the target switching chip (RMT defaults)."""

    phv_bits: int = PHV_BITS
    num_elements: int = 32
    max_parallel_ops: int = MAX_FIELDS
    packets_per_second: float = 960e6
    native_popcnt: bool = False   # §3 ablation: 32-bit POPCNT primitive
    name: str = "rmt"

    @property
    def max_activation_bits(self) -> int:
        # Duplication halves the usable PHV; a native POPCNT removes the
        # duplication step and doubles it back (paper §3).
        return self.phv_bits if self.native_popcnt else self.phv_bits // 2


RMT = ChipSpec()
RMT_NATIVE_POPCNT = ChipSpec(native_popcnt=True, name="rmt+popcnt32")


@dataclasses.dataclass
class LayerPlan:
    """Compiler bookkeeping for one BNN layer (possibly several neuron groups)."""

    layer_index: int
    n_in: int
    n_out: int
    parallel: int           # neurons per group
    groups: int             # ceil(n_out / parallel)
    elements_per_group: int
    element_range: tuple[int, int]  # [start, end) indices into program.elements


@dataclasses.dataclass(eq=False)  # identity eq/hash: programs are cache keys
class PipelineProgram:
    """A compiled N2Net program: a straight-line sequence of elements.

    Programs are built by the compiler and treated as **structurally
    immutable** from the first time they are fingerprinted, executed, or
    lowered — the fingerprint is memoized then, and the jit/lowering caches
    it keys would go stale under later mutation.  Mutate freely only before
    first use.
    """

    chip: ChipSpec
    elements: list[Element]
    num_fields: int                      # interpreter register-file size
    input_fields: list[Field]            # packed input activation words
    input_bits: int
    output_fields: list[Field]           # packed output Y words
    output_bits: int
    layer_plans: list[LayerPlan]
    peak_phv_bits: int
    # Optional per-layer (weight_bits (n_out, n_in) uint8, thresholds (n_out,)
    # int32) metadata the compiler attaches so lowering can build a bit-packed
    # execution plan (``dataplane.lowering.PackedProgram``).  Purely derived
    # from data already hashed by ``fingerprint()`` (XNOR/GE immediates), so
    # it does not participate in the hash.
    packed_layers: tuple[tuple, ...] | None = None

    @property
    def num_elements(self) -> int:
        return len(self.elements)

    @property
    def passes(self) -> int:
        """Pipeline passes needed on this program's chip — i.e. ``passes - 1``
        recirculations; a program that fits runs in 1 pass (0 recirculations).
        """
        return max(1, math.ceil(self.num_elements / self.chip.num_elements))

    def fingerprint(self) -> str:
        """Structural content hash of the program.

        Two programs with identical execution semantics (same ops over the
        same fields, same I/O layout, same chip) share a fingerprint even if
        they are distinct Python objects.  Used to key jit/lowering caches —
        unlike ``id()``, a fingerprint can never alias a dead program's key,
        and recompiling an identical program hits the cache.  Memoized on
        first call (O(num_ops) once, O(1) on the hot dispatch path); see the
        class docstring for the resulting immutability contract.
        """
        memo = self.__dict__.get("_fingerprint_memo")
        if memo is not None:
            return memo
        h = hashlib.blake2b(digest_size=16)

        def put(*items) -> None:
            h.update(repr(items).encode())

        put(
            self.chip.phv_bits,
            self.chip.num_elements,
            self.chip.max_parallel_ops,
            self.chip.native_popcnt,
            self.num_fields,
            self.input_bits,
            self.output_bits,
        )
        put(tuple((f.fid, f.width) for f in self.input_fields))
        put(tuple((f.fid, f.width) for f in self.output_fields))
        for el in self.elements:
            for op in el.ops:
                put(
                    op.opcode.value,
                    op.dst.fid,
                    op.dst.width,
                    tuple(s.fid for s in op.srcs),
                    op.imm,
                )
            put("|")  # element boundary
        memo = h.hexdigest()
        self.__dict__["_fingerprint_memo"] = memo
        return memo

    def lower(self, compact: bool = True):
        """Lower to a dense op-table for the fused dataplane executor.

        Returns a :class:`repro.dataplane.lowering.LoweredProgram`.  Imported
        lazily so ``core`` stays dependency-free of the dataplane subsystem.
        """
        from repro.dataplane.lowering import lower_program

        return lower_program(self, compact=compact)

    def validate(self) -> None:
        for el in self.elements:
            el.validate(self.chip.max_parallel_ops)
        if self.peak_phv_bits > self.chip.phv_bits:
            raise ProgramConstraintError(
                f"peak PHV usage {self.peak_phv_bits}b exceeds {self.chip.phv_bits}b"
            )

    def summary(self) -> str:
        lines = [
            f"chip={self.chip.name} elements={self.num_elements} "
            f"passes={self.passes} peak_phv_bits={self.peak_phv_bits}",
        ]
        for lp in self.layer_plans:
            lines.append(
                f"  layer {lp.layer_index}: {lp.n_in}->{lp.n_out} "
                f"parallel={lp.parallel} groups={lp.groups} "
                f"elements/group={lp.elements_per_group}"
            )
        return "\n".join(lines)


class ProgramConstraintError(Exception):
    """A compiled program violates a chip constraint."""


def elements_for_neuron_group(n_act: int, parallel: int, chip: ChipSpec = RMT) -> int:
    """The paper's element-cost model for one group of neurons.

    Standard RMT (no POPCNT primitive):
        replication(1) + XNOR&dup(1) + POPCNT(2*log2(N)) + SIGN(1)
        + folding(1 iff parallel > 1)
    = ``3 + 2*log2(N)`` for a single neuron (paper text) and Table 1's
      12/14/16/18/20/22/24/25 for N = 16..2048 with parallelism.

    With a native 32-bit POPCNT (§3): replication(1) + XNOR(1, no dup)
    + POPCNT(1) + cross-word ADD tree(log2(ceil(N/32))) + SIGN(1)
    + folding(1 iff parallel > 1) — the paper's 5..10 range.
    """
    if n_act < 2 or n_act & (n_act - 1):
        raise ValueError(f"activation width must be a power of two >= 2, got {n_act}")
    fold = 1 if parallel > 1 else 0
    if chip.native_popcnt:
        words = math.ceil(n_act / 32)
        add_levels = int(math.log2(words)) if words > 1 else 0
        return 1 + 1 + 1 + add_levels + 1 + fold
    return 3 + 2 * int(math.log2(n_act)) + fold


def max_parallel_neurons(n_act: int, chip: ChipSpec = RMT) -> int:
    """Table 1, row 'Parallel neur. (max)': PHV-capacity-derived parallelism."""
    return max(1, chip.max_activation_bits // n_act)
