"""Analytic throughput model for N2Net on an RMT chip.

Reproduces the paper's evaluation numbers:
  * 960M packets/s pipeline rate;
  * "960 million neurons per second when using 2048b activations";
  * higher neuron rates at smaller activations via parallelism;
  * the headline "960 million two-layer BNNs per second using 32b
    activations and layers of 64 and 32 neurons" — which requires the whole
    network to fit one pipeline pass (<= 32 elements).
"""
from __future__ import annotations

import dataclasses

from repro.core import bnn
from repro.core.pipeline import (
    RMT,
    ChipSpec,
    PipelineProgram,
    elements_for_neuron_group,
    max_parallel_neurons,
)


@dataclasses.dataclass(frozen=True)
class ThroughputReport:
    packets_per_second: float
    passes: int
    networks_per_second: float   # one network evaluation per packet
    neurons_per_second: float
    elements_used: int
    elements_available: int

    def csv(self) -> str:
        return (
            f"{self.packets_per_second:.3e},{self.passes},"
            f"{self.networks_per_second:.3e},{self.neurons_per_second:.3e},"
            f"{self.elements_used},{self.elements_available}"
        )


def neuron_rate(n_act: int, chip: ChipSpec = RMT) -> float:
    """Paper's Table-1-style rate: neurons/s at a given activation width.

    At 2048b one neuron rides each packet -> 960M neurons/s; smaller
    activations multiply by the parallelism (e.g. 32b -> 64x).
    """
    return chip.packets_per_second * max_parallel_neurons(n_act, chip)


def analytic_elements(spec: bnn.BnnSpec, chip: ChipSpec = RMT) -> int:
    """Element count from the paper's cost model (no compilation)."""
    total = 0
    sizes = spec.layer_sizes
    for n_in, n_out in zip(sizes[:-1], sizes[1:]):
        n_act = 1 << (n_in - 1).bit_length()  # paper model assumes pow2
        par = min(n_out, max_parallel_neurons(n_act, chip))
        groups = -(-n_out // par)
        total += groups * elements_for_neuron_group(n_act, par, chip)
    return total


def report_for_spec(spec: bnn.BnnSpec, chip: ChipSpec = RMT) -> ThroughputReport:
    """Throughput from the analytic cost model."""
    used = analytic_elements(spec, chip)
    passes = max(1, -(-used // chip.num_elements))
    pps = chip.packets_per_second / passes
    total_neurons = sum(spec.layer_sizes[1:])
    return ThroughputReport(
        packets_per_second=pps,
        passes=passes,
        networks_per_second=pps,
        neurons_per_second=pps * total_neurons,
        elements_used=used,
        elements_available=chip.num_elements,
    )


def report_for_program(prog: PipelineProgram) -> ThroughputReport:
    """Throughput of an actually-compiled program (recirculation-aware)."""
    chip = prog.chip
    passes = prog.passes
    pps = chip.packets_per_second / passes
    total_neurons = sum(lp.n_out for lp in prog.layer_plans)
    return ThroughputReport(
        packets_per_second=pps,
        passes=passes,
        networks_per_second=pps,
        neurons_per_second=pps * total_neurons,
        elements_used=prog.num_elements,
        elements_available=chip.num_elements,
    )
