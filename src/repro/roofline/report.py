"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run artifacts.

Usage:  PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except json.JSONDecodeError:
            continue
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(recs: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | status | compile s | params | per-dev arg GiB | "
        "per-dev peak GiB | fits 16GiB | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r["cell"].endswith("_" + mesh):
            continue
        cell = r["cell"][: -len(mesh) - 1]
        arch, shape = cell.rsplit("_", 1) if cell.count("_") == 1 else (
            "_".join(cell.split("_")[:-2]), "_".join(cell.split("_")[-2:])
        )
        # cell format: <arch>_<shape>; shapes contain one underscore
        parts = cell.split("_")
        shape = "_".join(parts[-2:])
        arch = "_".join(parts[:-2])
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | skipped: {r['reason']} | | | | | | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | ERROR {r.get('error','')[:40]} | | | | | | |")
            continue
        m = r["memory"]
        coll = ", ".join(
            f"{k}x{int(v)}" for k, v in sorted(r.get("collective_counts", {}).items())
        ) or "-"
        # fits: exact per-device argument bytes (weights/opt/cache shards from
        # the compiled shardings) under 12 GiB, leaving >=4 GiB of headroom
        # for activations at the chosen microbatch size.  XLA's CPU
        # temp_size has no liveness analysis and wildly overstates.
        fits = m["argument_bytes_per_dev"] < 12 * 2**30
        lines.append(
            f"| {arch} | {shape} | ok | {r['compile_s']} | "
            f"{r['n_params']/1e9:.2f}B | {fmt_bytes(m['argument_bytes_per_dev'])} | "
            f"{fmt_bytes(max(m['peak_bytes_per_dev'], m['argument_bytes_per_dev']))} | "
            f"{fits} | {coll} |"
        )
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL_FLOPs | useful frac | roofline frac | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or not r["cell"].endswith("_" + mesh):
            continue
        parts = r["cell"][: -len(mesh) - 1].split("_")
        shape = "_".join(parts[-2:])
        arch = "_".join(parts[:-2])
        rl = r["roofline"]
        fix = {
            "memory": "cut attention score/prob HBM traffic (fused flash kernel)",
            "collective": "bf16 collectives + overlap; shrink TP extent",
            "compute": "raise MXU utilization (larger tiles, less remat)",
        }[rl["bottleneck"]]
        lines.append(
            f"| {arch} | {shape} | {rl['compute_s']:.4g} | {rl['memory_s']:.4g} | "
            f"{rl['collective_s']:.4g} | **{rl['bottleneck']}** | "
            f"{rl['model_flops']:.3g} | {rl['useful_flops_fraction']:.3f} | "
            f"{rl['roofline_fraction']:.4f} | {fix} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    for mesh in ("pod", "multipod"):
        print(f"\n### Dry-run — {mesh}\n")
        print(dryrun_table(recs, mesh))
        print(f"\n### Roofline — {mesh}\n")
        print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
