"""Roofline terms from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = per-chip collective link-bytes / link_bw

All three inputs come from the scan-aware HLO analyzer (``roofline.hlo``),
because XLA's ``cost_analysis()`` counts while-loop bodies once (verified —
see hlo.py docstring).  The analyzer returns PER-DEVICE numbers (the module
is the SPMD-partitioned program), so the compute/memory terms divide by the
per-chip peaks only; "chips" is retained in the report for context.
"""
from __future__ import annotations

import dataclasses

from repro.roofline import hw
from repro.roofline.hlo import HloCosts, analyze  # noqa: F401 (re-export)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float               # per device
    hlo_bytes: float               # per device
    collective_bytes_per_chip: float
    model_flops: float             # whole model, all chips
    compute_s: float
    memory_s: float
    collective_s: float
    collective_detail: dict
    per_device_hbm_bytes: float | None = None
    xla_cost_flops: float | None = None  # raw cost_analysis value (body-once)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPs/s at roofline step time vs aggregate peak — the MFU
        upper bound of this compiled program."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / t / (self.chips * hw.PEAK_FLOPS_BF16)

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "collective_detail": self.collective_detail,
            "per_device_hbm_bytes": self.per_device_hbm_bytes,
            "xla_cost_flops": self.xla_cost_flops,
        }


def build(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    costs: HloCosts,
    model_flops: float,
    per_device_hbm_bytes: float | None = None,
    xla_cost_flops: float | None = None,
) -> Roofline:
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=costs.flops,
        hlo_bytes=costs.bytes,
        collective_bytes_per_chip=costs.collective_bytes,
        model_flops=model_flops,
        compute_s=costs.flops / hw.PEAK_FLOPS_BF16,
        memory_s=costs.bytes / hw.HBM_BW,
        collective_s=costs.collective_bytes / hw.ICI_LINK_BW,
        collective_detail=dict(costs.collective_detail),
        per_device_hbm_bytes=per_device_hbm_bytes,
        xla_cost_flops=xla_cost_flops,
    )


def model_flops_estimate(n_active_params: float, tokens: float, kind: str) -> float:
    """6·N·D for training, 2·N·D forward-only (decode: D = batch tokens)."""
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * n_active_params * tokens
