"""Scan-aware analyzer for compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while``-loop bodies ONCE
(verified empirically: scan length 2 and 8 give identical flops), which makes
it useless for scanned-layer transformers.  This module re-derives the
roofline inputs directly from ``compiled.as_text()``:

  * FLOPs     — every ``dot`` costs 2 * |output| * (contracted extent),
                multiplied by the product of enclosing loop trip counts;
  * bytes     — per materialized op: output bytes + operand bytes (post-fusion
                HLO, so each op boundary is a real buffer touch; bitcasts,
                tuples, GTEs and parameters are free);
  * collectives — ring-model per-chip link bytes by kind (see analysis.py).

Loop trip counts come from the largest scalar integer constant in the loop's
condition computation (the ``lax.scan`` bound).  Conditionals count both
branches at the parent multiplier (upper bound; branches in our models are
trivial).  All numbers are per-device: the module is the SPMD-partitioned
program.
"""
from __future__ import annotations

import dataclasses
import re

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"            # name
    r"(\(.*?\)|[\w]+\[[\d,]*\](?:\{[^}]*\})?)\s+"     # type (tuple or array;
    r"([\w\-]+)\("                                        # tuples may contain
)                                                         # /*index=N*/ comments
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|\w+\[[\d,]*\](?:\{[^}]*\})?)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "token", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "custom-call",  # bodies accounted
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _type_bytes_and_dims(type_str: str):
    """-> (total bytes, dims of the first array component)."""
    total = 0
    first_dims = None
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
        if first_dims is None:
            first_dims = [int(d) for d in dims.split(",") if d]
    return total, (first_dims or [])


@dataclasses.dataclass
class OpInfo:
    name: str
    type_str: str
    opcode: str
    line: str

    @property
    def bytes(self) -> int:
        return _type_bytes_and_dims(self.type_str)[0]

    @property
    def dims(self) -> list[int]:
        return _type_bytes_and_dims(self.type_str)[1]


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict = dataclasses.field(default_factory=dict)     # name -> OpInfo
    order: list = dataclasses.field(default_factory=list)


_REF_RE = re.compile(
    r"(?:body|condition|to_apply|calls|true_computation|false_computation)"
    r"=\{?%?([\w\.\-]+)"
)


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    referenced: set[str] = set()
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if raw.lstrip().startswith("ENTRY"):
                entry = cur.name
            # parameters declared in the header
            for pname, ptype in _PARAM_RE.findall(hdr.group(2)):
                op = OpInfo(pname, ptype, "parameter", line)
                cur.ops[pname] = op
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        referenced.update(_REF_RE.findall(line))
        m = _OP_RE.match(line)
        if m:
            name, type_str, opcode = m.groups()
            op = OpInfo(name, type_str, opcode, line)
            cur.ops[name] = op
            cur.order.append(name)
    if entry is None:
        # CPU scheduled modules carry no ENTRY marker: the entry is the
        # computation nothing else references (prefer one containing whiles).
        unref = [n for n in comps if n not in referenced]
        with_while = [
            n for n in unref
            if any(o.opcode == "while" for o in comps[n].ops.values())
        ]
        pool = with_while or unref or list(comps)
        if pool:
            entry = max(pool, key=lambda n: len(comps[n].order))
    return comps, entry


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)

    def add_collective(self, kind: str, b: float, mult: float):
        self.collective_bytes += b * mult
        self.collective_detail[kind] = self.collective_detail.get(kind, 0.0) + b * mult
        self.collective_counts[kind] = self.collective_counts.get(kind, 0) + mult


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    out = op.dims
    out_n = 1
    for d in out:
        out_n *= d
    cm = _CONTRACT_RE.search(op.line)
    contract = 1
    if cm:
        # operand list: first two %refs after the opcode's '('
        call = op.line.split(op.opcode + "(", 1)[1]
        refs = _OPERAND_RE.findall(call.split(")")[0])
        if refs:
            lhs = comp.ops.get(refs[0])
            if lhs is not None:
                ldims = lhs.dims
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(ldims):
                        contract *= ldims[int(idx)]
    return 2.0 * out_n * contract


def _collective_bytes(op: OpInfo) -> tuple[str, float]:
    kind = op.opcode.replace("-start", "")
    size = op.bytes
    m = _GROUPS_V2_RE.search(op.line)
    if m:
        n = int(m.group(2))
    else:
        m2 = _GROUPS_RE.search(op.line)
        n = len([t for t in m2.group(1).split(",") if t.strip()]) if m2 else 2
    n = max(2, n)
    frac = (n - 1) / n
    if kind == "all-reduce":
        return kind, 2 * frac * size
    if kind == "all-gather":
        return kind, frac * size
    if kind == "reduce-scatter":
        return kind, frac * size * n
    if kind == "all-to-all":
        return kind, frac * size
    return kind, float(size)


def analyze(text: str) -> HloCosts:
    comps, entry = parse_module(text)
    costs = HloCosts()
    if entry is None:
        return costs

    def trip_count(cond_name: str) -> float:
        vals = [
            int(v)
            for op in comps.get(cond_name, Computation("")).ops.values()
            for v in _CONST_RE.findall(op.line)
        ]
        return float(max(vals)) if vals else 1.0

    stack: list[str] = []

    def buffer_bytes(o: OpInfo, trips: float) -> float:
        """Bytes an op touches for one buffer, scan-stash aware.

        Inside a while body with trip count T, a buffer whose LEADING dim
        equals T is a scan xs/ys/stash: each iteration touches exactly the
        1/T slice (XLA aliases the dynamic-update-slice in place), so charge
        bytes/T instead of the full array.
        """
        b = float(o.bytes)
        dims = o.dims
        if trips > 1 and dims and float(dims[0]) == trips:
            return b / trips
        return b

    def op_traffic(op: OpInfo, comp: Computation, trips: float) -> float:
        total = buffer_bytes(op, trips)
        call = op.line.split(op.opcode + "(", 1)
        if len(call) < 2:
            return total
        refs = _OPERAND_RE.findall(call[1].split(")")[0])
        for r in refs:
            o = comp.ops.get(r)
            if o is not None and o.opcode not in ("constant",):
                total += buffer_bytes(o, trips)
        return total

    def walk(name: str, mult: float, trips_here: float):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack.append(name)
        for opname in comp.order:
            op = comp.ops[opname]
            code = op.opcode
            if code in _COLLECTIVES and not code.endswith("-done"):
                kind, b = _collective_bytes(op)
                costs.add_collective(kind, b, mult)
                costs.bytes += mult * op_traffic(op, comp, trips_here)
            elif code == "dot":
                costs.flops += mult * _dot_flops(op, comp)
                costs.bytes += mult * op_traffic(op, comp, trips_here)
            elif code == "while":
                body = _BODY_RE.search(op.line)
                cond = _COND_RE.search(op.line)
                trips = trip_count(cond.group(1)) if cond else 1.0
                if body:
                    walk(body.group(1), mult * trips, trips)
            elif code in ("call", "conditional", "custom-call"):
                for mm in _TO_APPLY_RE.finditer(op.line):
                    walk(mm.group(1), mult, trips_here)
                for key in ("true_computation", "false_computation"):
                    mm = re.search(key + r"=%?([\w\.\-]+)", op.line)
                    if mm:
                        walk(mm.group(1), mult, trips_here)
                mm = re.search(r"branch_computations=\{([^}]*)\}", op.line)
                if mm:
                    for nm in _OPERAND_RE.findall(mm.group(1)):
                        walk(nm, mult, trips_here)
            elif code in _FREE_OPS:
                continue
            else:
                # fusion / copy / convert / reduce / scatter / dus / etc.
                costs.bytes += mult * op_traffic(op, comp, trips_here)
        stack.pop()

    walk(entry, 1.0, 1.0)
    return costs
