"""TPU v5e hardware constants (per chip) used by the roofline analysis."""

PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_LINK_BW = 50e9             # bytes/s per link (≈ per-chip injection for
                               # ring collectives on one axis)
HBM_BYTES = 16 * 2 ** 30       # 16 GiB capacity
