"""Roofline probes for compiled dataplane executables.

Bridges the dormant HLO cost analyzer (``roofline.hlo``) into the
dataplane: AOT-lower the *exact* jitted function a stream/fleet run
dispatches, analyze its HLO for per-dispatch FLOPs and bytes, and turn
the TPU v5e roofline (``roofline.hw``) into a **packets-per-second upper
bound** — so "fast as the hardware allows" is a number next to every
measured rate.

A BNN dataplane executable has essentially no dot FLOPs (XNOR +
popcount lowers to elementwise integer ops), so MFU is meaningless here;
the honest hardware ceiling is the *memory* roofline:

    roofline_pps = packets_per_dispatch / max(bytes / HBM_BW,
                                              flops / PEAK_FLOPS,
                                              collective_bytes / ICI_BW)

and ``fraction = measured_pps / roofline_pps`` is the utilization number
the CI gate tracks (``dataplane_packed_roofline_frac``).

Probes are cached per (fingerprint, path, shape): lowering + HLO analysis
costs milliseconds but not nothing, and the executor hooks run it at most
once per compiled executable — in the warmup window, never on the steady
hot path, and only when ``repro.obs`` is enabled (``record`` is the
fail-soft entry point the executor/fleet/serving hooks call).

Everything JAX-facing is imported lazily so this module stays importable
(and the analyzer usable on saved HLO text) without touching the
dataplane, and so ``repro.dataplane`` can import it without a cycle.
"""
from __future__ import annotations

import dataclasses
import math

from repro.roofline import hw
from repro.roofline.hlo import HloCosts, analyze

__all__ = [
    "DataplaneRoofline",
    "probe_fleet",
    "probe_stream",
    "record",
]

_CACHE: dict[tuple, "DataplaneRoofline"] = {}


@dataclasses.dataclass(frozen=True)
class DataplaneRoofline:
    """HLO costs + roofline bound for one compiled dataplane executable."""

    path: str            # e.g. "packed", "jnp", "packed+scan", "fleet64:packed"
    fingerprint: str     # LoweredProgram.fingerprint()
    chunk: int           # packets per stream per dispatch
    streams: int         # 1 for a single stream; N for a vmapped fleet
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float

    @property
    def packets(self) -> int:
        """Packets per compiled dispatch."""
        return self.chunk * self.streams

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / hw.ICI_LINK_BW

    @property
    def step_time_s(self) -> float:
        """Roofline dispatch time (perfect overlap of the three engines)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def roofline_pps(self) -> float:
        """Hardware packets/s ceiling for this executable."""
        t = self.step_time_s
        return self.packets / t if t > 0 else math.inf

    @property
    def bytes_per_packet(self) -> float:
        return self.hlo_bytes / self.packets if self.packets else 0.0

    def fraction(self, measured_pps: float) -> float:
        """measured / roofline — the utilization number the gate tracks."""
        bound = self.roofline_pps
        if not (measured_pps > 0) or not math.isfinite(bound) or bound <= 0:
            return 0.0
        return measured_pps / bound


def _build(key: tuple, path: str, lowered, chunk: int, streams: int,
           costs: HloCosts) -> "DataplaneRoofline":
    rf = DataplaneRoofline(
        path=path,
        fingerprint=lowered.fingerprint(),
        chunk=chunk,
        streams=streams,
        hlo_flops=costs.flops,
        hlo_bytes=costs.bytes,
        collective_bytes=costs.collective_bytes,
    )
    _CACHE[key] = rf
    return rf


def probe_stream(
    lowered,
    *,
    backend: str,
    chunk: int,
    interpret: bool | None = None,
    scan_hops: bool = False,
) -> DataplaneRoofline:
    """Roofline for one ``executor._run_chunk`` dispatch at ``chunk``
    packets — the executable ``execute`` / ``execute_stream`` runs.

    Wraps the whole chunk path (parse -> hop -> deparse, which on the
    op-table backends is a *composition* of jitted pieces) in one jit and
    AOT-lowers it, so the analyzed HLO is the fused dispatch, not a part.
    """
    path = backend + ("+scan" if scan_hops else "")
    key = (lowered.fingerprint(), path, chunk, 1, interpret)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    import jax
    import jax.numpy as jnp

    from repro.dataplane import executor as _executor

    fn = jax.jit(
        lambda p: _executor._run_chunk(
            lowered, p, backend, interpret, scan_hops
        )
    )
    spec = jax.ShapeDtypeStruct((chunk, lowered.input_bits), jnp.int32)
    costs = analyze(fn.lower(spec).compile().as_text())
    return _build(key, path, lowered, chunk, 1, costs)


def probe_fleet(
    lowered,
    *,
    backend: str,
    streams: int,
    chunk: int,
    interpret: bool | None = None,
    scan_hops: bool = False,
    devices: int | None = None,
) -> DataplaneRoofline:
    """Roofline for one vmapped fleet dispatch: ``streams`` streams of
    ``chunk`` packets through ``fleet.fleet_fn``'s compiled executable."""
    path = f"fleet{streams}:{backend}" + ("+scan" if scan_hops else "")
    key = (lowered.fingerprint(), path, chunk, streams, interpret, devices)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    import jax
    import jax.numpy as jnp

    from repro.dataplane import fleet as _fleet

    fn = _fleet.fleet_fn(
        lowered,
        backend=backend,
        interpret=interpret,
        scan_hops=scan_hops,
        devices=devices,
    )
    spec = jax.ShapeDtypeStruct((streams, chunk, lowered.input_bits), jnp.int32)
    costs = analyze(fn.lower(spec).compile().as_text())
    return _build(key, path, lowered, chunk, streams, costs)


def record(rf: DataplaneRoofline, measured_pps: float | None = None) -> None:
    """Publish a probe's costs (and utilization, when a measured rate is
    known) as ``roofline.*`` gauges in the global obs registry.

    The hook the executor/fleet/serving paths call from their warmup
    windows; a no-op when observability is off, so the disabled hot path
    stays untouched.
    """
    from repro import obs

    if not obs.enabled():
        return
    m = obs.registry()
    m.gauge("roofline.hlo_bytes", path=rf.path).set(rf.hlo_bytes)
    m.gauge("roofline.hlo_flops", path=rf.path).set(rf.hlo_flops)
    m.gauge("roofline.bytes_per_packet", path=rf.path).set(rf.bytes_per_packet)
    m.gauge("roofline.pps_bound", path=rf.path).set(rf.roofline_pps)
    if measured_pps is not None and measured_pps > 0:
        m.gauge("roofline.fraction", path=rf.path).set(rf.fraction(measured_pps))
