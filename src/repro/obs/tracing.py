"""Span tracer: nested timing contexts exporting to Chrome Trace Event JSON.

Spans are context managers that nest — the dataplane's hot-path hierarchy
is ``stream > chunk > hop > execute`` — and every finished span records its
name, category, wall-clock interval, nesting depth, and free-form args.
The category is the phase axis the ROADMAP's 10,000x-gap work needs:
instrumented code opens ``cat="compile"`` spans around jit warm-up and
``cat="execute"`` spans around steady-state dispatch, so a trace decomposes
end-to-end time into named phases instead of one opaque wall-time number.

Export is the Chrome Trace Event format (one ``"X"`` complete event per
span, microsecond timestamps) — load the JSON in ``chrome://tracing`` or
Perfetto and the span nesting renders as a flame graph per thread.

Invariants:

* **Observation only** — entering/leaving a span never affects the traced
  code; exceptions propagate untouched (the span still records).
* **Well nested per thread** — spans track a per-thread stack, so depths
  and parent names are consistent even with the tracer shared across
  threads.
* **Monotonic clock** — all intervals come from ``time.perf_counter``
  against a per-tracer epoch; events are relative, not wall-dated.
"""
from __future__ import annotations

import dataclasses
import threading
import time

__all__ = [
    "Span",
    "SpanRecord",
    "Tracer",
]


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span."""

    name: str
    cat: str
    start: float          # seconds since tracer epoch
    duration: float       # seconds
    thread_id: int
    depth: int
    parent: str | None
    args: dict


class Span:
    """Context manager recording one timed interval into its tracer."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0", "_depth", "_parent")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0
        self._depth = 0
        self._parent: str | None = None

    def __enter__(self) -> "Span":
        stack = self._tracer._stack()
        self._depth = len(stack)
        self._parent = stack[-1] if stack else None
        stack.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        self._tracer._stack().pop()
        self._tracer._record(
            SpanRecord(
                name=self.name,
                cat=self.cat,
                start=self._t0 - self._tracer.epoch,
                duration=t1 - self._t0,
                thread_id=threading.get_ident(),
                depth=self._depth,
                parent=self._parent,
                args=self.args,
            )
        )
        return False


class Tracer:
    """Collects finished spans; export via :func:`chrome_trace_events`."""

    def __init__(self) -> None:
        self.epoch = time.perf_counter()
        self.records: list[SpanRecord] = []
        self._tls = threading.local()
        self._lock = threading.Lock()

    def _stack(self) -> list[str]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        return stack

    def _record(self, record: SpanRecord) -> None:
        with self._lock:
            self.records.append(record)

    def span(self, name: str, cat: str = "span", **args) -> Span:
        return Span(self, name, cat, args)

    def reset(self) -> None:
        with self._lock:
            self.records.clear()
            self.epoch = time.perf_counter()

    def total_by_category(self) -> dict[str, float]:
        """Summed span seconds per category (top-level spans of each
        category only: a span's time is not double-counted under a same-
        category ancestor)."""
        totals: dict[str, float] = {}
        # Build per-record ancestor-category sets by replaying depth order
        # per thread; records list preserves completion order, so recompute
        # from the records' (thread, depth, interval) structure instead:
        # a span is "top-level for its category" if no other span of the
        # same category on the same thread strictly contains it.
        by_thread: dict[int, list[SpanRecord]] = {}
        for r in self.records:
            by_thread.setdefault(r.thread_id, []).append(r)
        for recs in by_thread.values():
            for r in recs:
                contained = any(
                    o is not r
                    and o.cat == r.cat
                    and o.start <= r.start
                    and o.start + o.duration >= r.start + r.duration
                    and o.depth < r.depth
                    for o in recs
                )
                if not contained:
                    totals[r.cat] = totals.get(r.cat, 0.0) + r.duration
        return totals

    def chrome_trace_events(self) -> list[dict]:
        """Finished spans as Chrome Trace Event ``"X"`` (complete) events,
        microsecond units, ready for ``chrome://tracing`` / Perfetto."""
        tids = {}
        events = []
        for r in self.records:
            tid = tids.setdefault(r.thread_id, len(tids))
            events.append(
                {
                    "name": r.name,
                    "cat": r.cat or "span",
                    "ph": "X",
                    "ts": r.start * 1e6,
                    "dur": r.duration * 1e6,
                    "pid": 1,
                    "tid": tid,
                    "args": {
                        **{k: _jsonable(v) for k, v in r.args.items()},
                        "depth": r.depth,
                        **({"parent": r.parent} if r.parent else {}),
                    },
                }
            )
        events.sort(key=lambda e: e["ts"])
        return events


def _jsonable(value):
    if isinstance(value, (bool, int, float, str)) or value is None:
        return value
    return str(value)
