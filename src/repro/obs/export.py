"""Exporters: metrics JSONL, Prometheus-style text, Chrome Trace JSON.

Three on-disk formats, one source of truth (the live
:class:`~repro.obs.metrics.MetricsRegistry` and
:class:`~repro.obs.tracing.Tracer`):

* **metrics JSONL** (``*_metrics.jsonl``) — one JSON object per metric per
  line, the machine-readable artifact ``tools/obs_report.py`` renders and
  CI uploads.  Histogram lines carry count/sum/min/max/p50/p95/p99 plus the
  raw sparse buckets, so downstream tools can re-derive any quantile.
* **Prometheus text** (``*_metrics.prom``) — the text exposition format a
  scrape endpoint would serve: counters/gauges as single samples,
  histograms as summaries (``{quantile="..."}`` samples plus ``_count`` /
  ``_sum``).  Metric names are sanitized (dots -> underscores).
* **Chrome trace** (``*_trace.json``) — ``{"traceEvents": [...]}``, loadable
  in ``chrome://tracing`` / Perfetto (see ``repro.obs.tracing``).

``export_all`` writes all three under one directory with one prefix — the
single call the benchmark harness and examples use.
"""
from __future__ import annotations

import json
import os
import re

from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import Tracer

__all__ = [
    "export_all",
    "render_prometheus",
    "write_chrome_trace",
    "write_metrics_jsonl",
]

_PROM_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_QUANTILES = (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99"))


def write_metrics_jsonl(path: str, registry: MetricsRegistry) -> str:
    """One JSON object per metric per line; returns ``path``."""
    with open(path, "w") as fh:
        for row in registry.snapshot():
            fh.write(json.dumps(row, sort_keys=True) + "\n")
    return path


def _prom_escape(value: str) -> str:
    """Escape a label value per the Prometheus text exposition format:
    backslash, double-quote, and newline must be backslash-escaped."""
    return (
        str(value)
        .replace("\\", "\\\\")
        .replace('"', '\\"')
        .replace("\n", "\\n")
    )


def _prom_labels(labels: dict[str, str], extra: dict[str, str] | None = None) -> str:
    merged = {**labels, **(extra or {})}
    if not merged:
        return ""
    inner = ",".join(
        f'{k}="{_prom_escape(v)}"' for k, v in sorted(merged.items())
    )
    return "{" + inner + "}"


def render_prometheus(registry: MetricsRegistry) -> str:
    """The registry in Prometheus text exposition format."""
    lines: list[str] = []
    seen_types: set[str] = set()
    for row in registry.snapshot():
        name = _PROM_NAME_RE.sub("_", row["name"])
        labels = row["labels"]
        if row["type"] in ("counter", "gauge"):
            if name not in seen_types:
                lines.append(f"# TYPE {name} {row['type']}")
                seen_types.add(name)
            lines.append(f"{name}{_prom_labels(labels)} {row['value']:.10g}")
        else:
            if name not in seen_types:
                lines.append(f"# TYPE {name} summary")
                seen_types.add(name)
            for q, key in _QUANTILES:
                val = row[key]
                if val is not None:
                    lines.append(
                        f"{name}{_prom_labels(labels, {'quantile': q})} "
                        f"{val:.10g}"
                    )
            lines.append(f"{name}_count{_prom_labels(labels)} {row['count']}")
            lines.append(f"{name}_sum{_prom_labels(labels)} {row['sum']:.10g}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(path: str, registry: MetricsRegistry) -> str:
    with open(path, "w") as fh:
        fh.write(render_prometheus(registry))
    return path


def write_chrome_trace(path: str, tracer: Tracer) -> str:
    """Chrome Trace Event JSON (``chrome://tracing`` / Perfetto)."""
    payload = {
        "traceEvents": tracer.chrome_trace_events(),
        "displayTimeUnit": "ms",
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def export_all(
    out_dir: str,
    registry: MetricsRegistry,
    tracer: Tracer,
    *,
    prefix: str = "obs",
) -> dict[str, str]:
    """Write all three artifacts under ``out_dir``; returns their paths
    keyed ``{"metrics_jsonl", "metrics_prom", "trace"}``."""
    os.makedirs(out_dir, exist_ok=True)
    return {
        "metrics_jsonl": write_metrics_jsonl(
            os.path.join(out_dir, f"{prefix}_metrics.jsonl"), registry
        ),
        "metrics_prom": write_prometheus(
            os.path.join(out_dir, f"{prefix}_metrics.prom"), registry
        ),
        "trace": write_chrome_trace(
            os.path.join(out_dir, f"{prefix}_trace.json"), tracer
        ),
    }
