"""Per-tenant SLO specs, burn rates, and deterministic breach events.

The control-plane half of the performance observatory: a tenant (or a
whole fleet) declares targets — "p99 queue delay under 2ms", "at least
50k pps" — and a :class:`SloTracker` turns the live windowed metrics
(``repro.obs.windows``) into **burn rates** and **breach events** the
scheduler / serving layer can expose (``MultiTenantTelemetry``,
``FleetEngine.health()``) and eventually act on.

Burn rate follows the SRE convention: how fast the error budget is being
consumed, normalised so ``1.0`` means "spending budget exactly as fast as
the SLO allows" and anything above is a breach-in-progress.

* **queue delay** — the target is a p99, so the allowed bad fraction is
  ``budget_fraction`` (default 1%).  The tracker keeps an *exact* count of
  windowed observations over the target (a paired :class:`WindowedRate`,
  not a bucket estimate), and
  ``burn = (bad / total) / budget_fraction`` — e.g. 5% of packets over
  target burns at 5.0x.
* **throughput** — the target is a floor, so the bad fraction is the
  windowed shortfall ``max(0, 1 - pps / min_pps)`` and
  ``burn = shortfall / budget_fraction`` — e.g. running at half the floor
  burns at 50x.

Determinism: every observation and every :meth:`SloTracker.update` takes
an **explicit timestamp** (the ``windows`` contract), so given the same
observations and the same update times, the status and the full breach
event sequence are bit-identical — regardless of how the observations
were chunked between updates, and across process restarts that replay the
same time axis.  Breach events fire exactly on ok -> breaching
transitions per objective (and recovery re-arms them), so an event list
is a deterministic function of (observations, update times).

Burn rates are ``None`` until the first relevant observation arrives —
an idle tracker is "no data", not "breaching".
"""
from __future__ import annotations

import dataclasses

from repro.obs.windows import (
    DEFAULT_BUCKETS,
    WindowedHistogram,
    WindowedRate,
)

__all__ = [
    "BreachEvent",
    "SloSpec",
    "SloStatus",
    "SloTracker",
]

QUEUE_DELAY = "queue_delay"
THROUGHPUT = "throughput"


@dataclasses.dataclass(frozen=True)
class SloSpec:
    """A tenant's service-level objectives over a trailing window."""

    tenant: str
    p99_queue_delay_s: float | None = None  # delay target (p99, seconds)
    min_pps: float | None = None            # throughput floor (packets/s)
    window_s: float = 10.0                  # trailing window the SLO is judged over
    budget_fraction: float = 0.01           # allowed bad fraction (1% for a p99)

    def __post_init__(self) -> None:
        if self.p99_queue_delay_s is None and self.min_pps is None:
            raise ValueError(
                f"SLO for {self.tenant!r} needs at least one target "
                "(p99_queue_delay_s and/or min_pps)"
            )
        if self.p99_queue_delay_s is not None and self.p99_queue_delay_s <= 0:
            raise ValueError("p99_queue_delay_s must be > 0")
        if self.min_pps is not None and self.min_pps <= 0:
            raise ValueError("min_pps must be > 0")
        if self.window_s <= 0:
            raise ValueError("window_s must be > 0")
        if not 0.0 < self.budget_fraction <= 1.0:
            raise ValueError("budget_fraction must be in (0, 1]")


@dataclasses.dataclass(frozen=True)
class BreachEvent:
    """One ok -> breaching transition for one objective."""

    tenant: str
    objective: str           # QUEUE_DELAY | THROUGHPUT
    t: float                 # update timestamp the breach was detected at
    burn_rate: float         # budget burn at detection (> 1.0 by definition)
    value: float             # measured windowed value (p99 delay / pps)
    target: float            # the spec's target it crossed


@dataclasses.dataclass(frozen=True)
class SloStatus:
    """One tenant's SLO posture as of an explicit ``now``."""

    tenant: str
    now: float
    window_s: float
    p99_queue_delay_s: float | None      # measured, windowed
    delay_target_s: float | None
    delay_burn_rate: float | None        # None until first delay observation
    pps: float | None                    # measured, windowed
    min_pps: float | None
    pps_burn_rate: float | None          # None until first packet observation

    @property
    def breached(self) -> bool:
        return any(
            b is not None and b > 1.0
            for b in (self.delay_burn_rate, self.pps_burn_rate)
        )


class SloTracker:
    """Feed windowed observations, read burn rates, collect breach events.

    All methods take explicit timestamps; the tracker never reads a clock
    (see module docstring for the determinism contract this buys).
    """

    def __init__(self, spec: SloSpec, *, buckets: int = DEFAULT_BUCKETS):
        self.spec = spec
        self._delay = WindowedHistogram(spec.window_s, buckets=buckets)
        self._delay_total = WindowedRate(spec.window_s, buckets=buckets)
        self._delay_bad = WindowedRate(spec.window_s, buckets=buckets)
        self._packets = WindowedRate(spec.window_s, buckets=buckets)
        self._saw_delay = False
        self._saw_packets = False
        self._breaching: dict[str, bool] = {QUEUE_DELAY: False, THROUGHPUT: False}
        self.events: list[BreachEvent] = []

    # -- observations --------------------------------------------------------

    def observe_queue_delay(self, t: float, delay_s: float, count: int = 1) -> None:
        """``count`` packets experienced ``delay_s`` of queueing at time ``t``."""
        if count <= 0:
            return
        self._saw_delay = True
        self._delay.observe(t, delay_s, count)
        self._delay_total.add(t, count)
        if (
            self.spec.p99_queue_delay_s is not None
            and delay_s > self.spec.p99_queue_delay_s
        ):
            self._delay_bad.add(t, count)

    def observe_packets(self, t: float, count: float) -> None:
        """``count`` packets served at time ``t`` (feeds the windowed pps)."""
        if count <= 0:
            return
        self._saw_packets = True
        self._packets.add(t, count)

    # -- status / events -----------------------------------------------------

    def status(self, now: float) -> SloStatus:
        """The SLO posture over the trailing window ending at ``now``."""
        spec = self.spec
        delay_burn = None
        p99 = None
        if self._saw_delay:
            p99 = self._delay.p99(now)
            if spec.p99_queue_delay_s is not None:
                total = self._delay_total.count(now)
                bad = self._delay_bad.count(now)
                frac = (bad / total) if total > 0 else 0.0
                delay_burn = frac / spec.budget_fraction
        pps = self._packets.rate(now) if self._saw_packets else None
        pps_burn = None
        if self._saw_packets and spec.min_pps is not None:
            shortfall = max(0.0, 1.0 - pps / spec.min_pps)
            pps_burn = shortfall / spec.budget_fraction
        return SloStatus(
            tenant=spec.tenant,
            now=now,
            window_s=spec.window_s,
            p99_queue_delay_s=p99,
            delay_target_s=spec.p99_queue_delay_s,
            delay_burn_rate=delay_burn,
            pps=pps,
            min_pps=spec.min_pps,
            pps_burn_rate=pps_burn,
        )

    def update(self, now: float) -> list[BreachEvent]:
        """Evaluate both objectives at ``now``; emit (and return) an event
        per objective that just transitioned ok -> breaching."""
        st = self.status(now)
        fresh: list[BreachEvent] = []
        checks = (
            (QUEUE_DELAY, st.delay_burn_rate, st.p99_queue_delay_s,
             self.spec.p99_queue_delay_s),
            (THROUGHPUT, st.pps_burn_rate, st.pps, self.spec.min_pps),
        )
        for objective, burn, value, target in checks:
            breaching = burn is not None and burn > 1.0
            if breaching and not self._breaching[objective]:
                fresh.append(
                    BreachEvent(
                        tenant=self.spec.tenant,
                        objective=objective,
                        t=now,
                        burn_rate=burn,
                        value=value if value is not None else 0.0,
                        target=target if target is not None else 0.0,
                    )
                )
            self._breaching[objective] = breaching
        self.events.extend(fresh)
        return fresh
