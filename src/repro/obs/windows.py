"""Sliding time-windowed metrics: live rates and histograms.

The registry metrics in ``repro.obs.metrics`` are cumulative — perfect for
end-of-run export, useless for "what is the fleet doing *right now*".
This module adds the live view: a :class:`WindowedRate` answers "packets
per second over the last N seconds" and a :class:`WindowedHistogram`
answers "p99 queue delay over the last N seconds", both of which the SLO
layer (``repro.obs.slo``) and ``FleetEngine.health()`` build on.

Design — absolute bucket indexing:

Time is cut into fixed-width buckets of ``horizon / buckets`` seconds,
keyed by the *absolute* index ``floor(t / width)`` (not by slots relative
to "now").  An observation at time ``t`` lands in exactly one bucket
regardless of when it is delivered or what else has been observed, which
buys three properties at once:

* **Exact rotation** — a query at time ``now`` includes precisely the
  buckets whose start lies inside ``(now - horizon, now]``; there is no
  drift, no partial-bucket approximation at boundaries, and two queries at
  the same ``now`` always agree.
* **Associative merge** — merging is bucket-wise addition keyed by the
  same absolute indices, so ``merge`` is associative and commutative and
  equals having observed both streams into one window (the same contract
  ``metrics.Histogram.merge`` gives the cumulative histograms).
* **Determinism** — every method takes an **explicit timestamp**; the
  module never reads a clock.  Callers that want wall-clock behaviour pass
  ``time.perf_counter()``; callers that want reproducible behaviour (the
  SLO determinism tests, ``FleetEngine``'s injectable clock) pass their
  own time axis and get bit-identical windows back.

State is pruned to the most recent ``buckets`` indices ever observed —
pruning only drops buckets that can never enter a window anchored at or
after the newest observation, so it is invisible to queries (which are
anchored at ``now >= last observation`` in every sane use) and preserves
merge associativity (a bucket pruned early would be pruned by the final
merge's newer anchor anyway).  Memory is O(buckets), independent of
observation count.
"""
from __future__ import annotations

import math

from repro.obs.metrics import Histogram

__all__ = [
    "WindowedHistogram",
    "WindowedRate",
]

DEFAULT_HORIZON = 10.0
DEFAULT_BUCKETS = 10


def _check_window(horizon: float, buckets: int) -> float:
    if not (horizon > 0 and math.isfinite(horizon)):
        raise ValueError(f"horizon must be finite > 0, got {horizon}")
    if buckets < 1:
        raise ValueError(f"buckets must be >= 1, got {buckets}")
    return horizon / buckets


class WindowedRate:
    """Count of events in the trailing ``horizon`` seconds, as of a caller-
    supplied ``now`` — the live pps / arrivals-per-window primitive."""

    __slots__ = ("horizon", "buckets", "width", "_counts", "_max_idx")

    def __init__(
        self,
        horizon: float = DEFAULT_HORIZON,
        *,
        buckets: int = DEFAULT_BUCKETS,
    ) -> None:
        self.width = _check_window(horizon, buckets)
        self.horizon = float(horizon)
        self.buckets = int(buckets)
        self._counts: dict[int, float] = {}
        self._max_idx: int | None = None

    def _prune(self) -> None:
        if self._max_idx is None:
            return
        floor = self._max_idx - self.buckets
        if any(i <= floor for i in self._counts):
            self._counts = {
                i: c for i, c in self._counts.items() if i > floor
            }

    def add(self, t: float, count: float = 1.0) -> None:
        """Record ``count`` events at time ``t`` (explicit timestamp)."""
        if count <= 0:
            return
        idx = math.floor(t / self.width)
        self._counts[idx] = self._counts.get(idx, 0.0) + float(count)
        if self._max_idx is None or idx > self._max_idx:
            self._max_idx = idx
            self._prune()

    def count(self, now: float) -> float:
        """Events whose bucket starts inside ``(now - horizon, now]``."""
        lo = math.floor((now - self.horizon) / self.width)
        hi = math.floor(now / self.width)
        return sum(
            c for i, c in self._counts.items() if lo < i <= hi
        )

    def rate(self, now: float) -> float:
        """Events per second over the trailing window as of ``now``."""
        return self.count(now) / self.horizon

    def merge(self, other: "WindowedRate") -> None:
        """Fold ``other`` in (in place); windows must be congruent."""
        if (other.horizon, other.buckets) != (self.horizon, self.buckets):
            raise ValueError(
                f"cannot merge a {other.horizon}s/{other.buckets}-bucket "
                f"window into a {self.horizon}s/{self.buckets}-bucket one"
            )
        for idx, c in other._counts.items():
            self._counts[idx] = self._counts.get(idx, 0.0) + c
        if other._max_idx is not None and (
            self._max_idx is None or other._max_idx > self._max_idx
        ):
            self._max_idx = other._max_idx
        self._prune()


class WindowedHistogram:
    """A :class:`~repro.obs.metrics.Histogram` per time bucket, queried over
    the trailing window — live p50/p99 without keeping samples."""

    __slots__ = ("horizon", "buckets", "width", "_hists", "_max_idx")

    def __init__(
        self,
        horizon: float = DEFAULT_HORIZON,
        *,
        buckets: int = DEFAULT_BUCKETS,
    ) -> None:
        self.width = _check_window(horizon, buckets)
        self.horizon = float(horizon)
        self.buckets = int(buckets)
        self._hists: dict[int, Histogram] = {}
        self._max_idx: int | None = None

    def _prune(self) -> None:
        if self._max_idx is None:
            return
        floor = self._max_idx - self.buckets
        if any(i <= floor for i in self._hists):
            self._hists = {
                i: h for i, h in self._hists.items() if i > floor
            }

    def observe(self, t: float, value: float, count: int = 1) -> None:
        """Record ``count`` observations of ``value`` at time ``t``."""
        if count <= 0:
            return
        idx = math.floor(t / self.width)
        hist = self._hists.get(idx)
        if hist is None:
            hist = self._hists[idx] = Histogram()
        hist.observe(value, count)
        if self._max_idx is None or idx > self._max_idx:
            self._max_idx = idx
            self._prune()

    def window(self, now: float) -> Histogram:
        """The trailing window as one merged cumulative histogram."""
        lo = math.floor((now - self.horizon) / self.width)
        hi = math.floor(now / self.width)
        out = Histogram()
        for idx in sorted(self._hists):
            if lo < idx <= hi:
                out.merge(self._hists[idx])
        return out

    def count(self, now: float) -> int:
        return self.window(now).count

    def quantile(self, now: float, q: float) -> float | None:
        """Windowed ``q``-quantile as of ``now`` (``None`` if empty)."""
        return self.window(now).quantile(q)

    def p99(self, now: float) -> float | None:
        return self.quantile(now, 0.99)

    def merge(self, other: "WindowedHistogram") -> None:
        """Fold ``other`` in (in place); windows must be congruent."""
        if (other.horizon, other.buckets) != (self.horizon, self.buckets):
            raise ValueError(
                f"cannot merge a {other.horizon}s/{other.buckets}-bucket "
                f"window into a {self.horizon}s/{self.buckets}-bucket one"
            )
        for idx, h in other._hists.items():
            mine = self._hists.get(idx)
            if mine is None:
                mine = self._hists[idx] = Histogram()
            mine.merge(h)
        if other._max_idx is not None and (
            self._max_idx is None or other._max_idx > self._max_idx
        ):
            self._max_idx = other._max_idx
        self._prune()
