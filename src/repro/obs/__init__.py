"""``repro.obs`` — runtime observability for the dataplane hot path.

One global switch, plus two always-on live-view layers (``obs.windows``
sliding windows and ``obs.slo`` SLO burn-rate tracking — explicit-
timestamp, deterministic, owned by whoever instantiates them rather than
the global registry), and three switched capabilities:

* a **metrics registry** (``obs.metrics``): counters, gauges, and
  streaming histograms with p50/p95/p99 — packets/s, chunk latency,
  per-tenant queue delay, drops/defers, jit/table cache hits;
* a **span tracer** (``obs.tracing``): nested context-manager spans
  (``stream > chunk > hop > execute``) with explicit ``compile`` vs
  ``execute`` categories, exporting Chrome Trace Event JSON;
* **exporters** (``obs.export``): metrics JSONL, Prometheus-style text,
  chrome trace — rendered human-readable by ``tools/obs_report.py``.

Usage — instrumented code (the executor, fabric, scheduler, featurizer,
trainer) calls the module-level helpers, which are no-ops until
:func:`enable` flips the switch::

    from repro import obs

    with obs.span("execute:chunk", cat="execute", packets=n):
        ...hot work...
    if obs.enabled():
        obs.registry().counter("dataplane.packets_total").inc(n)

Operators (tests, benchmarks, examples, CI) turn it on around a run and
export::

    obs.enable(reset=True)
    ...traced run...
    paths = obs.export_all("obs_out")   # jsonl + prom + chrome trace

Invariants:

* **Disabled means no-op** — with the switch off, :func:`span` returns a
  shared null context manager and instrumented code skips all metric
  work; the instrumented paths are bit-exact with uninstrumented code in
  *both* states (observability never touches data), and the disabled-path
  overhead is bounded by test and benchmark (< 5%).
* **One global state** — helpers address a single process-wide registry +
  tracer pair, so instrumentation at any layer lands in one export.
  :func:`enable`'s ``reset=True`` starts a clean capture.
* **Import-light** — this package imports only stdlib + numpy; dataplane
  modules can instrument without import cycles.
"""
from __future__ import annotations

import os

from repro.obs import export as _export
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.slo import BreachEvent, SloSpec, SloStatus, SloTracker
from repro.obs.tracing import Span, SpanRecord, Tracer
from repro.obs.windows import WindowedHistogram, WindowedRate

__all__ = [
    "BreachEvent",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SloSpec",
    "SloStatus",
    "SloTracker",
    "Span",
    "SpanRecord",
    "Tracer",
    "WindowedHistogram",
    "WindowedRate",
    "disable",
    "enable",
    "enable_from_env",
    "enabled",
    "export_all",
    "registry",
    "reset",
    "span",
    "tracer",
]

OBS_ENV = "REPRO_OBS"           # truthy value enables at enable_from_env()
OBS_DIR_ENV = "REPRO_OBS_DIR"   # export directory for harnesses that honor it

_enabled = False
_registry = MetricsRegistry()
_tracer = Tracer()


class _NullSpan:
    """Shared do-nothing context manager — the disabled hot path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


def enabled() -> bool:
    """Is the global observability switch on?"""
    return _enabled


def enable(*, reset: bool = False) -> None:
    """Turn observability on (``reset=True`` starts a clean capture)."""
    global _enabled
    if reset:
        globals()["_registry"] = MetricsRegistry()
        _tracer.reset()
    _enabled = True


def disable() -> None:
    """Turn observability off (captured state is kept for export)."""
    global _enabled
    _enabled = False


def reset() -> None:
    """Drop all captured metrics and spans (switch state unchanged)."""
    globals()["_registry"] = MetricsRegistry()
    _tracer.reset()


def enable_from_env() -> bool:
    """Enable iff ``$REPRO_OBS`` is set truthy; returns the switch state.

    The hook harnesses use (``benchmarks/run.py``, CI) so a job can opt a
    whole run into tracing without code changes.
    """
    val = os.environ.get(OBS_ENV, "").strip().lower()
    if val not in ("", "0", "false", "no", "off"):
        enable()
    return _enabled


def registry() -> MetricsRegistry:
    """The process-wide metrics registry."""
    return _registry


def tracer() -> Tracer:
    """The process-wide span tracer."""
    return _tracer


def span(name: str, cat: str = "span", **args):
    """A timed span when enabled, a shared no-op otherwise."""
    if not _enabled:
        return _NULL_SPAN
    return _tracer.span(name, cat, **args)


def export_all(out_dir: str, *, prefix: str = "obs") -> dict[str, str]:
    """Write metrics JSONL + Prometheus text + chrome trace to ``out_dir``
    (see ``repro.obs.export.export_all``); returns the artifact paths."""
    return _export.export_all(out_dir, _registry, _tracer, prefix=prefix)
