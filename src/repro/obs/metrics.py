"""Runtime metrics: counters, gauges, and streaming histograms.

The dataplane's observability substrate (``repro.obs``).  Three metric
kinds, all cheap enough for per-chunk hot-path use and all label-aware so
one name can fan out per tenant / per hop / per scenario:

* :class:`Counter` — monotonically increasing float (packets served,
  drops, cache hits).
* :class:`Gauge`   — last-write-wins float (queue depth, loss, accuracy).
* :class:`Histogram` — streaming log-bucketed distribution with
  constant-memory percentile estimates (chunk latency, per-tenant queue
  delay, train-step time).

Histogram design: observations land in exponential buckets of width
``GROWTH = 2**(1/8)`` (8 buckets per octave), so any quantile estimate is
within ~4.4% relative error of the true sample quantile — while the state
is just a sparse ``{bucket_index: count}`` dict plus exact count/sum/
min/max.  Two histograms with the same growth merge by adding bucket
counts, which is what lets per-chunk or per-worker histograms roll up into
a run-level distribution without keeping samples.

Invariants:

* **Bounded memory** — a histogram never stores samples; state is O(number
  of distinct buckets touched), independent of observation count.
* **Exact extremes** — ``min``/``max``/``count``/``sum`` are exact;
  quantiles are clamped into ``[min, max]``, so a single-sample histogram
  reports that sample exactly at every quantile.
* **Mergeable** — ``merge`` is associative and commutative; merging equals
  having observed both streams into one histogram.
* **Observation only** — metrics never influence the code paths they
  measure (the ``repro.obs`` contract).
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Iterable

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]

# 8 buckets per octave: bucket edges grow by 2**(1/8) ~ 1.0905, so the
# geometric-midpoint estimate of any sample in a bucket is within
# sqrt(GROWTH) - 1 ~ 4.4% of its true value.
_GROWTH_LOG = math.log(2.0) / 8.0


def _bucket_index(value: float) -> int:
    return math.floor(math.log(value) / _GROWTH_LOG)


def _bucket_mid(index: int) -> float:
    return math.exp((index + 0.5) * _GROWTH_LOG)


class Counter:
    """Monotonic float counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up; got {amount}")
        self.value += amount


class Gauge:
    """Last-write-wins float gauge."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Streaming log-bucketed histogram with percentile estimates.

    Observations must be finite and non-negative; zeros are tracked in a
    dedicated bucket (queue delays and latencies can legitimately round to
    0.0).  ``quantile`` returns ``None`` on an empty histogram.
    """

    __slots__ = ("count", "total", "vmin", "vmax", "zero_count", "buckets")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.zero_count = 0
        self.buckets: dict[int, int] = {}

    def observe(self, value: float, count: int = 1) -> None:
        """Record ``count`` observations of ``value`` (weighted observe is
        how e.g. a chunk dispatch latency is attributed to every packet in
        the chunk without a per-packet loop)."""
        if count <= 0:
            return
        value = float(value)
        if not math.isfinite(value) or value < 0:
            raise ValueError(f"histogram values must be finite >= 0, got {value}")
        self.count += count
        self.total += value * count
        self.vmin = min(self.vmin, value)
        self.vmax = max(self.vmax, value)
        if value == 0.0:
            self.zero_count += count
        else:
            idx = _bucket_index(value)
            self.buckets[idx] = self.buckets.get(idx, 0) + count

    def observe_array(self, values: Iterable[float]) -> None:
        """Vectorized :meth:`observe` for a numpy array of values."""
        vals = np.asarray(values, np.float64).ravel()
        if vals.size == 0:
            return
        if not np.isfinite(vals).all() or (vals < 0).any():
            raise ValueError("histogram values must be finite >= 0")
        self.count += int(vals.size)
        self.total += float(vals.sum())
        self.vmin = min(self.vmin, float(vals.min()))
        self.vmax = max(self.vmax, float(vals.max()))
        zero = int((vals == 0.0).sum())
        self.zero_count += zero
        pos = vals[vals > 0.0]
        if pos.size:
            idx = np.floor(np.log(pos) / _GROWTH_LOG).astype(np.int64)
            uniq, cnt = np.unique(idx, return_counts=True)
            for i, c in zip(uniq.tolist(), cnt.tolist()):
                self.buckets[i] = self.buckets.get(i, 0) + c

    @property
    def mean(self) -> float | None:
        return self.total / self.count if self.count else None

    def quantile(self, q: float) -> float | None:
        """Estimated ``q``-quantile (``0 <= q <= 1``); ``None`` if empty.

        Walks buckets in value order to the bucket containing the target
        rank and returns its geometric midpoint, clamped to the exact
        ``[min, max]`` — so single-sample (and single-bucket-extreme)
        histograms are exact.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return None
        rank = max(1, math.ceil(q * self.count))
        seen = self.zero_count
        if rank <= seen:
            return 0.0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if rank <= seen:
                return min(max(_bucket_mid(idx), self.vmin), self.vmax)
        return self.vmax  # pragma: no cover - rank <= count by construction

    @property
    def p50(self) -> float | None:
        return self.quantile(0.50)

    @property
    def p95(self) -> float | None:
        return self.quantile(0.95)

    @property
    def p99(self) -> float | None:
        return self.quantile(0.99)

    def merge(self, other: "Histogram") -> None:
        """Fold ``other``'s state into this histogram (in place)."""
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        self.zero_count += other.zero_count
        for idx, c in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + c


@dataclasses.dataclass(frozen=True)
class _Key:
    name: str
    labels: tuple[tuple[str, str], ...]


def _key(name: str, labels: dict[str, str]) -> _Key:
    return _Key(name, tuple(sorted((k, str(v)) for k, v in labels.items())))


class MetricsRegistry:
    """Get-or-create store of labeled metrics.

    ``registry.counter("mt.dropped_total", tenant="t0")`` returns the same
    :class:`Counter` on every call with the same name+labels; a name is
    bound to exactly one metric kind (mixing kinds raises).  ``snapshot``
    serializes everything for the exporters in ``repro.obs.export``.
    """

    def __init__(self) -> None:
        self._metrics: dict[_Key, object] = {}
        self._kinds: dict[str, type] = {}
        self._lock = threading.Lock()

    def _get(self, cls: type, name: str, labels: dict[str, str]):
        key = _key(name, labels)
        metric = self._metrics.get(key)
        if metric is None:
            with self._lock:
                metric = self._metrics.get(key)
                if metric is None:
                    bound = self._kinds.setdefault(name, cls)
                    if bound is not cls:
                        raise TypeError(
                            f"metric {name!r} is a {bound.__name__}, "
                            f"requested as {cls.__name__}"
                        )
                    metric = cls()
                    self._metrics[key] = metric
        if type(metric) is not cls:
            raise TypeError(
                f"metric {name!r} is a {type(metric).__name__}, "
                f"requested as {cls.__name__}"
            )
        return metric

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: str) -> Histogram:
        return self._get(Histogram, name, labels)

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> list[dict]:
        """Every metric as a JSON-ready dict (sorted by name, labels)."""
        out = []
        for key in sorted(
            self._metrics, key=lambda k: (k.name, k.labels)
        ):
            metric = self._metrics[key]
            row: dict = {"name": key.name, "labels": dict(key.labels)}
            if isinstance(metric, Counter):
                row["type"] = "counter"
                row["value"] = metric.value
            elif isinstance(metric, Gauge):
                row["type"] = "gauge"
                row["value"] = metric.value
            else:
                assert isinstance(metric, Histogram)
                row["type"] = "histogram"
                row["count"] = metric.count
                row["sum"] = metric.total
                row["min"] = metric.vmin if metric.count else None
                row["max"] = metric.vmax if metric.count else None
                row["mean"] = metric.mean
                row["p50"] = metric.p50
                row["p95"] = metric.p95
                row["p99"] = metric.p99
                row["zero_count"] = metric.zero_count
                row["buckets"] = {
                    str(i): c for i, c in sorted(metric.buckets.items())
                }
            out.append(row)
        return out
