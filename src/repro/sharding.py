"""Sharding rules: param/cache/batch pytrees -> PartitionSpecs.

Mesh axes: ``(pod, data, model)`` multi-pod or ``(data, model)`` single-pod.
  * batch dims shard over (pod, data) — pure DP across pods;
  * projection output/input dims shard over ``model`` (TP); MoE experts shard
    over ``model`` (EP); vocab shards over ``model``;
  * with ``cfg.fsdp`` the *other* weight dim additionally shards over
    ``data`` (ZeRO-3; GSPMD inserts the per-layer all-gathers).

Rules are matched on the param-tree path *suffix*; stacked leading dims
(scan-over-layers) are absorbed automatically (a rule shorter than the leaf
rank is left-padded with ``None``).  Any rule axis whose dimension is not
divisible by the mesh axis size is dropped (replicated) — recorded by
``explain()`` so the dry-run log shows what didn't shard.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# (path-suffix regex, base spec, fsdp spec) — first match wins.
# embed/table falls back to d_model sharding when the vocab doesn't divide
# (see param_specs) so odd vocabs (50280, 73448, 92553, ...) never replicate
# a multi-hundred-MB table.
_PARAM_RULES: list[tuple[str, tuple, tuple]] = [
    (r"embed/table$",            ("model", None),        ("model", "data")),
    (r"moe/router/w$",           ("model", None),        ("model", None)),
    (r"(wq|wk|wv|wq_a|wq_b|wkv_a|wkv_b)/w$", ("model", None), ("model", "data")),
    (r"wo/w$",                   (None, "model"),        ("data", "model")),
    # packed BNN weights: out-channel dim over model (Kw packing keeps the
    # contraction dim word-aligned, so it stays unsharded)
    (r"w_packed$",               ("model", None),        ("model", None)),
    (r"/alpha$",                 ("model",),             ("model",)),
    (r"(w_gate|w_up|w_in)/w$",   ("model", None),        ("model", "data")),
    (r"(w_down|w_out)/w$",       (None, "model"),        ("data", "model")),
    (r"moe/w_(gate|up|down)/packed$", ("model", None, None), ("model", None, None)),
    (r"moe/w_(gate|up|down)/alpha$",  ("model", None),       ("model", None)),
    (r"moe/(w_gate|w_up)$",      ("model", None, None),  ("model", None, "data")),
    (r"moe/w_down$",             ("model", None, None),  ("model", "data", None)),
    (r"(z_proj|x_proj|dt_proj)/w$", ("model", None),     ("model", "data")),
    (r"bc_proj/w$",              (None, None),           (None, "data")),
    (r"out_proj/w$",             (None, "model"),        ("data", "model")),
    (r"conv_x_[wb]$",            None,                   None),  # last-dim model
    (r"conv_bc_[wb]$",           None,                   None),
    (r"(A_log|D|dt_bias)$",      ("model",),             ("model",)),
    (r"mamba/norm/scale$",       ("model",),             ("model",)),
    (r".*scale$",                (None,),                (None,)),
    (r".*bias$",                 (None,),                (None,)),
]

_CONV_RULES = {
    "conv_x_w": (None, "model"),
    "conv_x_b": ("model",),
    "conv_bc_w": (None, None),
    "conv_bc_b": (None,),
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def _fit_spec(base: tuple, shape: tuple, mesh: Mesh, log: list, path: str) -> P:
    """Left-pad for stacked dims; drop non-divisible axes."""
    pad = len(shape) - len(base)
    if pad < 0:
        base = base[-len(shape):] if len(shape) else ()
        pad = 0
    spec = [None] * pad + list(base)
    for i, ax in enumerate(spec):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        if any(a not in mesh.axis_names for a in axes):
            spec[i] = None
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if shape[i] % size != 0:
            log.append(f"{path}: dim {i} ({shape[i]}) % {ax}({size}) != 0 -> replicated")
            spec[i] = None
    return P(*spec)


def param_specs(
    cfg: ModelConfig, params: Any, mesh: Mesh, *, log: Optional[list] = None
) -> Any:
    """PartitionSpec tree matching a parameter pytree (arrays or SDS)."""
    log = log if log is not None else []

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if ps.endswith("embed/table"):
            # vocab over model; odd vocabs REPLICATE (measured: d_model-
            # sharding the table turns the unembed into a TP matmul whose
            # (B,S,V) f32 partial-sum all-reduce costs far more than the
            # 200-400MB of replicated table; uneven vocab sharding is
            # rejected by pjit input shardings).
            return _fit_spec(
                ("model", "data") if cfg.fsdp and "data" in mesh.axis_names
                else ("model", None),
                shape, mesh, log, ps,
            )
        for name, rule in _CONV_RULES.items():
            if ps.endswith(name):
                return _fit_spec(rule, shape, mesh, log, ps)
        for pat, base, fsdp in _PARAM_RULES:
            if base is None:
                continue
            if re.search(pat, ps):
                rule = fsdp if cfg.fsdp else base
                # FSDP needs the data axis present
                if cfg.fsdp and "data" not in mesh.axis_names:
                    rule = base
                return _fit_spec(rule, shape, mesh, log, ps)
        log.append(f"{ps}: no rule -> replicated")
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_if_divisible(mesh: Mesh, n: int):
    axes = dp_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return axes if size > 1 and n % size == 0 else None


def batch_specs(cfg: ModelConfig, batch: Any, mesh: Mesh) -> Any:
    """Input batch specs: leading batch dim over (pod, data) when divisible."""

    def leaf_spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        lead = _dp_if_divisible(mesh, b)
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(leaf_spec, batch)


def cache_specs(cfg: ModelConfig, cache: Any, mesh: Mesh) -> Any:
    """Decode-cache specs.

    Layer-stacked arrays (L, B, S, ...): batch over (pod,data) when divisible,
    else the *sequence* axis shards over data (long-context, batch=1); heads /
    latent dims over model when divisible.
    """
    model_ok = "model" in mesh.axis_names

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if leaf.ndim == 0:  # index scalar
            return P()
        spec = [None] * leaf.ndim
        lead = _dp_if_divisible(mesh, shape[1]) if leaf.ndim > 1 else None
        if leaf.ndim > 1:
            spec[1] = lead
        if re.search(r"(^|/)(k|v)$", ps) and leaf.ndim == 5:
            # (L, B, S, KVH, D): prefer KVH over model; fall back to S over
            # model when KV heads don't divide (extreme GQA: kv=2..8 vs 16
            # model shards would otherwise replicate a 100s-of-GiB cache).
            if lead is None and shape[2] % mesh.shape.get("data", 1) == 0:
                spec[2] = "data"
            if model_ok and shape[3] % mesh.shape["model"] == 0:
                spec[3] = "model"
            elif model_ok and spec[2] is None and shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"
        elif re.search(r"c_kv$|k_rope$", ps) and leaf.ndim == 4:
            # (L, B, S, R): MLA latents have no head axis, so decode's
            # natural parallelism is SEQUENCE over model — scores and the
            # softmax partials stay shard-local (tiny psum of (B,H,1,R)
            # outputs) instead of rank-sharded scores that all-reduce a
            # (B,H,1,S) tensor per layer.
            if lead is None and shape[2] % mesh.shape.get("data", 1) == 0:
                spec[2] = "data"
            if model_ok and spec[2] is None and shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"
            elif model_ok and shape[3] % mesh.shape["model"] == 0:
                spec[3] = "model"
        elif re.search(r"(^|/)h$", ps) and leaf.ndim == 5:
            # SSD state (L, B, H, N, P)
            if model_ok and shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"
        elif "conv_x" in ps and leaf.ndim == 4:
            if model_ok and shape[3] % mesh.shape["model"] == 0:
                spec[3] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def to_named(tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def activation_spec(mesh: Mesh, batch: int) -> P:
    """(B, S, d) activations: batch over (pod, data)."""
    return P(_dp_if_divisible(mesh, batch), None, None)


# ---------------------------------------------------------------------------
# Fleet sharding (dataplane): streams over a 1-D device mesh
# ---------------------------------------------------------------------------

# jax >= 0.6 promotes shard_map to jax.shard_map (check_vma=); older releases
# ship it as jax.experimental.shard_map.shard_map (check_rep=).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_NO_CHECK = {"check_vma": False}
else:  # pragma: no cover - exercised on older jax only
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_NO_CHECK = {"check_rep": False}


def fleet_mesh(num_devices: Optional[int] = None) -> Mesh:
    """1-D device mesh over a ``fleet`` axis for batched stream serving.

    The dataplane's fleet executor (``repro.dataplane.fleet``) vmaps one
    compiled program over the leading stream axis of ``(streams, chunk,
    bits)`` blocks; this mesh is what ``shard_streams`` splits that axis
    over, one group of simulated switches per device.  Defaults to every
    local device.
    """
    devices = jax.devices()
    n = len(devices) if num_devices is None else num_devices
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"need 1..{len(devices)} local devices, got {num_devices}"
        )
    return Mesh(np.asarray(devices[:n]), ("fleet",))


def shard_streams(fn, mesh: Mesh):
    """Wrap a ``(streams, ...) -> (streams, ...)`` batched function in
    ``shard_map`` over the ``fleet`` axis: each device independently runs
    ``fn`` on its local slice of streams (no collectives — streams never
    communicate, exactly like the independent switches they simulate)."""
    spec = P("fleet")
    return _shard_map(
        fn, mesh=mesh, in_specs=(spec,), out_specs=spec, **_SHARD_MAP_NO_CHECK
    )
