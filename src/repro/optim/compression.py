"""Error-feedback gradient compression for cross-pod all-reduce.

At 1000+ nodes the pod-crossing links are the scarcest resource; the standard
mitigation is compressed gradient exchange with error feedback (EF-SGD /
1-bit Adam lineage):

    e_{t}   : residual carried per leaf
    c_t     = C(g_t + e_t)           # compress
    e_{t+1} = (g_t + e_t) - D(c_t)   # new residual
    exchange c_t, apply D(c_t)

Compressors:
  * ``sign``  — 1-bit sign with per-leaf L1 scale (32x smaller);
  * ``int8``  — linear quantization with per-leaf absmax scale (4x);
  * ``topk``  — magnitude top-k% sparsification (k/100 x).

All pure functions over pytrees — unit-tested for the EF contract
(compression error is carried, long-run mean update is unbiased).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Compressor:
    kind: str = "sign"        # sign | int8 | topk | none
    topk_frac: float = 0.01

    def init_error(self, params: Any) -> Any:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress_decompress(self, grads: Any, error: Any):
        """-> (decompressed grads to exchange/apply, new error, bits ratio)."""
        if self.kind == "none":
            return grads, error, 1.0

        def leaf(g, e):
            gf = g.astype(jnp.float32) + e
            if self.kind == "sign":
                scale = jnp.mean(jnp.abs(gf))
                dec = jnp.sign(gf) * scale
            elif self.kind == "int8":
                amax = jnp.max(jnp.abs(gf)) + 1e-12
                q = jnp.clip(jnp.round(gf / amax * 127.0), -127, 127)
                dec = q * (amax / 127.0)
            elif self.kind == "topk":
                k = max(1, int(gf.size * self.topk_frac))
                flat = gf.reshape(-1)
                thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
                dec = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(gf.shape)
            else:
                raise ValueError(self.kind)
            return dec, gf - dec

        out = jax.tree.map(leaf, grads, error)
        is_tup = lambda x: isinstance(x, tuple)  # noqa: E731
        dec = jax.tree.map(lambda t: t[0], out, is_leaf=is_tup)
        err = jax.tree.map(lambda t: t[1], out, is_leaf=is_tup)
        ratio = {"sign": 1 / 32, "int8": 1 / 4, "topk": self.topk_frac}[self.kind]
        return dec, err, ratio
