"""AdamW in pure JAX (no optax), with bf16-param / f32-state discipline.

State layout per leaf: ``m`` and ``v`` in float32 plus an optional float32
master copy of the parameter when the parameter itself is stored in bf16
(mixed-precision training).  The state pytree mirrors the param tree, so the
parameter PartitionSpecs apply verbatim to every state leaf (sharded
optimizer states come for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # f32 master params (None leaves when param already f32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32   # bf16 moments fit the largest models
    use_master: bool = True           # f32 master copy of bf16 params

    def init(self, params: Any) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, self.moment_dtype), params
        )
        if self.use_master:
            master = jax.tree.map(
                lambda p: p.astype(jnp.float32) if p.dtype != jnp.float32 else p,
                params,
            )
        else:
            master = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros), master)

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads: Any, state: AdamWState, params: Any):
        """Returns (new_params, new_state, metrics)."""
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-12))
        step = state.step + 1
        lr = self._lr(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def leaf(g, m, v, master, p):
            g = g.astype(jnp.float32) * scale
            mf = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            vf = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * jnp.square(g)
            upd = (mf / b1c) / (jnp.sqrt(vf / b2c) + self.eps)
            ref = master if self.use_master else p.astype(jnp.float32)
            new_ref = ref - lr * (upd + self.weight_decay * ref)
            new_master = new_ref if self.use_master else master
            return (
                new_ref.astype(p.dtype),
                mf.astype(self.moment_dtype),
                vf.astype(self.moment_dtype),
                new_master,
            )

        is_tup = lambda x: isinstance(x, tuple)  # noqa: E731
        out = jax.tree.map(leaf, grads, state.m, state.v, state.master, params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_tup)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_tup)
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_tup)
        new_master = jax.tree.map(lambda t: t[3], out, is_leaf=is_tup)
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step, new_m, new_v, new_master), metrics


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return fn
