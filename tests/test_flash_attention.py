"""Flash-attention Pallas kernel vs the naive oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def naive(q, k, v, causal, scale):
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        S = q.shape[1]
        s = jnp.where(jnp.tril(jnp.ones((S, S), bool))[None], s, -jnp.inf)
    return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1).astype(q.dtype), v)


@pytest.mark.parametrize("shape", [(2, 128, 32), (4, 256, 64), (1, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_naive(shape, causal):
    bh, s, d = shape
    ks = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q = jax.random.normal(ks[0], shape, jnp.float32)
    k = jax.random.normal(ks[1], shape, jnp.float32)
    v = jax.random.normal(ks[2], shape, jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = naive(q, k, v, causal, 1.0 / (d ** 0.5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 128, 64), dtype)
    k = jax.random.normal(ks[1], (2, 128, 64), dtype)
    v = jax.random.normal(ks[2], (2, 128, 64), dtype)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = naive(q, k, v, True, 1.0 / 8.0)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )
    assert got.dtype == dtype


def test_block_mismatch_raises():
    q = jnp.zeros((1, 100, 32))
    with pytest.raises(ValueError, match="must divide"):
        flash_attention(q, q, q, block_q=64, block_k=64, interpret=True)


def test_model_forward_with_flash_impl(rng_key=None):
    """Whole-model equivalence: attn_impl='pallas_flash' == 'xla'."""
    import sys, os
    sys.path.insert(0, os.path.dirname(__file__))
    from conftest import make_batch, tiny_config
    from repro.models import forward, init_params

    key = jax.random.PRNGKey(0)
    cfg_x = tiny_config("phi3-mini-3.8b", num_layers=2, attn_q_chunk=16)
    cfg_f = tiny_config("phi3-mini-3.8b", num_layers=2, attn_impl="pallas_flash")
    params = init_params(cfg_x, key)
    batch = make_batch(cfg_x, 2, 32, key)
    lx, _ = forward(params, batch, cfg_x, remat=False)
    lf, _ = forward(params, batch, cfg_f, remat=False)
    np.testing.assert_allclose(
        np.asarray(lx), np.asarray(lf), rtol=3e-2, atol=3e-2
    )


def test_flash_gqa_repeat():
    """GQA (kvh < h) path through _flash_full matches naive."""
    from repro.layers.attention import _flash_full

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, h, kvh, d = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    got = _flash_full(q, k, v, causal=True, scale=0.25)
    kr = jnp.repeat(k, h // kvh, axis=2)
    vr = jnp.repeat(v, h // kvh, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * 0.25
    sc = jnp.where(jnp.tril(jnp.ones((s, s), bool))[None, None], sc, -jnp.inf)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
