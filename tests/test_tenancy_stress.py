"""Tenancy at fleet scale: 100+ tenants on one shared chip.

The interleaved merged layout is what makes this census possible — concat
would need the *sum* of every tenant's elements while interleave needs the
*deepest* tenant (plus a per-stage ALU budget for the widest shared stage).
This suite stresses the full contract at 120 tenants: deterministic
admission against the shared-stage budgets, per-tenant bit-exactness of
every served packet on the jnp and packed backends, conservation of the
tail-drop/deferral accounting under IAT-driven arrivals (capture-derived
inter-arrival times via an injectable clock), and bit-identical SLO
breach-event logs across identical runs.

Everything here is ``@pytest.mark.stress`` (deselected from tier-1 by
``pytest.ini``; CI runs it in the fuzz job with failure artifacts).
"""
import jax
import numpy as np
import pytest

from repro.core import bnn, compile_bnn
from repro.core.pipeline import ChipSpec
from repro.dataplane import (
    AdmissionError,
    SwitchScheduler,
    TenantTrafficSpec,
    execute,
    mixed_tenant_generate,
    mixed_tenant_stream,
    pcap,
)
from repro.dataplane.lowering import peak_stage_rows
from repro.obs.slo import SloSpec

pytestmark = pytest.mark.stress

NUM_TENANTS = 120
# Tiny mixed shapes: depth 1..2, widths crossing neither word boundary —
# the point is tenant *count*, not per-tenant size.
SHAPE_CYCLE = [(4, 2), (6, 4), (8, 4, 2), (5, 3, 2), (3, 5)]
SCENARIO_CYCLE = [
    "uniform_random",
    "iot_telemetry",
    "ddos_burst",
    "flow_tuple",
    "pcap:stress",
]
PCAP_SCENARIO = "pcap:stress"


class FakeClock:
    """Deterministic monotone clock: every call advances by ``step``."""

    def __init__(self, step: float = 0.25, start: float = 0.0):
        self.t = start
        self.step = step
        self.calls = 0

    def __call__(self) -> float:
        self.calls += 1
        self.t += self.step
        return self.t


class IatClock:
    """A clock that replays capture inter-arrival times, cyclically.

    Deterministic by construction (same timestamps -> same tick sequence),
    so two runs under two fresh ``IatClock``s over the same capture see
    identical arrival/serve timestamps.
    """

    def __init__(self, timestamps, scale: float = 1.0):
        iats = np.diff(np.asarray(timestamps, np.float64))
        iats = iats[iats > 0]
        self._iats = iats * scale if iats.size else np.array([1e-3])
        self.t = 0.0
        self.calls = 0

    def __call__(self) -> float:
        self.t += float(self._iats[self.calls % self._iats.size])
        self.calls += 1
        return self.t


def _compiled(sizes, seed):
    params = bnn.init_params(bnn.BnnSpec(tuple(sizes)), jax.random.PRNGKey(seed))
    return compile_bnn([np.asarray(w) for w in params])


@pytest.fixture(scope="module")
def census():
    """120 compiled tenants + traffic specs + a chip sized so interleave
    (and only interleave) fits them all, plus the capture whose IATs drive
    the arrival clock."""
    pkts, ts, _ = pcap.synthesize_capture(600, seed=11)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    pcap.register_pcap_scenario(PCAP_SCENARIO, cap, overwrite=True)

    programs = []
    specs = []
    for i in range(NUM_TENANTS):
        shape = SHAPE_CYCLE[i % len(SHAPE_CYCLE)]
        prog = _compiled(shape, seed=i)
        programs.append(prog)
        specs.append(
            TenantTrafficSpec(
                SCENARIO_CYCLE[i % len(SCENARIO_CYCLE)],
                prog.input_bits,
                1.0 + (i % 3),
            )
        )
    lowereds = [p.lower() for p in programs]
    peak = peak_stage_rows(lowereds)
    # PHV carries 2KiB of slack so an extra tenant is judged against the
    # *stage* budget (the interleave-specific one), not the PHV sum.
    chip = ChipSpec(
        num_elements=max(p.num_elements for p in programs) + 4,
        phv_bits=sum(p.peak_phv_bits for p in programs) + 2048,
        max_parallel_ops=peak + 8,
        name="stress-chip",
    )
    # The chip must be a genuine interleave-only regime: concat's element
    # sum cannot fit, interleave's max does.
    assert sum(p.num_elements for p in programs) > chip.num_elements
    return programs, specs, chip, ts


def _admit_all(census, **kw):
    programs, specs, chip, _ = census
    sched = SwitchScheduler(chip, **kw)
    for i, (prog, spec) in enumerate(zip(programs, specs)):
        sched.admit(prog, name=f"t{i}", weight=spec.weight)
    return sched


# -- admission at scale -------------------------------------------------------

def test_stress_admission_admits_120_and_rejects_hogs_deterministically(
    census,
):
    programs, _, chip, _ = census

    def build():
        sched = _admit_all(census, mode="merged")
        assert len(sched.tenants) == NUM_TENANTS
        # Hog 1: more elements than the whole chip -> per-program reject.
        hog_elems = _compiled((8, 8, 8, 8, 8, 8, 8, 8), seed=999)
        assert hog_elems.num_elements > chip.num_elements
        try:
            sched.admit(hog_elems, name="hog-elems")
        except AdmissionError as e:
            err_elems = str(e)
        else:
            raise AssertionError("element hog admitted")
        # Hog 2: fits the element budget but blows the widest shared
        # stage past max_parallel_ops -> interleave budget reject.
        hog_wide = _compiled((32, 24), seed=998)
        assert hog_wide.num_elements <= chip.num_elements
        try:
            sched.admit(hog_wide, name="hog-wide")
        except AdmissionError as e:
            err_wide = str(e)
        else:
            raise AssertionError("stage hog admitted")
        assert "parallel ops" in err_wide
        # Rejection never half-admits.
        assert len(sched.tenants) == NUM_TENANTS
        return err_elems, err_wide

    assert build() == build()  # bit-identical admit/reject decisions


# -- per-tenant bit-exactness of every served packet --------------------------

@pytest.mark.parametrize("backend", ["jnp", "packed"])
def test_stress_merged_interleave_bit_exact_120_tenants(census, backend):
    programs, specs, _, _ = census
    sched = _admit_all(census, mode="merged")
    n = 6000
    tids, bits = mixed_tenant_generate(specs, n, seed=13)
    res = sched.run(
        (tids, bits),
        mode="merged",
        backend=backend,
        chunk_size=1024,
        collect=True,
    )
    assert res.mode == "merged" and res.merged_layout == "interleave"
    assert res.packets == n
    served = 0
    for t, prog in enumerate(programs):
        mine = bits[tids == t][:, : prog.input_bits]
        st = res.stats_for(t)
        assert st.packets == st.served == mine.shape[0]
        assert st.dropped == 0
        want = execute(sched.tenants[t].lowered, mine, backend="jnp")
        np.testing.assert_array_equal(
            res.outputs_for(t),
            want,
            err_msg=f"tenant {t} diverges on backend {backend!r}",
        )
        served += st.served
    assert served == n


# -- IAT-driven time-slicing: conservation + determinism ----------------------

def _sliced_run(census, *, max_queue, quantum, n=4000):
    _, specs, _, ts = census
    sched = _admit_all(
        census,
        mode="time_sliced",
        clock=IatClock(ts, scale=4.0),
        max_queue=max_queue,
        quantum=quantum,
    )
    res = sched.run(
        mixed_tenant_stream(specs, n, chunk_size=1000, seed=21),
        mode="time_sliced",
    )
    return sched, res


def test_stress_time_sliced_iat_arrivals_conserve_and_drop(census):
    n = 4000
    _, res = _sliced_run(census, max_queue=16, quantum=8, n=n)
    assert res.packets == n
    # Small queues under bursty IAT arrivals must tail-drop somewhere,
    # and quantum 8 against 1000-packet bursts must defer.
    assert sum(st.dropped for st in res.tenants) > 0
    assert sum(st.deferred for st in res.tenants) > 0
    total_served = 0
    for st in res.tenants:
        assert st.packets == st.served + st.dropped  # per-tenant conservation
        total_served += st.served
    assert total_served + sum(st.dropped for st in res.tenants) == n


def test_stress_time_sliced_runs_are_bit_identical(census):
    _, res_a = _sliced_run(census, max_queue=16, quantum=8)
    _, res_b = _sliced_run(census, max_queue=16, quantum=8)
    for t in range(NUM_TENANTS):
        sa, sb = res_a.stats_for(t), res_b.stats_for(t)
        assert (sa.packets, sa.served, sa.dropped, sa.deferred) == (
            sb.packets, sb.served, sb.dropped, sb.deferred
        ), f"tenant {t} accounting diverges across identical runs"
        np.testing.assert_array_equal(
            res_a.outputs_for(t), res_b.outputs_for(t)
        )


# -- SLO breach events at scale -----------------------------------------------

def test_stress_slo_breach_events_deterministic(census):
    _, specs, _, _ = census

    def run():
        sched = _admit_all(census, clock=FakeClock(step=0.125), quantum=64)
        # Unreachable throughput floors on a spread of tenants: breaches
        # must fire, and fire identically, on every run.
        for t in (0, 17, 59, 118):
            sched.set_slo(SloSpec(f"t{t}", min_pps=1e12))
        sched.run(
            mixed_tenant_stream(specs, 3000, chunk_size=750, seed=5),
            mode="merged",
            chunk_size=1024,
        )
        return sched

    a, b = run(), run()
    for t in (0, 17, 59, 118):
        ev_a = a.slo_tracker(f"t{t}").events
        assert [e.objective for e in ev_a] == ["throughput"]
        assert ev_a == b.slo_tracker(f"t{t}").events
    tel_a, tel_b = a.telemetry(), b.telemetry()
    assert tel_a.breached_tenants == tel_b.breached_tenants
    assert set(tel_a.breached_tenants) == {"t0", "t17", "t59", "t118"}
