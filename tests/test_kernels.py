"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
interpret=True (CPU), plus STE gradient behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bitpack import bitpack
from repro.kernels.bnn_matmul import bnn_matmul_packed
from repro.kernels.bnn_matmul_mxu import bnn_matmul_mxu

SHAPES = [(8, 16, 64), (128, 128, 256), (37, 50, 100), (64, 96, 513), (4, 4, 32)]
IMPLS = ["ref", "packed_ref", "pallas_packed", "pallas_mxu"]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("impl", IMPLS)
def test_binary_matmul_impl_exact(shape, impl):
    m, n, k = shape
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + n * 3 + k))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (n, k), jnp.float32)
    want = ref.bnn_matmul_ref(x, w)
    got = ops.binary_matmul(x, w, implementation=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_binary_matmul_dtypes(dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (32, 64), dtype)
    w = jax.random.normal(kw, (16, 64), dtype)
    want = ref.bnn_matmul_ref(x, w)
    for impl in IMPLS:
        got = ops.binary_matmul(x, w, implementation=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_packed_kernel_direct_blocks():
    """Aligned case straight through pl.pallas_call (no padding wrapper)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    m, n, k = 128, 128, 1024
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (n, k))
    xp, _ = ops.pack_weights(x)
    wp, _ = ops.pack_weights(w)
    got = bnn_matmul_packed(
        xp, wp, k_bits=k, block_m=64, block_n=64, block_kw=8, interpret=True
    )
    want = ref.bnn_matmul_packed_ref(xp, wp, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mxu_kernel_direct_blocks():
    kx, kw = jax.random.split(jax.random.PRNGKey(2))
    m, n, k = 128, 128, 512
    x = jax.random.normal(kx, (m, k))
    w = jnp.where(jax.random.normal(kw, (k, n)) >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    got = bnn_matmul_mxu(
        x, w, block_m=64, block_n=64, block_k=128, interpret=True
    )
    want = ref.bnn_matmul_mxu_ref(x, w.T)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-2)


@pytest.mark.parametrize("shape", [(8, 64), (64, 256), (256, 512)])
def test_bitpack_kernel(shape):
    x = jax.random.normal(jax.random.PRNGKey(3), shape)
    got = bitpack(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.bitpack_ref(x)))


def test_ste_sign_gradient():
    v = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
    g = jax.grad(lambda x: (ops.ste_sign(x) * jnp.arange(5.0)).sum())(v)
    # pass-through inside |v|<=1, clipped outside
    np.testing.assert_allclose(np.asarray(g), [0.0, 1.0, 2.0, 3.0, 0.0])


def test_binary_dense_train_infer_parity():
    kx, kw = jax.random.split(jax.random.PRNGKey(4))
    x = jax.random.normal(kx, (8, 64))
    w = jax.random.normal(kw, (16, 64)) * 0.5
    for scale in ("weight_only", "xnor", "none"):
        yt = ops.binary_dense_train(x, w, scale=scale)
        yi = ops.binary_dense_infer(x, w, scale=scale, implementation="packed_ref")
        np.testing.assert_allclose(np.asarray(yt), np.asarray(yi), atol=1e-4)


def test_binary_dense_grads_flow():
    kx, kw = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(kx, (8, 64))
    w = jax.random.normal(kw, (16, 64)) * 0.5
    gx, gw = jax.grad(
        lambda xx, ww: ops.binary_dense_train(xx, ww, scale="xnor").sum(),
        argnums=(0, 1),
    )(x, w)
    assert bool(jnp.isfinite(gx).all()) and bool(jnp.isfinite(gw).all())
    assert float(jnp.abs(gw).sum()) > 0


def test_pack_weights_padding_correction():
    """K not a multiple of 32: zero pad bits must cancel exactly."""
    kx, kw = jax.random.split(jax.random.PRNGKey(6))
    for k in (33, 63, 100, 511):
        x = jax.random.normal(kx, (4, k))
        w = jax.random.normal(kw, (8, k))
        got = ops.binary_matmul(x, w, implementation="packed_ref")
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.bnn_matmul_ref(x, w)), atol=1e-5
        )
