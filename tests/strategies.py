"""Shared generators for the differential fuzz suite.

Random BNN programs (layer widths, SIGN thresholds, folding), random chunked
streams, and random :class:`ChipSpec` budgets, expressed against the small
strategy surface that both real ``hypothesis`` and ``tests/_hypothesis_stub``
provide (``integers`` / ``lists`` / ``sampled_from`` / ``map`` / ``flatmap``)
— so the suite runs shrunk-and-replayed under hypothesis when it is
installed and degrades gracefully to the seeded-random stub when it is not.

Cases are lightweight hashable descriptions (:class:`ProgramCase`); the
expensive compile/lower step is memoized in :func:`build_case` so the
per-backend test functions that draw identical cases share one build.

The ``FUZZ_EXAMPLES`` env var widens/narrows the example count (CI pins it);
``FUZZ_ARTIFACT_DIR`` makes :func:`artifact_on_failure` persist failing-case
reprs for upload.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import pathlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    from _hypothesis_stub import given, settings, strategies as st

    HAVE_HYPOTHESIS = False

from repro.core.compiler import compile_bnn
from repro.core.pipeline import ChipSpec

__all__ = [
    "BuiltProgram",
    "FUZZ_PCAP_SCENARIO",
    "HAVE_HYPOTHESIS",
    "HEAVY_EXAMPLES",
    "ProgramCase",
    "TenantMixCase",
    "artifact_on_failure",
    "build_case",
    "chip_specs",
    "fleet_plans",
    "given",
    "mix_traffic",
    "packets_for",
    "program_cases",
    "settings",
    "st",
    "stream_plans",
    "tenant_mixes",
]

MAX_WIDTH = 48  # keeps compiles fast while still crossing the 32-bit word
THRESHOLD_MODES = ("default", "scalar", "per_neuron")
_SEED_MAX = 2**31 - 1

# Cap for the expensive compound properties (streaming, multi-tenant): they
# re-compile per drawn shape, so they run fewer examples than the cheap
# single-program properties.  Widen with FUZZ_EXAMPLES_HEAVY.
HEAVY_EXAMPLES = min(
    int(os.environ.get("FUZZ_EXAMPLES", 5)),
    int(os.environ.get("FUZZ_EXAMPLES_HEAVY", 3)),
)


def _register_fuzz_profile() -> None:
    # Default is sized for the tier-1 run (every program shape drawn is a
    # fresh jit compile); the CI fuzz job pins FUZZ_EXAMPLES=200.
    examples = int(os.environ.get("FUZZ_EXAMPLES", 5))
    kwargs: dict = {"max_examples": examples}
    if HAVE_HYPOTHESIS:
        # Pinned, replayable CI runs: no wall-clock deadline flakes, no
        # example database coupling between runs, full repr on failure.
        kwargs.update(
            derandomize=True, deadline=None, database=None, print_blob=True
        )
    settings.register_profile("fuzz", **kwargs)
    settings.load_profile("fuzz")


_register_fuzz_profile()


# ---------------------------------------------------------------------------
# Cases + memoized builds
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProgramCase:
    """A random-BNN-program description: everything needed to rebuild the
    weights, thresholds, and compiled program deterministically."""

    layer_sizes: tuple[int, ...]
    weight_seed: int
    threshold_mode: str  # one of THRESHOLD_MODES
    threshold_seed: int


@dataclasses.dataclass(eq=False)
class BuiltProgram:
    """A compiled + lowered :class:`ProgramCase`."""

    case: ProgramCase
    params: list[np.ndarray]          # {0,1} (n_out, n_in) per layer
    thresholds: list | None           # None, or per-layer scalar/(n_out,)
    program: object                   # PipelineProgram
    lowered: object                   # LoweredProgram


@functools.lru_cache(maxsize=256)
def build_case(case: ProgramCase) -> BuiltProgram:
    """Weights/thresholds from the case seeds -> compiled + lowered program.

    Thresholds cover the full legal range ``[0, n_in + 1]`` — including the
    never-fire and always-fire edges — per layer, scalar or per-neuron.
    """
    rng = np.random.default_rng(case.weight_seed)
    sizes = case.layer_sizes
    params = [
        rng.integers(0, 2, (sizes[i + 1], sizes[i])).astype(np.int32)
        for i in range(len(sizes) - 1)
    ]
    if case.threshold_mode == "default":
        thresholds = None
    else:
        trng = np.random.default_rng(case.threshold_seed)
        thresholds = []
        for w in params:
            n_out, n_in = w.shape
            if case.threshold_mode == "scalar":
                thresholds.append(int(trng.integers(0, n_in + 2)))
            else:
                thresholds.append(
                    trng.integers(0, n_in + 2, n_out).astype(np.int32)
                )
    program = compile_bnn(params, thresholds=thresholds)
    return BuiltProgram(
        case=case,
        params=params,
        thresholds=thresholds,
        program=program,
        lowered=program.lower(),
    )


def packets_for(case: ProgramCase, seed: int, n: int) -> np.ndarray:
    """Deterministic ``(n, input_bits)`` {0,1} packets for a case."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, (n, case.layer_sizes[0])).astype(np.int32)


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

def layer_size_lists(
    min_layers: int = 1, max_layers: int = 3, max_width: int = MAX_WIDTH
):
    """Layer-width tuples ``(input, h1, ..., out)`` with 1..max_width bits —
    deliberately including widths not divisible by 32 and width-1 edges."""
    widths = st.integers(min_value=1, max_value=max_width)
    return st.integers(min_value=min_layers, max_value=max_layers).flatmap(
        lambda n: st.lists(widths, min_size=n + 1, max_size=n + 1).map(tuple)
    )


def program_cases(
    min_layers: int = 1, max_layers: int = 3, max_width: int = MAX_WIDTH
):
    """Random :class:`ProgramCase`s: widths x weights x threshold modes."""
    seeds = st.integers(min_value=0, max_value=_SEED_MAX)
    return layer_size_lists(min_layers, max_layers, max_width).flatmap(
        lambda sizes: seeds.flatmap(
            lambda wseed: st.sampled_from(THRESHOLD_MODES).flatmap(
                lambda mode: seeds.map(
                    lambda tseed: ProgramCase(sizes, wseed, mode, tseed)
                )
            )
        )
    )


def stream_plans(max_packets: int = 300, max_chunk: int = 64):
    """``(n_packets, chunk_size, packet_seed)`` plans for chunking-invariance
    and mid-stream-resume tests; chunk sizes that divide, straddle, and
    exceed the packet count all occur."""
    return st.integers(min_value=1, max_value=max_packets).flatmap(
        lambda n: st.integers(min_value=1, max_value=max_chunk).flatmap(
            lambda c: st.integers(min_value=0, max_value=_SEED_MAX).map(
                lambda seed: (n, c, seed)
            )
        )
    )


def fleet_plans(
    max_streams: int = 16, max_packets: int = 120, max_chunk: int = 48
):
    """``(stream_lengths, chunk_size, packet_seed)`` fleet shapes: 1..16
    independent streams with *different* per-stream lengths (so fleet blocks
    zero-pad exhausted streams mid-run) and a shared per-stream chunk that
    divides, straddles, or exceeds the lengths."""
    lengths = st.integers(min_value=1, max_value=max_packets)
    return st.integers(min_value=1, max_value=max_streams).flatmap(
        lambda s: st.lists(lengths, min_size=s, max_size=s).flatmap(
            lambda ls: st.integers(min_value=1, max_value=max_chunk).flatmap(
                lambda c: st.integers(min_value=0, max_value=_SEED_MAX).map(
                    lambda seed: (tuple(ls), c, seed)
                )
            )
        )
    )


@dataclasses.dataclass(frozen=True)
class TenantMixCase:
    """A random multi-tenant scenario: N independent programs, each with a
    traffic identity (synthetic scenario or a pcap-backed replay), plus one
    shared mixed-stream shape."""

    cases: tuple[ProgramCase, ...]    # one program per tenant
    scenarios: tuple[str, ...]        # per-tenant traffic scenario name
    n_packets: int
    chunk: int
    seed: int

    @property
    def num_tenants(self) -> int:
        return len(self.cases)


# The pcap-backed tenant scenario the mixes draw from: a deterministic
# synthesized capture, registered lazily (and idempotently) on first use.
FUZZ_PCAP_SCENARIO = "pcap:fuzzmix"
_SCENARIO_NAMES = (
    "adversarial_bitflip",
    "ddos_burst",
    "flow_tuple",
    "iot_telemetry",
    "uniform_random",
    FUZZ_PCAP_SCENARIO,
)
_pcap_registered = False


def _ensure_fuzz_pcap_scenario() -> None:
    global _pcap_registered
    if _pcap_registered:
        return
    from repro.dataplane import pcap

    pkts, ts, _ = pcap.synthesize_capture(512, seed=0xF0CC)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    pcap.register_pcap_scenario(FUZZ_PCAP_SCENARIO, cap, overwrite=True)
    _pcap_registered = True


def mix_traffic(mix: TenantMixCase):
    """The mix's deterministic ``(tenant_ids, bits)`` mixed stream (pcap
    scenario registered on demand)."""
    from repro.dataplane import traffic

    if FUZZ_PCAP_SCENARIO in mix.scenarios:
        _ensure_fuzz_pcap_scenario()
    specs = [
        traffic.TenantTrafficSpec(scen, case.layer_sizes[0], 1.0)
        for case, scen in zip(mix.cases, mix.scenarios)
    ]
    return traffic.mixed_tenant_generate(specs, mix.n_packets, seed=mix.seed)


def tenant_mixes(
    max_tenants: int = 4,
    min_tenants: int = 2,
    max_layers: int = 2,
    max_width: int = 16,
    max_packets: int = 200,
    max_chunk: int = 64,
):
    """Random :class:`TenantMixCase`s: 2..max_tenants programs of mixed
    widths/depths, each paired with a scenario (pcap-backed tenants
    included), plus a stream length / chunk size / traffic seed."""
    case = program_cases(max_layers=max_layers, max_width=max_width)
    scen = st.sampled_from(_SCENARIO_NAMES)
    return st.integers(min_value=min_tenants, max_value=max_tenants).flatmap(
        lambda t: st.lists(case, min_size=t, max_size=t).flatmap(
            lambda cs: st.lists(scen, min_size=t, max_size=t).flatmap(
                lambda ss: st.integers(
                    min_value=1, max_value=max_packets
                ).flatmap(
                    lambda n: st.integers(
                        min_value=1, max_value=max_chunk
                    ).flatmap(
                        lambda c: st.integers(
                            min_value=0, max_value=_SEED_MAX
                        ).map(
                            lambda seed: TenantMixCase(
                                tuple(cs), tuple(ss), n, c, seed
                            )
                        )
                    )
                )
            )
        )
    )


def chip_specs(
    min_elements: int = 4,
    max_elements: int = 96,
    min_phv: int = 256,
    max_phv: int = 8192,
):
    """Random chip budgets (element count x PHV bits).  Small budgets are
    *meant* to reject some programs — admission/validation fuzz checks that
    rejection is a clean typed error, never a wrong answer."""
    return st.integers(min_value=min_elements, max_value=max_elements).flatmap(
        lambda elems: st.integers(min_value=min_phv, max_value=max_phv).map(
            lambda phv: ChipSpec(
                num_elements=elems,
                phv_bits=phv,
                name=f"fuzz-{elems}el-{phv}b",
            )
        )
    )


# ---------------------------------------------------------------------------
# Failure artifacts
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def artifact_on_failure(test_name: str, case):
    """Re-raise any failure after appending the failing case's repr to
    ``$FUZZ_ARTIFACT_DIR/<test_name>.txt`` (CI uploads that directory), so a
    red fuzz run always ships its reproducer."""
    try:
        yield
    except BaseException:
        art_dir = os.environ.get("FUZZ_ARTIFACT_DIR")
        if art_dir:
            path = pathlib.Path(art_dir)
            path.mkdir(parents=True, exist_ok=True)
            with open(path / f"{test_name}.txt", "a") as fh:
                fh.write(f"{case!r}\n")
        raise
