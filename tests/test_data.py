import numpy as np

from conftest import tiny_config
from repro.data.pipeline import PipelineState, SyntheticTokens


def test_determinism_across_restarts():
    cfg = tiny_config("phi3-mini-3.8b")
    a = SyntheticTokens(cfg, global_batch=4, seq_len=16, seed=3)
    b1 = a.next_batch()
    b2 = a.next_batch()
    # resume from state at step 1
    b = SyntheticTokens(cfg, global_batch=4, seq_len=16, seed=3)
    b.state = PipelineState(3, 1)
    r2 = b.next_batch()
    np.testing.assert_array_equal(b2["tokens"], r2["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_host_slicing_partitions_global_batch():
    cfg = tiny_config("phi3-mini-3.8b")
    full = SyntheticTokens(cfg, global_batch=8, seq_len=16, seed=5).next_batch()
    parts = []
    for h in range(4):
        p = SyntheticTokens(cfg, global_batch=8, seq_len=16, seed=5)
        parts.append(p.next_batch(host_index=h, host_count=4)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), full["tokens"])


def test_labels_are_shifted_tokens():
    cfg = tiny_config("phi3-mini-3.8b")
    b = SyntheticTokens(cfg, global_batch=2, seq_len=16, seed=7).next_batch()
    # next-token objective: labels[t] continues tokens[t]
    assert b["tokens"].shape == b["labels"].shape
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_modality_batches():
    for arch in ("hubert-xlarge", "internvl2-2b"):
        cfg = tiny_config(arch)
        b = SyntheticTokens(cfg, global_batch=2, seq_len=32, seed=1).next_batch()
        if cfg.input_mode == "frames":
            assert b["frames"].shape == (2, 32, cfg.d_model)
        else:
            assert b["patches"].shape == (2, cfg.num_patches, cfg.d_model)
            assert b["tokens"].shape[1] == 32 - cfg.num_patches
