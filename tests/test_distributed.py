"""Multi-device semantics tests (subprocess with forced host device count):
MoE shard_map parity, dry-run cell compilation, HLO analyzer sanity.

These run jax in a fresh interpreter because the device count locks at
first init.  Marked slow; each is a single subprocess.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep + os.path.dirname(__file__)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_moe_shard_map_matches_local():
    """EP shard_map path == single-device dense path, bit-for-bit-ish."""
    out = _run(
        """
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from conftest import tiny_config
        from repro.layers.moe import moe_apply, moe_init
        cfg = tiny_config('qwen3-moe-30b-a3b')
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, num_experts=8, capacity_factor=8.0))
        params = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        y_local, aux_local = moe_apply(params, x, cfg, mesh=None)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        with mesh:
            fn = jax.jit(lambda p, xx: moe_apply(p, xx, cfg, mesh=mesh))
            y_dist, aux_dist = fn(params, x)
        np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_dist), rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(float(aux_local), float(aux_dist), rtol=1e-3)
        print('MOE_PARITY_OK')
        """
    )
    assert "MOE_PARITY_OK" in out


@pytest.mark.slow
def test_distributed_train_step_matches_single_device():
    """pjit on a (2,4) mesh computes the same loss as single-device."""
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from conftest import tiny_config, make_batch
        from repro import sharding
        from repro.models import init_params
        from repro.train.train_step import loss_fn
        cfg = tiny_config('phi3-mini-3.8b', num_kv_heads=4)
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        batch = make_batch(cfg, 8, 32, key)
        l_single, _ = loss_fn(params, batch, cfg)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        pspecs = sharding.param_specs(cfg, params, mesh)
        bspecs = sharding.batch_specs(cfg, batch, mesh)
        with mesh:
            fn = jax.jit(
                lambda p, b: loss_fn(p, b, cfg)[0],
                in_shardings=(sharding.to_named(pspecs, mesh), sharding.to_named(bspecs, mesh)),
            )
            l_dist = fn(params, batch)
        np.testing.assert_allclose(float(l_single), float(l_dist), rtol=2e-3)
        print('DIST_LOSS_OK', float(l_single), float(l_dist))
        """
    )
    assert "DIST_LOSS_OK" in out


@pytest.mark.slow
def test_dryrun_cell_small_mesh(tmp_path):
    """One train cell + one decode cell lower/compile on a 4x8 mesh."""
    out = _run(
        f"""
        import repro.launch.dryrun as dr
        import jax
        mesh = jax.make_mesh((4, 8), ('data', 'model'))
        r1 = dr.run_cell('zamba2-1.2b', 'train_4k', mesh, 't', r'{tmp_path}')
        r2 = dr.run_cell('chatglm3-6b', 'decode_32k', mesh, 't', r'{tmp_path}')
        assert r1['status'] == 'ok', r1
        assert r2['status'] == 'ok', r2
        assert r1['roofline']['hlo_flops_per_dev'] > 0
        assert r2['roofline']['collective_bytes_per_chip'] >= 0
        print('DRYRUN_OK')
        """,
        devices=32,
    )
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_hlo_analyzer_scan_awareness():
    """Analyzer multiplies while-body dots by trip count."""
    out = _run(
        """
        import jax, jax.numpy as jnp
        from repro.roofline import hlo
        W = jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)
        X = jax.ShapeDtypeStruct((16, 64), jnp.bfloat16)
        def make(n):
            def f(w, x):
                def body(h, _):
                    return h @ w, None
                h, _ = jax.lax.scan(body, x, None, length=n)
                return h
            return f
        texts = {n: jax.jit(make(n)).lower(W, X).compile().as_text() for n in (2, 8)}
        f2 = hlo.analyze(texts[2]).flops
        f8 = hlo.analyze(texts[8]).flops
        assert abs(f8 / f2 - 4.0) < 0.2, (f2, f8)
        print('HLO_OK', f2, f8)
        """,
        devices=1,
    )
    assert "HLO_OK" in out
