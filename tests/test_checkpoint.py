import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


@pytest.fixture
def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.bfloat16), "d": jnp.int32(7)},
    }


def test_save_restore_bit_exact(tmp_path, tree):
    ckpt.save(str(tmp_path), 5, tree, extras={"note": "x"})
    got = ckpt.restore_latest(str(tmp_path), tree)
    assert got is not None
    restored, step, extras = got
    assert step == 5 and extras == {"note": "x"}
    import jax

    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_latest_pointer_and_history(tmp_path, tree):
    for s in (1, 2, 3):
        ckpt.save(str(tmp_path), s, tree)
    assert ckpt.available_steps(str(tmp_path)) == [1, 2, 3]
    _, step, _ = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 3


def test_corruption_falls_back(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    # corrupt the newest checkpoint's first array
    victim = os.path.join(str(tmp_path), "step_00000002", "arr_00000.npy")
    with open(victim, "r+b") as f:
        f.seek(64)
        f.write(b"\xff\xff\xff\xff")
    _, step, _ = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 1  # fell back past the corrupt one


def test_no_checkpoint_returns_none(tmp_path, tree):
    assert ckpt.restore_latest(str(tmp_path), tree) is None
