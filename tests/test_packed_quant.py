"""The paper's technique in serving form: packed XNOR-popcount projections."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_config
from repro.configs.base import QuantConfig
from repro.kernels import ref
from repro.layers.linear import dense_apply, dense_init
from repro.models import decode_step, forward, init_cache, init_params

PACKED = QuantConfig(mode="bnn_packed", targets=("ffn", "attn_proj"))


@pytest.mark.parametrize("k", [32, 64, 100, 513])
def test_packed_dense_matches_oracle(k):
    key = jax.random.PRNGKey(k)
    p = dense_init(key, k, 48, quant=PACKED, tag="ffn")
    assert "w_packed" in p and p["w_packed"].dtype == jnp.uint32
    x = jax.random.normal(jax.random.PRNGKey(1), (6, k))
    y = dense_apply(p, x)
    # the same key reproduces the latent weights the packing came from
    w_lat = jax.random.normal(key, (48, k)) * 0.02
    want = (
        ref.bnn_matmul_ref(x, w_lat)
        * p["alpha"][None, :]
        * jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_packed_weights_are_16x_smaller():
    p_packed = dense_init(jax.random.PRNGKey(0), 1024, 512, quant=PACKED, tag="ffn")
    p_dense = dense_init(jax.random.PRNGKey(0), 1024, 512, dtype=jnp.bfloat16)
    packed_bytes = p_packed["w_packed"].size * 4 + p_packed["alpha"].size * 4
    dense_bytes = p_dense["w"].size * 2
    assert dense_bytes / packed_bytes > 15


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "qwen3-moe-30b-a3b", "mamba2-1.3b"])
def test_packed_model_forward_and_decode(arch, rng_key):
    targets = ("ffn", "attn_proj", "moe", "ssm_proj")
    cfg = tiny_config(arch, quant=QuantConfig(mode="bnn_packed", targets=targets))
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, 2, 32, rng_key)
    logits, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    assert bool(jnp.isfinite(logits).all())
    cache = init_cache(cfg, 2, 48)
    lg, c2 = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))(
        params, jnp.array([1, 2]), cache
    )
    assert bool(jnp.isfinite(lg).all()) and int(c2.index) == 1


def test_packed_moe_mm_matches_dense():
    from repro.layers.moe import _pack_experts, _packed_expert_mm

    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (4, 16, 64)) * 0.1      # (E, O, K)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 5, 64))
    pw, alpha = _pack_experts(w)
    got = _packed_expert_mm(x, {"packed": pw, "alpha": alpha})
    ws = jnp.where(w >= 0, 1.0, -1.0)
    beta = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
    xs = jnp.where(x >= 0, 1.0, -1.0)
    want = jnp.einsum("ecd,efd->ecf", xs, ws) * alpha[:, None, :] * beta
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
