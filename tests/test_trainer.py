"""Trainer fault tolerance + learning progress (system-level)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_config
from repro.train.trainer import Trainer, TrainerConfig


def _mk(tmp_path, **kw):
    cfg = tiny_config("phi3-mini-3.8b", num_layers=2, vocab_size=64)
    defaults = dict(
        total_steps=10, checkpoint_every=4, checkpoint_dir=str(tmp_path),
        global_batch=4, seq_len=32, log_every=2,
    )
    defaults.update(kw)
    return Trainer(cfg, TrainerConfig(**defaults))


def test_loss_decreases(tmp_path):
    t = _mk(tmp_path, total_steps=30)
    out = t.run()
    losses = [h["loss"] for h in out["history"] if "loss" in h]
    assert len(losses) >= 3
    assert losses[-1] < losses[0], f"no learning: {losses}"


def test_recovers_from_injected_failure(tmp_path):
    t = _mk(tmp_path, total_steps=10, fail_at_steps=(6,))
    out = t.run()
    assert out["final_step"] == 10
    assert out["recoveries"] == 1
    fails = [h for h in out["history"] if h.get("event") == "failure"]
    assert len(fails) == 1 and fails[0]["restored"]


def test_recovery_is_deterministic(tmp_path):
    """A failed+recovered run reaches the same params as an unfailed run."""
    t1 = _mk(tmp_path / "a", total_steps=8, checkpoint_every=4)
    t1.run()
    t2 = _mk(tmp_path / "b", total_steps=8, checkpoint_every=4,
             fail_at_steps=(6,))
    t2.run()
    import jax

    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6,
        )


def test_gradient_compression_variants(tmp_path):
    for kind in ("sign", "int8", "topk"):
        t = _mk(tmp_path / kind, total_steps=6, compression=kind)
        out = t.run()
        assert out["final_step"] == 6
        losses = [h["loss"] for h in out["history"] if "loss" in h]
        assert all(np.isfinite(losses))


def test_elastic_rescale(tmp_path):
    import jax

    from repro.train.elastic import rescale

    t = _mk(tmp_path, total_steps=4, checkpoint_every=2)
    t.run()
    new_mesh = jax.make_mesh((1, 1), ("data", "model"))
    got = rescale(t.cfg, str(tmp_path), {"params": t.params, "opt": t.opt_state},
                  new_mesh)
    assert got is not None
    bundle, step, extras = got
    assert step == 4
    for a, b in zip(jax.tree.leaves(t.params), jax.tree.leaves(bundle["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
