"""Layer-level unit tests against naive references."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoeConfig, SsmConfig
from repro.layers import rope
from repro.layers.attention import attention_apply, attention_init, chunked_attention
from repro.layers.mamba2 import mamba2_apply, mamba2_init
from repro.layers.moe import _expert_compute, _route, moe_apply, moe_init
from repro.layers.norms import rmsnorm, rmsnorm_init


def naive_attention(q, k, v, causal, scale):
    b, s, h, d = q.shape
    kvh = k.shape[2]
    k = jnp.repeat(k, h // kvh, axis=2)
    v = jnp.repeat(v, h // kvh, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("kvh", [1, 2, 4])
def test_chunked_attention_matches_naive(causal, kvh):
    key = jax.random.PRNGKey(0)
    b, s, h, d = 2, 64, 4, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, h, d))
    k = jax.random.normal(kk, (b, s, kvh, d))
    v = jax.random.normal(kv, (b, s, kvh, d))
    got = chunked_attention(q, k, v, causal=causal, q_chunk=16, scale=0.25)
    want = naive_attention(q, k, v, causal, 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-2)


def test_chunked_attention_ragged_seq():
    """Sequence not a multiple of q_chunk pads then trims correctly."""
    key = jax.random.PRNGKey(1)
    b, s, h, d = 1, 37, 2, 8
    q = jax.random.normal(key, (b, s, h, d))
    got = chunked_attention(q, q, q, causal=True, q_chunk=16, scale=1.0)
    want = naive_attention(q, q, q, True, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-2)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 16))
    pos = jnp.arange(8)[None, :]
    y = rope.rotate(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 16))
    def dot_at(p):
        rq = rope.rotate(q, jnp.array([[p]]))
        rv = rope.rotate(v, jnp.array([[p + 3]]))
        return float(jnp.sum(rq * rv))
    assert dot_at(0) == pytest.approx(dot_at(7), rel=1e-4)


def test_partial_rotary_passthrough():
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, 1, 16))
    y = rope.rotate(x, jnp.arange(4)[None], rotary_pct=0.5)
    np.testing.assert_array_equal(np.asarray(y[..., 8:]), np.asarray(x[..., 8:]))


def test_rmsnorm_matches_reference():
    p = rmsnorm_init(32)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 32)) * 3
    got = rmsnorm(p, x, 1e-5)
    want = x / np.sqrt((np.asarray(x) ** 2).mean(-1, keepdims=True) + 1e-5)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4)


def _ssm_cfg():
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=32, num_heads=1,
        num_kv_heads=1, head_dim=8, d_ff=0, vocab_size=11, attention="none",
        ssm=SsmConfig(state_dim=8, head_dim=8, expand=2, chunk=4),
    )


def sequential_ssd(x, dt, a_neg, bmat, cmat):
    """O(S) reference recurrence for the SSD scan."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    hstate = np.zeros((b, h, n, p))
    ys = []
    x, dt, bmat, cmat = map(np.asarray, (x, dt, bmat, cmat))
    a = np.asarray(a_neg)
    for t in range(s):
        lam = np.exp(dt[:, t] * a)  # (b,h)
        dbx = np.einsum("bh,bn,bhp->bhnp", dt[:, t], bmat[:, t], x[:, t])
        hstate = hstate * lam[:, :, None, None] + dbx
        ys.append(np.einsum("bn,bhnp->bhp", cmat[:, t], hstate))
    return np.stack(ys, 1), hstate


def test_ssd_chunked_matches_sequential():
    from repro.layers.mamba2 import _ssd_chunked

    key = jax.random.PRNGKey(7)
    b, s, h, p, n = 2, 12, 3, 4, 5
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, s, n))
    cmat = jax.random.normal(jax.random.fold_in(key, 9), (b, s, n))
    y, hf = _ssd_chunked(x, dt, a_neg, bmat, cmat, chunk=4)
    yref, href = sequential_ssd(x, dt, a_neg, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), yref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), href, rtol=2e-4, atol=2e-4)


def test_mamba2_decode_matches_prefill():
    """Step-by-step decode must reproduce the chunked prefill outputs."""
    cfg = _ssm_cfg()
    params = mamba2_init(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 6, cfg.d_model)) * 0.3

    full, cache_after = mamba2_apply(params, x, cfg, cache_index=jnp.int32(0))
    # replay one token at a time
    s_cfg = cfg.ssm
    d_inner = s_cfg.expand * cfg.d_model
    heads = d_inner // s_cfg.head_dim
    lc = {
        "h": jnp.zeros((1, heads, s_cfg.state_dim, s_cfg.head_dim)),
        "conv_x": jnp.zeros((1, s_cfg.conv_width - 1, d_inner)),
        "conv_bc": jnp.zeros((1, s_cfg.conv_width - 1, 2 * s_cfg.state_dim)),
    }
    outs = []
    for t in range(6):
        y, lc = mamba2_apply(params, x[:, t : t + 1], cfg, layer_cache=lc)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full), rtol=5e-3, atol=5e-3
    )
    np.testing.assert_allclose(
        np.asarray(lc["h"]), np.asarray(cache_after["h"]), rtol=5e-3, atol=5e-3
    )


def _moe_cfg():
    return ModelConfig(
        name="m", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=0, vocab_size=11,
        moe=MoeConfig(num_experts=4, top_k=2, expert_ffn_dim=8,
                      capacity_factor=8.0),
    )


def dense_moe_reference(params, x2, idx, gates):
    """Every token through its experts via plain gathers (no capacity)."""
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    out = np.zeros_like(np.asarray(x2))
    x2n = np.asarray(x2)
    for t in range(x2.shape[0]):
        for j in range(idx.shape[1]):
            e = int(idx[t, j])
            g = np.asarray(x2n[t] @ np.asarray(wg[e]).T)
            u = np.asarray(x2n[t] @ np.asarray(wu[e]).T)
            h = (g / (1 + np.exp(-g))) * u
            out[t] += float(gates[t, j]) * (h @ np.asarray(wd[e]).T)
    return out


def test_moe_capacity_dispatch_matches_dense_reference():
    cfg = _moe_cfg()
    params = moe_init(jax.random.PRNGKey(10), cfg)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 6, cfg.d_model))
    x2 = x.reshape(-1, cfg.d_model)
    idx, gates, _ = _route(params, x2, cfg)
    got = _expert_compute(
        x2, idx, gates, params["w_gate"], params["w_up"], params["w_down"],
        e_lo=0, num_experts=4, capacity=64,
    )
    want = dense_moe_reference(params, x2, np.asarray(idx), np.asarray(gates))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_overflow():
    """With capacity 1, most slots drop — outputs bounded, finite."""
    cfg = _moe_cfg()
    params = moe_init(jax.random.PRNGKey(12), cfg)
    x = jax.random.normal(jax.random.PRNGKey(13), (1, 16, cfg.d_model))
    x2 = x.reshape(-1, cfg.d_model)
    idx, gates, _ = _route(params, x2, cfg)
    got = _expert_compute(
        x2, idx, gates, params["w_gate"], params["w_up"], params["w_down"],
        e_lo=0, num_experts=4, capacity=1,
    )
    assert bool(jnp.isfinite(got).all())


def test_moe_apply_aux_loss_positive():
    cfg = _moe_cfg()
    params = moe_init(jax.random.PRNGKey(14), cfg)
    x = jax.random.normal(jax.random.PRNGKey(15), (2, 8, cfg.d_model))
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert float(aux) > 0


def test_attention_decode_matches_full():
    cfg = ModelConfig(
        name="a", family="dense", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=11, attn_q_chunk=8,
    )
    params = attention_init(jax.random.PRNGKey(16), cfg)
    x = jax.random.normal(jax.random.PRNGKey(17), (1, 5, 32)) * 0.5
    pos = jnp.arange(5)[None]
    full, _ = attention_apply(params, x, cfg, positions=pos)
    # decode protocol: the layer returns the NEW position's (B,1,KVH,D) k/v;
    # the caller commits it with a single-position update (models.decode_step)
    smax = 8
    kc = jnp.zeros((1, smax, 2, 8))
    vc = jnp.zeros((1, smax, 2, 8))
    for t in range(5):
        out, nc = attention_apply(
            params, x[:, t : t + 1], cfg,
            positions=jnp.array([[t]]), layer_cache={"k": kc, "v": vc},
            cache_index=jnp.int32(t),
        )
        kc = jax.lax.dynamic_update_slice(kc, nc["k"].astype(kc.dtype), (0, t, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, nc["v"].astype(vc.dtype), (0, t, 0, 0))
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), np.asarray(full[:, 4]), rtol=1e-3, atol=1e-3
    )
