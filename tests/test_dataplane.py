"""Dataplane subsystem: lowering, fused executor, traffic, fabric, telemetry.

The load-bearing contract is differential: the fused op-table executor must
be *bit-exact* with the legacy per-op interpreter (``run_program``) and the
mathematical oracle (``bnn.forward``) — across model shapes, chips, traffic
scenarios, backends, chunkings, and fabric partitionings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn, compile_bnn, interpreter
from repro.core.interpreter import run_program, run_program_jit
from repro.core.pipeline import RMT_NATIVE_POPCNT, ChipSpec
from repro.dataplane import (
    SwitchFabric,
    execute,
    execute_stream,
    lower_program,
    stage_telemetry,
    traffic,
)
from repro.dataplane.executor import _rechunk
from repro.dataplane.lowering import POPCNT, SHL_IMM

MODELS = [(8, 4), (32, 64, 32), (33, 17, 9), (96, 40, 12, 5)]


def _compiled(sizes, seed=0, chip=None):
    spec = bnn.BnnSpec(sizes)
    params = bnn.init_params(spec, jax.random.PRNGKey(seed))
    weights = [np.asarray(w) for w in params]
    prog = compile_bnn(weights, chip) if chip else compile_bnn(weights)
    return params, prog


def _packets(n, bits, seed=0):
    return np.random.default_rng(seed).integers(0, 2, (n, bits), dtype=np.int64)


# -- lowering ---------------------------------------------------------------

def test_lowering_tables_shape_and_row_counts():
    _, prog = _compiled((32, 64, 32))
    lp = lower_program(prog)
    e, r = lp.opcode.shape
    assert e == len(prog.elements)
    assert (lp.rows_per_element <= r).all()
    assert lp.num_ops == int(lp.rows_per_element.sum())
    # FOLD expands to one SHL micro-row per sign bit; everything else is 1:1.
    folds = sum(
        len(op.srcs) - 1
        for el in prog.elements
        for op in el.ops
        if op.opcode.name == "FOLD"
    )
    assert lp.num_ops == sum(len(el.ops) for el in prog.elements) + folds
    # Only FOLD continuation rows clear the first_write flag.
    n_rows = lp.rows_per_element
    real = np.concatenate([lp.first_write[i, : n_rows[i]] for i in range(e)])
    opc = np.concatenate([lp.opcode[i, : n_rows[i]] for i in range(e)])
    assert ((real == 1) | (opc == SHL_IMM)).all()


def test_lowering_compaction_shrinks_register_file():
    _, prog = _compiled((32, 64, 32))
    lp = lower_program(prog)
    lp_full = lower_program(prog, compact=False)
    assert lp.num_regs < lp_full.num_regs / 5
    assert lp.fingerprint() != lp_full.fingerprint()
    x = _packets(64, 32)
    np.testing.assert_array_equal(
        execute(lp, x, backend="jnp"), execute(lp_full, x, backend="jnp")
    )


def test_lowering_slice_out_of_range():
    _, prog = _compiled((8, 4))
    lp = lower_program(prog)
    with pytest.raises(ValueError):
        lp.slice_elements(0, lp.num_elements + 1)


# -- fused executor vs interpreter vs oracle --------------------------------

@pytest.mark.parametrize("sizes", MODELS)
def test_executor_bit_exact(sizes):
    params, prog = _compiled(sizes, seed=len(sizes))
    lp = lower_program(prog)
    x = _packets(193, sizes[0], seed=1)
    got = execute(lp, x, backend="jnp")
    np.testing.assert_array_equal(got, np.asarray(run_program(prog, x)))
    np.testing.assert_array_equal(
        got, np.asarray(bnn.forward(params, jnp.asarray(x)))
    )


@pytest.mark.parametrize("scenario", sorted(traffic.SCENARIOS))
def test_executor_bit_exact_per_scenario(scenario):
    params, prog = _compiled((32, 64, 32))
    lp = lower_program(prog)
    x = traffic.generate(scenario, 256, 32, seed=11)
    got = execute(lp, x, backend="jnp")
    np.testing.assert_array_equal(got, np.asarray(run_program(prog, x)))
    np.testing.assert_array_equal(
        got, np.asarray(bnn.forward(params, jnp.asarray(x)))
    )


def test_executor_native_popcnt_chip():
    params, prog = _compiled((64, 16, 8), chip=RMT_NATIVE_POPCNT)
    lp = lower_program(prog)
    assert POPCNT in lp.used_opcodes()
    x = _packets(100, 64, seed=2)
    got = execute(lp, x, backend="jnp")
    np.testing.assert_array_equal(got, np.asarray(run_program(prog, x)))
    np.testing.assert_array_equal(
        got, np.asarray(bnn.forward(params, jnp.asarray(x)))
    )


def test_executor_pallas_kernel_matches():
    _, prog = _compiled((16, 8, 4))
    lp = lower_program(prog)
    x = _packets(70, 16, seed=3)  # non-multiple of the batch block: pads
    want = execute(lp, x, backend="jnp")
    got = execute(lp, x, backend="pallas", interpret=True)
    np.testing.assert_array_equal(got, want)


def test_executor_chunked_equals_single_shot():
    _, prog = _compiled((16, 8))
    lp = lower_program(prog)
    x = _packets(333, 16, seed=4)
    np.testing.assert_array_equal(
        execute(lp, x, backend="jnp", chunk_size=128),
        execute(lp, x, backend="jnp"),
    )


def test_executor_rejects_bad_shapes():
    _, prog = _compiled((8, 4))
    lp = lower_program(prog)
    with pytest.raises(ValueError):
        execute(lp, _packets(10, 9))
    with pytest.raises(ValueError):
        execute(lp, _packets(10, 8), backend="nope")


# -- streaming --------------------------------------------------------------

def test_stream_equals_batch_and_counts_bits():
    _, prog = _compiled((16, 8, 4))
    lp = lower_program(prog)
    chunks = [_packets(97, 16, seed=i) for i in range(5)]
    allx = np.concatenate(chunks)
    sr = execute_stream(lp, iter(chunks), chunk_size=128, collect=True)
    want = execute(lp, allx, backend="jnp")
    np.testing.assert_array_equal(sr.outputs.astype(np.int32), want)
    np.testing.assert_array_equal(
        sr.bit_counts, want.sum(axis=0, dtype=np.int64)
    )
    assert sr.packets == allx.shape[0]
    assert sr.chunks == -(-allx.shape[0] // 128)
    assert sr.packets_per_second > 0


def test_rechunk_reslices_exactly():
    chunks = [np.arange(n)[:, None] for n in (5, 1, 9, 2)]
    out = list(_rechunk(iter(chunks), 4))
    assert [c.shape[0] for c in out] == [4, 4, 4, 4, 1]
    np.testing.assert_array_equal(
        np.concatenate(out), np.concatenate(chunks)
    )


# -- traffic ----------------------------------------------------------------

@pytest.mark.parametrize("scenario", sorted(traffic.SCENARIOS))
def test_traffic_shape_values_determinism(scenario):
    a = traffic.generate(scenario, 200, 48, seed=7)
    b = traffic.generate(scenario, 200, 48, seed=7)
    c = traffic.generate(scenario, 200, 48, seed=8)
    assert a.shape == (200, 48) and a.dtype == np.int32
    assert set(np.unique(a)) <= {0, 1}
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)  # seeds matter
    assert 0.0 < a.mean() < 1.0      # neither all-zeros nor all-ones


def test_traffic_stream_chunks_and_determinism():
    got = list(traffic.stream("flow_tuple", 250, 32, chunk_size=64, seed=3))
    assert [c.shape[0] for c in got] == [64, 64, 64, 58]
    again = list(traffic.stream("flow_tuple", 250, 32, chunk_size=64, seed=3))
    np.testing.assert_array_equal(np.concatenate(got), np.concatenate(again))


def test_traffic_stream_keeps_world_across_chunks():
    # flow_tuple: every chunk draws from the one flow pool set up at stream
    # start — the whole stream shows at most the pool's 256 distinct headers.
    allx = np.concatenate(
        list(traffic.stream("flow_tuple", 2000, 32, chunk_size=128, seed=1))
    )
    assert len(np.unique(allx, axis=0)) <= 256

    # ddos_burst: burst phase follows *global* packet position, so the
    # second burst window (packets 1024..1279) still carries the signature
    # drawn at setup even though chunking restarted many times in between.
    allx = np.concatenate(
        list(traffic.stream("ddos_burst", 2048, 32, chunk_size=300, seed=2))
    )
    first, second = allx[:256], allx[1024:1280]
    signature = (first.mean(axis=0) > 0.5).astype(np.int32)
    agreement = (second == signature[None, :]).mean()
    assert agreement > 0.9  # jitter is 2% per bit

    # iot_telemetry: sensor walks continue across chunks — streamed traffic
    # stays low-entropy (far fewer distinct headers than packets).
    allx = np.concatenate(
        list(traffic.stream("iot_telemetry", 1500, 32, chunk_size=100, seed=3))
    )
    assert len(np.unique(allx, axis=0)) < 800


@pytest.mark.parametrize("scenario", sorted(traffic.SCENARIOS))
def test_traffic_stream_resumed_mid_scenario_matches_uninterrupted(scenario):
    # A chunked stream — any chunking, including ones that pause and resume
    # mid-trace — must replay exactly the uninterrupted sequence.  The
    # canonical-chunk scheme guarantees it; before it, emitters threading one
    # rng through differently-shaped draws broke this for 3 of 5 scenarios.
    n = 3000
    want = traffic.generate(scenario, n, 24, seed=5)
    for chunk_size in (1, 173, traffic.CANONICAL_CHUNK, n):
        got = np.concatenate(
            list(traffic.stream(scenario, n, 24, chunk_size=chunk_size, seed=5))
        )
        np.testing.assert_array_equal(got, want)
    # Resume: consume the first half from one stream object, the rest from a
    # fresh stream advanced past it — identical world, identical packets.
    first = traffic.generate(scenario, 1700, 24, seed=5)
    rest = traffic.generate(scenario, n, 24, seed=5)[1700:]
    np.testing.assert_array_equal(np.concatenate([first, rest]), want)


def test_mixed_tenant_stream_resumed_matches_uninterrupted():
    specs = [
        traffic.TenantTrafficSpec("ddos_burst", 16, 2.0),
        traffic.TenantTrafficSpec("uniform_random", 24, 1.0),
    ]
    n = 2500
    want_t, want_b = traffic.mixed_tenant_generate(specs, n, seed=9)
    for chunk_size in (47, 300, traffic.CANONICAL_CHUNK, n):
        chunks = list(
            traffic.mixed_tenant_stream(specs, n, chunk_size=chunk_size, seed=9)
        )
        np.testing.assert_array_equal(
            np.concatenate([t for t, _ in chunks]), want_t
        )
        np.testing.assert_array_equal(
            np.concatenate([b for _, b in chunks]), want_b
        )


def test_traffic_unknown_scenario():
    with pytest.raises(KeyError):
        traffic.get_scenario("does_not_exist")


# -- fabric -----------------------------------------------------------------

@pytest.mark.parametrize("mode", ["multi_hop", "recirculate"])
def test_fabric_partition_bit_exact(mode):
    params, prog = _compiled((32, 64, 32))
    tiny = ChipSpec(num_elements=7)  # forces a multi-switch chain
    fab = SwitchFabric.partition(prog, mode=mode, chip=tiny)
    assert fab.num_hops == -(-len(prog.elements) // 7)
    # Hops tile the element range exactly.
    ranges = [h.element_range for h in fab.hops]
    assert ranges[0][0] == 0 and ranges[-1][1] == len(prog.elements)
    assert all(a[1] == b[0] for a, b in zip(ranges, ranges[1:]))

    x = traffic.generate("ddos_burst", 211, 32, seed=5)
    res = fab.run(x, chunk_size=100)
    np.testing.assert_array_equal(res.outputs, np.asarray(run_program(prog, x)))
    np.testing.assert_array_equal(
        res.outputs, np.asarray(bnn.forward(params, jnp.asarray(x)))
    )


def test_fabric_single_hop_when_program_fits():
    _, prog = _compiled((8, 4))
    fab = SwitchFabric.partition(prog)
    assert fab.num_hops == 1


def test_fabric_pallas_backend_matches_jnp():
    _, prog = _compiled((16, 8, 4))
    fab = SwitchFabric.partition(prog, chip=ChipSpec(num_elements=9))
    x = _packets(40, 16, seed=6)
    want = fab.run(x, backend="jnp").outputs
    got = fab.run(x, backend="pallas", interpret=True).outputs
    np.testing.assert_array_equal(got, want)


def test_fabric_mode_validation():
    _, prog = _compiled((8, 4))
    with pytest.raises(ValueError):
        SwitchFabric.partition(prog, mode="teleport")


def test_fabric_throughput_accounting():
    _, prog = _compiled((32, 64, 32))
    tiny = ChipSpec(num_elements=8)
    multi = SwitchFabric.partition(prog, mode="multi_hop", chip=tiny)
    recirc = SwitchFabric.partition(prog, mode="recirculate", chip=tiny)
    # A switch chain pipelines at full line rate; recirculation divides by
    # the pass count — the paper's §2 trade.
    assert multi.analytic_report().packets_per_second == tiny.packets_per_second
    assert recirc.analytic_report().packets_per_second == pytest.approx(
        tiny.packets_per_second / recirc.num_hops
    )


# -- telemetry --------------------------------------------------------------

def test_stage_telemetry_liveness_and_budgets():
    _, prog = _compiled((32, 64, 32))
    stages = stage_telemetry(prog)
    assert len(stages) == len(prog.elements)
    assert stages[0].live_in_bits == prog.input_bits
    peak = max(s.occupancy_bits for s in stages)
    # Liveness-derived occupancy is bounded by the allocator's conservative
    # overlay accounting, which in turn respects the 512B PHV.
    assert 0 < peak <= prog.peak_phv_bits <= prog.chip.phv_bits
    for s in stages:
        assert 0 < s.alu_utilization <= 1.0
        assert s.ops > 0 and s.written_bits > 0


def test_fabric_telemetry_uses_fabric_chip():
    _, prog = _compiled((8, 4))
    other = ChipSpec(num_elements=4, phv_bits=8192, name="bigphv")
    tel = SwitchFabric.partition(prog, chip=other).telemetry()
    assert tel.chip_name == "bigphv"
    # PHV utilization is judged against the fabric's switches, not the
    # program's compile-time target.
    assert tel.phv_utilization == tel.peak_occupancy_bits / 8192


def test_fabric_telemetry_rollup_and_render():
    _, prog = _compiled((32, 64, 32))
    fab = SwitchFabric.partition(
        prog, mode="multi_hop", chip=ChipSpec(num_elements=8)
    )
    res = fab.run(_packets(64, 32), chunk_size=64)
    tel = fab.telemetry(res)
    assert len(tel.hops) == fab.num_hops
    assert tel.measured_pps == pytest.approx(res.packets_per_second)
    assert 0 < tel.phv_utilization <= 1.0
    text = tel.render()
    assert "multi_hop" in text and "measured" in text


# -- interpreter cache fix --------------------------------------------------

def test_runner_cache_keyed_structurally():
    params, prog_a = _compiled((8, 4), seed=1)
    _, prog_b = _compiled((8, 4), seed=1)   # identical structure, new object
    _, prog_c = _compiled((8, 4), seed=2)   # different weights
    assert prog_a.fingerprint() == prog_b.fingerprint()
    assert prog_a.fingerprint() != prog_c.fingerprint()
    # Memoized after first call: O(1) on the jitted dispatch hot path.
    assert prog_a.fingerprint() is prog_a.fingerprint()

    interpreter._RUNNER_CACHE.clear()
    x = _packets(32, 8)
    out_a = np.asarray(run_program_jit(prog_a, x))
    assert len(interpreter._RUNNER_CACHE) == 1
    # Structurally identical program reuses the jitted runner...
    np.testing.assert_array_equal(np.asarray(run_program_jit(prog_b, x)), out_a)
    assert len(interpreter._RUNNER_CACHE) == 1
    # ...while a different program gets (and computes with) its own.
    out_c = np.asarray(run_program_jit(prog_c, x))
    assert len(interpreter._RUNNER_CACHE) == 2
    np.testing.assert_array_equal(
        out_c, np.asarray(bnn.forward(bnn.init_params(bnn.BnnSpec((8, 4)), jax.random.PRNGKey(2)), jnp.asarray(x)))
    )
