"""SLO wiring through the serving layer: scheduler telemetry and
``FleetEngine.health()``.

The determinism contract: windows and SLO trackers only ever see
timestamps from the injectable clock (``SwitchScheduler(clock=...)``,
``FleetEngine(clock=...)``), called on the dispatch path a fixed number of
times per served unit — so two identical runs under identical fake clocks
produce bit-identical windowed health fields and breach-event logs.  The
one exception is ``FleetHealth.overlap_ratio`` (wall-clock derived, by
design), which the equality checks here explicitly exclude; likewise the
merged scheduler's queue-delay *values* are real dispatch latencies, so
its determinism assertions pin the clock-driven fields only.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import bnn, compile_bnn
from repro.core.pipeline import ChipSpec
from repro.dataplane import (
    SwitchScheduler,
    TenantTrafficSpec,
    mixed_tenant_stream,
    traffic,
)
from repro.dataplane.plan import ExecutionPlan
from repro.obs.slo import SloSpec
from repro.serving.engine import FleetEngine

BIG = ChipSpec(num_elements=256, name="bigchip")
SHAPES = [(16, 8, 4), (32, 16), (8, 12, 6)]
SPECS = [
    TenantTrafficSpec("ddos_burst", 16, 3.0),
    TenantTrafficSpec("flow_tuple", 32, 1.0),
    TenantTrafficSpec("iot_telemetry", 8, 2.0),
]


class FakeClock:
    """Deterministic monotone clock: every call advances by ``step``."""

    def __init__(self, step: float = 0.25, start: float = 0.0):
        self.t = start
        self.step = step
        self.calls = 0

    def __call__(self) -> float:
        self.calls += 1
        self.t += self.step
        return self.t


def _compiled(sizes, seed=0):
    spec = bnn.BnnSpec(sizes)
    params = bnn.init_params(spec, jax.random.PRNGKey(seed))
    return compile_bnn([np.asarray(w) for w in params])


def _scheduler(**kw):
    sched = SwitchScheduler(BIG, **kw)
    for i, (spec, shape) in enumerate(zip(SPECS, SHAPES)):
        sched.admit(_compiled(shape, seed=i), name=f"t{i}", weight=spec.weight)
    return sched


# ------------------------------------------------------- scheduler wiring

def _slo_run(mode, *, seed=7, n=1200, chunk=300):
    """One scheduler run with SLOs on two tenants under a fake clock."""
    sched = _scheduler(clock=FakeClock(step=0.25), quantum=128)
    # t0: unreachable throughput floor -> deterministic THROUGHPUT breach.
    sched.set_slo(SloSpec("t0", min_pps=1e12))
    # t1: delay target of an hour -> never breaches.
    sched.set_slo(SloSpec("t1", p99_queue_delay_s=3600.0, min_pps=1e-6))
    sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=chunk, seed=seed),
        mode=mode,
        chunk_size=256,
    )
    return sched


@pytest.mark.parametrize("mode", ["merged", "time_sliced"])
def test_scheduler_slo_surfaces_in_telemetry(mode):
    sched = _slo_run(mode)
    tel = sched.telemetry()
    t0, t1, t2 = (tel.tenant(f"t{i}") for i in range(3))
    # t0 is starving against a 1e12 pps floor: breached, with one event.
    assert t0.slo is not None and t0.slo_breached
    assert t0.slo.pps_burn_rate is not None and t0.slo.pps_burn_rate > 1.0
    assert [e.objective for e in t0.breach_events] == ["throughput"]
    # t1 has lax targets: tracked but healthy.
    assert t1.slo is not None and not t1.slo_breached
    assert t1.breach_events == ()
    assert t1.slo.pps is not None and t1.slo.pps > 0
    # t2 has no SLO: untouched.
    assert t2.slo is None and t2.breach_events == ()
    assert tel.breached_tenants == ("t0",)
    text = tel.render()
    assert "slo:" in text and "BREACHED" in text and "ok" in text


@pytest.mark.parametrize("mode", ["merged", "time_sliced"])
def test_scheduler_slo_deterministic_across_identical_runs(mode):
    a = _slo_run(mode)
    b = _slo_run(mode)
    for name in ("t0", "t1"):
        ta = a.telemetry().tenant(name)
        tb = b.telemetry().tenant(name)
        assert ta.breach_events == tb.breach_events
        # The clock-driven status fields are bit-identical.  (Merged-mode
        # delay values are measured dispatch latencies, hence excluded.)
        for field in ("tenant", "now", "window_s", "pps", "min_pps",
                      "pps_burn_rate"):
            assert getattr(ta.slo, field) == getattr(tb.slo, field), field
        if mode == "time_sliced":
            # Time-sliced delays are clock-vs-clock: fully deterministic.
            assert ta.slo == tb.slo


def test_time_sliced_delay_breach_is_deterministic():
    # Arrivals and serves are both fake-clock timestamps; with a quantum
    # that forces deferral, queue delays exceed a tight target and the
    # QUEUE_DELAY breach fires identically on every run.
    def run():
        sched = _scheduler(clock=FakeClock(step=0.5), quantum=64)
        sched.set_slo(
            SloSpec("t0", p99_queue_delay_s=1e-3, window_s=1e6)
        )
        sched.run(
            mixed_tenant_stream(SPECS, 2000, chunk_size=1000, seed=1),
            mode="time_sliced",
        )
        return sched

    a, b = run(), run()
    ev_a = a.slo_tracker("t0").events
    assert [e.objective for e in ev_a] == ["queue_delay"]
    assert ev_a == b.slo_tracker("t0").events
    assert (
        a.slo_tracker("t0").status(a._slo_last_now)
        == b.slo_tracker("t0").status(b._slo_last_now)
    )


def test_set_slo_before_admit_and_replacement():
    sched = SwitchScheduler(BIG, clock=FakeClock())
    tr1 = sched.set_slo(SloSpec("later", min_pps=1.0))
    assert sched.slo_tracker("later") is tr1
    tr2 = sched.set_slo(SloSpec("later", min_pps=2.0))
    assert sched.slo_tracker("later") is tr2 and tr2 is not tr1
    assert sched.slo_tracker("missing") is None


# ------------------------------------------------------- FleetEngine.health

def _small_lowered():
    return _compiled((8, 4), seed=3).lower()


def _packets(n, seed=0):
    return traffic.generate("uniform_random", n, 8, seed=seed)


def _engine(**kw):
    base = dict(
        plan=ExecutionPlan(backend="packed", chunk_size=32),
        clock=FakeClock(step=0.5),
        window_s=20.0,
        slo=SloSpec("fleet", p99_queue_delay_s=3600.0, min_pps=1e9),
    )
    base.update(kw)
    return FleetEngine(_small_lowered(), **base)


def _comparable(h):
    """A FleetHealth with the wall-clock-derived field normalised out."""
    return dataclasses.replace(h, overlap_ratio=None)


def test_health_before_any_serve_is_empty_but_valid():
    eng = _engine()
    h = eng.health(now=0.0)
    assert h.streams == 0 and h.chunks == 0 and h.packets == 0
    assert h.windowed_pps == 0.0 and h.chunk_p99_s is None
    assert h.queue_depth == 0 and h.queue_capacity == eng.queue_depth
    assert h.slo is not None and not h.slo.breached  # idle = no data
    assert "fleet health" in h.render()


def test_health_snapshot_and_determinism():
    streams = [_packets(130, seed=8), _packets(77, seed=9)]

    def serve_once():
        eng = _engine()
        eng.serve(streams)
        return eng

    a, b = serve_once(), serve_once()
    now = a._last_now
    assert now == b._last_now        # same clock-call count per block
    ha, hb = a.health(now=now), b.health(now=now)
    assert _comparable(ha) == _comparable(hb)
    # Live sanity: the snapshot reflects the run.
    assert ha.streams == 2 and ha.packets == 130 + 77
    assert ha.chunks == max(-(-130 // 32), -(-77 // 32))
    assert ha.windowed_pps > 0
    assert len(ha.per_stream_pps) == 2 and all(
        p > 0 for p in ha.per_stream_pps
    )
    assert ha.chunk_p99_s is not None and ha.chunk_p99_s > 0
    assert ha.overlap_ratio is not None  # a serve completed
    # The 1e9-pps floor is unreachable: a THROUGHPUT breach, exactly once.
    assert ha.slo is not None and ha.slo.breached
    assert [e.objective for e in ha.breach_events] == ["throughput"]
    assert ha.breach_events == hb.breach_events
    assert "BREACHED" in ha.render()


def test_health_without_slo_and_window_passed():
    eng = _engine(slo=None, window_s=2.0)
    eng.serve([_packets(64)])
    h = eng.health(now=eng._last_now)
    assert h.slo is None and h.breach_events == ()
    assert h.windowed_pps > 0
    # Query far past the window: everything has rotated out.
    later = eng.health(now=eng._last_now + 100.0)
    assert later.windowed_pps == 0.0 and later.chunk_p99_s is None
    assert later.packets == 64      # cumulative totals never rotate


def test_health_roofline_fields_absent_when_obs_disabled():
    # Roofline probing rides the obs switch; with obs off the health
    # snapshot simply reports no bound rather than paying for a probe.
    eng = _engine()
    eng.serve([_packets(64)])
    h = eng.health(now=eng._last_now)
    assert h.roofline_pps_bound is None and h.roofline_fraction is None
