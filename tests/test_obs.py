"""Runtime observability layer: metrics math, tracing, exporters, no-op path.

The load-bearing contracts:

* **Histogram/percentile math** — empty, single-sample (exact), many-sample
  (within the log-bucket relative error), weighted and vectorized
  observation, and associative merge.
* **Trace schema** — spans nest, export as valid Chrome Trace Event JSON
  ("X" events with ts/dur in microseconds), and keep compile vs execute
  categories distinct.
* **Observation only** — with observability disabled the instrumented hot
  path is bit-exact vs enabled, and the disabled span call is a cheap
  no-op (bounded-overhead check with a generous CI-safe bound).
"""
import json
import time

import numpy as np
import pytest

from repro import obs
from repro.core import bnn, compile_bnn
from repro.dataplane import (
    SwitchScheduler,
    TenantTrafficSpec,
    execute_stream,
    lower_program,
    mixed_tenant_stream,
    traffic,
)
from repro.obs.export import render_prometheus, write_chrome_trace
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.tracing import Tracer


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with observability disabled."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------- histogram

class TestHistogram:
    def test_empty(self):
        h = Histogram()
        assert h.count == 0
        assert h.quantile(0.5) is None
        assert h.p50 is None and h.p95 is None and h.p99 is None

    def test_single_sample_is_exact(self):
        h = Histogram()
        h.observe(0.037)
        for q in (0.0, 0.5, 0.95, 0.99, 1.0):
            assert h.quantile(q) == pytest.approx(0.037)
        assert h.count == 1
        assert h.total == pytest.approx(0.037)

    def test_zero_bucket_and_negative_rejected(self):
        h = Histogram()
        h.observe(0.0)
        h.observe(0.0)
        assert h.count == 2
        assert h.quantile(0.5) == 0.0
        # Negatives are a caller bug (delays are clamped at the callsite).
        with pytest.raises(ValueError):
            h.observe(-1.0)

    def test_quantile_relative_error(self):
        rng = np.random.default_rng(0)
        vals = rng.lognormal(mean=-7.0, sigma=2.0, size=20_000)
        h = Histogram()
        for v in vals:
            h.observe(float(v))
        for q in (0.5, 0.95, 0.99):
            exact = float(np.quantile(vals, q))
            got = h.quantile(q)
            # 8 buckets/octave => ~4.4% max relative quantile error.
            assert abs(got - exact) / exact < 0.05, (q, got, exact)

    def test_observe_array_matches_loop(self):
        vals = np.abs(np.random.default_rng(1).normal(size=1000)) + 1e-6
        ha, hb = Histogram(), Histogram()
        ha.observe_array(vals)
        for v in vals:
            hb.observe(float(v))
        assert ha.count == hb.count == 1000
        assert ha.total == pytest.approx(hb.total)
        assert ha.buckets == hb.buckets
        assert ha.quantile(0.5) == pytest.approx(hb.quantile(0.5))

    def test_weighted_observe(self):
        h = Histogram()
        h.observe(0.25, count=10)
        assert h.count == 10
        assert h.total == pytest.approx(2.5)
        assert h.quantile(0.5) == pytest.approx(0.25)

    def test_merge(self):
        a, b, c = Histogram(), Histogram(), Histogram()
        va = np.linspace(0.001, 0.1, 500)
        vb = np.linspace(0.05, 2.0, 700)
        a.observe_array(va)
        b.observe_array(vb)
        c.observe_array(np.concatenate([va, vb]))
        a.merge(b)
        assert a.count == c.count
        assert a.total == pytest.approx(c.total)
        assert a.vmin == c.vmin and a.vmax == c.vmax
        assert a.buckets == c.buckets

    def test_merge_empty_identity(self):
        a, b = Histogram(), Histogram()
        a.observe(1.0)
        a.merge(b)
        assert a.count == 1 and a.quantile(0.5) == pytest.approx(1.0)
        b.merge(a)
        assert b.count == 1 and b.quantile(0.5) == pytest.approx(1.0)


# ----------------------------------------------------------------- registry

class TestRegistry:
    def test_counter_gauge_roundtrip(self):
        reg = MetricsRegistry()
        reg.counter("pkts", tenant="a").inc(3)
        reg.counter("pkts", tenant="a").inc(2)
        reg.counter("pkts", tenant="b").inc()
        reg.gauge("rate").set(42.5)
        snap = {(r["name"], tuple(sorted((r.get("labels") or {}).items()))): r
                for r in reg.snapshot()}
        assert snap[("pkts", (("tenant", "a"),))]["value"] == 5
        assert snap[("pkts", (("tenant", "b"),))]["value"] == 1
        assert snap[("rate", ())]["value"] == 42.5

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_snapshot_histogram_fields(self):
        reg = MetricsRegistry()
        reg.histogram("lat").observe_array(np.full(100, 0.01))
        (row,) = reg.snapshot()
        assert row["type"] == "histogram"
        assert row["count"] == 100
        for key in ("sum", "min", "max", "mean", "p50", "p95", "p99"):
            assert key in row

    def test_prometheus_render(self):
        reg = MetricsRegistry()
        reg.counter("dataplane.packets_total", tenant="t0").inc(7)
        reg.histogram("mt.queue_delay_seconds", tenant="t0").observe(0.01)
        text = render_prometheus(reg)
        assert 'dataplane_packets_total{tenant="t0"} 7' in text
        assert 'quantile="0.99"' in text
        assert "mt_queue_delay_seconds_count" in text


# ------------------------------------------------------------------ tracing

class TestTracing:
    def test_nesting_and_chrome_schema(self, tmp_path):
        tr = Tracer()
        with tr.span("stream:run", cat="stream"):
            with tr.span("compile:chunk", cat="compile"):
                time.sleep(0.002)
            with tr.span("execute:chunk", cat="execute", packets=5):
                time.sleep(0.001)
        events = tr.chrome_trace_events()
        assert len(events) == 3
        for e in events:
            assert e["ph"] == "X"
            assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
            assert e["dur"] >= 0
            assert {"pid", "tid", "name", "cat"} <= set(e)
        by_name = {e["name"]: e for e in events}
        outer, inner = by_name["stream:run"], by_name["execute:chunk"]
        assert inner["args"]["depth"] == 1
        assert inner["args"]["parent"] == "stream:run"
        assert inner["args"]["packets"] == 5
        assert outer["ts"] <= inner["ts"]
        assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]

        path = tmp_path / "trace.json"
        write_chrome_trace(str(path), tr)
        payload = json.loads(path.read_text())
        assert isinstance(payload["traceEvents"], list)
        assert {e["cat"] for e in payload["traceEvents"]} == {
            "stream", "compile", "execute",
        }

    def test_total_by_category_containment(self):
        tr = Tracer()
        with tr.span("outer", cat="execute"):
            with tr.span("inner", cat="execute"):
                time.sleep(0.001)
        totals = tr.total_by_category()
        (_, outer_dur), (_, inner_dur) = (
            (r.name, r.duration) for r in tr.records
        )
        # Same-category nesting must not double-count.
        assert totals["execute"] == pytest.approx(
            max(r.duration for r in tr.records)
        )

    def test_span_exception_still_records(self):
        tr = Tracer()
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("x")
        assert len(tr.records) == 1
        assert tr.records[0].name == "boom"


# ---------------------------------------------------- global switch / no-op

class TestGlobalSwitch:
    def test_disabled_span_is_noop_singleton(self):
        obs.disable()
        a = obs.span("x")
        b = obs.span("y", cat="execute", packets=3)
        assert a is b
        with a:
            pass
        assert not obs.tracer().records

    def test_enable_from_env(self, monkeypatch):
        monkeypatch.setenv(obs.OBS_ENV, "0")
        assert obs.enable_from_env() is False
        assert not obs.enabled()
        monkeypatch.setenv(obs.OBS_ENV, "1")
        assert obs.enable_from_env() is True
        assert obs.enabled()

    def test_disabled_span_overhead_bounded(self):
        obs.disable()
        n = 50_000
        t0 = time.perf_counter()
        for _ in range(n):
            with obs.span("bench"):
                pass
        per_call = (time.perf_counter() - t0) / n
        # Generous CI-safe bound: the no-op path must stay in the microsecond
        # class — ~3 calls per multi-ms chunk dispatch keeps overhead <<5%.
        assert per_call < 20e-6, f"{per_call * 1e9:.0f}ns per disabled span"


# ------------------------------------------------- end-to-end instrumented

def _small_lp():
    import jax

    params = bnn.init_params(bnn.BnnSpec((16, 8, 4)), jax.random.PRNGKey(0))
    return lower_program(compile_bnn([np.asarray(w) for w in params]))


class TestInstrumentedPaths:
    def test_stream_bit_exact_disabled_vs_enabled(self):
        lp = _small_lp()

        def run():
            return execute_stream(
                lp,
                traffic.stream("uniform_random", 2048, 16, chunk_size=512),
                chunk_size=512,
                backend="jnp",
                collect=True,
            )

        obs.disable()
        off = run()
        obs.enable(reset=True)
        on = run()
        assert np.array_equal(off.outputs, on.outputs)
        assert off.packets == on.packets

    def test_stream_emits_metrics_and_spans(self):
        lp = _small_lp()
        obs.enable(reset=True)
        execute_stream(
            lp,
            traffic.stream("uniform_random", 1024, 16, chunk_size=256),
            chunk_size=256,
            backend="jnp",
        )
        names = {r["name"] for r in obs.registry().snapshot()}
        assert "dataplane.packets_total" in names
        assert "dataplane.chunk_seconds" in names
        cats = {r.cat for r in obs.tracer().records}
        assert {"stream", "compile", "execute"} <= cats

    def test_multitenant_per_tenant_queue_delay(self):
        import jax

        progs = []
        for i, shape in enumerate([(16, 8, 4), (16, 12, 2)]):
            params = bnn.init_params(bnn.BnnSpec(shape), jax.random.PRNGKey(i))
            progs.append(compile_bnn([np.asarray(w) for w in params]))
        from repro.core.pipeline import ChipSpec

        chip = ChipSpec(
            num_elements=sum(p.num_elements for p in progs) + 1,
            phv_bits=sum(p.peak_phv_bits for p in progs),
            name="shared",
        )
        specs = [
            TenantTrafficSpec("uniform_random", 16, 1.0),
            TenantTrafficSpec("iot_telemetry", 16, 1.0),
        ]
        obs.enable(reset=True)
        sched = SwitchScheduler(chip, quantum=256)
        sched.admit(progs[0], name="a")
        sched.admit(progs[1], name="b")
        sched.run(
            mixed_tenant_stream(specs, 2048, chunk_size=512, seed=3),
            mode="time_sliced",
            backend="jnp",
            chunk_size=512,
            collect=False,
        )
        tel = sched.telemetry()
        rows = obs.registry().snapshot()
        qdelay = {
            (r.get("labels") or {}).get("tenant"): r
            for r in rows
            if r["name"] == "mt.queue_delay_seconds"
        }
        assert {"a", "b"} <= set(qdelay)
        for name in ("a", "b"):
            row = qdelay[name]
            assert row["count"] == tel.tenant(name).served
            assert row["p50"] is not None and row["p99"] is not None

    def test_export_all_artifacts(self, tmp_path):
        lp = _small_lp()
        obs.enable(reset=True)
        execute_stream(
            lp,
            traffic.stream("uniform_random", 512, 16, chunk_size=256),
            chunk_size=256,
            backend="jnp",
        )
        paths = obs.export_all(str(tmp_path))
        for p in paths.values():
            assert (tmp_path / p.split("/")[-1]).exists()
        rows = [
            json.loads(line)
            for line in open(paths["metrics_jsonl"])
            if line.strip()
        ]
        assert all("name" in r and "type" in r for r in rows)
        payload = json.load(open(paths["trace"]))
        assert payload["traceEvents"]


# ------------------------------------------------- telemetry per-tenant API

class TestTelemetryQueries:
    def test_tenant_lookup_by_tid_and_name(self):
        import jax

        params = bnn.init_params(bnn.BnnSpec((16, 8, 4)), jax.random.PRNGKey(0))
        prog = compile_bnn([np.asarray(w) for w in params])
        from repro.core.pipeline import ChipSpec

        chip = ChipSpec(
            num_elements=prog.num_elements + 1,
            phv_bits=prog.peak_phv_bits,
            name="solo",
        )
        sched = SwitchScheduler(chip, quantum=64, max_queue=128)
        sched.admit(prog, name="only")
        sched.run(
            mixed_tenant_stream(
                [TenantTrafficSpec("uniform_random", 16, 1.0)],
                1024,
                chunk_size=256,
                seed=0,
            ),
            mode="time_sliced",
            backend="jnp",
            chunk_size=256,
            collect=False,
        )
        tel = sched.telemetry()
        t = tel.tenant("only")
        assert tel.tenant(0) is t
        assert tel.dropped_for("only") == t.dropped
        assert tel.deferred_for(0) == t.deferred
        assert tel.total_deferred == sum(x.deferred for x in tel.tenants)
        with pytest.raises(KeyError):
            tel.tenant("nope")
        with pytest.raises(KeyError):
            tel.tenant(99)
