"""Prefill + step-wise decode must agree with the full forward pass —
the serving path's correctness contract, per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_config
from repro.models import decode_step, forward, init_params, prefill

FAMS = {
    "gqa": "phi3-mini-3.8b",
    "extreme-gqa": "chatglm3-6b",
    "mla": "minicpm3-4b",
    "moe": "qwen3-moe-30b-a3b",
    "ssm": "mamba2-1.3b",
    "hybrid": "zamba2-1.2b",
}


@pytest.mark.parametrize("fam", sorted(FAMS))
def test_prefill_decode_matches_forward(fam, rng_key):
    cfg = tiny_config(FAMS[fam])
    if cfg.moe is not None:
        import dataclasses

        # ample capacity so no tokens drop (drop-free equivalence)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    params = init_params(cfg, rng_key)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab_size)

    # ground truth: full forward logits
    full_logits, _ = forward(params, {"tokens": toks}, cfg, remat=False)

    # prefill on the first 6, decode 7..10
    last, cache = prefill(params, {"tokens": toks[:, :6]}, cfg)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, 5]), rtol=3e-2, atol=3e-2
    )

    # pad cache to length 10+ for decode
    def pad(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == 6:  # (L, B, S, ...) seq axis
            pads = [(0, 0)] * leaf.ndim
            pads[2] = (0, 8)
            return jnp.pad(leaf, pads)
        return leaf

    cache = jax.tree.map(pad, cache)
    for t in range(6, 10):
        logits, cache = decode_step(params, toks[:, t], cache, cfg)
        if t < 9:
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(full_logits[:, t]),
                rtol=3e-2, atol=3e-2,
            )
