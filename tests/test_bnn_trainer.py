"""BNN STE trainer: forward-pass parity, learning, checkpoint resume.

The load-bearing contract: at *any* latent state, the trainer's float STE
forward pass emits exactly the bits the exported bit-matrix network computes
(``bnn.forward``), which the dataplane tests already tie to the compiled
pipeline — so training-time predictions are switch predictions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn
from repro.core.bnn import binarize_ste
from repro.core.export import bit_weights_from_latent
from repro.train.bnn_trainer import (
    BnnTrainConfig,
    BnnTrainer,
    forward_bits,
    init_latent,
    make_traffic_task,
)

TINY = dict(
    layer_sizes=(16, 32, 1),
    steps=40,
    batch=128,
    train_packets_per_class=512,
    eval_packets_per_class=128,
    log_every=10,
)


def _tiny_cfg(**kw):
    return BnnTrainConfig(**{**TINY, **kw})


# -- STE primitive (shared with weights: bnn.binarize_ste) --------------------

def test_activation_ste_forward_matches_oracle_tie_rule():
    u = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
    np.testing.assert_array_equal(
        np.asarray(binarize_ste(u)), [-1.0, -1.0, 1.0, 1.0, 1.0]
    )


def test_activation_ste_gradient_gate():
    g = jax.grad(lambda u: binarize_ste(u).sum())(
        jnp.array([-2.0, -0.5, 0.5, 2.0])
    )
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 0.0])


@pytest.mark.parametrize("seed", [0, 1])
def test_forward_bits_matches_oracle_at_any_latent(seed):
    # Includes exact-zero latents (binarization boundary) alongside random.
    spec = bnn.BnnSpec((24, 16, 8, 3))
    latent = init_latent(spec, jax.random.PRNGKey(seed))
    latent[0] = latent[0].at[:4].set(0.0)
    x = np.random.default_rng(seed).integers(0, 2, (97, 24), dtype=np.int32)
    got = np.asarray(forward_bits(latent, jnp.asarray(x)))
    bits = [jnp.asarray(w) for w in bit_weights_from_latent(latent)]
    np.testing.assert_array_equal(got, np.asarray(bnn.forward(bits, jnp.asarray(x))))


# -- task generation ----------------------------------------------------------

def test_make_traffic_task_split_shapes_and_balance():
    tx, ty, ex, ey = make_traffic_task(
        ("iot_telemetry", "ddos_burst"), 300, 16, seed=5, eval_per_class=100
    )
    assert tx.shape == (600, 16) and ex.shape == (200, 16)
    assert ty.sum() == 300 and ey.sum() == 100  # balanced classes
    assert set(np.unique(tx)) <= {0, 1}
    # Temporal split from one world: eval packets are not the train packets.
    tx2, ty2, ex2, ey2 = make_traffic_task(
        ("iot_telemetry", "ddos_burst"), 300, 16, seed=5, eval_per_class=100
    )
    np.testing.assert_array_equal(tx, tx2)  # deterministic
    np.testing.assert_array_equal(ex, ex2)


def test_config_validation():
    with pytest.raises(ValueError, match="exactly 2 scenarios"):
        BnnTrainConfig(scenarios=("uniform_random",))
    with pytest.raises(ValueError, match="final layer"):
        BnnTrainConfig(layer_sizes=(16, 8, 4))
    with pytest.raises(KeyError):
        BnnTrainConfig(scenarios=("uniform_random", "nope"))


# -- training -----------------------------------------------------------------

def test_training_learns_and_history_logs():
    # Prototype-based traffic vs noise is separable even at this tiny budget;
    # pairs of near-uniform folded headers (flow_tuple at 16b) are not.
    tr = BnnTrainer(_tiny_cfg(scenarios=("uniform_random", "adversarial_bitflip")))
    summary = tr.train()
    assert summary["final_step"] == tr.cfg.steps
    steps = [h["step"] for h in summary["history"]]
    assert steps[0] == 1 and steps[-1] == tr.cfg.steps
    # The task is learnable: better than chance on the held-out split.
    assert tr.evaluate_held_out()["accuracy"] > 0.6
    first, last = summary["history"][0], summary["history"][-1]
    assert last["loss"] < first["loss"]


def test_trainer_export_is_bit_exact_with_ste_forward():
    tr = BnnTrainer(_tiny_cfg(steps=10))
    tr.train()
    ex = tr.export()
    assert ex.spec.layer_sizes == tr.cfg.layer_sizes
    from repro.core.export import verify_roundtrip

    rep = verify_roundtrip(
        ex, tr.eval_x, reference_bits=tr.forward_bits(tr.eval_x)
    )
    assert rep.ok


def test_checkpoint_resume_is_bit_consistent(tmp_path):
    straight = BnnTrainer(_tiny_cfg(steps=12, checkpoint_dir=None))
    straight.train()

    cfg = _tiny_cfg(
        steps=6, checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=3
    )
    BnnTrainer(cfg).train()  # "crashes" after 6 steps (checkpoint written)

    resumed = BnnTrainer(dataclasses.replace(cfg, steps=12))
    summary = resumed.train()
    assert summary["resumed"]
    # (seed, step)-deterministic batches: the resumed run replays the
    # uninterrupted one exactly.
    for a, b in zip(straight.latent, resumed.latent):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_noop_when_already_done(tmp_path):
    cfg = _tiny_cfg(steps=5, checkpoint_dir=str(tmp_path / "ck"))
    BnnTrainer(cfg).train()
    again = BnnTrainer(cfg)
    summary = again.train()
    assert summary["resumed"] and summary["final_step"] == 5
