"""Multi-tenant switch scheduling: merging, time-slicing, admission, traffic.

The load-bearing contract is per-tenant bit-exactness: for N >= 3 tenants on
one mixed packet stream, each tenant's outputs must equal its own
single-program run — through ``executor.execute``, the legacy interpreter,
and the ``bnn.forward`` oracle — in both merged and time-sliced modes, under
any stream chunking.  Merging relocates registers and concatenates element
ranges; it must never change results.
"""
import jax
import numpy as np
import pytest

from repro.core import bnn, compile_bnn
from repro.core.interpreter import run_program
from repro.core.pipeline import ChipSpec
from repro.dataplane import (
    AdmissionError,
    SwitchScheduler,
    TenantTrafficSpec,
    execute,
    mixed_tenant_generate,
    mixed_tenant_stream,
    traffic,
)
from repro.dataplane.lowering import peak_stage_rows
from repro.dataplane.multitenant import interleave_lowered, merge_lowered

SHAPES = [(16, 8, 4), (32, 16), (8, 12, 6)]
SPECS = [
    TenantTrafficSpec("ddos_burst", 16, 3.0),
    TenantTrafficSpec("flow_tuple", 32, 1.0),
    TenantTrafficSpec("iot_telemetry", 8, 2.0),
]
BIG = ChipSpec(num_elements=256, name="bigchip")


def _compiled(sizes, seed=0):
    spec = bnn.BnnSpec(sizes)
    params = bnn.init_params(spec, jax.random.PRNGKey(seed))
    weights = [np.asarray(w) for w in params]
    return params, compile_bnn(weights)


@pytest.fixture(scope="module")
def tenants3():
    """3 compiled programs of different shapes + their oracle params."""
    return [_compiled(s, seed=i) for i, s in enumerate(SHAPES)]


def _scheduler(tenants3, **kw):
    sched = SwitchScheduler(BIG, **kw)
    for i, (spec, (_, prog)) in enumerate(zip(SPECS, tenants3)):
        sched.admit(prog, name=f"t{i}", weight=spec.weight)
    return sched


# -- merging ------------------------------------------------------------------

def test_merge_lowered_layout(tenants3):
    lps = [prog.lower() for _, prog in tenants3]
    mp = merge_lowered(lps, BIG)
    # Element ranges tile the merged table in tenant order.
    assert mp.element_ranges[0][0] == 0
    assert mp.element_ranges[-1][1] == mp.lowered.num_elements
    assert all(
        a[1] == b[0] for a, b in zip(mp.element_ranges, mp.element_ranges[1:])
    )
    # Slot windows are disjoint and cover the shared file.
    assert mp.slot_windows[0][0] == 0
    assert all(
        a[1] == b[0] for a, b in zip(mp.slot_windows, mp.slot_windows[1:])
    )
    assert mp.slot_windows[-1][1] == mp.lowered.num_slots
    # The program-id column tags every element with its owner.
    for t, (a, b) in enumerate(mp.element_ranges):
        assert (mp.element_program[a:b] == t).all()
    # No remapped row can address outside its window (or the shared null).
    null = mp.lowered.null_slot
    for t, ((a, b), (s0, s1)) in enumerate(
        zip(mp.element_ranges, mp.slot_windows)
    ):
        for tbl in (mp.lowered.dst, mp.lowered.src0, mp.lowered.src1):
            seg = tbl[a:b]
            ok = ((seg >= s0) & (seg < s1)) | (seg == null)
            assert ok.all()


def test_interleave_lowered_windows_disjoint_per_row(tenants3):
    """Every interleaved row addresses only its owner tenant's window."""
    lps = [prog.lower() for _, prog in tenants3]
    mp = interleave_lowered(lps, BIG)
    assert mp.layout == "interleave"
    # Slot windows are pairwise disjoint and inside the shared file.
    spans = sorted(mp.slot_windows)
    assert all(a[1] <= b[0] for a, b in zip(spans, spans[1:]))
    assert spans[0][0] >= 0 and spans[-1][1] <= mp.lowered.num_slots
    null = mp.lowered.null_slot
    for t in range(len(lps)):
        s0, s1 = mp.slot_windows[t]
        sel = mp.row_tenant == t
        assert sel.any()
        for tbl in (mp.lowered.dst, mp.lowered.src0, mp.lowered.src1):
            seg = tbl[sel]
            ok = ((seg >= s0) & (seg < s1)) | (seg == null)
            assert ok.all()
    # Pad rows own no tenant; true rows all do.
    total_true = sum(int(lp.rows_per_element.sum()) for lp in lps)
    assert int((mp.row_tenant >= 0).sum()) == total_true


def test_interleave_invariant_to_insertion_order(tenants3):
    """Same tenant set, any admission order -> the same fingerprint-keyed
    merged plan (tables included), so compiled executors are shared."""
    lps = [prog.lower() for _, prog in tenants3]
    perm = [2, 0, 1]
    mp_a = interleave_lowered(lps, BIG)
    mp_b = interleave_lowered([lps[t] for t in perm], BIG)
    assert mp_a.lowered.fingerprint() == mp_b.lowered.fingerprint()
    for name in ("opcode", "dst", "src0", "src1", "imm0", "imm1", "mask",
                 "first_write", "rows_per_element"):
        np.testing.assert_array_equal(
            getattr(mp_a.lowered, name), getattr(mp_b.lowered, name)
        )
    # Routing stays tid-indexed: tenant t in mp_a is tenant perm.index(t)
    # in mp_b, and their windows/IO tables must agree.
    for t_a, t_b in [(t, perm.index(t)) for t in range(len(lps))]:
        assert mp_a.slot_windows[t_a] == mp_b.slot_windows[t_b]
        np.testing.assert_array_equal(
            mp_a.in_slot[t_a], mp_b.in_slot[t_b]
        )
        np.testing.assert_array_equal(
            mp_a.out_slot[t_a], mp_b.out_slot[t_b]
        )


def test_interleave_uninterleave_round_trips_each_tenant(tenants3):
    """``tenant_rows`` recovers every tenant's relocated table exactly."""
    lps = [prog.lower() for _, prog in tenants3]
    mp = interleave_lowered(lps, BIG)
    fields = ("opcode", "dst", "src0", "src1", "imm0", "imm1", "mask",
              "first_write")
    for t, lp in enumerate(lps):
        rel = lp.with_slot_window(
            mp.slot_windows[t][0], mp.lowered.num_slots
        )
        elems, rows, got = mp.tenant_rows(t)
        assert elems.shape == rows.shape == (int(lp.rows_per_element.sum()),)
        # Per-element row counts survive the round trip.
        np.testing.assert_array_equal(
            np.bincount(elems, minlength=lp.num_elements),
            lp.rows_per_element,
        )
        for name in fields:
            np.testing.assert_array_equal(
                got[name], getattr(rel, name)[elems, rows]
            )


def test_peak_stage_rows_matches_manual_sum(tenants3):
    lps = [prog.lower() for _, prog in tenants3]
    max_e = max(lp.num_elements for lp in lps)
    want = max(
        sum(
            int(lp.rows_per_element[e])
            for lp in lps
            if e < lp.num_elements
        )
        for e in range(max_e)
    )
    assert peak_stage_rows(lps) == want
    assert peak_stage_rows([]) == 0


def test_merged_register_windows_reject_bad_fit(tenants3):
    lp = tenants3[0][1].lower()
    with pytest.raises(ValueError):
        lp.with_slot_window(1, lp.num_slots)  # offset pushes past the file
    with pytest.raises(ValueError):
        lp.pad_rows(lp.max_rows - 1)


# -- per-tenant bit-exactness (the acceptance criterion) ----------------------

@pytest.mark.parametrize("mode", ["merged", "time_sliced"])
def test_scheduler_bit_exact_per_tenant(tenants3, mode):
    sched = _scheduler(tenants3)
    n = 2000
    tids, bits = mixed_tenant_generate(SPECS, n, seed=7)
    res = sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=300, seed=7),
        mode=mode,
        chunk_size=512,
    )
    assert res.mode == mode and res.packets == n
    for t, (params, prog) in enumerate(tenants3):
        mine = bits[tids == t][:, : prog.input_bits]
        got = res.outputs_for(t)
        want = execute(sched.tenants[t].lowered, mine, backend="jnp")
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(
            got, np.asarray(run_program(prog, mine))
        )
        np.testing.assert_array_equal(
            got, np.asarray(bnn.forward(params, np.asarray(mine)))
        )
        st = res.stats_for(t)
        assert st.packets == st.served + st.dropped == mine.shape[0]
        assert st.dropped == 0


def test_scheduler_modes_agree_and_chunking_is_irrelevant(tenants3):
    sched = _scheduler(tenants3)
    n = 1500
    merged = sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=256, seed=3),
        mode="merged",
        chunk_size=128,
    )
    sliced = sched.run(
        mixed_tenant_generate(SPECS, n, seed=3),  # one-shot pair, no chunks
        mode="time_sliced",
    )
    for t in range(3):
        np.testing.assert_array_equal(
            merged.outputs_for(t), sliced.outputs_for(t)
        )


def test_scheduler_merged_pallas_backend_matches(tenants3):
    sched = _scheduler(tenants3)
    n = 300
    pair = mixed_tenant_generate(SPECS, n, seed=9)
    want = sched.run(pair, mode="merged", backend="jnp", chunk_size=128)
    got = sched.run(
        pair, mode="merged", backend="pallas", interpret=True, chunk_size=128
    )
    for t in range(3):
        np.testing.assert_array_equal(got.outputs_for(t), want.outputs_for(t))


# -- admission ----------------------------------------------------------------

def test_admission_rejects_oversized_program(tenants3):
    _, prog = tenants3[0]
    with pytest.raises(AdmissionError, match="elements"):
        SwitchScheduler(ChipSpec(num_elements=prog.num_elements - 1)).admit(
            prog
        )
    tiny_phv = ChipSpec(num_elements=256, phv_bits=prog.peak_phv_bits - 1)
    with pytest.raises(AdmissionError, match="PHV"):
        SwitchScheduler(tiny_phv).admit(prog)


def test_admission_forced_merged_rejects_overflow_auto_falls_back(tenants3):
    # Concat layout sums element footprints, so a chip one element short of
    # the pair still rejects a forced merged admit.
    _, a = tenants3[0]
    _, b = tenants3[2]
    chip = ChipSpec(num_elements=a.num_elements + b.num_elements - 1)
    forced = SwitchScheduler(chip, mode="merged", merged="concat")
    forced.admit(a)
    with pytest.raises(AdmissionError, match="merged footprint"):
        forced.admit(b)
    auto = SwitchScheduler(chip, mode="auto", merged="concat")
    auto.admit(a)
    auto.admit(b)
    assert auto.resolve_mode() == "time_sliced"
    with pytest.raises(ValueError, match="time-slice|time_sliced"):
        auto.run(mixed_tenant_generate(SPECS[:2], 64, seed=0), mode="merged")


def test_admission_interleave_rejects_on_stage_budget(tenants3):
    # Interleave's budget is the widest *shared stage*, not the element sum:
    # a chip whose per-stage ALU count is one short of the pair's peak
    # rejects under interleave but still admits under concat.
    _, a = tenants3[0]
    _, b = tenants3[2]
    lps = [a.lower(), b.lower()]
    peak = peak_stage_rows(lps)
    assert peak > max(peak_stage_rows([lp]) for lp in lps)
    chip = ChipSpec(num_elements=256, max_parallel_ops=peak - 1)
    forced = SwitchScheduler(chip, mode="merged")  # interleave default
    forced.admit(a)
    with pytest.raises(AdmissionError, match="parallel ops"):
        forced.admit(b)
    auto = SwitchScheduler(chip, mode="auto")
    auto.admit(a)
    auto.admit(b)
    assert auto.resolve_mode() == "time_sliced"
    # Concat does not share stages, so the same chip merges fine.
    concat = SwitchScheduler(chip, mode="merged", merged="concat")
    concat.admit(a)
    concat.admit(b)
    assert concat.resolve_mode() == "merged"


def test_scheduler_requires_tenants_and_validates_ids(tenants3):
    with pytest.raises(ValueError, match="no tenants"):
        SwitchScheduler(BIG).run((np.zeros(4, np.int32), np.zeros((4, 8))))
    sched = _scheduler(tenants3)
    bad = (np.array([0, 7], np.int32), np.zeros((2, 32), np.int32))
    with pytest.raises(ValueError, match="tenant ids"):
        sched.run(bad, mode="merged", chunk_size=64)


# -- time-slicing policy ------------------------------------------------------

def test_time_sliced_drops_at_queue_capacity_and_conserves(tenants3):
    sched = _scheduler(tenants3, max_queue=200, quantum=128)
    n = 3000
    res = sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=1000, seed=7),
        mode="time_sliced",
    )
    assert sum(st.dropped for st in res.tenants) > 0
    for t, (_, prog) in enumerate(tenants3):
        st = res.stats_for(t)
        assert st.packets == st.served + st.dropped  # conservation
        assert res.outputs_for(t).shape == (st.served, prog.output_bits)
    assert res.packets == n


def test_time_sliced_weighted_quanta_and_deferral(tenants3):
    sched = _scheduler(tenants3, quantum=256)
    # Heaviest tenant (weight 3) gets the full quantum per turn; the others
    # proportionally fewer.
    assert sched._quanta() == [256, max(1, round(256 / 3)), round(256 * 2 / 3)]
    res = sched.run(
        mixed_tenant_stream(SPECS, 4000, chunk_size=2000, seed=1),
        mode="time_sliced",
    )
    # Arrival bursts far exceed every quantum: backlog must defer, and the
    # chip must alternate (every tenant gets multiple slices).
    assert all(st.deferred > 0 for st in res.tenants)
    assert all(st.slices >= 2 for st in res.tenants)


# -- telemetry ----------------------------------------------------------------

def test_multitenant_telemetry_rollup(tenants3):
    sched = _scheduler(tenants3)
    n = 1000
    res = sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=250, seed=5),
        mode="merged",
        chunk_size=256,
    )
    tel = sched.telemetry(res)
    assert tel.mode == "merged"
    assert tel.total_packets == n and tel.total_dropped == 0
    # Interleave packs tenants onto shared stages: the footprint is the
    # deepest tenant, not the sum.
    assert tel.elements_used == max(p.num_elements for _, p in tenants3)
    assert tel.elements_available == BIG.num_elements
    weights = [t.weight for t in tel.tenants]
    assert weights == [3.0, 1.0, 2.0]
    for t in tel.tenants:
        # Merged mode: every tenant rides the full line rate.
        assert t.analytic_pps == BIG.packets_per_second
        assert t.peak_occupancy_bits > 0
        assert 0 < t.peak_alu_utilization <= 1.0
        assert t.measured_pps is not None and t.measured_pps > 0
    text = tel.render()
    assert "merged" in text and "tenants=3" in text

    sliced = sched.run(
        mixed_tenant_generate(SPECS, 500, seed=5), mode="time_sliced"
    )
    tel2 = sched.telemetry(sliced)
    total_w = sum(weights)
    for t, w in zip(tel2.tenants, weights):
        assert t.analytic_pps == pytest.approx(
            BIG.packets_per_second * w / total_w
        )


def test_telemetry_tolerates_tenant_admitted_after_run(tenants3):
    sched = SwitchScheduler(BIG)
    sched.admit(tenants3[0][1], weight=1.0)
    sched.admit(tenants3[1][1], weight=1.0)
    sched.run(mixed_tenant_generate(SPECS[:2], 200, seed=2), chunk_size=128)
    late = sched.admit(tenants3[2][1], name="late", weight=1.0)
    tel = sched.telemetry()  # must not fail on the run-less tenant
    row = tel.tenants[late.tid]
    assert row.name == "late" and row.packets == 0
    assert row.measured_pps is None
    assert tel.total_packets == 200


def test_fabric_analytic_report_is_memoized(tenants3):
    from repro.dataplane import SwitchFabric

    _, prog = tenants3[0]
    fab = SwitchFabric.partition(prog, chip=ChipSpec(num_elements=8))
    assert fab.analytic_report() is fab.analytic_report()
    # Recirculation accounting: passes == hop count, rate divides by it.
    recirc = SwitchFabric.partition(
        prog, mode="recirculate", chip=ChipSpec(num_elements=8)
    )
    rep = recirc.analytic_report()
    assert rep.passes == recirc.num_hops
    assert rep.packets_per_second == pytest.approx(
        recirc.chip.packets_per_second / recirc.num_hops
    )


# -- mixed-tenant traffic -----------------------------------------------------

def test_mixed_traffic_shapes_weights_and_padding():
    n = 4000
    tids, bits = mixed_tenant_generate(SPECS, n, seed=11)
    assert tids.shape == (n,) and tids.dtype == np.int32
    assert bits.shape == (n, 32) and bits.dtype == np.int32
    assert set(np.unique(bits)) <= {0, 1}
    # Width padding beyond a tenant's input_bits is zero.
    for t, spec in enumerate(SPECS):
        assert (bits[tids == t][:, spec.input_bits :] == 0).all()
    # Arrival shares track the weights (3:1:2 over 4000 draws).
    counts = np.bincount(tids, minlength=3) / n
    np.testing.assert_allclose(counts, [0.5, 1 / 6, 1 / 3], atol=0.05)


def test_mixed_traffic_tenant_subsequence_is_its_scenario_stream():
    tids, bits = mixed_tenant_generate(SPECS, 2000, seed=7)
    for t, spec in enumerate(SPECS):
        mine = bits[tids == t][:, : spec.input_bits]
        ref = traffic.generate(
            spec.scenario,
            mine.shape[0],
            spec.input_bits,
            seed=traffic.tenant_stream_seed(7, t),
        )
        np.testing.assert_array_equal(mine, ref)


def test_mixed_traffic_validation():
    with pytest.raises(ValueError):
        list(mixed_tenant_stream([], 10, chunk_size=4))
    with pytest.raises(KeyError):
        TenantTrafficSpec("nope", 8)
    with pytest.raises(ValueError):
        TenantTrafficSpec("uniform_random", 8, weight=0.0)
    with pytest.raises(ValueError):
        TenantTrafficSpec("uniform_random", 0)
