"""Multi-tenant switch scheduling: merging, time-slicing, admission, traffic.

The load-bearing contract is per-tenant bit-exactness: for N >= 3 tenants on
one mixed packet stream, each tenant's outputs must equal its own
single-program run — through ``executor.execute``, the legacy interpreter,
and the ``bnn.forward`` oracle — in both merged and time-sliced modes, under
any stream chunking.  Merging relocates registers and concatenates element
ranges; it must never change results.
"""
import jax
import numpy as np
import pytest

from repro.core import bnn, compile_bnn
from repro.core.interpreter import run_program
from repro.core.pipeline import ChipSpec
from repro.dataplane import (
    AdmissionError,
    SwitchScheduler,
    TenantTrafficSpec,
    execute,
    mixed_tenant_generate,
    mixed_tenant_stream,
    traffic,
)
from repro.dataplane.multitenant import merge_lowered

SHAPES = [(16, 8, 4), (32, 16), (8, 12, 6)]
SPECS = [
    TenantTrafficSpec("ddos_burst", 16, 3.0),
    TenantTrafficSpec("flow_tuple", 32, 1.0),
    TenantTrafficSpec("iot_telemetry", 8, 2.0),
]
BIG = ChipSpec(num_elements=256, name="bigchip")


def _compiled(sizes, seed=0):
    spec = bnn.BnnSpec(sizes)
    params = bnn.init_params(spec, jax.random.PRNGKey(seed))
    weights = [np.asarray(w) for w in params]
    return params, compile_bnn(weights)


@pytest.fixture(scope="module")
def tenants3():
    """3 compiled programs of different shapes + their oracle params."""
    return [_compiled(s, seed=i) for i, s in enumerate(SHAPES)]


def _scheduler(tenants3, **kw):
    sched = SwitchScheduler(BIG, **kw)
    for i, (spec, (_, prog)) in enumerate(zip(SPECS, tenants3)):
        sched.admit(prog, name=f"t{i}", weight=spec.weight)
    return sched


# -- merging ------------------------------------------------------------------

def test_merge_lowered_layout(tenants3):
    lps = [prog.lower() for _, prog in tenants3]
    mp = merge_lowered(lps, BIG)
    # Element ranges tile the merged table in tenant order.
    assert mp.element_ranges[0][0] == 0
    assert mp.element_ranges[-1][1] == mp.lowered.num_elements
    assert all(
        a[1] == b[0] for a, b in zip(mp.element_ranges, mp.element_ranges[1:])
    )
    # Slot windows are disjoint and cover the shared file.
    assert mp.slot_windows[0][0] == 0
    assert all(
        a[1] == b[0] for a, b in zip(mp.slot_windows, mp.slot_windows[1:])
    )
    assert mp.slot_windows[-1][1] == mp.lowered.num_slots
    # The program-id column tags every element with its owner.
    for t, (a, b) in enumerate(mp.element_ranges):
        assert (mp.element_program[a:b] == t).all()
    # No remapped row can address outside its window (or the shared null).
    null = mp.lowered.null_slot
    for t, ((a, b), (s0, s1)) in enumerate(
        zip(mp.element_ranges, mp.slot_windows)
    ):
        for tbl in (mp.lowered.dst, mp.lowered.src0, mp.lowered.src1):
            seg = tbl[a:b]
            ok = ((seg >= s0) & (seg < s1)) | (seg == null)
            assert ok.all()


def test_merged_register_windows_reject_bad_fit(tenants3):
    lp = tenants3[0][1].lower()
    with pytest.raises(ValueError):
        lp.with_slot_window(1, lp.num_slots)  # offset pushes past the file
    with pytest.raises(ValueError):
        lp.pad_rows(lp.max_rows - 1)


# -- per-tenant bit-exactness (the acceptance criterion) ----------------------

@pytest.mark.parametrize("mode", ["merged", "time_sliced"])
def test_scheduler_bit_exact_per_tenant(tenants3, mode):
    sched = _scheduler(tenants3)
    n = 2000
    tids, bits = mixed_tenant_generate(SPECS, n, seed=7)
    res = sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=300, seed=7),
        mode=mode,
        chunk_size=512,
    )
    assert res.mode == mode and res.packets == n
    for t, (params, prog) in enumerate(tenants3):
        mine = bits[tids == t][:, : prog.input_bits]
        got = res.outputs_for(t)
        want = execute(sched.tenants[t].lowered, mine, backend="jnp")
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(
            got, np.asarray(run_program(prog, mine))
        )
        np.testing.assert_array_equal(
            got, np.asarray(bnn.forward(params, np.asarray(mine)))
        )
        st = res.stats_for(t)
        assert st.packets == st.served + st.dropped == mine.shape[0]
        assert st.dropped == 0


def test_scheduler_modes_agree_and_chunking_is_irrelevant(tenants3):
    sched = _scheduler(tenants3)
    n = 1500
    merged = sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=256, seed=3),
        mode="merged",
        chunk_size=128,
    )
    sliced = sched.run(
        mixed_tenant_generate(SPECS, n, seed=3),  # one-shot pair, no chunks
        mode="time_sliced",
    )
    for t in range(3):
        np.testing.assert_array_equal(
            merged.outputs_for(t), sliced.outputs_for(t)
        )


def test_scheduler_merged_pallas_backend_matches(tenants3):
    sched = _scheduler(tenants3)
    n = 300
    pair = mixed_tenant_generate(SPECS, n, seed=9)
    want = sched.run(pair, mode="merged", backend="jnp", chunk_size=128)
    got = sched.run(
        pair, mode="merged", backend="pallas", interpret=True, chunk_size=128
    )
    for t in range(3):
        np.testing.assert_array_equal(got.outputs_for(t), want.outputs_for(t))


# -- admission ----------------------------------------------------------------

def test_admission_rejects_oversized_program(tenants3):
    _, prog = tenants3[0]
    with pytest.raises(AdmissionError, match="elements"):
        SwitchScheduler(ChipSpec(num_elements=prog.num_elements - 1)).admit(
            prog
        )
    tiny_phv = ChipSpec(num_elements=256, phv_bits=prog.peak_phv_bits - 1)
    with pytest.raises(AdmissionError, match="PHV"):
        SwitchScheduler(tiny_phv).admit(prog)


def test_admission_forced_merged_rejects_overflow_auto_falls_back(tenants3):
    _, a = tenants3[0]
    _, b = tenants3[2]
    chip = ChipSpec(num_elements=a.num_elements + b.num_elements - 1)
    forced = SwitchScheduler(chip, mode="merged")
    forced.admit(a)
    with pytest.raises(AdmissionError, match="merged footprint"):
        forced.admit(b)
    auto = SwitchScheduler(chip, mode="auto")
    auto.admit(a)
    auto.admit(b)
    assert auto.resolve_mode() == "time_sliced"
    with pytest.raises(ValueError, match="time-slice|time_sliced"):
        auto.run(mixed_tenant_generate(SPECS[:2], 64, seed=0), mode="merged")


def test_scheduler_requires_tenants_and_validates_ids(tenants3):
    with pytest.raises(ValueError, match="no tenants"):
        SwitchScheduler(BIG).run((np.zeros(4, np.int32), np.zeros((4, 8))))
    sched = _scheduler(tenants3)
    bad = (np.array([0, 7], np.int32), np.zeros((2, 32), np.int32))
    with pytest.raises(ValueError, match="tenant ids"):
        sched.run(bad, mode="merged", chunk_size=64)


# -- time-slicing policy ------------------------------------------------------

def test_time_sliced_drops_at_queue_capacity_and_conserves(tenants3):
    sched = _scheduler(tenants3, max_queue=200, quantum=128)
    n = 3000
    res = sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=1000, seed=7),
        mode="time_sliced",
    )
    assert sum(st.dropped for st in res.tenants) > 0
    for t, (_, prog) in enumerate(tenants3):
        st = res.stats_for(t)
        assert st.packets == st.served + st.dropped  # conservation
        assert res.outputs_for(t).shape == (st.served, prog.output_bits)
    assert res.packets == n


def test_time_sliced_weighted_quanta_and_deferral(tenants3):
    sched = _scheduler(tenants3, quantum=256)
    # Heaviest tenant (weight 3) gets the full quantum per turn; the others
    # proportionally fewer.
    assert sched._quanta() == [256, max(1, round(256 / 3)), round(256 * 2 / 3)]
    res = sched.run(
        mixed_tenant_stream(SPECS, 4000, chunk_size=2000, seed=1),
        mode="time_sliced",
    )
    # Arrival bursts far exceed every quantum: backlog must defer, and the
    # chip must alternate (every tenant gets multiple slices).
    assert all(st.deferred > 0 for st in res.tenants)
    assert all(st.slices >= 2 for st in res.tenants)


# -- telemetry ----------------------------------------------------------------

def test_multitenant_telemetry_rollup(tenants3):
    sched = _scheduler(tenants3)
    n = 1000
    res = sched.run(
        mixed_tenant_stream(SPECS, n, chunk_size=250, seed=5),
        mode="merged",
        chunk_size=256,
    )
    tel = sched.telemetry(res)
    assert tel.mode == "merged"
    assert tel.total_packets == n and tel.total_dropped == 0
    assert tel.elements_used == sum(p.num_elements for _, p in tenants3)
    assert tel.elements_available == BIG.num_elements
    weights = [t.weight for t in tel.tenants]
    assert weights == [3.0, 1.0, 2.0]
    for t in tel.tenants:
        # Merged mode: every tenant rides the full line rate.
        assert t.analytic_pps == BIG.packets_per_second
        assert t.peak_occupancy_bits > 0
        assert 0 < t.peak_alu_utilization <= 1.0
        assert t.measured_pps is not None and t.measured_pps > 0
    text = tel.render()
    assert "merged" in text and "tenants=3" in text

    sliced = sched.run(
        mixed_tenant_generate(SPECS, 500, seed=5), mode="time_sliced"
    )
    tel2 = sched.telemetry(sliced)
    total_w = sum(weights)
    for t, w in zip(tel2.tenants, weights):
        assert t.analytic_pps == pytest.approx(
            BIG.packets_per_second * w / total_w
        )


def test_telemetry_tolerates_tenant_admitted_after_run(tenants3):
    sched = SwitchScheduler(BIG)
    sched.admit(tenants3[0][1], weight=1.0)
    sched.admit(tenants3[1][1], weight=1.0)
    sched.run(mixed_tenant_generate(SPECS[:2], 200, seed=2), chunk_size=128)
    late = sched.admit(tenants3[2][1], name="late", weight=1.0)
    tel = sched.telemetry()  # must not fail on the run-less tenant
    row = tel.tenants[late.tid]
    assert row.name == "late" and row.packets == 0
    assert row.measured_pps is None
    assert tel.total_packets == 200


def test_fabric_analytic_report_is_memoized(tenants3):
    from repro.dataplane import SwitchFabric

    _, prog = tenants3[0]
    fab = SwitchFabric.partition(prog, chip=ChipSpec(num_elements=8))
    assert fab.analytic_report() is fab.analytic_report()
    # Recirculation accounting: passes == hop count, rate divides by it.
    recirc = SwitchFabric.partition(
        prog, mode="recirculate", chip=ChipSpec(num_elements=8)
    )
    rep = recirc.analytic_report()
    assert rep.passes == recirc.num_hops
    assert rep.packets_per_second == pytest.approx(
        recirc.chip.packets_per_second / recirc.num_hops
    )


# -- mixed-tenant traffic -----------------------------------------------------

def test_mixed_traffic_shapes_weights_and_padding():
    n = 4000
    tids, bits = mixed_tenant_generate(SPECS, n, seed=11)
    assert tids.shape == (n,) and tids.dtype == np.int32
    assert bits.shape == (n, 32) and bits.dtype == np.int32
    assert set(np.unique(bits)) <= {0, 1}
    # Width padding beyond a tenant's input_bits is zero.
    for t, spec in enumerate(SPECS):
        assert (bits[tids == t][:, spec.input_bits :] == 0).all()
    # Arrival shares track the weights (3:1:2 over 4000 draws).
    counts = np.bincount(tids, minlength=3) / n
    np.testing.assert_allclose(counts, [0.5, 1 / 6, 1 / 3], atol=0.05)


def test_mixed_traffic_tenant_subsequence_is_its_scenario_stream():
    tids, bits = mixed_tenant_generate(SPECS, 2000, seed=7)
    for t, spec in enumerate(SPECS):
        mine = bits[tids == t][:, : spec.input_bits]
        ref = traffic.generate(
            spec.scenario,
            mine.shape[0],
            spec.input_bits,
            seed=traffic.tenant_stream_seed(7, t),
        )
        np.testing.assert_array_equal(mine, ref)


def test_mixed_traffic_validation():
    with pytest.raises(ValueError):
        list(mixed_tenant_stream([], 10, chunk_size=4))
    with pytest.raises(KeyError):
        TenantTrafficSpec("nope", 8)
    with pytest.raises(ValueError):
        TenantTrafficSpec("uniform_random", 8, weight=0.0)
    with pytest.raises(ValueError):
        TenantTrafficSpec("uniform_random", 0)
