"""Pcap ingestion: reader/writer round trips, featurizer exactness, the
scenario chunking-invariance contract, and the capture -> trainer hook.

The load-bearing contracts: (1) write -> read reproduces capture bytes
exactly in both formats and every magic variant, and malformed input raises
instead of silently dropping packets; (2) the featurizer's bit encodings
match the documented layout bit-for-bit; (3) a registered pcap scenario is
chunking-invariant exactly like the five synthetic scenarios, so every
consumer (streams, trainer tasks, mixed-tenant serving) can replay captures
under any chunking.
"""
import struct

import numpy as np
import pytest

from repro.dataplane import pcap, traffic
from repro.dataplane.pcap import PcapFormatError


def _capture(n=256, seed=0):
    pkts, ts, labels = pcap.synthesize_capture(n, seed=seed)
    return pkts, ts, labels


# -- writers/readers ---------------------------------------------------------

@pytest.mark.parametrize("endian", ["<", ">"])
@pytest.mark.parametrize("nanosecond", [False, True])
def test_classic_round_trip(endian, nanosecond):
    pkts, ts, _ = _capture(200)
    raw = pcap.write_pcap(pkts, ts, endian=endian, nanosecond=nanosecond)
    cap = pcap.read_pcap(raw)
    assert cap.fmt == "pcap"
    assert cap.linktype == pcap.LINKTYPE_ETHERNET
    assert cap.packets() == pkts
    atol = 2e-9 if nanosecond else 1e-6
    np.testing.assert_allclose(cap.timestamps, ts, atol=atol)


@pytest.mark.parametrize("endian", ["<", ">"])
def test_pcapng_round_trip(endian):
    pkts, ts, _ = _capture(200)
    raw = pcap.write_pcapng(pkts, ts, endian=endian)
    cap = pcap.read_pcap(raw)
    assert cap.fmt == "pcapng"
    assert cap.packets() == pkts
    np.testing.assert_allclose(cap.timestamps, ts, atol=1e-6)


def test_round_trip_via_files(tmp_path):
    pkts, ts, _ = _capture(64)
    p1 = tmp_path / "t.pcap"
    p2 = tmp_path / "t.pcapng"
    pcap.write_pcap(pkts, ts, path=p1)
    pcap.write_pcapng(pkts, ts, path=p2)
    assert pcap.read_pcap(p1).packets() == pkts
    assert pcap.read_pcap(p2).packets() == pkts


def test_writers_declare_snaplen_covering_jumbo_packets():
    # caplen > declared snaplen reads as corruption to libpcap tools; a
    # jumbo packet must raise the declared snaplen in both formats.
    jumbo = _tcp_packet() + b"\x00" * 70000
    raw = pcap.write_pcap([jumbo], [0.0])
    assert struct.unpack_from("<I", raw, 16)[0] >= len(jumbo)  # snaplen
    assert pcap.read_pcap(raw).packets() == [jumbo]
    raw_ng = pcap.write_pcapng([jumbo], [0.0])
    assert struct.unpack_from("<I", raw_ng, 28 + 12)[0] >= len(jumbo)
    assert pcap.read_pcap(raw_ng).packets() == [jumbo]


def test_synthesize_capture_deterministic():
    a = pcap.synthesize_capture(300, seed=7)
    b = pcap.synthesize_capture(300, seed=7)
    assert a[0] == b[0]
    np.testing.assert_array_equal(a[1], b[1])
    np.testing.assert_array_equal(a[2], b[2])
    c = pcap.synthesize_capture(300, seed=8)
    assert c[0] != a[0]


def test_empty_capture_round_trips():
    raw = pcap.write_pcap([], [])
    cap = pcap.read_pcap(raw)
    assert cap.num_packets == 0
    assert pcap.featurize(cap).shape == (0, pcap.PCAP_FEATURE_BITS)
    assert pcap.featurize(cap, 64).shape == (0, 64)  # fold of zero rows
    with pytest.raises(PcapFormatError):
        pcap.pcap_scenario(cap, name="pcap:empty")


def test_writer_input_validation():
    with pytest.raises(ValueError):
        pcap.write_pcap([b"x"], [1.0, 2.0])  # count mismatch
    with pytest.raises(ValueError):
        pcap.write_pcap([b"x"], [-1.0])  # negative time
    with pytest.raises(ValueError):
        pcap.write_pcap([b"x"], [1.0], endian="=")
    with pytest.raises(ValueError):
        pcap.write_pcapng([b"x"], [1.0], endian="=")


def test_classic_malformed_inputs_raise():
    pkts, ts, _ = _capture(8)
    raw = pcap.write_pcap(pkts, ts)
    with pytest.raises(PcapFormatError):  # unknown magic
        pcap.read_pcap(b"\xde\xad\xbe\xef" + raw[4:])
    with pytest.raises(PcapFormatError):  # truncated global header
        pcap.read_pcap(raw[:20])
    with pytest.raises(PcapFormatError):  # truncated record header
        pcap.read_pcap(raw[: 24 + 10])
    with pytest.raises(PcapFormatError):  # truncated record data
        pcap.read_pcap(raw[:-3])
    with pytest.raises(PcapFormatError):  # nothing at all
        pcap.read_pcap(b"\xa1")


def test_pcapng_malformed_inputs_raise():
    pkts, ts, _ = _capture(8)
    raw = pcap.write_pcapng(pkts, ts)
    with pytest.raises(PcapFormatError):  # truncated final block
        pcap.read_pcap(raw[:-5])
    # corrupt the SHB trailing length (bytes 24..28 of the 28-byte SHB)
    bad = bytearray(raw)
    struct.pack_into("<I", bad, 24, 999)
    with pytest.raises(PcapFormatError):
        pcap.read_pcap(bytes(bad))
    # bad byte-order magic
    bad = bytearray(raw)
    struct.pack_into("<I", bad, 8, 0x11111111)
    with pytest.raises(PcapFormatError):
        pcap.read_pcap(bytes(bad))
    # packet block before any interface description: SHB + EPB, no IDB
    shb = raw[:28]
    epb_start = 28 + 20  # after SHB + IDB
    epb_len = struct.unpack_from("<I", raw, epb_start + 4)[0]
    with pytest.raises(PcapFormatError):
        pcap.read_pcap(shb + raw[epb_start : epb_start + epb_len])


def test_pcapng_multi_section_resets_interfaces():
    # Interface ids are section-scoped: an EPB in section 2 must resolve
    # against section 2's IDBs (here nanosecond tsresol), not section 1's.
    def idb(tsresol=None):
        opts = b""
        if tsresol is not None:
            opts = struct.pack("<HHB3x", 9, 1, tsresol) + struct.pack(
                "<HH", 0, 0
            )
        blen = 20 + len(opts)
        return (
            struct.pack("<IIHHI", 1, blen, 1, 0, 65535)
            + opts
            + struct.pack("<I", blen)
        )

    shb = struct.pack("<IIIHHqI", 0x0A0D0D0A, 28, 0x1A2B3C4D, 1, 0, -1, 28)
    pkt = _tcp_packet()
    pad = (-len(pkt)) % 4
    ts64 = 1_000_000_000  # 1.0 s at ns resolution, 1000 s at us
    epb = (
        struct.pack(
            "<IIIIIII", 6, 32 + len(pkt) + pad, 0, ts64 >> 32,
            ts64 & 0xFFFFFFFF, len(pkt), len(pkt),
        )
        + pkt
        + b"\x00" * pad
        + struct.pack("<I", 32 + len(pkt) + pad)
    )
    cap = pcap.read_pcap(shb + idb() + shb + idb(tsresol=9) + epb)
    assert cap.num_packets == 1
    np.testing.assert_allclose(cap.timestamps, [1.0])


def test_pcapng_snaplen_zero_means_unlimited():
    # IDB snaplen 0 = no limit: an SPB longer than 65535 must round-trip
    # whole, not silently truncate.
    big = _tcp_packet() + b"\x00" * 70000
    shb = struct.pack("<IIIHHqI", 0x0A0D0D0A, 28, 0x1A2B3C4D, 1, 0, -1, 28)
    idb = struct.pack("<IIHHII", 1, 20, 1, 0, 0, 20)
    pad = (-len(big)) % 4
    blen = 16 + len(big) + pad
    spb = (
        struct.pack("<III", 3, blen, len(big))
        + big
        + b"\x00" * pad
        + struct.pack("<I", blen)
    )
    cap = pcap.read_pcap(shb + idb + spb)
    assert cap.packets() == [big]


def test_classic_epoch_scale_timestamp_precision():
    # Splitting seconds before scaling keeps epoch-scale times precise to
    # float64's own resolution (~0.24 us at 1.7e9 s) in both resolutions.
    base = 1_700_000_000.0
    ts = [base, base + 12.345678, base + 1e9]
    pkts = [_tcp_packet()] * 3
    for nanosecond in (False, True):
        got = pcap.read_pcap(
            pcap.write_pcap(pkts, ts, nanosecond=nanosecond)
        ).timestamps
        np.testing.assert_allclose(got, ts, rtol=0, atol=5e-7)


def test_pcapng_truncated_tsresol_option_raises():
    # IDB whose option header claims a value byte past the block's end must
    # raise PcapFormatError, not IndexError.
    shb = struct.pack("<IIIHHqI", 0x0A0D0D0A, 28, 0x1A2B3C4D, 1, 0, -1, 28)
    opts = struct.pack("<HH", 9, 1)  # if_tsresol header, no value byte
    blen = 20 + len(opts)
    idb = (
        struct.pack("<IIHHI", 1, blen, 1, 0, 65535)
        + opts
        + struct.pack("<I", blen)
    )
    with pytest.raises(PcapFormatError):
        pcap.read_pcap(shb + idb)


def test_pcapng_mixed_linktypes_raise():
    # Two interfaces with different link types, packets on both: refuse
    # (a Capture carries one linktype; raw-IP sliced at Ethernet offsets
    # would be garbage features).
    shb = struct.pack("<IIIHHqI", 0x0A0D0D0A, 28, 0x1A2B3C4D, 1, 0, -1, 28)
    idb_eth = struct.pack("<IIHHII", 1, 20, 1, 0, 65535, 20)
    idb_raw = struct.pack("<IIHHII", 1, 20, 101, 0, 65535, 20)  # RAW IP

    def epb(iface):
        pkt = _tcp_packet()
        pad = (-len(pkt)) % 4
        blen = 32 + len(pkt) + pad
        return (
            struct.pack("<IIIIIII", 6, blen, iface, 0, 0, len(pkt), len(pkt))
            + pkt + b"\x00" * pad + struct.pack("<I", blen)
        )

    with pytest.raises(PcapFormatError):
        pcap.read_pcap(shb + idb_eth + idb_raw + epb(0) + epb(1))
    # single linktype (even non-Ethernet) still reads; featurizer gates it
    cap = pcap.read_pcap(shb + idb_eth + idb_raw + epb(1) + epb(1))
    assert cap.linktype == 101
    with pytest.raises(PcapFormatError):
        pcap.parse_headers(cap)


def test_pcapng_skips_unknown_blocks():
    pkts, ts, _ = _capture(8)
    raw = pcap.write_pcapng(pkts, ts)
    # splice a well-formed unknown block (type 0x0BAD) after SHB + IDB
    unknown = struct.pack("<III", 0x0BAD, 16, 0) + struct.pack("<I", 16)
    spliced = raw[:48] + unknown + raw[48:]
    assert pcap.read_pcap(spliced).packets() == pkts


# -- featurizer --------------------------------------------------------------

def _tcp_packet():
    eth = b"\xaa" * 6 + b"\xbb" * 6 + struct.pack(">H", 0x0800)
    ip = struct.pack(
        ">BBHHHBBHII", 0x45, 0, 40, 0x1234, 0x4000, 64, 6, 0,
        0xC0A80001, 0x0A010203,
    )
    tcp = struct.pack(
        ">HHIIBBHHH", 443, 51000, 1, 0, 0x50, 0x12, 4096, 0, 0
    )
    return eth + ip + tcp


def test_featurizer_bit_encodings_exact():
    cap = pcap.read_pcap(pcap.write_pcap([_tcp_packet()], [0.0]))
    f = pcap.parse_headers(cap)
    assert f.is_ipv4.all() and f.is_tcp.all() and not f.is_udp.any()
    assert f.src_ip[0] == 0xC0A80001 and f.dst_ip[0] == 0x0A010203
    assert f.src_port[0] == 443 and f.dst_port[0] == 51000
    assert f.proto[0] == 6 and f.ip_len[0] == 40 and f.tcp_flags[0] == 0x12
    assert f.iat_bucket[0] == 0  # first packet: IAT 0

    bits = pcap.featurize(cap)[0]
    assert bits.shape == (pcap.PCAP_FEATURE_BITS,)
    off = 0
    expected = {
        "src_ip": 0xC0A80001, "dst_ip": 0x0A010203, "src_port": 443,
        "dst_port": 51000, "proto": 6, "ip_len": 40, "tcp_flags": 0x12,
    }
    for name, width in pcap.FEATURE_LAYOUT:
        field = bits[off : off + width]
        if name == "iat_bucket":
            want = np.zeros(width, np.int32)
            want[0] = 1  # one-hot bucket 0
        else:  # little-endian integer bits
            want = (expected[name] >> np.arange(width)) & 1
        np.testing.assert_array_equal(field, want, err_msg=name)
        off += width
    assert off == pcap.PCAP_FEATURE_BITS


def test_featurizer_vlan_and_non_ip():
    plain = _tcp_packet()
    vlan = plain[:12] + struct.pack(">HH", 0x8100, 5) + plain[12:]
    arp = b"\xaa" * 6 + b"\xbb" * 6 + struct.pack(">H", 0x0806) + b"\x00" * 28
    runt = plain[:20]  # IPv4 header cut short
    cap = pcap.read_pcap(
        pcap.write_pcap([plain, vlan, arp, runt], [0.0, 1.0, 2.0, 3.0])
    )
    f = pcap.parse_headers(cap)
    np.testing.assert_array_equal(f.is_ipv4, [True, True, False, False])
    assert f.src_ip[1] == f.src_ip[0] and f.dst_port[1] == f.dst_port[0]
    assert f.src_ip[2] == 0 and f.src_port[3] == 0 and f.tcp_flags[2] == 0
    bits = pcap.featurize(cap)
    assert set(np.unique(bits)) <= {0, 1}


def test_iat_buckets_log_spaced():
    # IATs in us: [0 (first)], 1, 10, 1000, 100000 -> log4 buckets
    ts = np.cumsum([0.0, 1e-6, 10e-6, 1000e-6, 100000e-6])
    pkts = [_tcp_packet()] * 5
    f = pcap.parse_headers(pcap.read_pcap(pcap.write_pcap(pkts, ts)))
    np.testing.assert_array_equal(f.iat_bucket, [0, 0, 1, 4, 7])


def test_featurize_fold_matches_full_layout():
    pkts, ts, _ = _capture(500)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    full = pcap.featurize(cap)
    for width in (24, 64, 136, 200):
        np.testing.assert_array_equal(
            pcap.featurize(cap, width), traffic._fold_bits(full, width)
        )
    with pytest.raises(ValueError):
        pcap.featurize(cap, 0)


# -- scenario contract -------------------------------------------------------

def test_registered_pcap_scenario_is_chunking_invariant():
    pkts, ts, _ = _capture(1500, seed=3)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    pcap.register_pcap_scenario("pcap:chunktest", cap, overwrite=True)
    n = 3000  # > capture size: exercises cyclic replay too
    want = traffic.generate("pcap:chunktest", n, 24, seed=5)
    for chunk_size in (1, 173, traffic.CANONICAL_CHUNK, n):
        got = np.concatenate(
            list(
                traffic.stream(
                    "pcap:chunktest", n, 24, chunk_size=chunk_size, seed=5
                )
            )
        )
        np.testing.assert_array_equal(got, want)
    # pause/resume mid-trace replays the uninterrupted sequence
    first = traffic.generate("pcap:chunktest", 1700, 24, seed=5)
    rest = traffic.generate("pcap:chunktest", n, 24, seed=5)[1700:]
    np.testing.assert_array_equal(np.concatenate([first, rest]), want)
    # cyclic: position k and k + capture_size emit the same packet
    np.testing.assert_array_equal(want[:1500], want[1500:])
    # seed-independent: the capture is the world
    np.testing.assert_array_equal(
        traffic.generate("pcap:chunktest", 500, 24, seed=99), want[:500]
    )


def test_register_scenario_collision_and_overwrite():
    pkts, ts, _ = _capture(100)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    s = pcap.register_pcap_scenario("pcap:collide", cap, overwrite=True)
    assert traffic.register_scenario(s) is s  # same object: no-op
    with pytest.raises(ValueError):
        pcap.register_pcap_scenario("pcap:collide", cap)
    s2 = pcap.register_pcap_scenario("pcap:collide", cap, overwrite=True)
    assert traffic.get_scenario("pcap:collide") is s2


def test_scenario_and_labels_accept_precomputed_work():
    pkts, ts, _ = _capture(300)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    fields = pcap.parse_headers(cap)
    feats = pcap.featurize(cap)
    np.testing.assert_array_equal(
        pcap.label_packets(cap, lambda f: (f.proto == 6).astype(int)),
        pcap.label_packets(cap, lambda f: (f.proto == 6).astype(int),
                           fields=fields),
    )
    a = pcap.pcap_scenario(cap, name="pcap:pre").generate(400, 32)
    b = pcap.pcap_scenario(cap, name="pcap:pre", features=feats).generate(
        400, 32
    )
    np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError):
        pcap.pcap_scenario(cap, name="pcap:pre", features=feats[:10])


def test_mixed_tenant_stream_with_pcap_tenant():
    pkts, ts, _ = _capture(800, seed=4)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    pcap.register_pcap_scenario("pcap:tenant", cap, overwrite=True)
    specs = [
        traffic.TenantTrafficSpec("pcap:tenant", 48, 2.0),
        traffic.TenantTrafficSpec("uniform_random", 16, 1.0),
    ]
    n = 2500
    tids, bits = traffic.mixed_tenant_generate(specs, n, seed=11)
    # tenant 0's subsequence IS the capture replay at its width
    rows = tids == 0
    np.testing.assert_array_equal(
        bits[rows, :48],
        traffic.generate("pcap:tenant", int(rows.sum()), 48),
    )
    # and the mixed stream stays chunking-invariant with a pcap tenant
    chunks = list(traffic.mixed_tenant_stream(specs, n, chunk_size=137, seed=11))
    np.testing.assert_array_equal(
        np.concatenate([b for _, b in chunks]), bits
    )


# -- trainer hook ------------------------------------------------------------

def test_make_capture_task_temporal_split():
    from repro.train.bnn_trainer import make_capture_task

    pkts, ts, labels = _capture(1000, seed=6)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    bits = pcap.featurize(cap, 32)
    tr_x, tr_y, ev_x, ev_y = make_capture_task(
        bits, labels, train_frac=0.8, seed=1
    )
    assert tr_x.shape == (800, 32) and ev_x.shape == (200, 32)
    # held-out tail is the capture's arrival-order suffix
    np.testing.assert_array_equal(ev_x, bits[800:])
    np.testing.assert_array_equal(ev_y, labels[800:])
    # train is a permutation of the prefix (labels travel with packets)
    order = np.lexsort(tr_x.T)
    want = bits[:800]
    np.testing.assert_array_equal(tr_x[order], want[np.lexsort(want.T)])
    with pytest.raises(ValueError):
        make_capture_task(bits, labels[:10])
    with pytest.raises(ValueError):
        make_capture_task(bits, labels, train_frac=1.5)
    with pytest.raises(ValueError):
        make_capture_task(bits[:1], labels[:1], train_frac=0.5)


def test_trainer_accepts_capture_task():
    from repro.train.bnn_trainer import (
        BnnTrainConfig,
        BnnTrainer,
        make_capture_task,
    )

    pkts, ts, labels = _capture(600, seed=2)
    cap = pcap.read_pcap(pcap.write_pcap(pkts, ts))
    bits = pcap.featurize(cap, 16)
    task = make_capture_task(bits, labels, train_frac=0.75, seed=0)
    cfg = BnnTrainConfig(
        layer_sizes=(16, 8, 1), steps=3, batch=32, log_every=1,
        checkpoint_every=0,
    )
    trainer = BnnTrainer(cfg, task=task)
    summary = trainer.train()
    assert summary["final_step"] == 3
    held = trainer.evaluate_held_out()
    assert held["packets"] == 150
    # non-ndarray task elements are converted at construction, not later
    as_lists = tuple(np.asarray(a).tolist() for a in task)
    t2 = BnnTrainer(cfg, task=as_lists)
    assert t2.evaluate_held_out()["packets"] == 150
    with pytest.raises(ValueError):  # width mismatch vs layer_sizes
        BnnTrainer(cfg, task=make_capture_task(pcap.featurize(cap, 24), labels))
    with pytest.raises(ValueError):  # eval width mismatch is caught too
        BnnTrainer(cfg, task=(task[0], task[1], task[2][:, :8], task[3]))
    with pytest.raises(ValueError):  # label length mismatch
        BnnTrainer(cfg, task=(task[0], task[1][:-1], task[2], task[3]))
