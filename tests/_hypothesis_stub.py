"""Minimal stand-in for the ``hypothesis`` API used by this test suite.

The real hypothesis package is preferred when installed; test modules fall
back to this stub so the tier-1 suite collects and runs in environments
without it.  Only the surface actually used here is implemented:

  * ``strategies.integers(lo, hi)`` / ``strategies.lists(...)`` /
    ``Strategy.flatmap`` / ``Strategy.map``
  * ``given(*strategies)`` — draws ``settings.max_examples`` deterministic
    examples per test (seeded per example index, no shrinking)
  * ``settings.register_profile`` / ``settings.load_profile``
"""
from __future__ import annotations

import os

import numpy as np

# Base seed for the deterministic example sequence; override with FUZZ_SEED
# to explore a different (still pinned) slice of the input space locally.
BASE_SEED = int(os.environ.get("FUZZ_SEED", 0xB90F))


class Strategy:
    """A sampler: ``draw(rng) -> value``."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def flatmap(self, fn) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)).example(rng))

    def map(self, fn) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))


class _StrategiesModule:
    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(
            lambda rng: int(
                rng.integers(min_value, max_value, endpoint=True, dtype=np.int64)
            )
        )

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size, endpoint=True))
            return [elements.example(rng) for _ in range(n)]

        return Strategy(draw)

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(options) -> Strategy:
        options = list(options)
        return Strategy(lambda rng: options[int(rng.integers(0, len(options)))])


strategies = _StrategiesModule()


class settings:
    """Profile registry; only ``max_examples`` is honoured."""

    _profiles: dict[str, dict] = {"default": {"max_examples": 10}}
    _current: dict = _profiles["default"]

    def __init__(self, **kwargs):  # used as decorator in real hypothesis
        self._kwargs = kwargs

    def __call__(self, fn):
        fn._stub_settings = self._kwargs
        return fn

    @classmethod
    def register_profile(cls, name: str, **kwargs) -> None:
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._current = cls._profiles[name]

    @classmethod
    def max_examples(cls) -> int:
        return int(cls._current.get("max_examples", 10))


def given(*strats: Strategy):
    """Run the test over deterministic pseudo-random examples."""

    def decorate(fn):
        overrides = getattr(fn, "_stub_settings", {})

        def wrapper():
            n = int(overrides.get("max_examples", settings.max_examples()))
            for i in range(n):
                rng = np.random.default_rng(BASE_SEED + 7919 * i)
                args = [s.example(rng) for s in strats]
                try:
                    fn(*args)
                except Exception as e:  # noqa: BLE001 — attach the failing example
                    raise AssertionError(
                        f"falsifying example (stub, draw {i}): {args!r}"
                    ) from e

        # NOTE: deliberately no functools.wraps — pytest would follow
        # __wrapped__ and treat the generated arguments as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate
