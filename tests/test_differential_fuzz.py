"""Generative differential fuzzing: packed == fused == interpreter == oracle.

The credibility test for the bit-packed PHV executor (and the opcode-run
op-table scan it rode in with): random BNN programs — layer widths including
non-multiples of 32, learned SIGN thresholds across the full legal range,
folding — run through every executor backend and checked bit-for-bit against
the interpreter, the ``bnn.forward`` oracle, and (for default thresholds)
the STE trainer's forward.  Edge cases the generator might under-sample
(popcount ties, all-zero/all-one PHVs, extreme widths) are pinned
deterministically alongside.

Runs under real ``hypothesis`` when installed, else the seeded-random stub
(``tests/_hypothesis_stub.py``).  ``FUZZ_EXAMPLES`` scales the example
count (CI pins 200); failing case reprs land in ``$FUZZ_ARTIFACT_DIR``.
"""
from __future__ import annotations

import numpy as np
import pytest

from strategies import (
    HEAVY_EXAMPLES,
    ProgramCase,
    TenantMixCase,
    artifact_on_failure,
    build_case,
    chip_specs,
    given,
    mix_traffic,
    packets_for,
    program_cases,
    settings,
    st,
    stream_plans,
    tenant_mixes,
)

from repro.core import bitops, bnn, interpreter
from repro.core.compiler import compile_bnn
from repro.core.pipeline import ProgramConstraintError
from repro.dataplane import executor
from repro.dataplane.lowering import pack_bit_rows
from repro.dataplane.multitenant import AdmissionError, SwitchScheduler
from repro.train import bnn_trainer

BACKENDS = ("jnp", "pallas", "packed")


def _oracle(built, packets: np.ndarray) -> np.ndarray:
    return np.asarray(
        bnn.forward(
            [np.asarray(w) for w in built.params],
            packets,
            thresholds=built.thresholds,
        )
    )


def _assert_all_backends(built, packets: np.ndarray) -> None:
    """Every executor backend == interpreter == oracle on these packets."""
    oracle = _oracle(built, packets)
    interp = np.asarray(interpreter.run_program(built.program, packets))
    np.testing.assert_array_equal(interp, oracle)
    for backend in BACKENDS:
        out = executor.execute(built.lowered, packets, backend=backend)
        np.testing.assert_array_equal(
            out, oracle, err_msg=f"backend {backend!r} diverges from oracle"
        )


# ---------------------------------------------------------------------------
# The headline property: random programs, every backend, bit-exact
# ---------------------------------------------------------------------------

@given(program_cases())
def test_fuzz_backends_match_oracle(case: ProgramCase):
    with artifact_on_failure("fuzz_backends_match_oracle", case):
        built = build_case(case)
        packets = packets_for(case, seed=case.weight_seed ^ 0x5EED, n=40)
        _assert_all_backends(built, packets)


@given(program_cases())
def test_fuzz_ste_forward_matches_packed(case: ProgramCase):
    """The STE trainer's forward (the deploy-path witness) agrees with the
    packed executor for default thresholds — the only regime the trainer
    models."""
    if case.threshold_mode != "default":
        case = ProgramCase(
            case.layer_sizes, case.weight_seed, "default", case.threshold_seed
        )
    with artifact_on_failure("fuzz_ste_forward_matches_packed", case):
        built = build_case(case)
        packets = packets_for(case, seed=case.threshold_seed ^ 0x57E, n=32)
        latent = [
            np.asarray(bitops.bits_to_sign(w, np.float32))
            for w in built.params
        ]
        ste = np.asarray(bnn_trainer.forward_bits(latent, packets))
        packed = executor.execute(built.lowered, packets, backend="packed")
        np.testing.assert_array_equal(packed, ste)


@given(program_cases(), stream_plans())
@settings(max_examples=HEAVY_EXAMPLES)
def test_fuzz_chunking_invariance_and_resume(case: ProgramCase, plan):
    """Chunked execution and mid-stream resume never change any bit: one
    shot == chunked execute == a stream stopped and resumed mid-way."""
    n, chunk, seed = plan
    with artifact_on_failure(
        "fuzz_chunking_invariance_and_resume", (case, plan)
    ):
        built = build_case(case)
        packets = packets_for(case, seed=seed, n=n)
        one_shot = executor.execute(built.lowered, packets, backend="packed")
        np.testing.assert_array_equal(one_shot, _oracle(built, packets))
        for backend in BACKENDS:
            chunked = executor.execute(
                built.lowered, packets, backend=backend, chunk_size=chunk
            )
            np.testing.assert_array_equal(chunked, one_shot)
            # Mid-stream resume: feed the same packets as two separate
            # streams split at an uneven point; concatenated outputs must
            # equal the uninterrupted run.
            cut = max(1, n // 3)
            first = executor.execute_stream(
                built.lowered,
                [packets[:cut]],
                backend=backend,
                chunk_size=chunk,
                collect=True,
            )
            second = executor.execute_stream(
                built.lowered,
                [packets[cut:]],
                backend=backend,
                chunk_size=chunk,
                collect=True,
            )
            resumed = np.concatenate(
                [first.outputs, second.outputs]
            ).astype(np.int32)
            np.testing.assert_array_equal(resumed, one_shot)


@given(program_cases(max_layers=2, max_width=24), chip_specs())
@settings(max_examples=HEAVY_EXAMPLES)
def test_fuzz_chip_budgets_compile_or_reject(case: ProgramCase, chip):
    """A random chip budget either compiles the program — then it must be
    bit-exact — or rejects it with the typed constraint error.  Never a
    silent wrong answer."""
    with artifact_on_failure(
        "fuzz_chip_budgets_compile_or_reject", (case, chip)
    ):
        built = build_case(case)  # reference build on the default chip
        try:
            prog = compile_bnn(
                built.params, chip, thresholds=built.thresholds
            )
        except ProgramConstraintError:
            return
        packets = packets_for(case, seed=case.weight_seed ^ 0xC41B, n=24)
        oracle = _oracle(built, packets)
        lp = prog.lower()
        for backend in BACKENDS:
            out = executor.execute(lp, packets, backend=backend)
            np.testing.assert_array_equal(out, oracle)


# ---------------------------------------------------------------------------
# Deterministic edges the generator may under-sample
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_in", [2, 4, 31, 32, 33, 64])
def test_popcount_tie_resolution(n_in):
    """Agreement exactly at / one below the default ceil(n/2) threshold: the
    tie must resolve to 1 on every backend, exactly as the oracle does."""
    thr = (n_in + 1) // 2
    w = np.zeros((2, n_in), np.int32)
    w[:, :] = 0
    x = np.zeros((2, n_in), np.int32)
    # Packet 0: exactly thr agreements (tie -> fire).  Packet 1: thr - 1.
    x[0, thr:] = 1   # n_in - thr disagreements -> thr agreements
    x[1, thr - 1:] = 1
    prog = compile_bnn([w])
    built_oracle = np.asarray(bnn.forward([w], x))
    assert built_oracle[0, 0] == 1 and built_oracle[1, 0] == 0
    lp = prog.lower()
    for backend in BACKENDS:
        np.testing.assert_array_equal(
            executor.execute(lp, x, backend=backend), built_oracle
        )


@pytest.mark.parametrize(
    "sizes", [(1, 1), (32, 64, 32), (33, 65, 31), (31, 97, 5), (48, 48)]
)
@pytest.mark.parametrize("fill", [0, 1])
def test_all_zero_all_one_phvs(sizes, fill):
    case = ProgramCase(sizes, weight_seed=99, threshold_mode="default",
                       threshold_seed=0)
    built = build_case(case)
    packets = np.full((8, sizes[0]), fill, np.int32)
    _assert_all_backends(built, packets)


@pytest.mark.parametrize(
    "sizes", [(33, 65, 31), (1, 2, 1), (47, 33), (17, 33, 5)]
)
def test_widths_not_divisible_by_32(sizes):
    case = ProgramCase(sizes, weight_seed=7, threshold_mode="per_neuron",
                       threshold_seed=11)
    built = build_case(case)
    packets = packets_for(case, seed=3, n=48)
    _assert_all_backends(built, packets)


def test_threshold_extremes_never_and_always_fire():
    """thr = 0 fires on every packet, thr = n_in + 1 on none — on all
    backends, matching the oracle."""
    w = np.asarray(
        np.random.default_rng(5).integers(0, 2, (6, 20)), np.int32
    )
    thresholds = [np.array([0, 21, 10, 0, 21, 1], np.int32)]
    prog = compile_bnn([w], thresholds=thresholds)
    packets = np.asarray(
        np.random.default_rng(6).integers(0, 2, (32, 20)), np.int32
    )
    oracle = np.asarray(bnn.forward([w], packets, thresholds=thresholds))
    assert oracle[:, 0].all() and not oracle[:, 1].any()
    lp = prog.lower()
    for backend in BACKENDS:
        np.testing.assert_array_equal(
            executor.execute(lp, packets, backend=backend), oracle
        )


def test_bitpack_kernel_matches_reference_packing():
    """The Pallas pack kernel (interpret mode off-TPU) agrees with the numpy
    word-layout reference for ragged shapes."""
    from repro.kernels.bitpack import pack_bits_words

    rng = np.random.default_rng(12)
    for m, n in [(1, 1), (13, 45), (256, 32), (7, 96), (300, 17)]:
        bits = rng.integers(0, 2, (m, n)).astype(np.int32)
        packed = np.asarray(pack_bits_words(bits, interpret=True))
        np.testing.assert_array_equal(packed, pack_bit_rows(bits))


def test_opcode_runs_cover_all_elements():
    case = ProgramCase((32, 64, 32), 0, "default", 0)
    lp = build_case(case).lowered
    runs = lp.opcode_runs()
    assert runs[0][0] == 0 and runs[-1][1] == lp.num_elements
    for (_, stop_a, _), (start_b, _, _) in zip(runs, runs[1:]):
        assert stop_a == start_b
    # Within a run's rows, only that run's opcodes (plus pads) appear.
    for start, stop, used in runs:
        present = set(np.unique(lp.opcode[start:stop]).tolist())
        assert present <= set(used)


# ---------------------------------------------------------------------------
# Multi-tenant: random tenant mixes on the packed path
# ---------------------------------------------------------------------------

@given(
    program_cases(max_layers=2, max_width=24),
    program_cases(max_layers=3, max_width=16),
    stream_plans(max_packets=200, max_chunk=64),
)
@settings(max_examples=HEAVY_EXAMPLES)
def test_fuzz_multitenant_packed_bit_exact(case_a, case_b, plan):
    """Random tenant mixes through merged and time-sliced modes on the
    packed path: every tenant's outputs equal its single-program run."""
    n, chunk, seed = plan
    with artifact_on_failure(
        "fuzz_multitenant_packed_bit_exact", (case_a, case_b, plan)
    ):
        builts = [build_case(case_a), build_case(case_b)]
        from repro.core.pipeline import ChipSpec

        chip = ChipSpec(num_elements=512, phv_bits=1 << 16, name="fuzz-big")
        rng = np.random.default_rng(seed)
        width = max(b.program.input_bits for b in builts)
        tids = rng.integers(0, len(builts), n).astype(np.int32)
        bits = rng.integers(0, 2, (n, width)).astype(np.int32)
        singles = [
            executor.execute(
                b.lowered,
                bits[np.nonzero(tids == t)[0], : b.program.input_bits],
                backend="jnp",
            )
            for t, b in enumerate(builts)
        ]
        for mode in ("merged", "time_sliced"):
            sched = SwitchScheduler(chip, mode=mode, quantum=max(1, chunk))
            for t, b in enumerate(builts):
                sched.admit(b.program, name=f"t{t}")
            res = sched.run(
                (tids, bits),
                mode=mode,
                backend="packed",
                chunk_size=chunk,
                collect=True,
            )
            for t in range(len(builts)):
                np.testing.assert_array_equal(
                    res.outputs_for(t),
                    singles[t],
                    err_msg=f"mode {mode!r} tenant {t} diverges",
                )


@given(tenant_mixes(max_tenants=4))
@settings(max_examples=HEAVY_EXAMPLES)
def test_fuzz_tenant_mix_all_schedules_agree(mix: TenantMixCase):
    """The five-way equivalence on random tenant mixes: merged-interleave
    == merged-concat == time-sliced == the per-tenant single-program
    executor == the ``bnn.forward`` oracle, on the jnp and packed
    backends alike (pcap-backed tenants included)."""
    with artifact_on_failure("fuzz_tenant_mix_all_schedules_agree", mix):
        from repro.core.pipeline import ChipSpec

        builts = [build_case(c) for c in mix.cases]
        tids, bits = mix_traffic(mix)
        chip = ChipSpec(
            num_elements=1024,
            phv_bits=1 << 16,
            max_parallel_ops=1 << 12,
            name="fuzz-mix",
        )
        sched = SwitchScheduler(chip, quantum=max(1, mix.chunk))
        for t, b in enumerate(builts):
            sched.admit(b.program, name=f"t{t}")
        singles = []
        for t, b in enumerate(builts):
            mine = bits[tids == t][:, : b.program.input_bits]
            want = executor.execute(b.lowered, mine, backend="jnp")
            np.testing.assert_array_equal(
                want, _oracle(b, mine),
                err_msg=f"tenant {t} single-program run diverges from oracle",
            )
            singles.append(want)
        schedules = (
            ("merged", "interleave"),
            ("merged", "concat"),
            ("time_sliced", None),
        )
        for backend in ("jnp", "packed"):
            for mode, layout in schedules:
                res = sched.run(
                    (tids, bits),
                    mode=mode,
                    merged=layout,
                    backend=backend,
                    chunk_size=mix.chunk,
                    collect=True,
                )
                assert res.mode == mode
                if mode == "merged":
                    assert res.merged_layout == layout
                for t in range(mix.num_tenants):
                    np.testing.assert_array_equal(
                        res.outputs_for(t),
                        singles[t],
                        err_msg=(
                            f"backend {backend!r} {mode}/{layout} tenant "
                            f"{t} diverges from its single-program run"
                        ),
                    )


@given(program_cases(max_layers=2, max_width=24), chip_specs())
def test_fuzz_admission_is_typed(case: ProgramCase, chip):
    """Random chip budgets either admit a tenant or raise AdmissionError —
    the scheduler never half-admits."""
    with artifact_on_failure("fuzz_admission_is_typed", (case, chip)):
        built = build_case(case)
        sched = SwitchScheduler(chip, mode="merged")
        try:
            sched.admit(built.program)
        except AdmissionError:
            assert not sched.tenants
            return
        assert len(sched.tenants) == 1


# ---------------------------------------------------------------------------
# pcap featurizer: malformed capture bytes must raise, never mis-featurize
# ---------------------------------------------------------------------------

from repro.dataplane import pcap  # noqa: E402

_SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _random_capture_bytes(rng) -> tuple[bytes, str]:
    """A valid capture file in a random on-disk dialect."""
    n = int(rng.integers(1, 12))
    pkts, ts, _ = pcap.synthesize_capture(n, seed=int(rng.integers(1 << 16)))
    fmt = ("classic", "classic_be", "classic_ns", "ng")[int(rng.integers(4))]
    if fmt == "ng":
        return pcap.write_pcapng(pkts, ts), fmt
    data = pcap.write_pcap(
        pkts,
        ts,
        nanosecond=(fmt == "classic_ns"),
        endian=">" if fmt == "classic_be" else "<",
    )
    return data, fmt


def _featurizes_cleanly(cap) -> None:
    feats = pcap.featurize(cap, input_bits=32)
    assert feats.shape == (cap.num_packets, 32)
    assert set(np.unique(feats).tolist()) <= {0, 1}


@given(_SEEDS)
def test_fuzz_pcap_truncation_raises_or_parses_whole_records(seed):
    """Truncated captures either raise PcapFormatError or parse to a valid
    shorter capture that featurizes cleanly; for classic pcap, any cut
    *inside* a record must raise — a half record never becomes features."""
    with artifact_on_failure("fuzz_pcap_truncation", seed):
        rng = np.random.default_rng(seed)
        data, fmt = _random_capture_bytes(rng)
        boundaries = None
        if fmt != "ng":
            boundaries, off = {24}, 24
            # caplen field sits 8 bytes into each 16-byte record header
            endian = ">" if fmt == "classic_be" else "<"
            import struct

            while off < len(data):
                caplen = struct.unpack_from(endian + "I", data, off + 8)[0]
                off += 16 + caplen
                boundaries.add(off)
        cuts = set(int(c) for c in rng.integers(0, len(data), 10))
        cuts |= {0, 1, 4, 23, 24, len(data) - 1}
        for cut in sorted(cuts):
            try:
                cap = pcap.read_pcap(data[:cut])
            except pcap.PcapFormatError:
                continue
            _featurizes_cleanly(cap)
            if boundaries is not None:
                assert cut in boundaries, (
                    f"{fmt}: mid-record cut at {cut} parsed silently"
                )


@given(_SEEDS)
def test_fuzz_pcap_mutation_never_escapes_typed_error(seed):
    """Byte-flipped captures either raise PcapFormatError — from the parser
    or the featurizer (e.g. a flipped linktype field) — or still featurize
    to a well-formed {0,1} matrix.  No other exception type, no hang, no
    silent garbage features."""
    with artifact_on_failure("fuzz_pcap_mutation", seed):
        rng = np.random.default_rng(seed)
        data, _ = _random_capture_bytes(rng)
        for _ in range(10):
            blob = bytearray(data)
            for _ in range(int(rng.integers(1, 8))):
                blob[int(rng.integers(len(blob)))] = int(rng.integers(256))
            try:
                _featurizes_cleanly(pcap.read_pcap(bytes(blob)))
            except pcap.PcapFormatError:
                continue


@given(_SEEDS)
def test_fuzz_pcap_garbage_raises(seed):
    """Pure random bytes never parse: wrong magic, short files, and noise
    all surface as PcapFormatError."""
    with artifact_on_failure("fuzz_pcap_garbage", seed):
        rng = np.random.default_rng(seed)
        for length in (0, 3, 4, 16, 64, 500):
            blob = rng.integers(0, 256, length, dtype=np.uint8).tobytes()
            with pytest.raises(pcap.PcapFormatError):
                pcap.read_pcap(blob)
