"""Sliding windows + SLO trackers: rotation, merge algebra, determinism.

The contract under test is the one ``FleetEngine.health()`` and the
scheduler's SLO telemetry stand on: windows use absolute bucket indexing
with explicit timestamps, so (a) rotation at bucket boundaries is exact,
(b) ``merge`` is associative/commutative and equals single-stream
observation, and (c) an SLO tracker's status and breach-event log are a
pure function of (observations, update times) — chunking between updates
and mid-stream restarts must not change them.
"""
import dataclasses
import random

import pytest

from repro.obs.slo import (
    QUEUE_DELAY,
    THROUGHPUT,
    SloSpec,
    SloTracker,
)
from repro.obs.windows import WindowedHistogram, WindowedRate


# ------------------------------------------------------------------ windows

class TestWindowedRate:
    def test_rotation_at_exact_boundaries(self):
        w = WindowedRate(10.0, buckets=10)
        w.add(0.5, 100)  # bucket 0: [0, 1)
        # Visible while bucket 0's start (t=0.0) is inside (now - 10, now]:
        # that is for every now strictly below 10.0, and gone at exactly 10.0
        # — rotation happens at the bucket boundary, with no partial decay.
        assert w.count(0.5) == 100
        assert w.count(9.999) == 100
        assert w.count(10.0) == 0
        assert w.count(11.0) == 0

    def test_window_includes_current_partial_bucket(self):
        w = WindowedRate(10.0, buckets=10)
        w.add(10.2, 7)    # bucket 10
        assert w.count(10.2) == 7   # same-bucket query sees it immediately
        assert w.rate(10.2) == pytest.approx(0.7)

    def test_observations_spread_and_expire_one_bucket_at_a_time(self):
        w = WindowedRate(4.0, buckets=4)
        for t in (0.5, 1.5, 2.5, 3.5):
            w.add(t, 10)
        assert w.count(3.9) == 40
        assert w.count(4.5) == 30   # bucket 0 out
        assert w.count(5.5) == 20   # bucket 1 out
        assert w.count(7.8) == 0

    def test_merge_associative_commutative_and_equals_union(self):
        rng = random.Random(7)
        obs = [(rng.uniform(0, 20), k + 1) for k in range(60)]
        thirds = [obs[0::3], obs[1::3], obs[2::3]]

        def filled(chunks):
            ws = []
            for chunk in chunks:
                w = WindowedRate(10.0, buckets=10)
                for t, c in chunk:
                    w.add(t, c)
                ws.append(w)
            return ws

        # union oracle: everything observed into one window
        union = WindowedRate(10.0, buckets=10)
        for t, c in obs:
            union.add(t, c)

        a, b, c = filled(thirds)
        ab_c = filled(thirds)
        ab_c[0].merge(ab_c[1]); ab_c[0].merge(ab_c[2])     # (a+b)+c
        c_ba = filled(thirds)
        c_ba[2].merge(c_ba[1]); c_ba[2].merge(c_ba[0])     # (c+b)+a
        # Query at/after the newest observation — the anchor pruning is
        # guaranteed invisible for (partitions prune on their own maxima
        # until the merge aligns them).
        for now in (20.0, 22.5, 25.0, 31.0):
            assert (
                ab_c[0].count(now) == c_ba[2].count(now) == union.count(now)
            )
        assert union.count(20.0) > 0

    def test_merge_rejects_incongruent_windows(self):
        w = WindowedRate(10.0, buckets=10)
        with pytest.raises(ValueError, match="cannot merge"):
            w.merge(WindowedRate(5.0, buckets=10))
        with pytest.raises(ValueError, match="cannot merge"):
            w.merge(WindowedRate(10.0, buckets=5))

    def test_pruning_is_query_invisible(self):
        # Old observations beyond the horizon are dropped internally, but a
        # query anchored at/after the newest observation cannot tell.
        w = WindowedRate(2.0, buckets=2)
        for t in range(100):
            w.add(float(t), 1)
        assert len(w._counts) <= w.buckets + 1
        assert w.count(99.0) == 2

    def test_validation(self):
        with pytest.raises(ValueError, match="horizon"):
            WindowedRate(0.0)
        with pytest.raises(ValueError, match="buckets"):
            WindowedRate(1.0, buckets=0)
        w = WindowedRate(1.0)
        w.add(0.0, 0)       # non-positive counts are ignored
        w.add(0.0, -5)
        assert w.count(0.0) == 0


class TestWindowedHistogram:
    def test_windowed_quantile_rotates(self):
        h = WindowedHistogram(10.0, buckets=10)
        h.observe(1.0, 0.100, count=100)  # slow early packets
        h.observe(9.0, 0.001, count=100)  # fast late packets
        assert h.p99(9.0) == pytest.approx(0.100, rel=0.05)
        # Once the slow bucket rotates out, the p99 collapses.
        assert h.p99(12.0) == pytest.approx(0.001, rel=0.05)
        assert h.count(12.0) == 100
        assert h.quantile(25.0, 0.99) is None  # empty window

    def test_merge_matches_union_and_checks_congruence(self):
        rng = random.Random(3)
        obs = [(rng.uniform(0, 12), rng.uniform(1e-4, 1e-1)) for _ in range(200)]
        union = WindowedHistogram(10.0, buckets=10)
        a = WindowedHistogram(10.0, buckets=10)
        b = WindowedHistogram(10.0, buckets=10)
        for i, (t, v) in enumerate(obs):
            union.observe(t, v)
            (a if i % 2 else b).observe(t, v)
        a.merge(b)
        for now in (12.0, 15.0, 18.0):
            assert a.count(now) == union.count(now)
            assert a.quantile(now, 0.5) == union.quantile(now, 0.5)
            assert a.p99(now) == union.p99(now)
        assert a.count(12.0) > 0
        with pytest.raises(ValueError, match="cannot merge"):
            a.merge(WindowedHistogram(3.0, buckets=10))


# ------------------------------------------------------------------ SLO

def _spec(**kw):
    base = dict(
        tenant="t0", p99_queue_delay_s=0.002, min_pps=1000.0, window_s=10.0
    )
    base.update(kw)
    return SloSpec(**base)


class TestSloSpec:
    def test_needs_a_target_and_validates_ranges(self):
        with pytest.raises(ValueError, match="at least one target"):
            SloSpec("t")
        with pytest.raises(ValueError, match="p99_queue_delay_s"):
            SloSpec("t", p99_queue_delay_s=0.0)
        with pytest.raises(ValueError, match="min_pps"):
            SloSpec("t", min_pps=-1.0)
        with pytest.raises(ValueError, match="budget_fraction"):
            SloSpec("t", min_pps=1.0, budget_fraction=0.0)


class TestSloTracker:
    def test_idle_tracker_is_no_data_not_breaching(self):
        tr = SloTracker(_spec())
        st = tr.status(5.0)
        assert st.delay_burn_rate is None and st.pps_burn_rate is None
        assert not st.breached
        assert tr.update(5.0) == [] and tr.events == []

    def test_delay_burn_rate_is_exact_bad_fraction(self):
        tr = SloTracker(_spec(budget_fraction=0.01))
        tr.observe_queue_delay(1.0, 0.001, count=95)   # under target
        tr.observe_queue_delay(1.0, 0.010, count=5)    # over target: 5%
        st = tr.status(1.0)
        assert st.delay_burn_rate == pytest.approx(5.0)
        assert st.breached

    def test_throughput_burn_rate_is_shortfall_over_budget(self):
        tr = SloTracker(_spec(min_pps=1000.0, budget_fraction=0.01))
        tr.observe_packets(9.9, 5000)   # windowed pps = 500 -> 50% shortfall
        st = tr.status(9.9)
        assert st.pps == pytest.approx(500.0)
        assert st.pps_burn_rate == pytest.approx(50.0)

    def test_breach_fires_once_and_rearms_on_recovery(self):
        tr = SloTracker(_spec(p99_queue_delay_s=None))
        tr.observe_packets(1.0, 20000)          # 2000 pps: ok
        assert tr.update(1.0) == []
        tr.observe_packets(11.5, 100)           # old bucket rotated: starving
        (ev,) = tr.update(11.5)
        assert ev.objective == THROUGHPUT and ev.burn_rate > 1.0
        assert tr.update(12.0) == []            # still breaching: no new event
        tr.observe_packets(13.0, 50000)         # recovered
        assert tr.update(13.0) == []
        tr.observe_packets(25.0, 1)             # breach again -> new event
        assert len(tr.update(25.0)) == 1
        assert [e.objective for e in tr.events] == [THROUGHPUT, THROUGHPUT]

    def test_event_log_deterministic_under_chunking_and_reorder(self):
        """Same observations + same update times => identical breach logs.
        Windows are commutative in the observations, so the delivery order
        *between* two updates must not matter (that is exactly the freedom
        a chunked scheduler vs a resumed one exercises), and splitting the
        deliveries into arbitrary batches must not matter either."""
        rng = random.Random(11)
        observations = []
        t = 0.0
        for _ in range(300):
            t += rng.uniform(0.01, 0.3)
            observations.append(
                ("delay", t, rng.choice([0.0005, 0.0009, 0.004]),
                 rng.randint(1, 40))
            )
            observations.append(("packets", t, rng.randint(1, 2000)))
        update_times = [i * 0.5 for i in range(1, 120)]

        def replay(shuffle_seed):
            tr = SloTracker(_spec())
            order = random.Random(shuffle_seed)
            prev = float("-inf")
            for ut in update_times:
                batch = [o for o in observations if prev < o[1] <= ut]
                if shuffle_seed is not None:
                    order.shuffle(batch)   # delivery order inside the gap
                for kind, *rest in batch:
                    if kind == "delay":
                        tr.observe_queue_delay(rest[0], rest[1], rest[2])
                    else:
                        tr.observe_packets(rest[0], rest[1])
                tr.update(ut)
                prev = ut
            return tr

        a, b, c = replay(None), replay(7), replay(23)
        assert a.events == b.events == c.events
        assert len(a.events) > 0      # the workload actually breaches
        final = max(update_times)
        assert a.status(final) == b.status(final) == c.status(final)

    def test_status_fields_roundtrip(self):
        tr = SloTracker(_spec())
        tr.observe_queue_delay(2.0, 0.0015, count=10)
        tr.observe_packets(2.0, 30000)
        st = tr.status(2.0)
        assert st.tenant == "t0" and st.window_s == 10.0
        assert st.p99_queue_delay_s == pytest.approx(0.0015, rel=0.05)
        assert st.delay_burn_rate == 0.0
        assert st.pps == pytest.approx(3000.0)
        assert st.pps_burn_rate == 0.0
        assert not st.breached
        assert dataclasses.replace(st, pps_burn_rate=2.0).breached
        assert QUEUE_DELAY == "queue_delay"
