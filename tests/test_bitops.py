"""Property tests for the chip-legal bit primitives."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # environment without hypothesis: deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import bitops

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
def test_hakmem_popcount_matches_native(words):
    x = jnp.asarray(np.array(words, np.uint32))
    got = bitops.hakmem_popcount(x)
    want = jax.lax.population_count(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    st.integers(1, 5).flatmap(
        lambda w: st.lists(
            st.lists(st.integers(0, 1), min_size=32 * w, max_size=32 * w),
            min_size=1, max_size=4,
        )
    )
)
def test_pack_unpack_roundtrip(rows):
    bits = jnp.asarray(np.array(rows, np.int64))
    packed = bitops.pack_bits(bits)
    back = bitops.unpack_bits(packed, count=bits.shape[-1])
    np.testing.assert_array_equal(np.asarray(back), np.asarray(bits))


@given(st.integers(2, 200), st.integers(0, 2**31))
def test_packed_dot_matches_pm1_dot(n_bits, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, n_bits)
    w = rng.integers(0, 2, n_bits)
    want = int(((2 * x - 1) * (2 * w - 1)).sum())
    xp = bitops.pack_bits(bitops.pad_to_word_multiple(jnp.asarray(x)))
    wp = bitops.pack_bits(bitops.pad_to_word_multiple(jnp.asarray(w)))
    got = int(bitops.packed_dot(xp, wp, n_bits))
    assert got == want


def test_sign_conventions():
    x = jnp.array([-2.0, -0.0, 0.0, 3.0])
    np.testing.assert_array_equal(
        np.asarray(bitops.sign_to_bits(x)), [0, 1, 1, 1]
    )
    np.testing.assert_array_equal(
        np.asarray(bitops.bits_to_sign(jnp.array([0, 1]))), [-1.0, 1.0]
    )


def test_pad_to_word_multiple_noop_on_aligned():
    x = jnp.ones((3, 64), jnp.int32)
    assert bitops.pad_to_word_multiple(x) is x
