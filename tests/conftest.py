import dataclasses

import jax
import pytest

from repro.configs import get_config


def tiny_config(name: str, **kw):
    """Reduced config of the same family — the per-arch smoke recipe."""
    cfg = get_config(name)
    base = dict(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=97, max_seq_len=128, attn_q_chunk=16,
        microbatches=1, fsdp=False,
    )
    if cfg.ssm is not None:
        base["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk=8
        )
    if cfg.moe is not None:
        base["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, expert_ffn_dim=32,
            capacity_factor=2.0,
        )
    if cfg.mla is not None:
        base["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=16,
            qk_rope_dim=8, v_head_dim=16,
        )
    if cfg.family == "hybrid":
        base["hybrid_period"] = 2
    if cfg.family == "vlm":
        base["num_patches"] = 8
    base.update(kw)
    return dataclasses.replace(cfg, **base)


@pytest.fixture
def rng_key():
    return jax.random.PRNGKey(0)


def make_batch(cfg, batch: int, seq: int, key):
    """Random input batch matching the arch's input mode."""
    import jax.numpy as jnp

    kt, kp, kl = jax.random.split(key, 3)
    labels = jax.random.randint(kl, (batch, seq), 0, cfg.vocab_size)
    if cfg.input_mode == "frames":
        return {
            "frames": jax.random.normal(kt, (batch, seq, cfg.d_model)),
            "labels": labels,
        }
    if cfg.input_mode == "tokens+patches":
        st = seq - cfg.num_patches
        return {
            "tokens": jax.random.randint(kt, (batch, st), 0, cfg.vocab_size),
            "patches": jax.random.normal(kp, (batch, cfg.num_patches, cfg.d_model)),
            "labels": labels[:, :st],
        }
    return {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
        "labels": labels,
    }
