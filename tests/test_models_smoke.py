"""Per-arch smoke tests: every assigned architecture instantiates at reduced
size and runs one forward + one train step on CPU (shape + finiteness)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch, tiny_config
from repro.configs.archs import ASSIGNED_ARCHS
from repro.models import decode_step, forward, init_cache, init_params
from repro.optim.adamw import AdamW
from repro.train.train_step import make_train_step


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_smoke(arch, rng_key):
    cfg = tiny_config(arch)
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, 2, 32, rng_key)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    seq = 32 if cfg.input_mode != "tokens+patches" else 32
    assert logits.shape == (2, seq, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch, rng_key):
    cfg = tiny_config(arch)
    params = init_params(cfg, rng_key)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    batch = make_batch(cfg, 2, 32, rng_key)
    step = jax.jit(make_train_step(cfg, opt))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, pq: acc + float(jnp.abs(pq).sum()),
        jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), params, params2),
        0.0,
    )
    assert moved > 0


@pytest.mark.parametrize(
    "arch", [a for a in ASSIGNED_ARCHS if a != "hubert-xlarge"]
)
def test_decode_smoke(arch, rng_key):
    cfg = tiny_config(arch)
    params = init_params(cfg, rng_key)
    cache = init_cache(cfg, 2, 48)
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))(
        params, tok, cache
    )
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache.index) == 1


def test_encoder_only_has_no_decode(rng_key):
    cfg = tiny_config("hubert-xlarge")
    assert init_cache(cfg, 2, 16) is None
    params = init_params(cfg, rng_key)
    with pytest.raises(ValueError, match="encoder-only"):
        decode_step(params, jnp.array([1, 2]), None, cfg)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_quantized_variant_forward(arch, rng_key):
    """The paper's technique as a config flag: BNN-quantized projections."""
    from repro.configs.base import QuantConfig

    targets = ("ffn", "attn_proj", "ssm_proj")
    cfg = tiny_config(arch, quant=QuantConfig(mode="bnn_weight_only", targets=targets))
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, 2, 32, rng_key)
    logits, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    assert bool(jnp.isfinite(logits).all())
    # gradients flow through the STE
    def loss(p):
        lg, _ = forward(p, batch, cfg)
        return (lg.astype(jnp.float32) ** 2).mean()

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert gn > 0 and jnp.isfinite(gn)
