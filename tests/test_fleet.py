"""Fleet serving + typed-plan API: vmapped streams, scanned hops, factory.

The fleet claim is purely structural — every executor backend maps packet
rows independently, so batching N streams through one vmapped dispatch (or
scanning a hop chain as one ``lax.scan``) can only reorder *dispatches*,
never bits.  The fuzz properties here hold that claim against random fleet
shapes (1..16 streams, mixed lengths, mid-stream resume) and random hop
counts on every backend; the deterministic tests pin the new surfaces —
:class:`ExecutionPlan`/:func:`run`, :func:`build_fleet`, ``FleetEngine`` —
to the executors they wrap.
"""
from __future__ import annotations

import numpy as np
import pytest

from strategies import (
    HEAVY_EXAMPLES,
    ProgramCase,
    artifact_on_failure,
    build_case,
    fleet_plans,
    given,
    packets_for,
    program_cases,
    settings,
    st,
)

from repro.core import bnn
from repro.core.pipeline import ChipSpec
from repro.dataplane import executor
from repro.dataplane.fabric import SwitchFabric
from repro.dataplane.factory import FleetSpec, TenantSpec, build_fleet
from repro.dataplane.fleet import execute_fleet, fleet_blocks
from repro.dataplane.plan import Backend, ExecutionPlan, run
from repro.serving.engine import FleetEngine

BACKENDS = ("jnp", "pallas", "packed")


def _oracle(built, packets: np.ndarray) -> np.ndarray:
    return np.asarray(
        bnn.forward(
            [np.asarray(w) for w in built.params],
            packets,
            thresholds=built.thresholds,
        )
    )


def _streams_for(case: ProgramCase, lengths, seed: int) -> list[np.ndarray]:
    return [
        packets_for(case, seed=seed + 7 * i, n=n)
        for i, n in enumerate(lengths)
    ]


# ---------------------------------------------------------------------------
# Fuzz: fleet == per-stream == oracle, including mid-stream resume
# ---------------------------------------------------------------------------

@given(program_cases(max_layers=2, max_width=24), fleet_plans())
@settings(max_examples=HEAVY_EXAMPLES)
def test_fuzz_fleet_matches_per_stream_and_resume(case: ProgramCase, plan):
    """Random fleet shapes on every backend: the vmapped fleet's per-stream
    outputs equal running each stream alone, equal the oracle, and survive
    a mid-stream stop/resume split bit-for-bit."""
    lengths, chunk, seed = plan
    with artifact_on_failure(
        "fuzz_fleet_matches_per_stream_and_resume", (case, plan)
    ):
        built = build_case(case)
        streams = _streams_for(case, lengths, seed)
        singles = [
            executor.execute(built.lowered, s, backend="packed")
            for s in streams
        ]
        for s, single in zip(streams, singles):
            np.testing.assert_array_equal(single, _oracle(built, s))
        for backend in BACKENDS:
            eplan = ExecutionPlan(
                backend=backend, chunk_size=chunk, collect=True
            )
            fr = execute_fleet(built.lowered, streams, plan=eplan)
            assert fr.streams == len(streams)
            assert fr.packets == sum(lengths)
            np.testing.assert_array_equal(
                fr.per_stream_packets, np.asarray(lengths)
            )
            for i, single in enumerate(singles):
                np.testing.assert_array_equal(
                    fr.outputs[i],
                    single,
                    err_msg=f"backend {backend!r} stream {i} diverges",
                )
            # Mid-stream resume: stop every stream at an uneven cut, run the
            # fleet twice, concatenate per stream — nothing may change.  A
            # stream whose cut swallows it entirely resumes as the empty
            # stream (zero blocks), which the block zipper must tolerate.
            cuts = [max(1, n // 3) for n in lengths]
            first = execute_fleet(
                built.lowered,
                [s[:c] for s, c in zip(streams, cuts)],
                plan=eplan,
            )
            second = execute_fleet(
                built.lowered,
                [s[c:] for s, c in zip(streams, cuts)],
                plan=eplan,
            )
            for i, single in enumerate(singles):
                resumed = np.concatenate(
                    [first.outputs[i], second.outputs[i]]
                ).astype(np.int32)
                np.testing.assert_array_equal(
                    resumed,
                    single,
                    err_msg=f"backend {backend!r} stream {i} resume diverges",
                )


# ---------------------------------------------------------------------------
# Fuzz: scanned hop chains == unrolled == single switch == oracle
# ---------------------------------------------------------------------------

@given(
    program_cases(max_layers=2, max_width=24),
    st.integers(min_value=2, max_value=6),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=HEAVY_EXAMPLES)
def test_fuzz_scanned_hops_bit_exact(case: ProgramCase, hops, seed):
    """Random hop counts: the hop chain as one ``lax.scan`` over stacked
    tables == the unrolled per-hop loop == the single-switch executor, with
    the per-hop telemetry contract intact."""
    with artifact_on_failure(
        "fuzz_scanned_hops_bit_exact", (case, hops, seed)
    ):
        built = build_case(case)
        per_hop = -(-built.lowered.num_elements // hops)  # ceil
        chip = ChipSpec(
            num_elements=per_hop,
            phv_bits=built.program.chip.phv_bits,
            name=f"fuzz-{hops}hop",
        )
        fab = SwitchFabric.partition(
            built.program, mode="multi_hop", chip=chip
        )
        packets = packets_for(case, seed=seed, n=48)
        want = executor.execute(built.lowered, packets, backend="jnp")
        np.testing.assert_array_equal(want, _oracle(built, packets))
        for backend in BACKENDS:
            scanned = fab.run(packets, backend=backend, scan_hops=True)
            np.testing.assert_array_equal(
                scanned.outputs,
                want,
                err_msg=f"backend {backend!r} scanned fabric diverges",
            )
            assert scanned.scanned
            assert len(scanned.hop_seconds) == fab.num_hops
        unrolled = fab.run(packets, backend="jnp", scan_hops=False)
        np.testing.assert_array_equal(unrolled.outputs, want)
        assert not unrolled.scanned


# ---------------------------------------------------------------------------
# Deterministic: the block zipper and fleet edge shapes
# ---------------------------------------------------------------------------

def _built_small():
    return build_case(ProgramCase((16, 24, 8), 3, "per_neuron", 5))


def test_fleet_blocks_pad_and_valid_counts():
    """Mixed-length streams zip into fixed-shape blocks whose valid counts
    recover exactly the real rows; pad rows are zeros."""
    rng = np.random.default_rng(0)
    streams = [
        rng.integers(0, 2, (n, 16)).astype(np.int32) for n in (10, 3, 0, 7)
    ]
    blocks = list(fleet_blocks([[s] for s in streams], 4, 16))
    assert len(blocks) == 3  # ceil(10 / 4)
    totals = np.zeros(4, np.int64)
    for b, valid in blocks:
        assert b.shape == (4, 4, 16)
        for i in range(4):
            v = int(valid[i])
            np.testing.assert_array_equal(b[i, v:], 0)
            totals[i] += v
    np.testing.assert_array_equal(totals, [10, 3, 0, 7])


def test_fleet_empty_stream_yields_empty_outputs():
    built = _built_small()
    streams = [packets_for(built.case, seed=1, n=9), np.zeros((0, 16), np.int32)]
    fr = execute_fleet(
        built.lowered, streams, plan=ExecutionPlan(collect=True)
    )
    assert fr.outputs[1].shape == (0, built.lowered.output_bits)
    np.testing.assert_array_equal(
        fr.outputs[0], executor.execute(built.lowered, streams[0])
    )


def test_fleet_replicates_single_array_and_sharded_path():
    """A lone (n, bits) array + plan.fleet replicates it per switch; the
    shard_map path (devices=1 on CPU) stays bit-exact."""
    built = _built_small()
    x = packets_for(built.case, seed=2, n=33)
    want = executor.execute(built.lowered, x, backend="packed")
    for devices in (None, 1):
        fr = execute_fleet(
            built.lowered,
            x,
            plan=ExecutionPlan(
                backend=Backend.PACKED,
                fleet=4,
                devices=devices,
                chunk_size=8,
                collect=True,
            ),
        )
        assert fr.streams == 4 and fr.packets == 4 * 33
        for i in range(4):
            np.testing.assert_array_equal(fr.outputs[i], want)
    with pytest.raises(ValueError, match="shard evenly"):
        execute_fleet(
            built.lowered,
            [x, x, x],
            plan=ExecutionPlan(fleet=3, devices=2),
        )


# ---------------------------------------------------------------------------
# Deterministic: the typed plan API
# ---------------------------------------------------------------------------

def test_backend_coerce_aliases():
    assert Backend.coerce("jnp") is Backend.FUSED
    assert Backend.coerce("fused") is Backend.FUSED
    assert Backend.coerce(Backend.PACKED) is Backend.PACKED
    assert ExecutionPlan(backend="packed").backend is Backend.PACKED
    with pytest.raises(ValueError, match="unknown backend"):
        Backend.coerce("cuda")
    with pytest.raises(ValueError):
        ExecutionPlan(fleet=0)


def test_run_dispatches_every_program_kind():
    """One entry point: array, chunk stream, fleet, fabric, and the
    interpreter witness all agree through run()."""
    built = _built_small()
    x = packets_for(built.case, seed=4, n=40)
    want = _oracle(built, x)

    out = run(built.lowered, x, plan=ExecutionPlan(backend=Backend.PACKED))
    np.testing.assert_array_equal(out, want)

    sres = run(
        built.program,
        iter([x[:25], x[25:]]),
        plan=ExecutionPlan(backend="jnp", chunk_size=16, collect=True),
    )
    np.testing.assert_array_equal(sres.outputs, want)

    fres = run(
        built.lowered, x, plan=ExecutionPlan(fleet=3, chunk_size=8,
                                             collect=True)
    )
    for i in range(3):
        np.testing.assert_array_equal(fres.outputs[i], want)

    interp = run(
        built.program, x, plan=ExecutionPlan(backend=Backend.INTERPRETER)
    )
    np.testing.assert_array_equal(interp, want)
    with pytest.raises(ValueError, match="un-lowered"):
        run(built.lowered, x,
            plan=ExecutionPlan(backend=Backend.INTERPRETER))

    chip = ChipSpec(
        num_elements=max(1, built.lowered.num_elements // 3),
        phv_bits=built.program.chip.phv_bits,
        name="t/3hop",
    )
    fab = SwitchFabric.partition(built.program, chip=chip)
    fres = run(fab, x, plan=ExecutionPlan(backend="jnp"))
    np.testing.assert_array_equal(fres.outputs, want)
    assert fres.scanned


def test_fabric_packed_requires_scan():
    built = _built_small()
    chip = ChipSpec(
        num_elements=max(1, built.lowered.num_elements // 2),
        phv_bits=built.program.chip.phv_bits,
        name="t/2hop",
    )
    fab = SwitchFabric.partition(built.program, chip=chip)
    with pytest.raises(ValueError, match="packed"):
        fab.run(
            packets_for(built.case, seed=5, n=8),
            backend="packed",
            scan_hops=False,
        )


# ---------------------------------------------------------------------------
# Deterministic: the declarative factory
# ---------------------------------------------------------------------------

_TENANTS = (
    TenantSpec("a", scenario="ddos_burst", shape=(16, 24, 8), weight=2.0),
    TenantSpec("b", scenario="iot_telemetry", shape=(8, 12, 4), seed=1),
)


def test_build_fleet_wires_scheduler_stream_and_fabric():
    fleet = build_fleet(FleetSpec(tenants=_TENANTS))
    assert fleet.num_tenants == 2
    assert fleet.chip.num_elements == (
        sum(p.num_elements for p in fleet.programs) + 1
    )
    sched = fleet.scheduler(mode="merged")
    assert [t.name for t in sched.tenants] == ["a", "b"]
    res = sched.run(
        fleet.stream(600, chunk_size=128, seed=3), chunk_size=128,
        collect=True,
    )
    assert res.packets == 600
    # Per-tenant outputs equal the tenant's own compiled program run alone.
    for tid, prog in enumerate(fleet.programs):
        outs = res.outputs_for(tid)
        assert outs.shape[1] == prog.output_bits
    fab = fleet.fabric(0, hops=3)
    assert fab.num_hops == 3


def test_build_fleet_accepts_dict_and_rejects_bad_specs():
    fleet = build_fleet(
        {
            "tenants": [
                {"name": "x", "scenario": "ddos_burst", "shape": (8, 4)},
            ],
            "mode": "time_sliced",
        }
    )
    assert fleet.spec.mode == "time_sliced"
    with pytest.raises(ValueError, match="duplicate"):
        FleetSpec(tenants=(_TENANTS[0], _TENANTS[0]))
    with pytest.raises(ValueError, match="exactly one"):
        TenantSpec("y", scenario="ddos_burst")


# ---------------------------------------------------------------------------
# Deterministic: the async serving pipeline
# ---------------------------------------------------------------------------

def test_fleet_engine_bit_exact_with_execute_fleet():
    built = _built_small()
    x = packets_for(built.case, seed=8, n=130)
    streams = [x, x[:77], x[17:]]
    plan = ExecutionPlan(backend="packed", chunk_size=32, collect=True)
    want = execute_fleet(built.lowered, streams, plan=plan)
    eng = FleetEngine(built.lowered, plan=plan)
    got = eng.serve(streams, collect=True)
    assert got.packets == want.packets
    assert got.chunks == want.chunks
    for a, b in zip(got.outputs, want.outputs):
        np.testing.assert_array_equal(a, b)
    assert got.wall_seconds > 0 and got.ingest_seconds >= 0
