"""End-to-end behaviour tests for the paper's system.

1. The paper's use case: a DoS white/blacklist packet classifier — train a
   BNN with the straight-through estimator, compile it with the N2Net
   compiler, run the switch-pipeline interpreter on packets, and verify the
   in-network classifications match the trained model.
2. Framework end-to-end: a BNN-quantized LM trains (loss decreases) with the
   same substrate used by the assigned architectures.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnn, bitops, compile_bnn, throughput
from repro.core.interpreter import run_program
from repro.kernels import ops as kops


def _blacklist_dataset(key, n=512, bits=32, margin=4):
    """Synthetic dst-IP blacklist: a random ±1 hyperplane rule with margin
    (near-boundary IPs excluded — realistic ACLs aren't knife-edge)."""
    ips = jax.random.bernoulli(key, 0.5, (4 * n, bits)).astype(jnp.int32)
    w_true = bitops.bits_to_sign(
        jax.random.bernoulli(jax.random.fold_in(key, 7), 0.5, (bits,))
    )
    dots = bitops.bits_to_sign(ips) @ w_true
    idx = jnp.nonzero(jnp.abs(dots) >= margin, size=n, fill_value=0)[0]
    return ips[idx], (dots[idx] >= 0).astype(jnp.int32)


def _train_bnn_classifier(ips, labels, steps=600, width=16, lr=0.02):
    """Latent-weight BNN (32 -> width -> 1): STE + momentum SGD, scaled hinge."""
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (width, 32)) * 0.3
    w2 = jax.random.normal(k2, (1, width)) * 0.3
    x = bitops.bits_to_sign(ips)
    y = labels.astype(jnp.float32) * 2 - 1

    def forward_latent(w1, w2, x):
        h = kops.ste_sign(x @ kops.ste_sign(w1).T)
        return (h @ kops.ste_sign(w2).T)[:, 0]

    def loss(w1, w2):
        out = forward_latent(w1, w2, x) / jnp.sqrt(width)
        return jnp.mean(jax.nn.relu(1.0 - y * out))  # hinge

    @jax.jit
    def step(w1, w2, m1, m2):
        l, (g1, g2) = jax.value_and_grad(loss, argnums=(0, 1))(w1, w2)
        m1, m2 = 0.9 * m1 + g1, 0.9 * m2 + g2
        return l, w1 - lr * m1, w2 - lr * m2, m1, m2

    m1, m2 = jnp.zeros_like(w1), jnp.zeros_like(w2)
    for _ in range(steps):
        l, w1, w2, m1, m2 = step(w1, w2, m1, m2)
    return w1, w2


def test_dos_classifier_end_to_end():
    ips, labels = _blacklist_dataset(jax.random.PRNGKey(0))
    w1, w2 = _train_bnn_classifier(ips, labels)

    # export to {0,1} weights and compile to the switch pipeline
    weights = [np.asarray(bitops.sign_to_bits(w1)), np.asarray(bitops.sign_to_bits(w2))]
    prog = compile_bnn(weights)
    assert prog.passes == 1, "classifier must run at line rate (single pass)"

    # the in-network classification == the model's own forward pass
    chip_out = run_program(prog, ips)[:, 0]
    model_out = bnn.forward([jnp.asarray(w) for w in weights], ips)[:, 0]
    np.testing.assert_array_equal(np.asarray(chip_out), np.asarray(model_out))

    # and the model actually learned the task
    acc = float((model_out == labels).mean())
    assert acc > 0.8, f"classifier accuracy {acc}"

    # line-rate throughput claim holds for this program
    rep = throughput.report_for_program(prog)
    assert rep.packets_per_second == 960e6


def test_quantized_lm_trains():
    from conftest import make_batch, tiny_config
    from repro.configs.base import QuantConfig
    from repro.models import init_params
    from repro.optim.adamw import AdamW
    from repro.train.train_step import make_train_step

    cfg = tiny_config(
        "phi3-mini-3.8b", num_layers=2, vocab_size=64,
        quant=QuantConfig(mode="bnn_weight_only", targets=("ffn",)),
    )
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    opt = AdamW(lr=3e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = make_batch(cfg, 4, 32, key)  # fixed batch: memorization test
    losses = []
    for i in range(25):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]
