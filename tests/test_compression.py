"""Error-feedback compression contract tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # environment without hypothesis: deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.optim.compression import Compressor

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@pytest.mark.parametrize("kind", ["sign", "int8", "topk"])
def test_ef_identity(kind):
    """dec + new_err == g + old_err exactly (nothing lost, only deferred)."""
    c = Compressor(kind=kind)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
    e = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,)) * 0.1}
    dec, err, ratio = c.compress_decompress(g, e)
    np.testing.assert_allclose(
        np.asarray(dec["w"] + err["w"]),
        np.asarray(g["w"] + e["w"]),
        rtol=1e-5, atol=1e-6,
    )
    assert 0 < ratio <= 1


@given(st.integers(0, 1000))
def test_ef_long_run_unbiased(seed):
    """Accumulated applied updates track accumulated true gradients."""
    c = Compressor(kind="sign")
    rng = np.random.default_rng(seed)
    e = {"w": jnp.zeros((32,))}
    total_g = np.zeros(32)
    total_dec = np.zeros(32)
    for t in range(30):
        g = {"w": jnp.asarray(rng.standard_normal(32) * 0.1 + 0.05)}
        dec, e, _ = c.compress_decompress(g, e)
        total_g += np.asarray(g["w"])
        total_dec += np.asarray(dec["w"])
    # residual is bounded -> totals differ by at most the final error
    np.testing.assert_allclose(
        total_dec + np.asarray(e["w"]), total_g, rtol=1e-4, atol=1e-4
    )


def test_none_passthrough():
    c = Compressor(kind="none")
    g = {"w": jnp.ones((4,))}
    dec, err, ratio = c.compress_decompress(g, c.init_error(g))
    np.testing.assert_array_equal(np.asarray(dec["w"]), np.ones(4))
    assert ratio == 1.0
