"""Sharding rule-table unit tests on a fake 16x16 (and 2x16x16) mesh —
``param_specs``/``cache_specs`` only read ``mesh.shape``/``axis_names``."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import tiny_config
from repro import sharding
from repro.configs import get_config
from repro.configs.archs import ASSIGNED_ARCHS
from repro.models import init_cache, init_params


@dataclasses.dataclass
class FakeMesh:
    shape: dict
    axis_names: tuple


POD = FakeMesh({"data": 16, "model": 16}, ("data", "model"))
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16}, ("pod", "data", "model"))


def _axis_size(mesh, ax):
    return int(np.prod([mesh.shape[a] for a in ((ax,) if isinstance(ax, str) else ax)]))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [POD, MULTI], ids=["pod", "multipod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(cfg, params, mesh)

    def check(path, leaf, spec):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            size = _axis_size(mesh, ax)
            assert leaf.shape[dim] % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs
    )


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_big_weights_are_sharded(arch):
    """No >64MB parameter may stay fully replicated on the pod mesh —
    EXCEPT embedding tables with model-indivisible vocabs, which replicate
    by measured policy (d_model-sharding them turns the unembed into a TP
    matmul whose (B,S,V) all-reduce costs more than the replicated bytes;
    see EXPERIMENTS.md §Perf cross-cutting findings)."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(cfg, params, POD)
    offenders = []

    def check(path, leaf, spec):
        ps = sharding._path_str(path)
        if ps.endswith("embed/table") and leaf.shape[0] % POD.shape["model"]:
            return  # measured exemption (odd vocab)
        nbytes = int(np.prod(leaf.shape)) * 2
        if nbytes > 64 * 2**20 and all(ax is None for ax in spec):
            offenders.append((ps, leaf.shape))

    jax.tree_util.tree_map_with_path(lambda p, l, s: check(p, l, s), params, specs)
    assert not offenders, offenders


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS if a != "hubert-xlarge"])
def test_cache_specs_bound_memory(arch):
    """Decode caches at 32k/batch-128 must not replicate >2GiB per device."""
    cfg = get_config(arch)
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 32768))
    specs = sharding.cache_specs(cfg, cache, POD)
    total = 0.0

    def add(leaf, spec):
        nonlocal total
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        denom = 1
        for dim, ax in enumerate(spec):
            if ax is not None:
                denom *= _axis_size(POD, ax)
        total += n / denom

    jax.tree_util.tree_map(add, cache, specs)
    assert total < 8 * 2**30, f"{arch}: per-device cache {total/2**30:.1f} GiB"


def test_fsdp_adds_data_axis():
    cfg = get_config("deepseek-v2-236b")
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(cfg, params, POD)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    expert_specs = [
        s for p, s in flat if "moe/w_gate" in sharding._path_str(p)
    ]
    assert expert_specs and all("data" in str(s) for s in expert_specs)


def test_batch_specs_skip_indivisible():
    cfg = tiny_config("phi3-mini-3.8b")
    batch = {
        "tokens": jax.ShapeDtypeStruct((1, 64), jax.numpy.int32),  # batch 1
    }
    specs = sharding.batch_specs(cfg, batch, POD)
    assert specs["tokens"] == P(None, None)
    batch = {"tokens": jax.ShapeDtypeStruct((32, 64), jax.numpy.int32)}
    specs = sharding.batch_specs(cfg, batch, POD)
    assert specs["tokens"][0] in ("data", ("data",))
