import jax
import numpy as np

from repro.core import bnn, compile_bnn
from repro.core.p4gen import generate_p4


def test_p4_structure():
    params = bnn.init_params(bnn.BnnSpec((32, 16, 4)), jax.random.PRNGKey(0))
    prog = compile_bnn([np.asarray(w) for w in params])
    src = generate_p4(prog)
    assert src.count("action element_") == prog.num_elements
    # every element invoked exactly once, in order
    apply_block = src.split("apply {")[1]
    for i in range(prog.num_elements):
        assert f"element_{i}_" in apply_block
    # header declares the I/O fields
    for f in prog.input_fields + prog.output_fields:
        assert f"f{f.fid};" in src
    # only chip-legal constructs
    assert "float" not in src
