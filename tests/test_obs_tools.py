"""Operator tooling: obs_report hardening, obs_diff, bench_history, and
the Prometheus label-escaping pin.

These drive the CLIs in-process (``main(argv)``) so the tests pin exit
codes and messages without subprocess overhead.  The hardening contract:
empty or partial export directories produce a one-line message and a
non-zero exit — never a traceback — and partially-populated rows render
with defaults instead of KeyErrors.
"""
import json
import os
import sys

import pytest

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "tools")
)

import bench_history  # noqa: E402
import check_bench_regression as cbr  # noqa: E402
import obs_diff  # noqa: E402
import obs_report  # noqa: E402

from repro.obs.export import render_prometheus  # noqa: E402
from repro.obs.metrics import MetricsRegistry  # noqa: E402


# ------------------------------------------------------------- prometheus

def test_prometheus_label_values_are_escaped():
    reg = MetricsRegistry()
    reg.counter(
        "dataplane.packets_total",
        tenant='evil"name\\with\nnewline',
    ).inc(3)
    text = render_prometheus(reg)
    # Exposition format: backslash -> \\, quote -> \", newline -> \n.
    assert (
        'tenant="evil\\"name\\\\with\\nnewline"' in text
    )
    assert "\nnewline" not in text.split("} ")[0]  # no raw newline in labels
    for line in text.splitlines():
        assert "\n" not in line  # trivially true, but pins one-line-ness


# ------------------------------------------------------------- obs_report

def _write_metrics(path, rows):
    with open(path, "w") as fh:
        for row in rows:
            fh.write(json.dumps(row) + "\n")


def test_report_empty_dir_message_not_traceback(tmp_path, capsys):
    assert obs_report.main([str(tmp_path)]) == 1
    err = capsys.readouterr().err
    assert "no *_metrics.jsonl" in err and "export_all" in err


def test_report_missing_explicit_file_exits_cleanly(tmp_path):
    with pytest.raises(SystemExit, match="cannot read metrics file"):
        obs_report.main(["--metrics", str(tmp_path / "nope.jsonl")])
    with pytest.raises(SystemExit, match="cannot read trace file"):
        obs_report.main(["--trace", str(tmp_path / "nope.json")])


def test_report_malformed_inputs_exit_with_location(tmp_path):
    bad = tmp_path / "x_metrics.jsonl"
    bad.write_text('{"name": "a", "type": "counter", "value": 1}\n{oops\n')
    with pytest.raises(SystemExit, match="bad JSONL line"):
        obs_report.main([str(tmp_path)])
    bad.write_text('{"value": 1}\n')
    with pytest.raises(SystemExit, match="missing name/type"):
        obs_report.main([str(tmp_path)])
    trace = tmp_path / "y_trace.json"
    bad.unlink()
    trace.write_text("[1, 2]")
    with pytest.raises(SystemExit, match="not an object"):
        obs_report.main([str(tmp_path)])
    trace.write_text('{"no": "events"}')
    with pytest.raises(SystemExit, match="traceEvents"):
        obs_report.main([str(tmp_path)])


def test_report_partial_rows_render_with_defaults(tmp_path, capsys):
    # Metrics-only dir (no trace), rows missing optional fields.
    _write_metrics(
        tmp_path / "run_metrics.jsonl",
        [
            {"name": "c", "type": "counter"},                # no value
            {"name": "h", "type": "histogram"},              # no count/stats
            {"name": "mt.packets_total", "type": "counter",
             "labels": {"tenant": "t0"}},                    # no value
        ],
    )
    assert obs_report.main([str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "c = 0" in out and "h" in out and "t0" in out


def test_report_hardware_utilization_section(tmp_path, capsys):
    _write_metrics(
        tmp_path / "run_metrics.jsonl",
        [
            {"name": "roofline.pps_bound", "type": "gauge", "value": 3.3e9,
             "labels": {"path": "packed"}},
            {"name": "roofline.fraction", "type": "gauge", "value": 0.0025,
             "labels": {"path": "packed"}},
            {"name": "roofline.bytes_per_packet", "type": "gauge",
             "value": 248.0, "labels": {"path": "packed"}},
            {"name": "roofline.pps_bound", "type": "gauge", "value": 1.1e9,
             "labels": {"path": "fleet4:packed"}},
            {"name": "dataplane.stream_pps", "type": "gauge", "value": 5e6},
        ],
    )
    assert obs_report.main([str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "hardware utilization" in out
    assert "packed" in out and "fleet4:packed" in out
    assert "0.25%" in out                      # fraction formatting
    # roofline gauges are grouped, not repeated in the generic gauge dump
    assert "roofline.pps_bound" not in out
    assert "dataplane.stream_pps" in out


# --------------------------------------------------------------- obs_diff

def _export_dir(tmp_path, name, pps, events=True):
    d = tmp_path / name
    d.mkdir()
    _write_metrics(
        d / "run_metrics.jsonl",
        [
            {"name": "dataplane.stream_pps", "type": "gauge", "value": pps},
            {"name": "dataplane.packets_total", "type": "counter",
             "value": 1000},
        ],
    )
    if events:
        (d / "run_trace.json").write_text(json.dumps({
            "traceEvents": [
                {"ph": "X", "name": "compile:x", "cat": "compile",
                 "ts": 0, "dur": 1000 * pps / 1e6, "tid": 0, "pid": 0},
                {"ph": "X", "name": "execute:x", "cat": "execute",
                 "ts": 2000, "dur": 500, "tid": 0, "pid": 0},
            ]
        }))
    return d


def test_obs_diff_dirs_attributes_phase_movement(tmp_path, capsys):
    a = _export_dir(tmp_path, "a", pps=1e6)
    b = _export_dir(tmp_path, "b", pps=2e6)
    assert obs_diff.main([str(a), str(b)]) == 0
    out = capsys.readouterr().out
    assert "phase wall time" in out
    assert "attribution" in out and "compile" in out
    assert "dataplane.stream_pps" in out and "+100.0%" in out


def test_obs_diff_dir_missing_artifacts(tmp_path):
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(SystemExit, match="no \\*_metrics"):
        obs_diff.main([str(empty), str(empty)])


def _bench_payload(tmp_path, name, pps, warmup):
    d = tmp_path / name
    d.mkdir(exist_ok=True)
    path = d / "BENCH_dataplane_bench.json"
    path.write_text(json.dumps({
        "module": "dataplane_bench",
        "seconds": warmup + 1.0,
        "warmup_seconds": warmup,
        "steady_seconds": 1.0,
        "rows": [
            {"name": "dataplane_packed_x", "us_per_call": 10.0,
             "derived": f"pps={pps} warmup_us={warmup * 1e6}",
             "metrics": {"pps": pps, "warmup_us": warmup * 1e6}},
            {"name": "dataplane_packed", "us_per_call": 1.0,
             "derived": "roofline_frac=0.02",
             "metrics": {"roofline_frac": 0.02}},
        ],
    }))
    return path


def test_obs_diff_bench_files_warmup_vs_steady(tmp_path, capsys):
    a = _bench_payload(tmp_path, "a", pps=4e6, warmup=0.1)
    b = _bench_payload(tmp_path, "b", pps=3e6, warmup=0.9)
    assert obs_diff.main(["--bench", str(a), str(b)]) == 0
    out = capsys.readouterr().out
    assert "compile-side (warmup)" in out
    assert "dataplane_packed_x.pps" in out and "-25.0%" in out


def test_obs_diff_vs_baseline(tmp_path, capsys):
    _bench_payload(tmp_path, "cur", pps=4e6, warmup=0.1)
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({
        "budget_env": {k: os.environ.get(k) for k in cbr.BUDGET_ENV},
        "metrics": {
            "dataplane_packed_x.pps": {"value": 5e6,
                                       "higher_is_better": True},
            "dataplane_packed_roofline_frac": {"value": 0.025,
                                               "higher_is_better": True},
        },
    }))
    assert obs_diff.main([
        "--baseline", str(baseline), "--bench-dir", str(tmp_path / "cur"),
    ]) == 0
    out = capsys.readouterr().out
    assert "gated metrics" in out
    assert "dataplane_packed_roofline_frac" in out and "-20.0%" in out
    assert "WARNING" not in out     # budgets match


# ----------------------------------------------- regression-gate flattening

def test_collect_metrics_flattens_roofline_frac(tmp_path):
    _bench_payload(tmp_path, "cur", pps=4e6, warmup=0.1)
    metrics = cbr.collect_metrics(str(tmp_path / "cur"))
    assert metrics["dataplane_packed_roofline_frac"] == {
        "value": 0.02, "higher_is_better": True,
    }
    # and no spurious pps metric from the roofline row itself
    assert "dataplane_packed.pps" not in metrics
    assert "dataplane_packed_x.pps" in metrics


# ------------------------------------------------------------ bench_history

def test_bench_history_appends_jsonl(tmp_path, capsys):
    _bench_payload(tmp_path, "cur", pps=4e6, warmup=0.1)
    hist = tmp_path / "traj.jsonl"
    for note in ("first", "second"):
        assert bench_history.main([
            "--bench-dir", str(tmp_path / "cur"),
            "--history", str(hist), "--note", note,
        ]) == 0
    lines = [json.loads(x) for x in hist.read_text().splitlines()]
    assert [x["note"] for x in lines] == ["first", "second"]
    for line in lines:
        assert line["metrics"]["dataplane_packed_roofline_frac"] == 0.02
        assert line["warmup_seconds"] == 0.1
        assert set(line["budget_env"]) == set(cbr.BUDGET_ENV)
        assert "ts" in line


def test_bench_history_requires_bench_files(tmp_path):
    with pytest.raises(SystemExit, match="no BENCH_"):
        bench_history.main(["--bench-dir", str(tmp_path),
                            "--history", str(tmp_path / "t.jsonl")])
