"""Serving engine: batched continuous decoding equals a manual loop."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_config
from repro.models import decode_step, init_params, prefill
from repro.serving.engine import Engine, Request


def manual_greedy(cfg, params, prompt, n_new):
    last, cache = prefill(params, {"tokens": jnp.asarray(prompt[None, :])}, cfg)

    def pad(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == len(prompt):
            pads = [(0, 0)] * leaf.ndim
            pads[2] = (0, 64 - len(prompt))
            return jnp.pad(leaf, pads)
        return leaf

    cache = jax.tree.map(pad, cache)
    toks = [int(jnp.argmax(last[0]))]
    for _ in range(n_new - 1):
        logits, cache = decode_step(
            params, jnp.asarray([toks[-1]], jnp.int32), cache, cfg
        )
        toks.append(int(jnp.argmax(logits[0])))
    return toks


def test_engine_matches_manual_decode(rng_key):
    cfg = tiny_config("phi3-mini-3.8b", num_layers=2, vocab_size=64)
    params = init_params(cfg, rng_key)
    prompt = np.arange(6, dtype=np.int32) % 60

    want = manual_greedy(cfg, params, prompt, 5)

    eng = Engine(cfg, params, max_batch=1, max_len=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    done = eng.run()
    assert len(done) == 1
    assert done[0].output == want


def test_engine_batches_multiple_requests(rng_key):
    cfg = tiny_config("phi3-mini-3.8b", num_layers=2, vocab_size=64)
    params = init_params(cfg, rng_key)
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32) % 60,
                           max_new_tokens=3))
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    assert all(len(r.output) == 3 for r in done)


def test_engine_eos_terminates_early(rng_key):
    """A request whose sampler hits eos frees its slot before the budget."""
    cfg = tiny_config("phi3-mini-3.8b", num_layers=2, vocab_size=64)
    params = init_params(cfg, rng_key)
    # greedy argmax is deterministic; discover the first sampled token and
    # declare it the EOS — the request must then finish after 1 token.
    probe = Engine(cfg, params, max_batch=1, max_len=64)
    probe.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32), max_new_tokens=3))
    first = probe.run()[0].output[0]

    eng = Engine(cfg, params, max_batch=1, max_len=64)
    eng.submit(Request(rid=1, prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=10, eos_id=first))
    done = eng.run()
    assert len(done) == 1 and done[0].output[0] == first
    assert len(done[0].output) == 1
