"""Export round-trip: trained/arbitrary weights -> compile -> fabric, bit-exact.

Edge cases the train->deploy loop must survive: single-layer nets, sign ties
at ``popcount == N/2`` (and latent weights exactly 0.0), and models whose
compiled programs outgrow one switch and partition onto multi-hop fabrics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn
from repro.core.export import (
    ExportError,
    bit_weights_from_latent,
    export_bits,
    export_latent,
    load,
    verify_roundtrip,
)
from repro.core.pipeline import RMT_NATIVE_POPCNT, ChipSpec
from repro.train.bnn_trainer import forward_bits


def _rand_bits(shape, seed=0):
    return np.random.default_rng(seed).integers(0, 2, shape, dtype=np.int32)


def _rand_latent(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.uniform(-1, 1, (sizes[i + 1], sizes[i])).astype(np.float32)
        for i in range(len(sizes) - 1)
    ]


# -- export construction and validation --------------------------------------

def test_export_bits_builds_spec_program_and_tables():
    ws = [_rand_bits((4, 8)), _rand_bits((2, 4), seed=1)]
    ex = export_bits(ws)
    assert ex.spec.layer_sizes == (8, 4, 2)
    assert ex.program.input_bits == 8 and ex.lowered.output_bits == 2
    assert ex.compile_seconds >= 0 and ex.lower_seconds >= 0


@pytest.mark.parametrize(
    "bad",
    [
        [],
        [np.array([0, 1])],                       # 1-D
        [np.array([[0, 2]])],                     # not {0,1}
        [_rand_bits((4, 8)), _rand_bits((2, 5))], # fan-in mismatch
    ],
)
def test_export_bits_rejects_bad_weights(bad):
    with pytest.raises(ExportError):
        export_bits(bad)


def test_bit_weights_from_latent_ties_round_to_one():
    # latent 0.0 is the binarization boundary: rounds to bit 1 (+1), the same
    # side binarize_ste and the oracle's SIGN take.
    bits = bit_weights_from_latent([np.zeros((2, 4), np.float32)])
    np.testing.assert_array_equal(bits[0], np.ones((2, 4), np.int32))


# -- round-trip verification --------------------------------------------------

def test_single_layer_roundtrip():
    ex = export_bits([_rand_bits((4, 8), seed=2)])
    x = _rand_bits((64, 8), seed=3)
    rep = verify_roundtrip(ex, x)
    assert rep.ok and rep.hops == 1 and rep.packets == 64
    np.testing.assert_array_equal(
        ex.oracle_forward(x),
        np.asarray(bnn.forward([jnp.asarray(w) for w in ex.weights], jnp.asarray(x))),
    )


def test_single_neuron_single_layer_roundtrip():
    ex = export_bits([_rand_bits((1, 16), seed=4)])
    assert verify_roundtrip(ex, _rand_bits((32, 16), seed=5)).ok


def test_tie_at_half_popcount_is_bit_one_everywhere():
    # All-ones weights, inputs with exactly N/2 ones: agreement == N/2, so
    # 2*pop == N — the oracle's tie, which must deploy as bit 1 on every
    # backend and match the trainer's float forward pass.
    n = 8
    ex = export_bits([np.ones((1, n), np.int32)])
    x = np.zeros((n + 1, n), np.int32)
    for i in range(n + 1):  # rows with 0..n ones: crosses the tie at n/2
        x[i, :i] = 1
    rep = verify_roundtrip(ex, x)
    assert rep.ok
    want = (2 * x.sum(axis=1, keepdims=True) >= n).astype(np.int32)
    np.testing.assert_array_equal(ex.oracle_forward(x), want)
    assert want[n // 2, 0] == 1  # the tie itself
    # Trainer-side witness: latent +1 weights binarize to the same network.
    latent = [np.ones((1, n), np.float32)]
    np.testing.assert_array_equal(
        np.asarray(forward_bits([jnp.asarray(w) for w in latent], jnp.asarray(x))),
        want,
    )


def test_latent_zero_weights_roundtrip_bit_exact():
    latent = [np.zeros((3, 8), np.float32), np.zeros((2, 3), np.float32)]
    ex = export_latent(latent)
    x = _rand_bits((50, 8), seed=6)
    rep = verify_roundtrip(
        ex,
        x,
        reference_bits=np.asarray(
            forward_bits([jnp.asarray(w) for w in latent], jnp.asarray(x))
        ),
    )
    assert rep.ok


@pytest.mark.parametrize("mode", ["multi_hop", "recirculate"])
def test_multi_hop_fabric_roundtrip(mode):
    # (32, 128, 64) outgrows the 32-element chip: the export cannot fit one
    # switch and must round-trip through a partitioned fabric.
    latent = _rand_latent((32, 128, 64), seed=7)
    ex = export_latent(latent)
    assert ex.program.num_elements > ex.chip.num_elements
    x = _rand_bits((128, 32), seed=8)
    rep = verify_roundtrip(
        ex,
        x,
        mode=mode,
        reference_bits=np.asarray(
            forward_bits([jnp.asarray(w) for w in latent], jnp.asarray(x))
        ),
    )
    assert rep.ok and rep.hops > 1


def test_deep_fabric_with_tiny_chip():
    ex = export_bits([_rand_bits((8, 16), seed=9), _rand_bits((4, 8), seed=10)])
    rep = verify_roundtrip(
        ex, _rand_bits((40, 16), seed=11), fabric_chip=ChipSpec(num_elements=5)
    )
    assert rep.ok and rep.hops >= 4


def test_native_popcnt_chip_roundtrip():
    ex = export_bits([_rand_bits((8, 32), seed=12)], chip=RMT_NATIVE_POPCNT)
    assert verify_roundtrip(ex, _rand_bits((64, 32), seed=13)).ok


def test_verify_raises_on_reference_mismatch():
    ex = export_bits([_rand_bits((4, 8), seed=14)])
    x = _rand_bits((16, 8), seed=15)
    wrong = 1 - ex.oracle_forward(x)
    with pytest.raises(ExportError, match="FAILED"):
        verify_roundtrip(ex, x, reference_bits=wrong)
    rep = verify_roundtrip(ex, x, reference_bits=wrong, check=False)
    assert not rep.ok and rep.reference_mismatches == 16
    assert rep.executor_mismatches == 0 and rep.fabric_mismatches == 0


def test_verify_rejects_bad_shapes():
    ex = export_bits([_rand_bits((4, 8), seed=16)])
    with pytest.raises(ExportError):
        verify_roundtrip(ex, _rand_bits((16, 9)))
    with pytest.raises(ExportError):
        verify_roundtrip(
            ex, _rand_bits((16, 8)), reference_bits=np.zeros((16, 3), np.int32)
        )


# -- persistence --------------------------------------------------------------

def test_save_load_roundtrip(tmp_path):
    ex = export_latent(_rand_latent((16, 8, 4), seed=17))
    d = ex.save(str(tmp_path / "model"))
    got = load(d)
    assert got.program.fingerprint() == ex.program.fingerprint()
    for a, b in zip(got.weights, ex.weights):
        np.testing.assert_array_equal(a, b)
    x = _rand_bits((32, 16), seed=18)
    np.testing.assert_array_equal(got.oracle_forward(x), ex.oracle_forward(x))


def test_load_detects_chip_mismatch(tmp_path):
    ex = export_bits([_rand_bits((4, 32), seed=19)])
    d = ex.save(str(tmp_path / "model"))
    with pytest.raises(ExportError, match="fingerprint"):
        load(d, chip=RMT_NATIVE_POPCNT)
