"""The paper's core claims: compiler + interpreter vs the BNN oracle,
Table 1, the §3 ablation, and the headline throughput example."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # environment without hypothesis: deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import bnn, bitops, compile_bnn, run_program, throughput
from repro.core.pipeline import (
    RMT,
    RMT_NATIVE_POPCNT,
    ProgramConstraintError,
    elements_for_neuron_group,
    max_parallel_neurons,
)

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

TABLE1_WIDTHS = (16, 32, 64, 128, 256, 512, 1024, 2048)
TABLE1_PARALLEL = (128, 64, 32, 16, 8, 4, 2, 1)
TABLE1_ELEMENTS = (12, 14, 16, 18, 20, 22, 24, 25)


def test_table1_parallel_neurons():
    got = [max_parallel_neurons(n) for n in TABLE1_WIDTHS]
    assert tuple(got) == TABLE1_PARALLEL


def test_table1_elements():
    got = [
        elements_for_neuron_group(n, p)
        for n, p in zip(TABLE1_WIDTHS, TABLE1_PARALLEL)
    ]
    assert tuple(got) == TABLE1_ELEMENTS


def test_single_neuron_formula():
    # paper: "3 + 2log2(N) elements to implement a single neuron"
    for n in TABLE1_WIDTHS:
        assert elements_for_neuron_group(n, 1) == 3 + 2 * int(np.log2(n))


def test_native_popcnt_range_is_5_to_10():
    # Paper §3 recomputes Table 1's operating points (Table-1 parallelism:
    # N=2048 stays single-neuron, so no folding element) with the POPCNT
    # primitive: 12-25 becomes 5-10.
    got = [
        elements_for_neuron_group(
            n, max_parallel_neurons(n, RMT), RMT_NATIVE_POPCNT
        )
        for n in TABLE1_WIDTHS
    ]
    assert min(got) == 5 and max(got) == 10, got  # paper §3: "a 5-10 range"


def test_native_popcnt_doubles_parallelism():
    for n in TABLE1_WIDTHS:
        assert max_parallel_neurons(n, RMT_NATIVE_POPCNT) == 2 * max_parallel_neurons(n, RMT)


def _random_model(layer_sizes, seed):
    spec = bnn.BnnSpec(tuple(layer_sizes))
    params = bnn.init_params(spec, jax.random.PRNGKey(seed))
    x = jax.random.bernoulli(
        jax.random.PRNGKey(seed + 1), 0.5, (16, layer_sizes[0])
    ).astype(jnp.int32)
    return spec, params, x


@given(
    st.lists(st.integers(2, 96), min_size=2, max_size=4),
    st.integers(0, 10_000),
)
def test_interpreter_matches_oracle(layer_sizes, seed):
    spec, params, x = _random_model(layer_sizes, seed)
    prog = compile_bnn([np.asarray(w) for w in params])
    got = run_program(prog, x)
    want = bnn.forward(params, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    st.lists(st.integers(2, 96), min_size=2, max_size=3),
    st.integers(0, 10_000),
)
def test_native_popcnt_interpreter_matches_oracle(layer_sizes, seed):
    spec, params, x = _random_model(layer_sizes, seed)
    prog = compile_bnn([np.asarray(w) for w in params], RMT_NATIVE_POPCNT)
    got = run_program(prog, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(bnn.forward(params, x)))


def test_oracle_identities(rng_key):
    """XNOR-popcount == ±1 arithmetic == packed HAKMEM arithmetic."""
    spec = bnn.BnnSpec((48, 32, 10))
    params = bnn.init_params(spec, rng_key)
    x = jax.random.bernoulli(jax.random.PRNGKey(9), 0.5, (32, 48)).astype(jnp.int32)
    base = bnn.forward(params, x)
    pm1 = bnn.forward_pm1(params, bitops.bits_to_sign(x))
    np.testing.assert_array_equal(np.asarray(2 * base - 1), np.asarray(pm1, np.int64))
    np.testing.assert_array_equal(
        np.asarray(bnn.packed_forward(params, x)), np.asarray(base)
    )


def test_headline_example_single_pass():
    """Paper: 960M two-layer BNNs/s — 32b activations, layers of 64 and 32."""
    spec = bnn.BnnSpec((32, 64, 32))
    params = bnn.init_params(spec, jax.random.PRNGKey(0))
    prog = compile_bnn([np.asarray(w) for w in params])
    assert prog.num_elements == 30          # 14 + 16, <= 32
    assert prog.passes == 1
    rep = throughput.report_for_program(prog)
    assert rep.networks_per_second == pytest.approx(960e6)
    # analytic model agrees with the compiled program
    assert throughput.analytic_elements(spec) == 30


def test_max_activation_vector_is_2048():
    """Paper: max activation length 2048 (duplication halves the 512B PHV)."""
    spec = bnn.BnnSpec((2048, 1))
    params = bnn.init_params(spec, jax.random.PRNGKey(0))
    prog = compile_bnn([np.asarray(w) for w in params])
    assert prog.peak_phv_bits == 4096       # exactly full PHV
    assert prog.num_elements == 25          # Table 1 right edge

    big = bnn.BnnSpec((4096, 1))
    bad = bnn.init_params(big, jax.random.PRNGKey(0))
    with pytest.raises(ProgramConstraintError):
        compile_bnn([np.asarray(w) for w in bad])


def test_neuron_rate_scales_with_parallelism():
    assert throughput.neuron_rate(2048) == pytest.approx(960e6)
    assert throughput.neuron_rate(32) == pytest.approx(960e6 * 64)


def test_recirculation_halves_throughput():
    """Networks too big for 32 elements recirculate; pps divides by passes."""
    spec = bnn.BnnSpec((128, 128, 64, 32))
    params = bnn.init_params(spec, jax.random.PRNGKey(1))
    prog = compile_bnn([np.asarray(w) for w in params])
    rep = throughput.report_for_program(prog)
    assert rep.passes == -(-prog.num_elements // 32)
    assert rep.packets_per_second == pytest.approx(960e6 / rep.passes)


@given(
    st.lists(st.integers(2, 64), min_size=2, max_size=4),
    st.integers(0, 10_000),
)
def test_compiled_programs_respect_chip_constraints(layer_sizes, seed):
    """PHV/element invariants hold for arbitrary model shapes."""
    spec, params, _ = _random_model(layer_sizes, seed)
    prog = compile_bnn([np.asarray(w) for w in params])
    assert prog.peak_phv_bits <= prog.chip.phv_bits
    for el in prog.elements:
        el.validate(prog.chip.max_parallel_ops)  # raises on violation
        dsts = [op.dst.fid for op in el.ops]
        assert len(dsts) == len(set(dsts))       # one write per field


def test_single_group_pow2_matches_cost_model():
    """Compiled element counts == the analytic model at Table-1 points."""
    for n in (16, 64, 512):
        par = max_parallel_neurons(n)
        params = bnn.init_params(bnn.BnnSpec((n, par)), jax.random.PRNGKey(n))
        prog = compile_bnn([np.asarray(w) for w in params])
        assert prog.num_elements == elements_for_neuron_group(n, par)
