"""Observability, end to end: a traced multi-tenant run -> report artifacts.

1. **Bit-exactness** — the same stream runs through the fused executor with
   observability OFF and ON; outputs must match element-wise (observation
   never touches data).
2. **Traced run** — with ``repro.obs`` enabled, a three-tenant shared chip
   serves a mixed stream in both scheduling modes (merged, time-sliced)
   and a single-tenant stream runs through ``execute_stream``; the hot
   paths emit spans (``stream:`` > ``compile:`` / ``execute:``) and the
   ``dataplane.*`` / ``mt.*`` metric families.
3. **Export** — metrics land as JSONL + Prometheus text, spans as a Chrome
   Trace Event JSON (load it in ``chrome://tracing`` / Perfetto); the run
   fails unless the trace contains *distinct* compile and execute spans
   and the metrics carry per-tenant queue-delay histograms.
4. **Report** — render the artifacts with::

       python tools/obs_report.py <out-dir>

Run:   PYTHONPATH=src python examples/observe_dataplane.py --out obs_out
Smoke: PYTHONPATH=src python examples/observe_dataplane.py --smoke --out obs_out
(exits non-zero if any bit-exactness or artifact gate fails)
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro import obs
from repro.dataplane import (
    FleetSpec,
    TenantSpec,
    build_fleet,
    execute_stream,
    lower_program,
    traffic,
)

_SPEC = FleetSpec(tenants=(
    TenantSpec("ddos", scenario="ddos_burst", shape=(32, 64, 32), weight=2.0,
               seed=0),
    TenantSpec("iot", scenario="iot_telemetry", shape=(16, 32, 8), seed=1),
    TenantSpec("flows", scenario="flow_tuple", shape=(32, 16), seed=2),
))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--packets", type=int, default=60_000)
    ap.add_argument("--out", default="obs_out", help="artifact directory")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny budget for CI: same gates, smaller stream",
    )
    args = ap.parse_args()
    n = 6_000 if args.smoke else args.packets
    chunk = min(1 << 12, n)
    failures: list[str] = []

    def gate(ok: bool, what: str) -> None:
        print(("  [ok]   " if ok else "  [FAIL] ") + what)
        if not ok:
            failures.append(what)

    # -- tenants: three independently compiled BNNs sharing one chip, all
    # constructed from the one declarative spec above -----------------------
    fleet = build_fleet(dataclasses.replace(_SPEC, quantum=chunk))

    # -- 1. bit-exactness: observability must not touch the data ----------
    print("== 1. bit-exactness (obs off vs on) ==")
    lp = lower_program(fleet.programs[0])

    def one_stream():
        return execute_stream(
            lp,
            traffic.stream("ddos_burst", n, 32, chunk_size=chunk),
            chunk_size=chunk,
            backend="jnp",
            collect=True,
        )

    obs.disable()
    off = one_stream()
    obs.enable(reset=True)
    on = one_stream()
    gate(
        np.array_equal(off.outputs, on.outputs),
        f"execute_stream outputs identical over {n} packets",
    )

    # -- 2. traced multi-tenant run (obs stays enabled, registry kept) ----
    print("== 2. traced multi-tenant run ==")
    for mode in ("merged", "time_sliced"):
        sched = fleet.scheduler()
        res = sched.run(
            fleet.stream(n, chunk_size=chunk, seed=7),
            mode=mode,
            backend="jnp",
            chunk_size=chunk,
            collect=False,
        )
        print(
            f"  {mode}: {res.packets} packets, "
            f"{res.packets_per_second:.3e} pkt/s, "
            f"warmup {res.warmup_seconds * 1e3:.1f}ms"
        )

    # -- 3. export + artifact gates ---------------------------------------
    print("== 3. export ==")
    paths = obs.export_all(args.out, prefix="example")
    for key in sorted(paths):
        print(f"  {key}: {paths[key]}")

    with open(paths["trace"]) as fh:
        events = json.load(fh)["traceEvents"]
    cats = {e.get("cat") for e in events if e.get("ph") == "X"}
    gate("compile" in cats and "execute" in cats,
         f"trace has distinct compile+execute spans (cats={sorted(cats)})")
    names = {e.get("name") for e in events}
    gate(any(s.startswith("stream:") for s in names),
         "trace has stream-level spans")

    rows = []
    with open(paths["metrics_jsonl"]) as fh:
        rows = [json.loads(line) for line in fh if line.strip()]
    qdelay = [
        r for r in rows
        if r["name"] == "mt.queue_delay_seconds"
        and (r.get("labels") or {}).get("tenant")
    ]
    gate(
        {(r["labels"]["tenant"]) for r in qdelay}
        >= {t.name for t in _SPEC.tenants},
        f"per-tenant queue-delay histograms exported ({len(qdelay)} tenants)",
    )
    gate(all(r.get("p50") is not None and r.get("p99") is not None
             for r in qdelay),
         "queue-delay histograms carry p50/p99")
    gate(any(r["name"] == "dataplane.packets_total" for r in rows),
         "dataplane.* metric family exported")

    obs.disable()
    print(
        f"\nrender the report:  python tools/obs_report.py {args.out}"
    )
    if failures:
        print(f"\n{len(failures)} gate(s) FAILED: {failures}")
        return 1
    print("\nall gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
