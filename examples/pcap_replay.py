"""Real-capture ingestion, end to end: pcap file -> trained BNN -> switch.

1. **Capture** — a deterministic two-class trace (IoT UDP telemetry vs TCP
   SYN flood) is synthesized as raw packet bytes and written to disk as
   BOTH classic pcap and pcapng; reading the files back must reproduce
   every packet byte-exactly (the reader/writer round-trip contract).
2. **Featurize** — the capture's Ethernet/IPv4/TCP/UDP header fields are
   sliced into activation-bit matrices (``dataplane.pcap.featurize``), the
   same fixed-width {0,1} rows the synthetic scenarios emit.
3. **Train** — a straight-through-estimator BNN fits the capture on a
   temporal split (``make_capture_task``): early packets train, the unseen
   tail is held out, exactly how a capture-then-deploy pipeline would.
4. **Deploy** — the exported op-tables run on a 5-hop simulated switch
   fabric; held-out packets must classify bit-exactly vs the mathematical
   oracle AND the training forward pass.
5. **Serve** — the capture is registered as a traffic scenario and served
   as one tenant of three on a shared chip (``SwitchScheduler``) in both
   merged and time-sliced modes, with per-tenant telemetry; the pcap
   tenant's outputs must again be bit-exact with the oracle.

Run:   PYTHONPATH=src python examples/pcap_replay.py
Smoke: PYTHONPATH=src python examples/pcap_replay.py --smoke
(exits non-zero if any round-trip, accuracy, or bit-exactness gate fails)
"""
from __future__ import annotations

import argparse
import os
import tempfile

import numpy as np

from repro.core.export import verify_roundtrip
from repro.dataplane import FleetSpec, TenantSpec, build_fleet, pcap, traffic
from repro.train.bnn_trainer import BnnTrainConfig, BnnTrainer, make_capture_task

ACCURACY_FLOOR = 0.95
FABRIC_HOPS = 5
SCENARIO_NAME = "pcap:replay"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--packets", type=int, default=20_000)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny budget for CI: skips the accuracy gate, keeps every "
        "round-trip and bit-exactness gate",
    )
    args = ap.parse_args()
    n = 4000 if args.smoke else args.packets
    steps = 40 if args.smoke else args.steps
    failures: list[str] = []

    print("== 1. capture (synthesize -> write -> read, both formats) ==")
    packets, ts, labels = pcap.synthesize_capture(n, seed=args.seed)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "trace.pcap")
        path_ng = os.path.join(tmp, "trace.pcapng")
        pcap.write_pcap(packets, ts, path=path)
        pcap.write_pcapng(packets, ts, path=path_ng)
        cap = pcap.read_pcap(path)
        cap_ng = pcap.read_pcap(path_ng)
        print(
            f"{cap.num_packets} packets, {os.path.getsize(path)} bytes pcap, "
            f"{os.path.getsize(path_ng)} bytes pcapng"
        )
    if cap.packets() != packets or cap_ng.packets() != packets:
        failures.append("capture file round trip is not byte-exact")
    flood = int(labels.sum())
    print(f"ground truth: {n - flood} telemetry, {flood} flood packets")

    print("\n== 2. featurize (header fields -> activation bits) ==")
    input_bits = 64
    bits = pcap.featurize(cap, input_bits)
    fields = pcap.parse_headers(cap)
    print(
        f"{pcap.PCAP_FEATURE_BITS}-bit layout folded to {input_bits} bits; "
        f"{int(fields.is_udp.sum())} UDP / {int(fields.is_tcp.sum())} TCP, "
        f"IAT buckets {sorted(np.unique(fields.iat_bucket).tolist())}"
    )

    print("\n== 3. train (temporal split of the capture) ==")
    task = make_capture_task(bits, labels, train_frac=0.8, seed=args.seed)
    cfg = BnnTrainConfig(
        layer_sizes=(input_bits, 64, 1), steps=steps, seed=args.seed
    )
    trainer = BnnTrainer(cfg, task=task)
    summary = trainer.train()
    held = trainer.evaluate_held_out()
    print(
        f"{summary['final_step']} steps in {summary['seconds']:.2f}s; "
        f"held-out (capture tail): {held['accuracy']:.2%} on "
        f"{held['packets']} packets"
    )
    if not args.smoke and held["accuracy"] < ACCURACY_FLOOR:
        failures.append(
            f"held-out accuracy {held['accuracy']:.2%} < {ACCURACY_FLOOR:.0%}"
        )

    print(f"\n== 4. deploy ({FABRIC_HOPS}-hop switch fabric) ==")
    exported = trainer.export()
    # One declarative spec builds the whole serving stack — the trained
    # export as the pcap-replay tenant plus two synthetic tenants — and
    # also hands out the deploy fabric for the export's program.
    traffic.register_scenario(
        pcap.pcap_scenario(cap, name=SCENARIO_NAME), overwrite=True
    )
    fleet = build_fleet(FleetSpec(tenants=(
        TenantSpec(f"t0:{SCENARIO_NAME}", scenario=SCENARIO_NAME,
                   program=exported.program, weight=2.0),
        TenantSpec("t1:iot_telemetry", scenario="iot_telemetry",
                   shape=(32, 16, 4), seed=100),
        TenantSpec("t2:ddos_burst", scenario="ddos_burst",
                   shape=(24, 12, 4), seed=101),
    )))
    fab = fleet.fabric(0, hops=FABRIC_HOPS)
    report = verify_roundtrip(
        exported,
        trainer.eval_x,
        fabric=fab,
        reference_bits=trainer.forward_bits(trainer.eval_x),
        check=False,
    )
    print(report.summary())
    if not report.ok:
        failures.append(f"round trip not bit-exact: {report.summary()}")
    if report.hops != FABRIC_HOPS:
        failures.append(f"expected {FABRIC_HOPS} hops, got {report.hops}")

    print("\n== 5. serve (3 tenants on one chip, one pcap-backed) ==")
    stream_n = 2 * n
    for mode in ("merged", "time_sliced"):
        sched = fleet.scheduler(mode=mode)
        res = sched.run(
            fleet.stream(stream_n, chunk_size=4096, seed=args.seed),
            chunk_size=4096,
        )
        print(sched.telemetry(res).render())
        for st in res.tenants:
            if st.packets != st.served + st.dropped:
                failures.append(
                    f"{mode} tenant {st.tid}: {st.packets} arrived != "
                    f"{st.served} served + {st.dropped} dropped"
                )
        # The pcap tenant's served packets ARE the capture replay: its
        # outputs must match the oracle on that exact subsequence.
        st = res.stats_for(0)
        replay = traffic.generate(SCENARIO_NAME, st.served, input_bits)
        want = exported.oracle_forward(replay)
        if not np.array_equal(res.outputs_for(0), want):
            failures.append(f"{mode}: pcap tenant outputs != oracle")
        else:
            print(
                f"{mode}: pcap tenant bit-exact vs oracle on "
                f"{st.served} replayed packets\n"
            )

    if failures:
        raise SystemExit("ACCEPTANCE FAILED: " + "; ".join(failures))
    print("acceptance: OK (file round trip, fabric + scheduler bit-exact)")


if __name__ == "__main__":
    main()
