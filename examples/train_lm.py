"""End-to-end training driver: any assigned architecture, reduced or full.

Exercises the whole substrate: synthetic data pipeline, AdamW, microbatch
gradient accumulation, atomic checkpoints, crash recovery, optional BNN
quantization and gradient compression.

Defaults train a ~15M-parameter reduced model for 200 steps on CPU; pass
``--preset full`` to use the real architecture config (sized for the TPU
mesh, not this container).

Run:  PYTHONPATH=src python examples/train_lm.py --arch phi3-mini-3.8b --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.train.trainer import Trainer, TrainerConfig


def reduced(cfg):
    extra = {}
    if cfg.ssm is not None:
        extra["ssm"] = dataclasses.replace(cfg.ssm, state_dim=32, head_dim=32, chunk=32)
    if cfg.moe is not None:
        extra["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, expert_ffn_dim=128
        )
    if cfg.mla is not None:
        extra["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=64, q_lora_rank=96, qk_nope_dim=32,
            qk_rope_dim=16, v_head_dim=32,
        )
    if cfg.family == "hybrid":
        extra["hybrid_period"] = 3
    return dataclasses.replace(
        cfg, num_layers=6, d_model=256, num_heads=8, num_kv_heads=4,
        head_dim=32, d_ff=512, vocab_size=2048, attn_q_chunk=64,
        fsdp=False, **extra,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--preset", choices=["reduced", "full"], default="reduced")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "sign", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="step at which to simulate a node failure")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = reduced(cfg)
    if args.quant:
        cfg = dataclasses.replace(
            cfg, quant=QuantConfig(mode="bnn_weight_only", targets=("ffn",))
        )

    tcfg = TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=max(10, args.steps // 4),
        checkpoint_dir=args.ckpt_dir,
        log_every=max(1, args.steps // 20),
        microbatches=args.microbatches,
        compression=args.compression,
        global_batch=args.batch,
        seq_len=args.seq,
        fail_at_steps=(args.inject_failure,) if args.inject_failure >= 0 else (),
    )
    trainer = Trainer(cfg, tcfg)
    n_params = sum(x.size for x in jax.tree.leaves(trainer.params))
    print(f"training {cfg.name} ({cfg.family}) — {n_params/1e6:.1f}M params, "
          f"quant={cfg.quant.mode}, compression={args.compression}")

    out = trainer.run()
    print(f"\nfinished at step {out['final_step']} "
          f"(recoveries: {out['recoveries']}, stragglers: {len(out['stragglers'])})")
    for h in out["history"]:
        if "loss" in h:
            print(f"  step {h['step']:5d}  loss {h['loss']:.4f}  "
                  f"grad_norm {h.get('grad_norm', float('nan')):.3f}  dt {h['dt']:.2f}s")


if __name__ == "__main__":
    main()
