"""End-to-end driver for the paper's first use case: an in-network DoS
white/blacklist classifier.

Pipeline:
  1. generate a labelled dataset of 104-bit packet 5-tuples (padded to 128);
  2. train a BNN (128 -> 64 -> 32 -> 2) with the straight-through estimator
     on latent weights (BinaryNet-style) in pure JAX;
  3. export {0,1} weights, compile with the N2Net compiler (both the
     standard RMT chip and the §3 native-POPCNT variant);
  4. classify a held-out packet stream on the simulated chip, verify
     bit-exact agreement with the model, report accuracy + ASIC throughput;
  5. emit the P4 program.

Run:  PYTHONPATH=src python examples/n2net_switch_demo.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.n2net_paper import FIVE_TUPLE
from repro.core import bitops, bnn, compile_bnn, throughput
from repro.core.interpreter import run_program_jit
from repro.core.p4gen import generate_p4
from repro.core.pipeline import RMT_NATIVE_POPCNT
from repro.kernels.ops import ste_sign


def make_dataset(key, n, bits=128):
    """Blacklist = membership in a union of masked prefixes (realistic ACL)."""
    k1, k2, k3 = jax.random.split(key, 3)
    pkts = jax.random.bernoulli(k1, 0.5, (n, bits)).astype(jnp.int32)
    n_rules = 12
    prefixes = jax.random.bernoulli(k2, 0.5, (n_rules, bits)).astype(jnp.int32)
    masks = (jax.random.uniform(k3, (n_rules, bits)) < 0.12).astype(jnp.int32)
    # packet matches rule r if it agrees with prefix r on all masked bits
    agree = 1 - jnp.bitwise_xor(pkts[:, None, :], prefixes[None])
    hit = jnp.all(jnp.where(masks[None].astype(bool), agree, 1), axis=-1)
    labels = jnp.any(hit, axis=-1).astype(jnp.int32)  # 1 = blacklisted
    return pkts, labels


def train_bnn(pkts, labels, sizes, steps, lr=0.05, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(sizes) - 1)
    ws = [
        jax.random.normal(k, (o, i)) * 0.3
        for k, i, o in zip(keys, sizes[:-1], sizes[1:])
    ]
    x = bitops.bits_to_sign(pkts)
    y = jax.nn.one_hot(labels, sizes[-1]) * 2 - 1

    def fwd(ws, x):
        h = x
        for w in ws[:-1]:
            h = ste_sign(h @ ste_sign(w).T)
        return h @ ste_sign(ws[-1]).T

    def loss(ws):
        return jnp.mean(jax.nn.relu(1.0 - y * fwd(ws, x)))

    @jax.jit
    def step(ws):
        l, gs = jax.value_and_grad(loss)(ws)
        return l, [w - lr * g for w, g in zip(ws, gs)]

    for i in range(steps):
        l, ws = step(ws)
        if i % max(1, steps // 5) == 0:
            print(f"  step {i:4d}  hinge loss {float(l):.4f}")
    return ws


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--train-size", type=int, default=2048)
    ap.add_argument("--test-size", type=int, default=2048)
    ap.add_argument("--p4-out", default="/tmp/n2net_dos_classifier.p4")
    args = ap.parse_args()

    sizes = FIVE_TUPLE.layer_sizes  # (128, 64, 32, 2)
    print(f"== training BNN {sizes} on synthetic ACL data ==")
    ptrain, ltrain = make_dataset(jax.random.PRNGKey(0), args.train_size)
    ptest, ltest = make_dataset(jax.random.PRNGKey(0), args.test_size)
    latent = train_bnn(ptrain, ltrain, sizes, args.steps)

    weights = [np.asarray(bitops.sign_to_bits(w)) for w in latent]
    model_params = [jnp.asarray(w) for w in weights]

    print("\n== compiling to the RMT pipeline ==")
    prog = compile_bnn(weights)
    print(prog.summary())

    chip_logits = run_program_jit(prog, ptest)
    model_logits = bnn.forward(model_params, ptest)
    assert (np.asarray(chip_logits) == np.asarray(model_logits)).all()
    pred = np.asarray(chip_logits)
    # argmax over the 2 output bits; tie -> class 0 ( bit ordering)
    yhat = (pred[:, 1] > pred[:, 0]).astype(int)
    acc = float((yhat == np.asarray(ltest)).mean())
    print(f"\nchip == model bit-exact ✔   held-out accuracy: {acc:.3f}")

    rep = throughput.report_for_program(prog)
    print(
        f"ASIC model: {rep.packets_per_second:.3e} packets/s "
        f"({rep.passes} pass(es), {rep.elements_used} elements)"
    )

    prog_np = compile_bnn(weights, RMT_NATIVE_POPCNT)
    rep_np = throughput.report_for_program(prog_np)
    print(
        f"§3 native-POPCNT chip: {rep_np.elements_used} elements "
        f"({rep.elements_used} on standard RMT), "
        f"{rep_np.packets_per_second:.3e} packets/s"
    )

    # software simulation rate, for context
    t0 = time.perf_counter()
    run_program_jit(prog, ptest).block_until_ready()
    dt = time.perf_counter() - t0
    print(f"(JAX chip-simulator: {args.test_size/dt:.3e} packets/s on CPU)")

    with open(args.p4_out, "w") as f:
        f.write(generate_p4(prog, name="dos_classifier"))
    print(f"\nP4 written to {args.p4_out}")


if __name__ == "__main__":
    main()
