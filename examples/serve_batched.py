"""End-to-end serving driver: batched requests through the Engine.

Serves a small LM (optionally BNN-quantized — the paper's technique as a
serving-time compression knob) with slot-based continuous batching:
requests of different prompt lengths stream through ``max_batch`` decode
slots, one batched decode_step per engine tick.

Run:  PYTHONPATH=src python examples/serve_batched.py [--quant] [--arch phi3-mini-3.8b]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.models import init_params
from repro.serving.engine import Engine, Request


def small(cfg):
    extra = {}
    if cfg.ssm is not None:
        extra["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16, chunk=16)
    if cfg.moe is not None:
        extra["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, expert_ffn_dim=64
        )
    if cfg.mla is not None:
        extra["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, q_lora_rank=64, qk_nope_dim=16,
            qk_rope_dim=8, v_head_dim=16,
        )
    if cfg.family == "hybrid":
        extra["hybrid_period"] = 2
    return dataclasses.replace(
        cfg, num_layers=4, d_model=128, num_heads=8, num_kv_heads=4,
        head_dim=16, d_ff=256, vocab_size=512, attn_q_chunk=32, fsdp=False,
        **extra,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--quant", action="store_true",
                    help="binarize FFN/attn projections (paper technique)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = small(get_config(args.arch))
    if args.quant:
        cfg = dataclasses.replace(
            cfg, quant=QuantConfig(mode="bnn_weight_only", targets=("ffn", "attn_proj"))
        )
    print(f"serving {cfg.name} ({cfg.family}) quant={cfg.quant.mode}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=128)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.new_tokens,
        ))
    done = eng.run()
    dt = time.perf_counter() - t0

    total_new = sum(len(r.output) for r in done)
    print(f"\ncompleted {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s on CPU)")
    for r in done[:4]:
        print(f"  req {r.rid}: {r.output[:10]}{'...' if len(r.output) > 10 else ''}")


if __name__ == "__main__":
    main()
